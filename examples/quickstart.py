"""Quickstart: the MoA pipeline end to end in one minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. Derive the paper's ONF for a GEMM and dimension-lift it (figs 3-5).
2. Solve block sizes statically from the hardware table (§3.4).
3. Run the Pallas MoA GEMM (interpret mode on CPU) against the oracle.
4. Train a tiny assigned-architecture LM for a few steps.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import blocking, lifting, onf
from repro.kernels import ops, ref

# -- 1. the algebra ---------------------------------------------------------
m, n, p = 8, 16, 8
o = onf.gemm_onf(m, n, p)
print("== MoA ONF (paper eq. 3) ==")
print(o.render_c())
lifted = onf.gemm_fully_lifted(m, n, p, procs=2, bk=8, bn=4)
print("\n== dimension-lifted (figs 4/5) ==")
print(lifted.render_c())

a = np.random.default_rng(0).standard_normal((m, n))
b = np.random.default_rng(1).standard_normal((n, p))
got = lifted.execute(np.zeros(m * p), a.ravel(), b.ravel())
assert np.allclose(got.reshape(m, p), a @ b)
print("\nlifted ONF == linear algebra: OK")

# -- 2. static blocking -----------------------------------------------------
print("\n== block solver ==")
print("V100 (paper):", blocking.solve_blocks_square(lifting.V100, "float64"),
      "^2 doubles per block")
bc = blocking.solve_blocks(4096, 4096, 4096, "bfloat16")
print("v5e bf16 4096^3:", bc.as_tuple(), f"VMEM {bc.vmem_bytes // 2**20}MiB",
      f"AI {bc.arithmetic_intensity:.0f} flops/B")

# -- 3. the kernel ----------------------------------------------------------
k1, k2 = jax.random.split(jax.random.PRNGKey(0))
A = jax.random.normal(k1, (256, 192), jnp.float32)
B = jax.random.normal(k2, (192, 128), jnp.float32)
C = ops.moa_gemm(A, B, interpret=True)
err = float(jnp.max(jnp.abs(C - ref.gemm_ref(A, B))))
print(f"\nPallas MoA GEMM vs oracle: max err {err:.2e}")
K = ops.kron(jnp.eye(2, dtype=jnp.float32), A[:4, :4], interpret=True)
print("ipophp kron through the same circuit:", K.shape)

# -- 4. a tiny assigned arch ------------------------------------------------
print("\n== 10-step training run (gemma-2b reduced) ==")
from repro.launch.train import main
main(["--arch", "gemma-2b", "--reduced", "--steps", "10", "--batch", "4",
      "--seq", "32", "--log-every", "2"])
