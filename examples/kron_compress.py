"""Paper appendix application: Kronecker-product compression of a dense
layer (refs [25, 28] — 'KPs can compress RNN layers by 16-38x').

A dense (m*p, n*q) weight is replaced by kron(A, B) with A (m, n), B (p, q):
  parameters  m*n + p*q  vs  m*n*p*q   (here: 128x compression)
  y = W x  becomes  Y = B X A^T  (reshape trick) — computed with the SAME
  MoA blocked GEMM circuit (ipophp), validating the paper's 'one circuit'
  claim on a real workload.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops

rng = jax.random.PRNGKey(0)
ka, kb, kx = jax.random.split(rng, 3)

m, n, p, q = 16, 16, 32, 32
A = jax.random.normal(ka, (m, n), jnp.float32)
B = jax.random.normal(kb, (p, q), jnp.float32)
x = jax.random.normal(kx, (n * q,), jnp.float32)

W = ops.kron(A, B, interpret=True)                     # (m*p, n*q), explicit
y_dense = W @ x

# compressed apply: W[(i*p+k),(j*q+l)] = A[i,j] B[k,l], so with
# X = reshape(x, (n, q)):  Y[i,k] = (A @ X @ B^T)[i,k]  and y = rav(Y) —
# two MoA GEMMs through the same blocked circuit.
X = x.reshape(n, q)
T = ops.moa_gemm(X, B.T, interpret=True)               # (n, p)
Y = ops.moa_gemm(A, T, interpret=True)                 # (m, p)
y_comp = Y.reshape(-1)

err = float(jnp.max(jnp.abs(y_dense - y_comp)))
params_dense = m * p * n * q
params_comp = m * n + p * q
print(f"dense params {params_dense:,} -> kron params {params_comp:,} "
      f"({params_dense / params_comp:.0f}x compression)")
print(f"apply error |Wx - vec(BXA^T)|_inf = {err:.2e}")
assert err < 1e-3
flops_dense = 2 * params_dense
flops_comp = 2 * (p * q * n + p * n * m)
print(f"flops/apply: {flops_dense:,} -> {flops_comp:,} "
      f"({flops_dense / flops_comp:.1f}x fewer)")
