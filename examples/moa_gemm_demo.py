"""The paper's derivation pipeline, end to end, on one GEMM:

   DNF -> ONF -> dimension lifting -> mesh sharding + Pallas blocks
   -> roofline + energy prediction  (what §3.4 does by hand, automated)

    PYTHONPATH=src python examples/moa_gemm_demo.py [--m 4096 --k 4096 --n 4096]
"""
import argparse

from repro.core import blocking, energy, lifting, onf
from repro.core.lifting import TPU_V5E, TPU_V5E_2POD

ap = argparse.ArgumentParser()
ap.add_argument("--m", type=int, default=4096)
ap.add_argument("--k", type=int, default=4096)
ap.add_argument("--n", type=int, default=4096)
args = ap.parse_args()
m, k, n = args.m, args.k, args.n

print(f"=== MoA derivation for C[{m},{n}] = A[{m},{k}] @ B[{k},{n}] (bf16) ===")

print("\n1. ONF (paper eq. 3):")
print(onf.gemm_onf(m, k, n).render_c())

print("\n2. dimension lifting to the v5e 2-pod hardware shape:")
ls = lifting.lift_shape(TPU_V5E_2POD, [
    ("i", m, [("pod", 2), ("data", 16)]),
    ("j", n, [("model", 16)]),
])
print("   mesh PartitionSpec:", ls.partition_spec())
print("   per-chip local shape:", ls.local_shape())

lm, lk, ln = ls.local_shape()[0], k, ls.local_shape()[1]
bc = blocking.solve_blocks(lm, lk, ln, "bfloat16", TPU_V5E)
print("\n3. VMEM lifting (block solver):")
print(f"   blocks (bm,bk,bn) = {bc.as_tuple()}")
print(f"   VMEM working set  = {bc.vmem_bytes / 2**20:.1f} MiB "
      f"(3 blocks + double buffering <= budget)")
print(f"   grid              = {blocking.grid_for(lm, lk, ln, bc)}")
print(f"   arithmetic int.   = {bc.arithmetic_intensity:.0f} flops/byte")

rep = energy.gemm_energy(lm, lk, ln, bc)
print("\n4. per-chip roofline + energy prediction:")
print(f"   time   {rep.time_s * 1e3:.3f} ms  ({rep.bound}-bound)")
print(f"   energy {rep.energy_J:.3f} J   power {rep.power_W:.0f} W")
hbm_naive = energy.gemm_unblocked_traffic(lm, lk, ln)
print(f"   HBM traffic {rep.hbm_bytes / 1e9:.2f} GB "
      f"(naive row-column: {hbm_naive / 1e9:.0f} GB, "
      f"{hbm_naive / rep.hbm_bytes:.0f}x worse)")
