"""Batched serving example: prefill + greedy decode over a request batch,
with per-request positions (ragged prompts via left-padding).

    PYTHONPATH=src python examples/serve_batch.py [--arch gemma-2b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))

    # ragged prompts, right-aligned into a common cache
    rng = jax.random.PRNGKey(1)
    lens = [3, 7, 5, 9][:args.batch]
    cache_len = max(lens) + args.new_tokens
    cache = registry.init_cache(cfg, args.batch, cache_len,
                                dtype=jnp.dtype(cfg.dtype))
    step = jax.jit(lambda p, t, pos, c: registry.decode_step(p, cfg, t, pos, c))

    # feed each prompt token (per-row positions differ -> true batched ragged)
    toks = jax.random.randint(rng, (args.batch, max(lens)), 0, cfg.vocab_size)
    pos = jnp.zeros((args.batch,), jnp.int32)
    logits = None
    active = jnp.asarray(lens, jnp.int32)
    for t in range(max(lens)):
        cur = toks[:, t]
        logits_t, cache = step(params, cur, pos, cache)
        # rows whose prompt is exhausted keep their last logits
        logits = logits_t if logits is None else jnp.where(
            (t < active)[:, None], logits_t, logits)
        pos = pos + (t < active).astype(jnp.int32)

    out = []
    t0 = time.time()
    for i in range(args.new_tokens):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(nxt)
        logits, cache = step(params, nxt, pos, cache)
        pos = pos + 1
    dt = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"arch={cfg.name} batch={args.batch} ragged lens={lens}")
    print(f"decode: {args.new_tokens} steps in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.0f} tok/s incl. dispatch)")
    for i in range(args.batch):
        print(f"req{i} len{lens[i]} ->", gen[i, :10].tolist())


if __name__ == "__main__":
    main()
