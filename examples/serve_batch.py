"""Batched serving example: ragged prompts through the continuous-batching
engine — admission, paged KV allocation, prefill/decode interleaving and
eviction all live in ``repro.serving.ServeEngine``; this example only
submits requests and reads tokens back.

    PYTHONPATH=src python examples/serve_batch.py [--arch gemma-2b]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import registry
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))

    # ragged prompts: each request keeps its own length and page table
    rng = jax.random.PRNGKey(1)
    lens = [3, 7, 5, 9][:args.batch]
    max_len = max(lens) + args.new_tokens
    engine = ServeEngine(cfg, params, max_slots=args.batch,
                         max_len=max_len, page=8)
    toks = jax.random.randint(rng, (args.batch, max(lens)), 0,
                              cfg.vocab_size)

    t0 = time.time()
    rids = [engine.submit(toks[i, :lens[i]].tolist(), args.new_tokens,
                          now=0.0)
            for i in range(args.batch)]
    results = engine.run(clock=lambda: time.time() - t0)
    dt = time.time() - t0

    n_tok = sum(len(results[r]["tokens"]) for r in rids)
    print(f"arch={cfg.name} batch={args.batch} ragged lens={lens} "
          f"paged={engine.paged} page={engine.page}")
    print(f"{n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.0f} tok/s incl. compile + dispatch)")
    for i, rid in enumerate(rids):
        print(f"req{rid} len{lens[i]} ->",
              results[rid]["tokens"][:10])


if __name__ == "__main__":
    main()
