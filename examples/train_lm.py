"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

Uses the stablelm family at a ~100M scale (d_model 512, 8 layers, vocab 8k)
on the synthetic learnable stream, with checkpointing every 100 steps and
resume-on-restart.  ``--small`` drops to a 2-minute CPU-friendly size with
the same code path.
"""
import argparse
import dataclasses
import sys

sys.argv0 = sys.argv[0]

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.train import main as train_main


def build_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    return ap.parse_args()


# a ~100M-param member of the stablelm family (the code path is identical to
# the full 1.6b config; only the lifted shapes differ)
def register_lm100m(small: bool):
    from repro import configs
    from repro.configs import stablelm_1_6b

    base = stablelm_1_6b.full()
    if small:
        cfg = base.with_(name="lm-tiny", n_layers=2, d_model=128, n_heads=4,
                         n_kv_heads=4, head_dim=32, d_ff=384, vocab_size=512,
                         dtype="float32")
    else:
        cfg = base.with_(name="lm-100m", n_layers=8, d_model=512, n_heads=8,
                         n_kv_heads=8, head_dim=64, d_ff=1536,
                         vocab_size=8192, dtype="float32")

    class _Mod:
        ARCH_ID = cfg.name
        @staticmethod
        def full():
            return cfg
        @staticmethod
        def reduced():
            return cfg
    configs.ARCHS[cfg.name] = _Mod
    return cfg


if __name__ == "__main__":
    args = build_args()
    cfg = register_lm100m(args.small)
    total, _ = cfg.param_count()
    print(f"training {cfg.name}: ~{total / 1e6:.0f}M params")
    train_main(["--arch", cfg.name, "--steps", str(args.steps),
                "--batch", "8", "--seq", "256" if not args.small else "64",
                "--lr", "1e-3", "--warmup", "50",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
                "--log-every", "10"])
