"""Serving steps: prefill + decode, plus a batched greedy generation loop
(used by examples/serve.py and the serving benchmarks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.common import ArchConfig


def make_prefill(cfg: ArchConfig):
    def prefill_step(params, batch):
        return registry.prefill(params, cfg, batch)
    return prefill_step


def make_decode(cfg: ArchConfig):
    def decode(params, tokens, pos, cache):
        return registry.decode_step(params, cfg, tokens, pos, cache)
    return decode


def greedy_generate(params, cfg: ArchConfig, prompt: jax.Array, n_new: int,
                    cache_len: int):
    """prompt: (B, S0) -> (B, S0+n_new).  Prefill then scan decode steps."""
    b, s0 = prompt.shape
    cache = registry.init_cache(cfg, b, cache_len,
                                dtype=jnp.dtype(cfg.dtype))
    # prefill by decoding the prompt token-by-token (keeps one code path for
    # every family incl. ring caches; examples use short prompts)
    def feed(carry, t):
        cache, _ = carry
        tok = prompt[:, t]
        logits, cache = registry.decode_step(params, cfg, tok,
                                             jnp.full((b,), t, jnp.int32),
                                             cache)
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(feed, (cache, jnp.zeros((b, cfg.vocab_size))),
                                      jnp.arange(s0))

    def gen(carry, i):
        cache, logits = carry
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = s0 + i
        new_logits, cache = registry.decode_step(
            params, cfg, tok, jnp.full((b,), pos, jnp.int32), cache)
        return (cache, new_logits), tok

    (_, _), toks = jax.lax.scan(gen, (cache, logits), jnp.arange(n_new))
    return jnp.concatenate([prompt, toks.T], axis=1)
