"""Serving steps: prefill + decode, plus a batched greedy generation loop
(used by examples/serve_batch.py and the serving benchmarks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import registry, transformer
from repro.models.common import ArchConfig


def make_prefill(cfg: ArchConfig):
    def prefill_step(params, batch):
        return registry.prefill(params, cfg, batch)
    return prefill_step


def make_decode(cfg: ArchConfig):
    def decode(params, tokens, pos, cache):
        return registry.decode_step(params, cfg, tokens, pos, cache)
    return decode


def greedy_generate(params, cfg: ArchConfig, prompt: jax.Array, n_new: int,
                    cache_len: int):
    """prompt: (B, S0) -> (B, S0+n_new).

    Prompt ingestion goes through the derived flash prefill
    (``registry.prefill``) — ONE kernel sweep over the prompt, with the
    forward-layout cache re-laid as the decode cache — for every family
    ``transformer.prefill_cache_to_decode`` covers.  Families whose decode
    cache has no forward equivalent (ring caches, grouped patterns,
    hybrid, vlm) fall back to the token-by-token decode scan.
    """
    b, s0 = prompt.shape
    if transformer.has_prefill_decode_relayout(cfg):
        logits, fwd_cache = registry.prefill(params, cfg,
                                             {"tokens": prompt})
        cache = transformer.prefill_cache_to_decode(cfg, fwd_cache,
                                                    cache_len)
    else:
        cache = registry.init_cache(cfg, b, cache_len,
                                    dtype=jnp.dtype(cfg.dtype))

        def feed(carry, t):
            cache, _ = carry
            tok = prompt[:, t]
            logits, cache = registry.decode_step(
                params, cfg, tok, jnp.full((b,), t, jnp.int32), cache)
            return (cache, logits), None

        (cache, logits), _ = jax.lax.scan(
            feed, (cache, jnp.zeros((b, cfg.vocab_size))),
            jnp.arange(s0))

    def gen(carry, i):
        cache, logits = carry
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = s0 + i
        new_logits, cache = registry.decode_step(
            params, cfg, tok, jnp.full((b,), pos, jnp.int32), cache)
        return (cache, new_logits), tok

    (_, _), toks = jax.lax.scan(gen, (cache, logits), jnp.arange(n_new))
    return jnp.concatenate([prompt, toks.T], axis=1)
