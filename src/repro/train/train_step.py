"""Training step: microbatched grad accumulation, remat'd model forward,
optional gradient compression, AdamW — all as one pure function suitable for
pjit across any mesh.

Microbatching is (once more) dimension lifting: the global batch is split
``B -> (microbatches, B/microbatches)`` and the new outer axis becomes a
sequential ``lax.scan`` accumulating gradients — the paper's "extra addition
loop to add up the blocks", applied to the batch axis to bound activation
memory.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.distributed import plan as dplan
from repro.models import registry
from repro.models.common import ArchConfig
from repro.optim import adamw


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState
    err_fb: Optional[dict]          # gradient-compression error feedback
    step: jax.Array


def init_state(cfg: ArchConfig, key: jax.Array,
               comp: compression.CompressionConfig = compression.CompressionConfig()
               ) -> tuple[TrainState, dict]:
    params, axes = registry.init(cfg, key)
    opt = adamw.init(params)
    err = compression.init_error_state(params) if comp.enabled else None
    return TrainState(params, opt, err, jnp.zeros((), jnp.int32)), axes


def state_logical_axes(state: TrainState, param_axes: dict):
    """Logical axes for the whole TrainState (optimizer mirrors params)."""
    none_like = lambda tree: jax.tree.map(lambda p: (None,) * p.ndim
                                          if hasattr(p, "ndim") else None, tree)
    return TrainState(
        params=param_axes,
        opt=adamw.AdamWState(step=None, master=param_axes, m=param_axes,
                             v=param_axes),
        err_fb=param_axes if state.err_fb is not None else None,
        step=None)


def trace_step_jaxpr(cfg: ArchConfig, batch_size: int = 2, seq: int = 32,
                     microbatches: int = 1):
    """Abstractly trace one full train step — forward, backward and the
    optimizer update — and return its closed jaxpr without executing any
    compute.

    This is the acceptance pin for the fully-derived training path: on a
    kernel-dispatch hardware entry every custom-VJP backward (flash dQ /
    dK/dV, the SSD reverse scan, the gated cotangent scan, both GEMM
    transposes) is itself a derived kernel, so the trace completes even
    when the jnp oracles (``ops._oracle_attention``, ``_ssd_oracle``,
    ``_gated_oracle``, ``ref.eval_expr``) are stubbed out to raise — which
    is exactly what the jaxpr-pin tests do."""
    from repro.data import PipelineConfig, SyntheticLM
    state, _ = init_state(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(PipelineConfig(cfg.vocab_size, seq, batch_size), cfg)
    batch = jax.tree.map(jnp.asarray, data.global_batch(0))
    step = make_train_step(cfg, microbatches=microbatches)
    return jax.make_jaxpr(step)(state, batch)


def make_train_step(cfg: ArchConfig,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    comp: compression.CompressionConfig = compression.CompressionConfig(),
                    microbatches: int = 1, planned_mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``planned_mesh``: a live ``jax.sharding.Mesh`` — the model's matmuls
    then execute through derived ``DistributedPlan``s (shard_map with
    planned collectives) instead of leaving partitioning to the SPMD
    pass; see ``repro.distributed.plan``."""

    def loss_fn(params, mb):
        if planned_mesh is None:
            return registry.loss(params, cfg, mb)
        with dplan.planned_mesh(planned_mesh):
            return registry.loss(params, cfg, mb)

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                carry = jax.tree.map(jnp.add, carry, g)
                return carry, (l, m)

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            grads, (losses, ms) = jax.lax.scan(acc_fn, zero, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        grads, err = compression.compress_grads(comp, grads, state.err_fb)
        new_params, new_opt, opt_m = adamw.update(opt_cfg, grads, state.opt,
                                                  state.params)
        metrics = dict(metrics, loss=loss, **opt_m)
        return TrainState(new_params, new_opt, err, state.step + 1), metrics

    return train_step
