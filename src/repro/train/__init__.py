from repro.train import serve_step, train_step  # noqa: F401
from repro.train.train_step import TrainState, init_state, make_train_step  # noqa: F401
