"""AdamW with f32 master weights, global-norm clipping, and decoupled weight
decay — pure JAX (no optax dependency).

Mixed precision: model params may be bf16; the optimizer keeps f32 masters
(plus f32 m/v) and re-casts updated masters into the model dtype each step,
so training is robust while HBM holds params once in bf16 + 12 B/param of
state — all of it sharded by the same lifting rules as the params (the
optimizer state mirrors the param tree structure, hence its shardings).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array            # ()
    master: dict               # f32 copies of params
    m: dict
    v: dict


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> AdamWState:
    # copy=True: when params are already f32, astype would alias the buffer
    # and donation of a TrainState would then donate it twice
    f32 = lambda t: jax.tree.map(lambda x: jnp.array(x, jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), master=f32(params),
                      m=zeros(params), v=zeros(params))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> tuple[dict, AdamWState, dict]:
    """Returns (new params in model dtype, new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_ma = tdef.flatten_up_to(state.master)
    out = [upd(g, m, v, ma) for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = tdef.unflatten([o[0] for o in out])
    new_v = tdef.unflatten([o[1] for o in out])
    new_master = tdef.unflatten([o[2] for o in out])
    flat_p = tdef.flatten_up_to(params)
    new_params = tdef.unflatten([ma.astype(p.dtype)
                                 for ma, p in zip([o[2] for o in out], flat_p)])
    new_state = AdamWState(step=step, master=new_master, m=new_m, v=new_v)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
