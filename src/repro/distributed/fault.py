"""Fault tolerance: step watchdog (straggler mitigation), elastic re-mesh,
and a restartable training-loop state machine.

Multi-thousand-node posture on a single-host harness: the *policies* are
real and unit-tested (deadline detection, quarantine decisions, reshard
math); the *actuation* (SIGKILLing a worker, re-scheduling a pod) is behind
the ``Coordinator`` interface that a cluster runtime implements.

* ``StepWatchdog`` — EMA of step latency; a step exceeding
  ``factor x EMA + slack`` records a straggler event and calls the
  coordinator's ``report_straggler`` (which may quarantine a host: at
  1000+ nodes the p99 host dominates step time, so detection must be
  automatic, not dashboard-driven).
* ``ElasticManager`` — on membership change: rebuild the mesh from surviving
  hosts (largest (dp, tp) factorization that divides the model's lifted
  axes), re-derive every sharding from the SAME lifting rules, and restore
  the latest checkpoint into the new shardings.  Data order is preserved
  because the pipeline is a pure function of step (repro.data.pipeline).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
from jax.sharding import Mesh

from repro.distributed import sharding as shard_rules


class Coordinator:
    """Cluster-runtime interface; the default implementation just records."""

    def __init__(self):
        self.events: list[dict] = []

    def report_straggler(self, step: int, latency_s: float, ema_s: float):
        self.events.append({"kind": "straggler", "step": step,
                            "latency_s": latency_s, "ema_s": ema_s})

    def report_failure(self, step: int, detail: str):
        self.events.append({"kind": "failure", "step": step, "detail": detail})


@dataclass
class StepWatchdog:
    coordinator: Coordinator
    factor: float = 3.0
    slack_s: float = 0.5
    ema_alpha: float = 0.1
    ema_s: Optional[float] = None
    stragglers: int = 0
    _t0: float = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        if self.ema_s is None:
            self.ema_s = dt
        else:
            if dt > self.factor * self.ema_s + self.slack_s:
                self.stragglers += 1
                self.coordinator.report_straggler(step, dt, self.ema_s)
            self.ema_s = (1 - self.ema_alpha) * self.ema_s + self.ema_alpha * dt
        return dt

    def observe(self, step: int, latency_s: float) -> bool:
        """Pure observation path (used by tests / simulated traces).
        Returns True if the step was flagged as a straggler."""
        flagged = False
        if self.ema_s is None:
            self.ema_s = latency_s
        else:
            if latency_s > self.factor * self.ema_s + self.slack_s:
                self.stragglers += 1
                self.coordinator.report_straggler(step, latency_s, self.ema_s)
                flagged = True
            self.ema_s = ((1 - self.ema_alpha) * self.ema_s
                          + self.ema_alpha * latency_s)
        return flagged


def best_mesh_shape(n_devices: int, model_divisors: tuple[int, ...] = (16, 8, 4, 2, 1)
                    ) -> tuple[int, int]:
    """Elastic re-mesh policy: largest model-parallel width from the allowed
    divisor ladder that divides n_devices; the rest becomes data-parallel."""
    for tp in model_divisors:
        if n_devices % tp == 0:
            return (n_devices // tp, tp)
    return (n_devices, 1)


@dataclass
class ElasticManager:
    """Rebuilds mesh + shardings after membership changes."""
    axis_names: tuple[str, str] = ("data", "model")

    def make_mesh(self, devices=None) -> Mesh:
        devices = list(devices if devices is not None else jax.devices())
        dp, tp = best_mesh_shape(len(devices))
        import numpy as np
        return Mesh(np.array(devices).reshape(dp, tp), self.axis_names)

    def reshard(self, tree, axes_tree, mesh: Mesh):
        """device_put a host (or differently-sharded) pytree onto ``mesh``
        using the global lifting rules."""
        shardings = shard_rules.param_shardings(tree, axes_tree, mesh)
        return jax.tree.map(jax.device_put, tree, shardings)
