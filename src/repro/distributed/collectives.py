"""Latency-hiding collective matmuls (overlap compute with ICI transfers).

Two schedules, both expressed as ppermute rings inside ``shard_map`` so XLA's
latency-hiding scheduler can overlap each step's transfer with the next
step's matmul (the classic "collective matmul" of Wang et al. / Megatron-TP
on TPU, here derived as one more dimension lifting: the contraction or
gather axis is lifted over the ring position):

* ``ag_matmul(x_shard, w, axis)``   — y = all_gather(x, axis) @ w without
  materializing the gathered x: at ring step t each device multiplies the
  chunk it currently holds into the matching output rows, then rotates the
  chunk.  Peak memory: one chunk instead of the full gather.

* ``psum_matmul(x, w_shard, axis)`` — y = psum_scatter(x @ w_shard) chunked
  over rows: each device's partial rotates around the ring accumulating, so
  reduction transfers hide behind the remaining chunks' matmuls.

Both are thin consumers of derived ``DistributedPlan``s
(``repro.distributed.plan``): the collective choice (all-gather vs psum) and
the ring's shard extents come from ``derive_plan`` over the mesh-lifted
matmul normal form — asserted, not assumed — and the rings are the
latency-hiding *implementations* of the plan's collective steps.

Numerics are validated against the naive forms in subprocess multi-device
tests (tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.mesh import MeshShape
from repro.distributed import plan as dplan
from repro.kernels import ops


def _axis_size(axis_name: str) -> int:
    """Static ring size: jax.lax.axis_size where it exists, else the classic
    psum(1) idiom (constant-folded to a Python int on older jax)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def ag_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: x (m_shard, k) sharded on rows over ``axis_name``;
    w (k, n) replicated.  Returns y = all_gather(x) @ w, (m_full, n),
    computed as a ppermute ring (no full gather buffer) — the ring being
    the latency-hiding form of the plan's derived all-gather."""
    p = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m_shard, kdim = x.shape
    n = w.shape[1]
    plan = dplan.matmul_plan(m_shard * p, kdim, n,
                             MeshShape(((axis_name, p),)),
                             shard={"m": axis_name}, replicate_out=True)
    assert plan.collective == "all_gather", plan.collective
    rows = plan.local_extent("i")                 # == m_shard, derived
    y = jnp.zeros((rows * p, n), x.dtype)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def body(t, carry):
        y, chunk = carry
        src = (idx - t) % p                       # whose rows we now hold
        part = ops.matmul(chunk, w, out_dtype=x.dtype)
        y = jax.lax.dynamic_update_slice(y, part, (src * rows, 0))
        chunk = jax.lax.ppermute(chunk, axis_name, perm)
        return (y, chunk)

    y, _ = jax.lax.fori_loop(0, p, body, (y, x))
    return y


def psum_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: x (m, k_shard) column-sharded, w (k_shard, n)
    row-sharded over ``axis_name``.  Returns the *full* y = sum_p x_p @ w_p
    on every device, with the derived psum pipelined as chunked per-row-block
    reductions so transfers overlap the remaining chunks' matmuls."""
    p = _axis_size(axis_name)
    m, k_shard = x.shape
    plan = dplan.matmul_plan(m, k_shard * p, w.shape[1],
                             MeshShape(((axis_name, p),)),
                             shard={"k": axis_name})
    assert plan.collective == "psum", plan.collective
    assert plan.local_extent("k") == k_shard
    chunks = min(p, max(m // 8, 1))
    rows = m // chunks

    def chunk_fn(i, acc):
        xi = jax.lax.dynamic_slice_in_dim(x, i * rows, rows, 0)
        part = ops.matmul(xi, w, out_dtype=jnp.float32)
        part = jax.lax.psum(part, axis_name)      # per-chunk reduction
        return jax.lax.dynamic_update_slice(acc, part.astype(x.dtype),
                                            (i * rows, 0))

    y = jnp.zeros((m, w.shape[1]), x.dtype)
    y = jax.lax.fori_loop(0, chunks, chunk_fn, y)
    if m % chunks:
        tail = ops.matmul(x[chunks * rows:], w, out_dtype=jnp.float32)
        y = y.at[chunks * rows:].set(jax.lax.psum(tail, axis_name).astype(x.dtype))
    return y


def reference_ag_matmul(x, w, axis_name):
    return jnp.dot(jax.lax.all_gather(x, axis_name, tiled=True), w,
                   preferred_element_type=jnp.float32).astype(x.dtype)


def reference_psum_matmul(x, w, axis_name):
    return jax.lax.psum(jnp.dot(x, w, preferred_element_type=jnp.float32),
                        axis_name).astype(x.dtype)
