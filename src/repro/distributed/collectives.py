"""Latency-hiding collective matmuls (overlap compute with ICI transfers).

Two schedules, both expressed as ppermute rings inside ``shard_map`` so XLA's
latency-hiding scheduler can overlap each step's transfer with the next
step's matmul (the classic "collective matmul" of Wang et al. / Megatron-TP
on TPU, here derived as one more dimension lifting: the contraction or
gather axis is lifted over the ring position):

* ``ag_matmul(x_shard, w, axis)``   — y = all_gather(x, axis) @ w without
  materializing the gathered x: at ring step t each device multiplies the
  chunk it currently holds into the matching output rows, then rotates the
  chunk.  Peak memory: one chunk instead of the full gather.

* ``psum_matmul(x, w_shard, axis)`` — y = psum_scatter(x @ w_shard) chunked
  over rows: each device's partial rotates around the ring accumulating, so
  reduction transfers hide behind the remaining chunks' matmuls.

Numerics are validated against the naive forms in subprocess multi-device
tests (tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops


def _axis_size(axis_name: str) -> int:
    """Static ring size: jax.lax.axis_size where it exists, else the classic
    psum(1) idiom (constant-folded to a Python int on older jax)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def ag_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: x (m_shard, k) sharded on rows over ``axis_name``;
    w (k, n) replicated.  Returns y = all_gather(x) @ w, (m_full, n),
    computed as a ppermute ring (no full gather buffer)."""
    p = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m_shard = x.shape[0]
    n = w.shape[1]
    y = jnp.zeros((m_shard * p, n), x.dtype)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def body(t, carry):
        y, chunk = carry
        src = (idx - t) % p                       # whose rows we now hold
        part = ops.matmul(chunk, w, out_dtype=x.dtype)
        y = jax.lax.dynamic_update_slice(y, part, (src * m_shard, 0))
        chunk = jax.lax.ppermute(chunk, axis_name, perm)
        return (y, chunk)

    y, _ = jax.lax.fori_loop(0, p, body, (y, x))
    return y


def psum_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: x (m, k_shard) column-sharded, w (k_shard, n)
    row-sharded over ``axis_name``.  Returns the *full* y = sum_p x_p @ w_p
    on every device, with the reduction pipelined as a ring of partial
    accumulations (reduce-then-broadcast fused into one rotation of 2p-2
    steps is approximated here by chunked psum over row blocks so transfers
    overlap matmuls)."""
    p = _axis_size(axis_name)
    m = x.shape[0]
    chunks = min(p, max(m // 8, 1))
    rows = m // chunks

    def chunk_fn(i, acc):
        xi = jax.lax.dynamic_slice_in_dim(x, i * rows, rows, 0)
        part = ops.matmul(xi, w, out_dtype=jnp.float32)
        part = jax.lax.psum(part, axis_name)      # per-chunk reduction
        return jax.lax.dynamic_update_slice(acc, part.astype(x.dtype),
                                            (i * rows, 0))

    y = jnp.zeros((m, w.shape[1]), x.dtype)
    y = jax.lax.fori_loop(0, chunks, chunk_fn, y)
    if m % chunks:
        tail = ops.matmul(x[chunks * rows:], w, out_dtype=jnp.float32)
        y = y.at[chunks * rows:].set(jax.lax.psum(tail, axis_name).astype(x.dtype))
    return y


def reference_ag_matmul(x, w, axis_name):
    return jnp.dot(jax.lax.all_gather(x, axis_name, tiled=True), w,
                   preferred_element_type=jnp.float32).astype(x.dtype)


def reference_psum_matmul(x, w, axis_name):
    return jax.lax.psum(jnp.dot(x, w, preferred_element_type=jnp.float32),
                        axis_name).astype(x.dtype)
