"""Distributed planning: shard_map plans derived from the same lifted ONF
that drives the Pallas kernels.

The paper's dimension lifting stops being a single-chip story here: a
``MeshShape`` (core/mesh.py) stacks named device axes on top of the
``HardwareShape``, and ``derive_plan`` lifts the requested logical axes of a
normalized expression one more level — ``size -> (mesh, proc, vector,
block)`` — then reads everything a multi-device execution needs back out of
the lifted normal form:

* **partition specs** — recovered from the lifted Access coefficients: each
  operand's storage-dim order is the descending-stride order of its affine
  coefficients (exactly how ``derive_schedule`` recovers BlockSpecs), and a
  storage dim is sharded iff its base axis was mesh-lifted.  A transposed
  operand therefore gets its spec on the right *stored* dim with no special
  casing.
* **the collective schedule** — derived, not chosen by hand: a mesh-lifted
  sigma (reduce) axis makes per-device partial results, so the plan emits a
  ``psum`` (or ``reduce_scatter`` when the caller asks for a scattered
  output); a mesh-lifted output axis with ``replicate_out`` emits an
  ``all_gather``; anything else needs no collective at all.
* **the per-shard schedule** — the existing ``derive_schedule`` pipeline run
  on the *local* (mesh-divided) extents, landing in the same process-wide
  schedule cache.

Plans are cached next to schedules, keyed on ``(Onf.key(), mesh shape,
sharding request, dtype, hardware)``.  Deriving a plan never touches jax
device state (PartitionSpec objects are emitted lazily); executing one is
``kernels.emit.emit_shard_map``.

Non-divisible axes fall back to replication (recorded in ``plan.dropped``)
instead of failing — the same policy as ``distributed/sharding.py``'s rule
table, now derived per expression instead of hand-written per tensor name.
"""
from __future__ import annotations

import contextlib
import threading
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Union

from repro.core import expr as expr_mod
from repro.core import onf as onf_mod
from repro.core import schedule as sched
from repro.core.blocking import _dtype_size
from repro.core.mesh import MeshShape, from_jax_mesh, mesh_resource
from repro.core.moa import pi
from repro.core.schedule import ScheduleBundle, _base


class ReplicationFallbackWarning(UserWarning):
    """A requested shard axis was not divisible by its mesh axis; the
    operand was replicated instead.  Silent before PR 7 — now warned at
    derivation and reported by ``repro.analysis.verify_plan``."""


@dataclass(frozen=True)
class CollectiveStep:
    """One derived collective: ``kind`` over device axis ``mesh_axis``;
    ``out_dim`` is the output storage dim gathered/scattered (None for a
    full psum)."""
    kind: str                       # "psum" | "reduce_scatter" | "all_gather"
    mesh_axis: str
    out_dim: Optional[int] = None


@dataclass(frozen=True)
class DistributedPlan:
    """Everything a shard_map execution needs, derived from one normal form.

    ``in_entries`` / ``out_entries`` are PartitionSpec entries per *storage*
    dim (None = replicated), matching the binding convention of
    ``ops.apply``; ``out_entries`` describes the output AFTER the collective
    schedule ran.  ``bundle`` is the per-shard ``ScheduleBundle`` (derived on
    local extents, resident in the schedule cache); ``local_nf`` the local
    normal form the XLA-oracle path evaluates.
    """
    name: str
    mesh: MeshShape
    applied: tuple[tuple[str, str], ...]       # (axis sym, mesh axis) sharded
    dropped: tuple[tuple[str, str], ...]       # non-divisible -> replicated
    in_entries: tuple[tuple[Optional[str], ...], ...]
    out_entries: tuple[Optional[str], ...]
    collectives: tuple[CollectiveStep, ...]
    local_nf: "expr_mod.NormalForm"
    bundle: ScheduleBundle
    out_shape: tuple[int, ...]                 # global logical result shape

    @property
    def collective(self) -> str:
        """The derived collective choice, as an assertable summary."""
        kinds = tuple(s.kind for s in self.collectives)
        return "+".join(kinds) if kinds else "none"

    def local_extent(self, sym: str) -> int:
        return self.local_nf.extent_map[sym]

    # ---- jax emitters (lazy: plan derivation itself never imports jax) ---
    def jax_in_specs(self):
        from jax.sharding import PartitionSpec as P
        return tuple(P(*e) for e in self.in_entries)

    def jax_out_spec(self):
        from jax.sharding import PartitionSpec as P
        return P(*self.out_entries)

    def check_mesh(self, mesh) -> None:
        got = from_jax_mesh(mesh)
        if got.axes != self.mesh.axes:
            raise ValueError(
                f"plan {self.name!r} was derived for mesh {self.mesh.axes}, "
                f"got {got.axes}")

    # ---- modeled per-device traffic (benchmarks / capacity planning) -----
    def local_out_shape(self) -> tuple[int, ...]:
        """Per-device result shape AFTER the collective schedule ran (an
        all-gather leaves the full output resident on every device)."""
        out = list(self.out_shape)
        for d, entry in enumerate(self.out_entries):
            if entry is not None:
                out[d] //= self.mesh.axis_size(entry)
        return tuple(out)

    def hbm_bytes_per_device(self, dtype="float32") -> int:
        """Resident bytes per device: local operand shards + the result as
        the collective schedule leaves it."""
        esize = _dtype_size(dtype)
        ws = sum(pi(s) for s in self.local_nf.leaf_storage_shapes())
        ws += max(pi(self.local_nf.out_shape()), pi(self.local_out_shape()))
        return ws * esize

    def ici_bytes_per_device(self, dtype="float32", acc_bytes: int = 4) -> int:
        """Interconnect bytes per device for the derived collective schedule
        (ring algorithms; partial sums travel at accumulator width)."""
        esize = _dtype_size(dtype)
        out_elems = pi(self.out_shape)
        total = 0.0
        for step in self.collectives:
            p = self.mesh.axis_size(step.mesh_axis)
            if p <= 1:
                continue
            if step.kind == "psum":                   # ring all-reduce
                total += 2.0 * (p - 1) / p * out_elems * acc_bytes
            elif step.kind == "reduce_scatter":
                total += (p - 1) / p * out_elems * acc_bytes
            elif step.kind == "all_gather":
                total += (p - 1) / p * out_elems * esize
        return int(total)


# ---------------------------------------------------------------------------
# the plan cache — keyed next to the schedule cache, on normal forms
# ---------------------------------------------------------------------------

PLAN_CACHE_SIZE = 128
_cache: "OrderedDict[tuple, DistributedPlan]" = OrderedDict()
_lock = threading.Lock()
_stats = {"hits": 0, "misses": 0}


def plan_cache_stats() -> dict[str, int]:
    with _lock:
        return dict(_stats)


def reset_plan_cache() -> None:
    with _lock:
        _cache.clear()
        for k in _stats:
            _stats[k] = 0


def _spec_entries(a: "onf_mod.Access", shard_axes: dict[str, str],
                  leaf: Optional["expr_mod.LeafSpec"] = None
                  ) -> tuple[Optional[str], ...]:
    """PartitionSpec entries recovered from lifted Access coefficients: the
    operand's storage dims are its base axes in descending-stride order (the
    BlockSpec recovery rule), and a dim is sharded iff its axis was
    mesh-lifted.

    ``leaf`` disambiguates psi views: a view fixes dims to constants, which
    contribute NO coefficient — only a constant term ``Access.const`` — so
    the entry sequence must interleave None at each fixed *storage* dim
    (leading for row layout, trailing once a col layout's reversal is
    applied).  Detection is structural (which leaf dims carry a symbol),
    never by ``Access.const`` truthiness: a view at index 0 has
    ``const == 0`` yet still binds its full slab storage.  Fixed dims are
    never sharded.  The constant itself needs no spec plumbing here — the
    per-shard schedule re-derives it at local extents as a BlockSpec
    index-map offset (``OperandSpec.offsets``)."""
    strides: dict[str, int] = {}
    for idx, c in a.coeffs.items():
        if c == 0:
            continue
        b = _base(idx)
        strides[b] = min(strides.get(b, c), c)
    order = sorted(strides, key=lambda b: -strides[b])
    entries = tuple(shard_axes.get(b) for b in order)
    if leaf is None:
        return entries
    dims = leaf.dims if leaf.layout == "row" else tuple(reversed(leaf.dims))
    it = iter(entries)
    return tuple(next(it) if isinstance(t, str) else None for t, _ in dims)


def _local_normal_form(nf: "expr_mod.NormalForm",
                       local_ext: dict[str, int]) -> "expr_mod.NormalForm":
    """The per-shard normal form: every mesh-lifted axis at its local
    extent, leaves included — ready for the existing schedule derivation."""
    leaves = tuple(
        expr_mod.LeafSpec(
            l.array,
            tuple((t, local_ext.get(t, e) if isinstance(t, str) else e)
                  for t, e in l.dims),
            l.layout)
        for l in nf.leaves)
    return expr_mod.NormalForm(
        name=nf.name + "@shard",
        out_axes=nf.out_axes,
        reduce_axes=nf.reduce_axes,
        extents=tuple((s, local_ext.get(s, e)) for s, e in nf.extents),
        leaves=leaves,
        combine=nf.combine,
        reduce_op=nf.reduce_op)


def derive_plan(expr: Union["expr_mod.Expr", "expr_mod.NormalForm"],
                mesh, *, shard: dict[str, str],
                hardware=None, dtype="float32",
                replicate_out: bool = False,
                scatter_axis: Optional[str] = None,
                acc_dtype: str = "float32",
                name: Optional[str] = None) -> DistributedPlan:
    """Derive the full multi-device plan for a normalizable expression.

    ``shard`` maps normal-form axis symbols to mesh axis names (use
    ``matmul_plan``/``expert_plan`` for role-named fronts).  A requested
    axis whose extent the mesh axis does not divide falls back to
    replication (recorded in ``plan.dropped`` and surfaced as a
    ``ReplicationFallbackWarning`` naming the axis).  ``replicate_out``
    asks for a replicated result (mesh-lifted output axes then emit
    all-gathers); ``scatter_axis`` names an output axis to scatter a sigma
    reduction over (reduce-scatter instead of psum).  ``acc_dtype``
    threads through to the per-shard schedule — the local accumulator is
    widened exactly as on the single-chip path, and legality against the
    hardware table is checked at derivation.
    """
    nf = expr if isinstance(expr, expr_mod.NormalForm) else \
        expr_mod.normal_form(expr, name=name or getattr(expr, "name", None)
                             or "expr")
    mesh = from_jax_mesh(mesh)
    from repro.core.hardware import current_hardware
    hw = hardware or current_hardware()
    hw_name = getattr(hw, "name", None) or hw.shape.name
    key = (nf.key(), mesh.axes, tuple(sorted(shard.items())),
           bool(replicate_out), scatter_axis, str(dtype), hw_name,
           str(acc_dtype))
    with _lock:
        hit = _cache.get(key)
        if hit is not None:
            _stats["hits"] += 1
            _cache.move_to_end(key)
            return hit
        _stats["misses"] += 1

    ext = nf.extent_map
    applied, dropped, used_axes = [], [], set()
    for sym in sorted(shard):
        axis = shard[sym]
        if sym not in ext:
            raise KeyError(f"unknown axis {sym!r}; normal form has "
                           f"{tuple(ext)}")
        p = mesh.axis_size(axis)                 # raises on unknown mesh axis
        if axis in used_axes:
            raise ValueError(f"mesh axis {axis!r} assigned to two axes")
        if ext[sym] % p:
            dropped.append((sym, axis))          # replication fallback
            warnings.warn(
                f"{nf.name}: axis {sym!r} (extent {ext[sym]}) is not "
                f"divisible by mesh axis {axis!r} (size {p}) — operand "
                f"replicated instead of sharded",
                ReplicationFallbackWarning, stacklevel=2)
            continue
        used_axes.add(axis)
        applied.append((sym, axis))
    applied, dropped = tuple(applied), tuple(dropped)
    shard_axes = dict(applied)

    # one more dimension lift: the mesh level, ahead of proc/vector/block
    o = nf.onf()
    for sym, axis in applied:
        o = onf_mod.lift_loop(o, sym, mesh.axis_size(axis),
                              mesh_resource(axis))

    in_entries = tuple(
        _spec_entries(a, shard_axes, leaf=leaf)
        for a, leaf in zip(o.ins, nf.leaves))
    out_entries = list(_spec_entries(o.out, shard_axes))

    # the collective schedule, from which axes were lifted where
    if scatter_axis is not None:
        if scatter_axis not in nf.out_axes:
            raise ValueError(f"scatter_axis {scatter_axis!r} is not an "
                             f"output axis of {nf.out_axes}")
        if not any(sym in nf.reduce_axes for sym, _ in applied):
            raise ValueError(
                "scatter_axis requires a mesh-lifted reduction axis — no "
                "sigma axis is sharded (or it fell back to replication), so "
                "there is nothing to reduce-scatter")
    steps: list[CollectiveStep] = []
    for sym, axis in applied:
        if sym not in nf.reduce_axes:
            continue
        if nf.reduce_op != "add":
            # psum/reduce-scatter ADD partials across devices; summing
            # per-device partial maxes/mins would silently corrupt any
            # other semiring — refuse instead of mis-reducing
            raise ValueError(
                f"mesh-lifting the sigma axis {sym!r} of a "
                f"(combine={nf.combine!r}, reduce={nf.reduce_op!r}) normal "
                "form needs a matching cross-device reduction; only 'add' "
                "(psum / reduce-scatter) is derivable today — shard an "
                "output axis instead")
        if scatter_axis is not None:
            d = nf.out_axes.index(scatter_axis)
            if out_entries[d] is not None:
                raise ValueError(f"scatter_axis {scatter_axis!r} is already "
                                 "mesh-sharded")
            steps.append(CollectiveStep("reduce_scatter", axis, d))
            out_entries[d] = axis
        else:
            steps.append(CollectiveStep("psum", axis))
    if replicate_out:
        for d, entry in enumerate(out_entries):
            if entry is not None and (nf.out_axes[d], entry) in applied:
                steps.append(CollectiveStep("all_gather", entry, d))
                out_entries[d] = None

    local_ext = {sym: ext[sym] // mesh.axis_size(axis)
                 for sym, axis in applied}
    local_nf = _local_normal_form(nf, local_ext)
    bundle = sched.get_schedule(local_nf, dtype=dtype, hardware=hw,
                                acc_dtype=acc_dtype)

    plan = DistributedPlan(
        name=nf.name, mesh=mesh, applied=applied, dropped=dropped,
        in_entries=in_entries, out_entries=tuple(out_entries),
        collectives=tuple(steps), local_nf=local_nf, bundle=bundle,
        out_shape=nf.out_shape())
    with _lock:
        plan = _cache.setdefault(key, plan)
        _cache.move_to_end(key)
        while len(_cache) > PLAN_CACHE_SIZE:
            _cache.popitem(last=False)
        return plan


# ---------------------------------------------------------------------------
# role-named fronts for the canonical expressions
# ---------------------------------------------------------------------------

#: matmul_expr's normal form names its axes (i, j) out + (k) reduce
MATMUL_ROLES = {"m": "i", "n": "j", "k": "k"}
#: expert_gemm_expr's normal form names its axes (i, j, l) out + (k) reduce
EXPERT_ROLES = {"e": "i", "m": "j", "n": "l", "k": "k"}


def _translate(shard: dict[str, str], roles: dict[str, str]) -> dict[str, str]:
    out = {}
    for role, axis in shard.items():
        if axis is None:
            continue
        if role not in roles:
            raise KeyError(f"unknown role {role!r}; valid: {sorted(roles)}")
        out[roles[role]] = axis
    return out


def matmul_plan(m: int, k: int, n: int, mesh, *, shard: dict[str, str],
                transpose_b: bool = False, **kw) -> DistributedPlan:
    """Plan a (possibly transposed-operand) matmul; ``shard`` uses roles
    {"m", "n", "k"} — k is the sigma axis, so sharding it derives the
    psum/reduce-scatter schedule."""
    kw.setdefault("name", "matmul")
    if "scatter_axis" in kw and kw["scatter_axis"] is not None:
        kw["scatter_axis"] = MATMUL_ROLES[kw["scatter_axis"]]
    return derive_plan(expr_mod.matmul_expr(m, k, n, transpose_b=transpose_b),
                       mesh, shard=_translate(shard, MATMUL_ROLES), **kw)


def expert_plan(e: int, cap: int, d: int, f: int, mesh, *,
                shard: dict[str, str], **kw) -> DistributedPlan:
    """Plan the capacity-padded expert GEMM; roles {"e", "m", "n", "k"} —
    sharding "e" is expert parallelism (each device a slice of experts)."""
    kw.setdefault("name", "expert_gemm")
    return derive_plan(expr_mod.expert_gemm_expr(e, cap, d, f), mesh,
                       shard=_translate(shard, EXPERT_ROLES), **kw)


# ---------------------------------------------------------------------------
# the planned-mesh context: models route their matmuls through derived
# plans when one is active (train/serve opt in; bare CPU runs unaffected)
# ---------------------------------------------------------------------------

class _PlannedMeshStack(threading.local):
    """Per-thread stack: concurrent traces (parallel test workers, an async
    eval next to training) must not see each other's planned mesh."""
    def __init__(self):
        self.stack: list = []


_PLANNED_MESH = _PlannedMeshStack()


@contextlib.contextmanager
def planned_mesh(mesh):
    """Scoped opt-in: inside this context, ``models/layers.py`` (and anything
    else consulting ``current_planned_mesh``) routes its matmuls through
    derived DistributedPlans on ``mesh`` instead of leaving sharding to the
    SPMD partitioner."""
    _PLANNED_MESH.stack.append(mesh)
    try:
        yield mesh
    finally:
        _PLANNED_MESH.stack.pop()


def current_planned_mesh():
    return _PLANNED_MESH.stack[-1] if _PLANNED_MESH.stack else None


def tp_matmul_shard(mesh, kind: str) -> dict[str, str]:
    """Megatron-style role assignment by mesh axis name, divisibility
    handled by the plan's replication fallback: rows ("m") over "data",
    and — per ``kind`` — the output columns ("col") or the contraction
    ("sigma", deriving the TP psum) over "model"."""
    if kind not in ("row", "col", "sigma"):
        raise ValueError(f"unknown kind {kind!r} (row|col|sigma)")
    names = from_jax_mesh(mesh).axis_names
    shard: dict[str, str] = {}
    if "data" in names:
        shard["m"] = "data"
    if "model" in names:
        if kind == "col":
            shard["n"] = "model"
        elif kind == "sigma":
            shard["k"] = "model"
    if not shard:
        # silence here would mean every device redundantly computes the
        # full GEMM while the caller believes TP is active — fail loudly
        raise ValueError(
            f"planned-mesh routing expects mesh axes named 'data'/'model'; "
            f"got {names} — pass explicit shard= roles instead")
    return shard
