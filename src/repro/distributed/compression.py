"""Gradient compression for data-parallel reduction: int8 block quantization
with error feedback (1-bit-Adam-family technique, adapted to int8).

Under SPMD the DP all-reduce is implicit in the gradient computation, so the
compression is expressed as a *quantize -> (reduce) -> dequantize* transform
applied to gradients, with the per-leaf quantization residual carried in the
train state and added back the next step (error feedback keeps the scheme
convergent: the compression error is O(1) bounded, not accumulating).

Wire-byte accounting: int8 payload + one f32 scale per block of
``block_size`` values => 4x reduction vs f32 (+1.6% scale overhead), which
the roofline's collective term models via ``compressed_bytes``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressionConfig(NamedTuple):
    enabled: bool = False
    block_size: int = 256


def init_error_state(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_dequant(g: jax.Array, block: int) -> jax.Array:
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    fp = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.reshape(-1)[:flat.size].reshape(g.shape)


def compress_grads(cfg: CompressionConfig, grads, err_state
                   ) -> tuple[dict, dict]:
    """Returns (decompressed grads as seen post-all-reduce, new error state)."""
    if not cfg.enabled:
        return grads, err_state

    def one(g, e):
        gf = g.astype(jnp.float32) + e                 # error feedback
        deq = _quant_dequant(gf, cfg.block_size)
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))


def compressed_bytes(n_params: int, block_size: int = 256) -> int:
    """Wire bytes for one compressed DP reduction of n_params f32 grads."""
    return n_params + (n_params // block_size) * 4
