"""Mesh-level dimension lifting: logical axis names -> mesh axes.

This is the paper's Definition 3.1 applied at the outermost hardware level:
every tensor axis is (conceptually) split ``size -> (mesh_extent, local)``
and the outer factor is given to a mesh resource.  The table below is the
single source of truth for the whole framework — model code only ever names
*logical* axes; pjit shardings, checkpoint resharding and the elastic
re-mesh all derive from here.

Lifting rules (v5e mesh ("pod", "data", "model")):

    batch        -> ("pod", "data")     data parallelism (+ pod DP)
    seq_sp       -> "model"             sequence parallelism at layer edges
    d_model      -> ("pod", "data")     FSDP: params/optimizer fully sharded
    d_ff/heads/
    vocab/experts/
    d_inner/lru  -> "model"             tensor/expert parallelism
    everything else -> replicated

A mesh axis is used at most once per spec (first logical axis wins), and an
axis is only assigned if it divides the dimension — otherwise it falls back
to replication (e.g. 40 heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> candidate mesh axes, in preference order.  Tuple entries
# mean "all together" (e.g. batch over pod AND data).
PARAM_RULES: dict[str, tuple] = {
    "d_ff": ("model",),
    "moe_ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "d_inner": ("model",),
    "lru": ("model",),
    "d_model": (("pod", "data"),),          # FSDP axis for parameters
}

ACT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"),),
    "seq_sp": ("model",),
    "kv_seq": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "d_inner": ("model",),
    "lru": ("model",),
    "ssm_heads": ("model",),
}


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve(rules: dict, axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh) -> P:
    if axes is None:
        axes = (None,) * len(shape)
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        assigned = None
        for cand in rules.get(name or "", ()):
            group = cand if isinstance(cand, tuple) else (cand,)
            group = tuple(g for g in group if g in sizes)
            if not group or any(g in used for g in group):
                continue
            extent = int(np.prod([sizes[g] for g in group]))
            if extent > 1 and dim % extent == 0:
                assigned = group if len(group) > 1 else group[0]
                used.update(group)
                break
        entries.append(assigned)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_spec(axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh) -> P:
    return _resolve(PARAM_RULES, axes, shape, mesh)


def act_spec(axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh) -> P:
    return _resolve(ACT_RULES, axes, shape, mesh)


def param_shardings(params, axes_tree, mesh: Mesh):
    """NamedSharding pytree for a params pytree + its logical-axes pytree."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_a = treedef.flatten_up_to(axes_tree)
    return treedef.unflatten([
        NamedSharding(mesh, param_spec(a, p.shape, mesh))
        for p, a in zip(flat_p, flat_a)])


def param_pspecs(params, axes_tree, mesh: Mesh):
    flat_p, treedef = jax.tree.flatten(params)
    flat_a = treedef.flatten_up_to(axes_tree)
    return treedef.unflatten([
        param_spec(a, p.shape, mesh) for p, a in zip(flat_p, flat_a)])


# ---------------------------------------------------------------------------
# in-model constraints: no-ops without a mesh, so models run on bare CPU
# ---------------------------------------------------------------------------

def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (divisibility-checked);
    identity when no mesh is active (smoke tests, single-device runs)."""
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = act_spec(axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(f, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable ``shard_map``: new jax exposes ``jax.shard_map``
    (``check_vma``); older releases ship ``jax.experimental.shard_map``
    (``check_rep``).  All in-repo callers go through here."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def _current_mesh() -> Optional[Mesh]:
    try:
        env = jax._src.mesh.thread_resources.env  # physical mesh ctx manager
        mesh = env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:  # use_mesh-style context
            cm = getattr(jax._src.mesh, "get_concrete_mesh", lambda: None)()
            return cm
    except Exception:
        pass
    return None
