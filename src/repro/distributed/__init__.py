"""Distribution layer: lifting-derived sharding, overlap collectives,
gradient compression, fault tolerance."""
from repro.distributed import sharding  # noqa: F401
