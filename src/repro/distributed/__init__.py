"""Distribution layer: lifting-derived sharding, derived shard_map plans,
overlap collectives, gradient compression, fault tolerance."""
from repro.distributed import plan, sharding  # noqa: F401
