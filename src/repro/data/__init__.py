from repro.data.pipeline import PipelineConfig, SyntheticLM  # noqa: F401
