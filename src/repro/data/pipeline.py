"""Deterministic, shardable, *resumable* synthetic data pipeline.

Real-cluster posture without shipping a corpus: batches are a pure function
of (seed, step, shard), so

* any host can regenerate exactly its shard of any step (determinism across
  restarts and across elastic re-sharding),
* the pipeline "state" checkpointed with the model is just the step counter,
* the stream is *learnable* (noisy affine token recurrence), so end-to-end
  training examples show a genuinely decreasing loss.

``global_batch(step)`` returns the full logical batch (the pjit path shards
it by the batch PartitionSpec); ``host_shard(step, shard, n_shards)`` returns
one host's slice for multi-process feeding.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.common import ArchConfig


@dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05            # fraction of tokens replaced with noise
    mult: int = 31                 # affine recurrence multiplier


class SyntheticLM:
    """tokens[t+1] = (mult * tokens[t] + row_offset) % vocab, with noise."""

    def __init__(self, cfg: PipelineConfig, arch: ArchConfig | None = None):
        self.cfg = cfg
        self.arch = arch

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, shard]))

    def _tokens(self, step: int, rows: int, shard: int = 0) -> np.ndarray:
        c = self.cfg
        rng = self._rng(step, shard)
        x0 = rng.integers(0, c.vocab_size, size=(rows, 1))
        offs = rng.integers(1, c.vocab_size, size=(rows, 1))
        toks = [x0]
        for _ in range(c.seq_len):
            toks.append((c.mult * toks[-1] + offs) % c.vocab_size)
        seq = np.concatenate(toks, axis=1)                 # (rows, seq+1)
        noise_mask = rng.random(seq.shape) < c.noise
        noise_vals = rng.integers(0, c.vocab_size, size=seq.shape)
        seq = np.where(noise_mask, noise_vals, seq)
        return seq.astype(np.int32)

    def _batch_from(self, seq: np.ndarray, rng: np.random.Generator) -> dict:
        batch = {"tokens": seq[:, :-1], "targets": seq[:, 1:]}
        if self.arch is not None and self.arch.family == "vlm":
            p = self.arch.num_patches
            batch["patches"] = rng.standard_normal(
                (seq.shape[0], p, self.arch.d_model)).astype(np.float32)
        if self.arch is not None and self.arch.family == "audio":
            batch["frames"] = rng.standard_normal(
                (seq.shape[0], self.arch.encoder_seq,
                 self.arch.d_model)).astype(np.float32)
        return batch

    def global_batch(self, step: int) -> dict:
        seq = self._tokens(step, self.cfg.global_batch, shard=0)
        return self._batch_from(seq, self._rng(step, 1 << 20))

    def host_shard(self, step: int, shard: int, n_shards: int) -> dict:
        assert self.cfg.global_batch % n_shards == 0
        rows = self.cfg.global_batch // n_shards
        # regenerate the full deterministic batch and slice: identical across
        # any re-sharding (elastic scaling keeps the data order)
        full = self._tokens(step, self.cfg.global_batch, shard=0)
        seq = full[shard * rows:(shard + 1) * rows]
        return self._batch_from(seq, self._rng(step, (1 << 20) + shard))

    # -- checkpointable state --------------------------------------------
    @staticmethod
    def state_dict(step: int) -> dict:
        return {"data_step": int(step)}

    @staticmethod
    def from_state(state: dict) -> int:
        return int(state.get("data_step", 0))
