"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec; conv frontend is a STUB (input_specs provides 1500
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.models.common import ArchConfig

ARCH_ID = "whisper-base"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="audio",
        n_layers=6, encoder_layers=6, encoder_seq=1500,
        d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=51865,
        mlp="gelu", norm="layernorm", use_bias=True, tie_embeddings=True,
        rope_pct=0.0,                       # sinusoidal positions, no rope
        train_microbatches=4,
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=2, encoder_layers=2, encoder_seq=16,
                        d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                        d_ff=256, vocab_size=512)
