"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 — GQA, no-bias, parallel attn+FFN block, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.common import ArchConfig

ARCH_ID = "command-r-plus-104b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
        d_ff=33792, vocab_size=256000,
        mlp="swiglu", norm="layernorm", use_bias=False, parallel_block=True,
        tie_embeddings=True, rope_theta=75_000_000.0,
        attn_chunk_min_seq=4096,   # chunked attention needed to fit train_4k
        train_microbatches=16,     # 104B on 16GiB chips: 4k tokens/device/microbatch
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                        head_dim=32, d_ff=256, vocab_size=512)
