"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + 1 shared — chunked local attention
(3 local : 1 full, iRoPE-style) makes the 500k cell sub-quadratic.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.common import ArchConfig

ARCH_ID = "llama4-scout-17b-a16e"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048,
        moe=True, n_experts=16, n_shared_experts=1, top_k=1, moe_d_ff=8192,
        layer_pattern=("local", "local", "local", "full"), local_window=8192,
        mlp="swiglu", norm="rmsnorm",
        train_microbatches=16,
        attn_chunk_min_seq=4096,   # 40-head 4k scores don't fit otherwise
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                        head_dim=32, d_ff=128, moe_d_ff=128, vocab_size=512,
                        n_experts=4, local_window=8)
