"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000 —
GeGLU, head_dim=256, tied embeddings.  [arXiv:2403.08295; hf]"""
from repro.models.common import ArchConfig

ARCH_ID = "gemma-2b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=256000,
        mlp="geglu", norm="rmsnorm", tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
                        head_dim=32, d_ff=256, vocab_size=512)
