"""stablelm-1.6b [dense]: 24L d_model=2048 32H (MHA kv=32) d_ff=5632
vocab=100352 — LayerNorm + qkv biases, partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.common import ArchConfig

ARCH_ID = "stablelm-1.6b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=5632, vocab_size=100352,
        mlp="swiglu", norm="layernorm", use_bias=True, rope_pct=0.25,
        attn_sharding="heads",     # kv=32 divides the 16-way model axis
        train_microbatches=2,
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        head_dim=32, d_ff=256, vocab_size=512)
