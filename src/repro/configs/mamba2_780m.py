"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality), chunked train / recurrent decode.
[arXiv:2405.21060; unverified]"""
from repro.models.common import ArchConfig

ARCH_ID = "mamba2-780m"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="ssm", attention="none",
        n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, head_dim=64,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        norm="rmsnorm",
        train_microbatches=4,      # SSD intra-chunk (b,c,h,q,q) working set
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=2, d_model=64, vocab_size=512,
                        ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
