"""Architecture registry: ``get_config("<arch-id>")`` for every assigned
architecture (``--arch`` on all launchers).  Plus the paper's own workload
(square GEMMs) as a pseudo-config for the benchmarks."""
from __future__ import annotations

from repro.configs import (command_r_plus_104b, deepseek_moe_16b, gemma_2b,
                           llama4_scout_17b_a16e, mamba2_780m, minicpm3_4b,
                           paligemma_3b, recurrentgemma_9b, stablelm_1_6b,
                           whisper_base)
from repro.models.common import SHAPES, ArchConfig, ShapeConfig  # noqa: F401

_MODULES = (command_r_plus_104b, minicpm3_4b, gemma_2b, stablelm_1_6b,
            mamba2_780m, llama4_scout_17b_a16e, deepseek_moe_16b,
            paligemma_3b, recurrentgemma_9b, whisper_base)

ARCHS = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(ARCHS)


def get_config(arch_id: str, reduced: bool = False) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    m = ARCHS[arch_id]
    return m.reduced() if reduced else m.full()


# (arch, shape) applicability: long_500k requires sub-quadratic attention.
SUBQUADRATIC = {"mamba2-780m", "recurrentgemma-9b", "llama4-scout-17b-a16e"}


def cell_applicable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch_id not in SUBQUADRATIC:
        return False, ("full-attention arch: 512k dense-attention decode is "
                       "skipped per task statement (see DESIGN.md)")
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
