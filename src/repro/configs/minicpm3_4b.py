"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention, absorbed decode over the compressed cache).
[hf:openbmb/MiniCPM3-4B; hf]"""
from repro.models.common import ArchConfig

ARCH_ID = "minicpm3-4b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", attention="mla",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=96,
        d_ff=6400, vocab_size=73448,
        mlp="swiglu", norm="rmsnorm",
        attn_chunk_min_seq=4096,   # absorbed-MLA chunked attention (+47% frac at train_4k)
        train_microbatches=16,
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=256, vocab_size=512)
