"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, pattern (rglru, rglru, local) with a
2-layer recurrent tail (38 = 12x3 + 2); window 2048.  [arXiv:2402.19427;
unverified]"""
from repro.models.common import ArchConfig

ARCH_ID = "recurrentgemma-9b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab_size=256000,
        layer_pattern=("rglru", "rglru", "local"), local_window=2048,
        lru_width=4096, conv_width=4,
        mlp="geglu", norm="rmsnorm", tie_embeddings=True,
        train_microbatches=4,
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=5, d_model=128, n_heads=4, n_kv_heads=1,
                        head_dim=32, d_ff=256, vocab_size=512,
                        local_window=8, lru_width=128)
