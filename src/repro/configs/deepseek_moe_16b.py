"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) expert d_ff=1408
vocab=102400 — 2 shared + 64 routed top-6 fine-grained experts, first layer
dense.  [arXiv:2401.06066; hf]"""
from repro.models.common import ArchConfig

ARCH_ID = "deepseek-moe-16b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=11264,                      # dense first layer (~(6+2)x1408)
        vocab_size=102400,
        moe=True, n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
        first_dense_layers=1,
        mlp="swiglu", norm="rmsnorm",
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
                        head_dim=32, d_ff=256, moe_d_ff=64, vocab_size=512,
                        n_experts=8, top_k=2, n_shared_experts=1)
