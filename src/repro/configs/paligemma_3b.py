"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216
— SigLIP frontend is a STUB (input_specs provides 256 precomputed patch
embeddings); gemma backbone with prefix-LM attention over the patches.
[arXiv:2407.07726; hf]"""
from repro.models.common import ArchConfig

ARCH_ID = "paligemma-3b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=257216,
        mlp="geglu", norm="rmsnorm", tie_embeddings=True, num_patches=256,
    )


def reduced() -> ArchConfig:
    return full().with_(dtype="float32", n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
                        head_dim=32, d_ff=256, vocab_size=512, num_patches=8)
