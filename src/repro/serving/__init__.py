"""Serving: continuous batching over a paged KV cache.

The page table is the psi view: a sequence's logical KV cache is an
index-0 view over fixed-size slabs in one shared pool, and the decode
kernel's BlockSpec index maps are derived from the table
(``kernels/emit._index_map``) instead of gather-copying pages.  The
engine (``engine.ServeEngine``) interleaves derived flash prefill with
paged ``windowed_decode`` steps under admission, slot and page pressure.
"""
from repro.serving.cache import OutOfPages, PagePool, pages_needed
from repro.serving.engine import Request, ServeEngine

__all__ = ["OutOfPages", "PagePool", "pages_needed", "Request",
           "ServeEngine"]
