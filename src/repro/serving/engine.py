"""Continuous batching over the paged KV cache.

One engine iteration (:meth:`ServeEngine.step`) admits waiting requests
into free slots (derived flash prefill — ONE kernel sweep per prompt,
scattered into freshly allocated slabs), then decodes every active slot.
For paged families the slots decode TOGETHER: the slot axis is one more
dimension-lift level, so one ``batched_decode`` launch covers all of
them through a stacked ``[slot, k]`` page table, with greedy sampling on
device and ONE host transfer per iteration.  The stacked table always
has ``max_slots`` rows (trimmed to the widest live slot's page count, so
guard-skipped grid steps don't pile up behind short sequences), and each
slot pins ONE row for its whole residency (lowest free row at
admission).  A row whose slot is inactive is dead by runtime data alone
— position -1 fails every block-skip guard, and the dead slot's K/V
write is routed past the pool and dropped — so its entries are
canonically all zeros and slot-count changes re-key NOTHING.  The table
is rebuilt each launch as a PURE function of live occupancy (slabs
zero-padded per row), so the executable key depends on nothing
historical: position and liveness are runtime data in the POS aux, and
the canonical allocator makes freed slabs (hence whole tables) recur
across requests so executables stay cached.

Under page pressure the engine preempts: the youngest other running
sequence is evicted (slabs freed, request re-queued with its tokens so
far) and re-prefills when re-admitted — recompute preemption, the
standard continuous-batching fallback.

Families without a paged KV view (ssm, hybrid, moe, mla, vlm) serve
through per-slot contiguous caches and ``registry.decode_step`` under
the same admission/slot scheduler, so one engine fronts every
architecture in the registry.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import registry, transformer
from repro.models.common import ArchConfig
from repro.serving.cache import OutOfPages, PagePool, pages_needed


@dataclass
class Request:
    """One generation request and its lifecycle metrics (caller clock)."""
    rid: int
    prompt: tuple
    max_new: int
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    first_tok_t: Optional[float] = None
    done_t: Optional[float] = None
    evictions: int = 0


@dataclass
class _Slot:
    req: Request
    tokens: list            # prompt + emitted tokens, in order
    n_emitted: int = 0
    slabs: list = field(default_factory=list)     # the page table
    cache: Optional[dict] = None                  # contiguous fallback only
    row: int = -1                                 # stacked-table row (batched)


def _paged_capable(cfg: ArchConfig) -> bool:
    """The derived paged path covers dense GQA/MQA-grouped decode: the
    folding weld needs a blocked group-row axis (g >= 2) and a plain KV
    cache (not MLA's latent, not vlm's patch-prefixed prefill)."""
    return (cfg.family == "dense" and cfg.attention != "mla"
            and cfg.n_heads // cfg.n_kv_heads >= 2)


class ServeEngine:
    """Continuous-batching scheduler over one model.

    ``max_len`` bounds any sequence (prompt + generated); ``pool_pages``
    sizes the shared slab pool; ``page=None`` takes the page size from
    ``ops.default_decode_page`` — the solved stream block IS the page.
    ``interpret`` rides through to the kernels (interpret-mode Pallas on
    CPU).  The caller supplies timestamps (``now``) so latency metrics
    use one clock.
    """

    def __init__(self, cfg: ArchConfig, params: Optional[dict] = None, *,
                 key=None, max_slots: int = 2, max_len: int = 256,
                 pool_pages: Optional[int] = None,
                 page: Optional[int] = None, dtype=jnp.float32,
                 interpret: Optional[bool] = None,
                 eos_id: Optional[int] = None,
                 batched: Optional[bool] = None):
        self.cfg = cfg
        if params is None:
            params, _ = registry.init(cfg, key if key is not None
                                      else jax.random.PRNGKey(0))
        self.params = params
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.interpret = interpret
        self.eos_id = eos_id
        self.paged = _paged_capable(cfg)
        # batched multi-slot decode rides the paged psi view (the stacked
        # table IS the slot lift); contiguous families fall back per-slot
        self.batched = self.paged and batched is not False
        if batched and not self.paged:
            raise ValueError(
                f"batched decode needs the paged path; family "
                f"{cfg.family!r}/{cfg.attention!r} serves contiguous")
        if page is None:
            g = cfg.n_heads // max(1, cfg.n_kv_heads)
            page = min(ops.default_decode_page(
                self.max_len, cfg.n_kv_heads, max(2, g), cfg.head_dim_,
                dtype=str(jnp.dtype(dtype))), self.max_len)
        self.page = int(page)
        if pool_pages is None:
            pool_pages = self.max_slots * pages_needed(self.max_len,
                                                       self.page)
        #: stacked-table row width cap: the most pages a slot can ever
        #: hold (each launch trims to the widest live slot)
        self._view_pages = pages_needed(self.max_len, self.page)
        self.pool: Optional[PagePool] = (
            PagePool(cfg, pool_pages, self.page, dtype) if self.paged
            else None)
        self.dtype = dtype
        #: decode-step executions since construction (a batched launch
        #: counts once however many slots it covers) — the numerator of
        #: the bench's ``kernel_calls_per_token``
        self.kernel_calls = 0
        self._waiting: list[Request] = []
        self._slots: list[_Slot] = []
        self._done: dict[int, Request] = {}
        self._out: dict[int, list] = {}
        self._next_rid = 0
        self._decode_fns: dict[tuple, callable] = {}
        self._prefill_fns: dict[int, callable] = {}

    # -- public API --------------------------------------------------------

    def submit(self, prompt, max_new: int, now: float = 0.0) -> int:
        """Queue a request; returns its id."""
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new} exceeds "
                f"max_len {self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self._waiting.append(Request(rid, prompt, int(max_new),
                                     submit_t=now))
        self._out[rid] = []
        return rid

    def step(self, now: float = 0.0) -> list[tuple[int, int]]:
        """One engine iteration: admit, then decode every active slot —
        ONE batched kernel launch on the paged path, a per-slot loop with
        one deferred host transfer otherwise.  Returns the ``(rid,
        token)`` pairs emitted."""
        emitted = self._admit(now)
        if self.batched:
            emitted.extend(self._decode_batched(now))
        else:
            emitted.extend(self._decode_sequential(now))
        return emitted

    @property
    def idle(self) -> bool:
        return not self._waiting and not self._slots

    def run(self, now: float = 0.0, clock=None) -> dict:
        """Step until idle; returns ``{rid: {"tokens", "request"}}``.
        ``clock`` (e.g. ``time.perf_counter``) refreshes ``now`` between
        iterations for latency metrics."""
        while not self.idle:
            self.step(now if clock is None else clock())
        return self.results()

    def results(self) -> dict:
        return {rid: {"tokens": list(self._out[rid]), "request": req}
                for rid, req in self._done.items()}

    # -- scheduling --------------------------------------------------------

    def _admit(self, now: float) -> list[tuple[int, int]]:
        emitted = []
        while self._waiting and len(self._slots) < self.max_slots:
            req = self._waiting[0]
            try:
                slot = self._start(req, now)
            except OutOfPages:
                if not self._evict(protect=None):
                    break               # nothing evictable; wait
                continue
            self._waiting.pop(0)
            self._slots.append(slot)
            tok = self._first_token(slot, now)
            if tok is not None:
                emitted.append((req.rid, tok))
            self._retire_if_done(slot, now)
        return emitted

    def _start(self, req: Request, now: float) -> _Slot:
        """Prefill the request's tokens-so-far into a fresh slot."""
        tokens = list(req.prompt) + list(self._out[req.rid])
        slot = _Slot(req=req, tokens=tokens,
                     n_emitted=len(self._out[req.rid]))
        s0 = len(tokens)
        if self.batched:
            used = {s.row for s in self._slots}
            slot.row = min(i for i in range(self.max_slots)
                           if i not in used)
        if self.paged:
            slot.slabs = self.pool.alloc(pages_needed(s0, self.page))
        logits, cache = self._prefill(tokens)
        if self.paged:
            self.pool.write_prefill(cache, slot.slabs, s0)
        else:
            slot.cache = transformer.prefill_cache_to_decode(
                self.cfg, cache, self.max_len)
            if slot.cache is None:
                raise NotImplementedError(
                    f"family {self.cfg.family!r} has no forward->decode "
                    f"cache re-layout; the engine cannot serve it")
        slot._logits = logits
        if req.admit_t is None:
            req.admit_t = now
        return slot

    def _first_token(self, slot: _Slot, now: float) -> Optional[int]:
        tok = int(jnp.argmax(slot._logits[0]))
        del slot._logits
        return self._emit(slot, tok, now)

    def _decode_batched(self, now: float) -> list[tuple[int, int]]:
        """Decode every active paged slot in ONE derived-kernel launch.

        Page allocation for all slots happens first (it may evict — a
        victim simply drops out of this iteration's batch, exactly as it
        dropped out of the old per-slot loop).  The stacked table is then
        rebuilt as a PURE function of live state: each live slot's slabs
        fill its pinned row, zero-padded to the widest live slot; dead
        rows are all zeros (POS -1 makes them inert and their writes
        drop, so the entries never matter).  Canonical rows mean the
        executor key — and hence the jitted executable — recurs whenever
        the engine revisits the same occupancy, including across whole
        replays of an identical trace.  Greedy argmax runs on device
        inside the jitted step; the (max_slots,) token vector is the one
        host transfer."""
        live = []
        for slot in list(self._slots):
            if slot not in self._slots:   # evicted by an earlier ensure
                continue
            try:
                self._ensure_pages(slot, len(slot.tokens))
            except OutOfPages:
                continue                  # pool saturated; retry next step
            live.append(slot)
        live = [s for s in live if s in self._slots]
        if not live:
            return []
        by_row = {s.row: s for s in live}
        # trim the view to the widest LIVE slot: shorter tables mean
        # fewer streamed grid steps per launch.  Width growth re-keys
        # the executor exactly as per-slot page allocation does
        width = max(len(s.slabs) for s in live)
        toks, poss, rows = [], [], []
        for i in range(self.max_slots):
            slot = by_row.get(i)
            if slot is not None:
                slabs = tuple(slot.slabs)
                rows.append(slabs + (0,) * (width - len(slabs)))
                toks.append(slot.tokens[-1])
                poss.append(len(slot.tokens) - 1)
            else:
                rows.append((0,) * width)
                toks.append(0)
                poss.append(-1)
        fn = self._batched_decode_fn(tuple(rows))
        next_toks, pools = fn(jnp.asarray(toks, jnp.int32),
                              jnp.asarray(poss, jnp.int32),
                              self.pool.pools)
        self.pool.update(pools)
        self.kernel_calls += 1
        next_toks = jax.device_get(next_toks)      # ONE sync per iteration
        emitted = []
        for slot in live:
            tok = self._emit(slot, int(next_toks[slot.row]), now)
            self._retire_if_done(slot, now)
            if tok is not None:
                emitted.append((slot.req.rid, tok))
        return emitted

    def _decode_sequential(self, now: float) -> list[tuple[int, int]]:
        """The per-slot fallback (contiguous families, ``batched=False``):
        one decode launch per slot, but sampling stays on device and the
        stacked token vector transfers ONCE after every slot has
        launched — JAX's async dispatch overlaps the launches, and no
        slot blocks the host per token."""
        pending = []                      # (slot, device argmax scalar)
        for slot in list(self._slots):
            if slot not in self._slots:   # evicted by an earlier ensure
                continue
            pos = len(slot.tokens) - 1    # feed the newest token here
            if self.paged:
                try:
                    self._ensure_pages(slot, pos + 1)
                except OutOfPages:
                    continue              # pool saturated; retry next step
                fn = self._paged_decode_fn(tuple(slot.slabs))
                logits, pools = fn(
                    jnp.asarray([slot.tokens[-1]], jnp.int32),
                    jnp.asarray([pos], jnp.int32), self.pool.pools)
                self.pool.update(pools)
            else:
                logits, slot.cache = self._contig_decode_fn()(
                    jnp.asarray([slot.tokens[-1]], jnp.int32),
                    jnp.asarray([pos], jnp.int32), slot.cache)
            self.kernel_calls += 1
            pending.append((slot, jnp.argmax(logits[0])))
        if not pending:
            return []
        toks = jax.device_get(jnp.stack([t for _, t in pending]))
        emitted = []
        for (slot, _), tok in zip(pending, toks):
            if slot not in self._slots:
                # evicted after its launch by a later slot's allocation:
                # drop the token — greedy decode recomputes it identically
                # on re-admission
                continue
            tok = self._emit(slot, int(tok), now)
            self._retire_if_done(slot, now)
            if tok is not None:
                emitted.append((slot.req.rid, tok))
        return emitted

    def _emit(self, slot: _Slot, tok: int, now: float) -> Optional[int]:
        if slot.req.first_tok_t is None:
            slot.req.first_tok_t = now
        slot.tokens.append(tok)
        slot.n_emitted += 1
        self._out[slot.req.rid].append(tok)
        return tok

    def _retire_if_done(self, slot: _Slot, now: float) -> None:
        done = (slot.n_emitted >= slot.req.max_new or
                (self.eos_id is not None and
                 slot.tokens[-1] == self.eos_id) or
                len(slot.tokens) >= self.max_len)
        if done and slot in self._slots:
            slot.req.done_t = now
            if self.paged:
                self.pool.free(slot.slabs)
            self._slots.remove(slot)
            self._done[slot.req.rid] = slot.req

    def _ensure_pages(self, slot: _Slot, tokens_needed: int) -> None:
        """Grow the slot's page table to cover ``tokens_needed`` rows,
        evicting other slots under pressure."""
        while len(slot.slabs) < pages_needed(tokens_needed, self.page):
            try:
                slot.slabs.extend(self.pool.alloc(1))
            except OutOfPages:
                if not self._evict(protect=slot):
                    raise

    def _evict(self, protect: Optional[_Slot]) -> bool:
        """Preempt the youngest running paged slot (recompute on
        re-admission).  Returns False when nothing is evictable."""
        victims = [s for s in self._slots if s is not protect and s.slabs]
        if not victims:
            return False
        victim = victims[-1]              # youngest admitted
        self.pool.free(victim.slabs)
        victim.slabs = []
        self._slots.remove(victim)
        victim.req.evictions += 1
        self._waiting.insert(0, victim.req)
        return True

    # -- executables (cached on static keys only) --------------------------

    def _prefill(self, tokens: list):
        fn = self._prefill_fns.get(len(tokens))
        if fn is None:
            fn = jax.jit(lambda t: registry.prefill(
                self.params, self.cfg, {"tokens": t}))
            self._prefill_fns[len(tokens)] = fn
        return fn(jnp.asarray([tokens], jnp.int32))

    def _paged_decode_fn(self, table: tuple):
        """The jitted paged decode step for one page table — THE derived
        ``windowed_decode`` kernel reading through the table's psi view."""
        fn = self._decode_fns.get(table)
        if fn is None:
            fn = jax.jit(functools.partial(
                transformer.decode_step_paged, self.params, self.cfg,
                page_table=table, page=self.page,
                interpret=self.interpret))
            self._decode_fns[table] = fn
        return fn

    def _batched_decode_fn(self, tables: tuple):
        """The jitted batched decode step for one STACKED page table —
        the derived ``batched_decode`` kernel covering every slot in one
        launch, with greedy argmax folded in so sampling happens on
        device and only the (max_slots,) token vector crosses to host."""
        fn = self._decode_fns.get(tables)
        if fn is None:
            def run(toks, poss, pools, _tables=tables):
                logits, pools = transformer.decode_step_paged_batched(
                    self.params, self.cfg, toks, poss, pools,
                    page_tables=_tables, page=self.page,
                    interpret=self.interpret)
                return jnp.argmax(logits, axis=-1), pools
            fn = jax.jit(run)
            self._decode_fns[tables] = fn
        return fn

    def _contig_decode_fn(self):
        fn = self._decode_fns.get(())
        if fn is None:
            fn = jax.jit(functools.partial(registry.decode_step,
                                           self.params, self.cfg))
            self._decode_fns[()] = fn
        return fn
