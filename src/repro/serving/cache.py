"""The paged KV pool: slab storage + the canonical page allocator.

One pool serves every sequence; a sequence owns a *page table* — the
tuple of slab ids its psi view reads through.  Slab ``t`` is rows
``[t * page, (t + 1) * page)`` of the per-layer ``(L, pool_tokens, KV,
hd)`` storage, so the table is exactly the per-page ``Access.const``
offset list the derived decode kernel lowers into its BlockSpec index
map (``RecurrentForm.page_table``).

The free list is a min-heap on purpose: allocation always hands out the
LOWEST free slab, so which slabs a sequence gets depends only on the
pool's current occupancy, never on the order past sequences freed — the
same admission pattern reproduces the same tables, and the
table-keyed decode executors (``ops._decode_executor``, the engine's
jitted steps) stay hot in steady-state serving instead of re-tracing
behind every drain/refill cycle.
"""
from __future__ import annotations

import heapq

import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import ArchConfig


class OutOfPages(RuntimeError):
    """The pool cannot satisfy an allocation — the engine's cue to evict."""


def pages_needed(tokens: int, page: int) -> int:
    """Pages covering ``tokens`` cache rows."""
    return -(-tokens // page)


class PagePool:
    """Slab storage for one model + the free-slab stack.

    ``pools`` holds the jnp arrays (``{"k", "v"}``, each ``(L,
    pool_pages * page, KV, hd)``); the engine threads the functionally
    updated arrays back through :meth:`update` after every decode step.
    Allocation is pure bookkeeping over slab ids — no array traffic.
    """

    def __init__(self, cfg: ArchConfig, pool_pages: int, page: int,
                 dtype=jnp.float32):
        if pool_pages < 1 or page < 1:
            raise ValueError(f"need pool_pages >= 1 and page >= 1, got "
                             f"{pool_pages}/{page}")
        self.page = int(page)
        self.pool_pages = int(pool_pages)
        self.pools = transformer.init_paged_pools(
            cfg, self.pool_pages * self.page, dtype)
        # min-heap: lowest free slab allocates first, so assignment is a
        # function of occupancy (canonical tables), not free order
        self._free = list(range(self.pool_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.pool_pages - len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take the ``n`` lowest free slabs."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise OutOfPages(
                f"need {n} page(s), {len(self._free)} free of "
                f"{self.pool_pages}")
        return [heapq.heappop(self._free) for _ in range(n)]

    def free(self, slabs) -> None:
        """Return slabs to the heap."""
        for s in slabs:
            if not 0 <= s < self.pool_pages:
                raise ValueError(f"slab {s} outside pool "
                                 f"[0, {self.pool_pages})")
            if s in self._free:
                raise ValueError(f"double free of slab {s}")
            heapq.heappush(self._free, s)

    def update(self, pools: dict) -> None:
        """Install the functionally-updated arrays after a decode step."""
        self.pools = pools

    def write_prefill(self, cache_kv, slabs: list[int], s0: int) -> None:
        """Scatter a prefill cache (forward layout ``(L, 1, s0, KV, hd)``
        per leaf) into the allocated slabs — the one copy at the
        prefill -> paged-decode layout transition."""
        page = self.page
        k, v = self.pools["k"], self.pools["v"]
        for vpg, slab in enumerate(slabs):
            lo = vpg * page
            if lo >= s0:
                break
            hi = min(s0, lo + page)
            row = slab * page
            k = k.at[:, row:row + (hi - lo)].set(
                cache_kv.k[:, 0, lo:hi].astype(k.dtype))
            v = v.at[:, row:row + (hi - lo)].set(
                cache_kv.v[:, 0, lo:hi].astype(v.dtype))
        self.pools = {"k": k, "v": v}
