"""Encoder-decoder transformer (Whisper backbone).  [arXiv:2212.04356]

The conv audio frontend is a STUB per the task statement: ``input_specs``
provides precomputed frame embeddings (B, n_frames, d_model); a linear
adapter stands in for the conv stack.  Positions are sinusoidal (whisper's
learned decoder positions are replaced by sinusoids so the 32k stress shapes
remain well-defined — noted in DESIGN.md).

Decode cache = decoder self-attention KV (ring-free, full length) + the
cross-attention K/V computed once from the encoder output.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ArchConfig, Collector
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens, init_embed,
                                 init_mlp, init_norm, logits_from_hidden,
                                 sinusoid_positions)


def _stack(n: int) -> tuple[tuple[int, str], ...]:
    return ((n, "layers"),)


def init_encdec(cfg: ArchConfig, key: jax.Array) -> tuple[dict, dict]:
    col = Collector(key, dtype=jnp.dtype(cfg.dtype))
    init_embed(col, cfg)
    col.param("frontend/adapter", (cfg.d_model, cfg.d_model),
              ("d_model", None), scale=cfg.d_model ** -0.5)
    E, L = cfg.encoder_layers, cfg.n_layers
    # encoder
    init_norm(col, "encoder/ln1", cfg.d_model, cfg, _stack(E))
    init_norm(col, "encoder/ln2", cfg.d_model, cfg, _stack(E))
    attn.init_attention(col, "encoder/attn", cfg, _stack(E))
    init_mlp(col, "encoder/mlp", cfg, stack=_stack(E))
    init_norm(col, "encoder_norm", cfg.d_model, cfg)
    init_norm(col, "final_norm", cfg.d_model, cfg)
    # decoder
    init_norm(col, "decoder/ln1", cfg.d_model, cfg, _stack(L))
    init_norm(col, "decoder/ln_x", cfg.d_model, cfg, _stack(L))
    init_norm(col, "decoder/ln2", cfg.d_model, cfg, _stack(L))
    attn.init_attention(col, "decoder/self_attn", cfg, _stack(L))
    attn.init_attention(col, "decoder/cross_attn", cfg, _stack(L))
    init_mlp(col, "decoder/mlp", cfg, stack=_stack(L))
    return col.done()


def encode(params: dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, n_frames, d_model) stub embeddings -> encoder states."""
    x = jnp.einsum("bfd,de->bfe", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frontend"]["adapter"],
                   preferred_element_type=jnp.float32).astype(jnp.dtype(cfg.dtype))
    s = x.shape[1]
    x = x + sinusoid_positions(jnp.arange(s), cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(s)[None, :]

    def body(xc, lp):
        h = apply_norm(lp["ln1"], xc, cfg)
        a, _ = attn.attention_fwd(lp["attn"], h, cfg, positions=positions,
                                  causal=False)
        xc = xc + a
        h2 = apply_norm(lp["ln2"], xc, cfg)
        return xc + apply_mlp(lp["mlp"], h2, cfg), None

    x, _ = jax.lax.scan(jax.checkpoint(body) if cfg.remat else body,
                        x, params["encoder"], unroll=bool(cfg.scan_unroll))
    return apply_norm(params["encoder_norm"], x, cfg)


def _cross_kv(lp: dict, enc: jax.Array, cfg: ArchConfig) -> attn.KV:
    k = jnp.einsum("bsd,dhk->bshk", enc, lp["wk"],
                   preferred_element_type=jnp.float32).astype(enc.dtype)
    v = jnp.einsum("bsd,dhk->bshk", enc, lp["wv"],
                   preferred_element_type=jnp.float32).astype(enc.dtype)
    if "bk" in lp:
        k = k + lp["bk"].astype(enc.dtype)
        v = v + lp["bv"].astype(enc.dtype)
    return attn.KV(k, v)


def decoder_forward(params: dict, cfg: ArchConfig, tokens: jax.Array,
                    enc: jax.Array) -> tuple[jax.Array, Any]:
    """Teacher-forcing decoder pass.  Returns (hidden, self-KV cache)."""
    x = embed_tokens(params, tokens, cfg)
    s = x.shape[1]
    x = x + sinusoid_positions(jnp.arange(s), cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(s)[None, :]

    def body(xc, lp):
        h = apply_norm(lp["ln1"], xc, cfg)
        a, kv = attn.attention_fwd(lp["self_attn"], h, cfg, positions=positions)
        xc = xc + a
        hx = apply_norm(lp["ln_x"], xc, cfg)
        ckv = _cross_kv(lp["cross_attn"], enc, cfg)
        ca, _ = attn.attention_fwd(lp["cross_attn"], hx, cfg,
                                   positions=positions, causal=False,
                                   kv_override=ckv)
        xc = xc + ca
        h2 = apply_norm(lp["ln2"], xc, cfg)
        return xc + apply_mlp(lp["mlp"], h2, cfg), kv

    x, kvs = jax.lax.scan(jax.checkpoint(body) if cfg.remat else body,
                          x, params["decoder"], unroll=bool(cfg.scan_unroll))
    return apply_norm(params["final_norm"], x, cfg), kvs


def encdec_loss(params: dict, cfg: ArchConfig, frames: jax.Array,
                tokens: jax.Array, targets: jax.Array) -> tuple[jax.Array, dict]:
    enc = encode(params, cfg, frames)
    hidden, _ = decoder_forward(params, cfg, tokens, enc)
    logits = logits_from_hidden(params, hidden, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    return loss, {"nll": loss}


class EncDecCache(NamedTuple):
    self_kv: attn.KV          # (L, B, S, KV, hd)
    cross_kv: attn.KV         # (L, B, Senc, KV, hd)


def init_encdec_cache(cfg: ArchConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16) -> EncDecCache:
    hd, kv, L = cfg.head_dim_, cfg.n_kv_heads, cfg.n_layers
    mk = lambda s: attn.KV(jnp.zeros((L, batch, s, kv, hd), dtype),
                           jnp.zeros((L, batch, s, kv, hd), dtype))
    return EncDecCache(self_kv=mk(cache_len), cross_kv=mk(cfg.encoder_seq))


def encdec_prefill(params: dict, cfg: ArchConfig, frames: jax.Array,
                   tokens: jax.Array) -> tuple[jax.Array, EncDecCache]:
    """Encode + teacher-forced prefix pass; returns last logits + cache."""
    enc = encode(params, cfg, frames)
    hidden, self_kv = decoder_forward(params, cfg, tokens, enc)
    cross = jax.vmap(lambda lp: _cross_kv(lp, enc, cfg))(
        params["decoder"]["cross_attn"])
    logits = logits_from_hidden(params, hidden[:, -1:], cfg)[:, 0]
    return logits, EncDecCache(self_kv=self_kv, cross_kv=cross)


def encdec_decode_step(params: dict, cfg: ArchConfig, tokens: jax.Array,
                       pos: jax.Array, cache: EncDecCache
                       ) -> tuple[jax.Array, EncDecCache]:
    """One decoder token.  tokens: (B,), pos: (B,)."""
    x = embed_tokens(params, tokens[:, None], cfg)
    x = x + sinusoid_positions(pos[:, None], cfg.d_model).astype(x.dtype)
    senc = cache.cross_kv.k.shape[2]

    def body(xc, scan_in):
        lp, skv, ckv = scan_in
        h = apply_norm(lp["ln1"], xc, cfg)
        a, skv = attn.attention_decode(lp["self_attn"], h, skv, pos, cfg)
        xc = xc + a
        hx = apply_norm(lp["ln_x"], xc, cfg)
        # cross attention: all encoder positions valid
        q = jnp.einsum("bsd,dhk->bshk", hx, lp["cross_attn"]["wq"],
                       preferred_element_type=jnp.float32).astype(hx.dtype)
        if "bq" in lp["cross_attn"]:
            q = q + lp["cross_attn"]["bq"].astype(hx.dtype)
        kvh = ckv.k.shape[2]
        mask = jnp.ones((1, 1, 1, 1, senc), bool)
        hd = q.shape[-1]
        out = attn._attend(attn._split_groups(q, kvh), ckv.k, ckv.v, mask,
                           hd ** -0.5)
        ca = jnp.einsum("bshk,hkd->bsd", out, lp["cross_attn"]["wo"],
                        preferred_element_type=jnp.float32).astype(hx.dtype)
        if "bo" in lp["cross_attn"]:
            ca = ca + lp["cross_attn"]["bo"].astype(hx.dtype)
        xc = xc + ca
        h2 = apply_norm(lp["ln2"], xc, cfg)
        return xc + apply_mlp(lp["mlp"], h2, cfg), skv

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], cache.self_kv, cache.cross_kv),
        unroll=bool(cfg.scan_unroll))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, x[:, 0:1], cfg)[:, 0]
    return logits, EncDecCache(self_kv=new_self, cross_kv=cache.cross_kv)
