"""Family dispatch: one uniform interface over all ten architectures.

    init(cfg, key)                  -> (params, logical_axes)
    loss(params, cfg, batch)        -> (loss, metrics)
    prefill_fn / decode_fn          -> serving entry points
    init_cache(cfg, batch, len)     -> decode cache
    input_specs(cfg, shape)         -> ShapeDtypeStruct stand-ins for every
                                       model input of that (arch x shape) cell
                                       (weak-type-correct, no allocation)
    cache_logical_axes(cache)       -> logical-axis pytree for cache sharding
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.common import ArchConfig, ShapeConfig


# ---------------------------------------------------------------------------
# init / loss / serve dispatch
# ---------------------------------------------------------------------------

def init(cfg: ArchConfig, key: jax.Array) -> tuple[dict, dict]:
    if cfg.family == "audio":
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def loss(params: dict, cfg: ArchConfig, batch: dict) -> tuple[jax.Array, dict]:
    if cfg.family == "audio":
        return encdec.encdec_loss(params, cfg, batch["frames"],
                                  batch["tokens"], batch["targets"])
    return transformer.lm_loss(params, cfg, batch["tokens"], batch["targets"],
                               patches=batch.get("patches"))


def prefill(params: dict, cfg: ArchConfig, batch: dict):
    if cfg.family == "audio":
        return encdec.encdec_prefill(params, cfg, batch["frames"],
                                     batch["tokens"])
    return transformer.prefill(params, cfg, batch["tokens"],
                               patches=batch.get("patches"))


def decode_step(params: dict, cfg: ArchConfig, tokens, pos, cache):
    if cfg.family == "audio":
        return encdec.encdec_decode_step(params, cfg, tokens, pos, cache)
    return transformer.decode_step(params, cfg, tokens, pos, cache)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16):
    if cfg.family == "audio":
        return encdec.init_encdec_cache(cfg, batch, cache_len, dtype)
    return transformer.init_cache(cfg, batch, cache_len, dtype)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; the dry-run lowers against these)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one (arch x shape) cell, as abstract values.

    train/prefill: token batch (+ stub patch/frame embeddings for vlm/audio);
    decode: one new token per row + positions + the full decode cache.
    """
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), i32), "targets": _sds((b, s), i32)}
        if cfg.family == "vlm":
            # patches are part of the 4k budget: text = s - num_patches
            batch["tokens"] = _sds((b, s - cfg.num_patches), i32)
            batch["targets"] = _sds((b, s - cfg.num_patches), i32)
            batch["patches"] = _sds((b, cfg.num_patches, cfg.d_model), bf16)
        if cfg.family == "audio":
            batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), bf16)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), i32)}
        if cfg.family == "vlm":
            batch["tokens"] = _sds((b, s - cfg.num_patches), i32)
            batch["patches"] = _sds((b, cfg.num_patches, cfg.d_model), bf16)
        if cfg.family == "audio":
            batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), bf16)
        return {"batch": batch}
    # decode: KV cache of seq_len, one new token
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {"tokens": _sds((b,), i32), "pos": _sds((b,), i32), "cache": cache}


# ---------------------------------------------------------------------------
# cache logical axes (for sharding the decode cache)
# ---------------------------------------------------------------------------

def cache_logical_axes(cache: Any) -> Any:
    """Logical-axis pytree mirroring a decode cache, keyed off leaf paths.

    KV k/v: (..., batch, kv_seq, kv_heads, hd); MLA c_kv/k_pe: (..., batch,
    kv_seq, rank); SSM conv/state, RG-LRU h/conv as documented in the
    respective modules.
    """
    def axes_for(path, leaf) -> tuple:
        names = [getattr(p, "name", getattr(p, "key", None)) for p in path]
        last = names[-1]
        nd = leaf.ndim
        def lead(n_used):
            return (None,) * (nd - n_used)
        if last in ("k", "v"):
            return lead(4) + ("batch", "kv_seq", "kv_heads", None)
        if last in ("c_kv", "k_pe"):
            return lead(3) + ("batch", "kv_seq", None)
        if last == "state":
            return lead(4) + ("batch", "ssm_heads", None, None)
        if last == "conv":
            return lead(3) + ("batch", None, "d_inner")
        if last == "h":
            return lead(2) + ("batch", "lru")
        return (None,) * nd

    return jax.tree_util.tree_map_with_path(axes_for, cache)
