"""Mamba-2 (SSD — state-space duality) block, chunked for training and
recurrent for decode.  [arXiv:2405.21060]

The chunked SSD algorithm is itself a dimension lifting: the sequence axis is
split ``S -> (chunks, chunk_len)`` and the computation decomposes into
block-diagonal (intra-chunk, quadratic-in-q matmuls on the MXU) plus low-rank
(inter-chunk, a carried-state recurrence over chunk states).  This module no
longer hand-rolls that loop: ``ssd_chunked`` is a thin consumer of
``ops.scan_ssd`` — the scan schedule (grid, BlockSpecs, chunk length, the
carried (h, p, n) state scratch and the final-state export) is *derived*
from the lifted recurrent form ``expr.ssd_form`` by the same pipeline as
every GEMM and the flash-attention kernel, with the chunk from
``solve_recurrence_blocks``.

Decode is the dual recurrent form: O(1) state update per token —
state (B, H, p, N);  h' = exp(dt*A) h + dt * x outer B;  y = C . h + D x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.common import ArchConfig, Collector


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssd_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def conv_dim(cfg: ArchConfig) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_state


def init_mamba2(col: Collector, path: str, cfg: ArchConfig,
                stack: tuple[tuple[int, str], ...] = ()):
    d = cfg.d_model
    din, h, n = d_inner(cfg), n_ssd_heads(cfg), cfg.ssm_state
    lead = tuple(s for s, _ in stack)
    laxes = tuple(a for _, a in stack)
    # in_proj -> [z, x, B, C, dt]
    col.param(f"{path}/w_in", lead + (d, 2 * din + 2 * n + h),
              laxes + ("d_model", "d_inner"), scale=d ** -0.5)
    col.param(f"{path}/conv_w", lead + (cfg.conv_width, conv_dim(cfg)),
              laxes + (None, "d_inner"), scale=cfg.conv_width ** -0.5)
    col.param(f"{path}/conv_b", lead + (conv_dim(cfg),), laxes + ("d_inner",),
              init="zeros")
    col.param(f"{path}/A_log", lead + (h,), laxes + ("ssm_heads",), init="zeros")
    col.param(f"{path}/D", lead + (h,), laxes + ("ssm_heads",), init="ones")
    col.param(f"{path}/dt_bias", lead + (h,), laxes + ("ssm_heads",), init="zeros")
    col.param(f"{path}/norm_scale", lead + (din,), laxes + ("d_inner",), init="ones")
    col.param(f"{path}/w_out", lead + (din, d), laxes + ("d_inner", "d_model"),
              scale=din ** -0.5)


class SSMCache(NamedTuple):
    conv: jax.Array        # (B, conv_width-1, conv_dim) — trailing inputs
    state: jax.Array       # (B, H, p, N) f32


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    h, p, n = n_ssd_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim(cfg)), dtype),
        state=jnp.zeros((batch, h, p, n), jnp.float32))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: (B,S,C), w: (W,C)."""
    wwidth = w.shape[0]
    out = x * w[-1]
    for i in range(1, wwidth):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[wwidth - 1 - i]
    return out + b


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int | None = None,
                init_state: jax.Array | None = None,
                unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    """SSD over a full sequence.  x: (b,s,h,p), dt: (b,s,h) (post-softplus),
    A: (h,) negative, B,C: (b,s,n).  Returns (y (b,s,h,p), final state
    (b,h,p,n) f32).

    Thin consumer of the derived recurrence subsystem: folds dt into the
    input and the log decay, then hands the carried-state chunked scan to
    ``ops.scan_ssd`` (derived kernel on Pallas backends, chunked-jnp oracle
    on "xla" entries and in the VJP).  ``chunk=None`` lets
    ``solve_recurrence_blocks`` choose the chunk length.
    """
    xf = (x * dt[..., None]).astype(jnp.float32)         # fold dt into x
    dA = (dt * A).astype(jnp.float32)                    # (b,s,h) log decay
    return ops.scan_ssd(xf, dA, B.astype(jnp.float32),
                        C.astype(jnp.float32), init_state=init_state,
                        chunk=chunk, unroll=bool(unroll))


def apply_mamba2(p: dict, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, SSMCache]:
    """Full-sequence Mamba-2 block.  Returns output and final cache."""
    b, s, d = x.shape
    din, h, n = d_inner(cfg), n_ssd_heads(cfg), cfg.ssm_state
    hp = cfg.ssm_head_dim
    zxbcdt = ops.matmul(x, p["w_in"], out_dtype=x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xbc = jax.nn.silu(xbc)
    xs, B, C = jnp.split(xbc, [din, din + n], axis=-1)
    xs = constrain(xs, "batch", None, "d_inner")
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, s, h, hp)
    y, final = ssd_chunked(xh, dtv, A, B, C,
                           min(cfg.ssm_chunk, s) if cfg.ssm_chunk else None,
                           unroll=bool(cfg.scan_unroll))
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, din).astype(x.dtype)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = ops.matmul(y, p["w_out"], out_dtype=x.dtype)
    # cache: last conv_width-1 pre-conv inputs + final state
    pre = ops.matmul(x[:, -(cfg.conv_width - 1):], p["w_in"],
                     out_dtype=x.dtype)
    conv_tail = pre[..., din:2 * din + 2 * n]
    return out, SSMCache(conv=conv_tail, state=final)


def decode_mamba2(p: dict, x: jax.Array, cache: SSMCache, cfg: ArchConfig
                  ) -> tuple[jax.Array, SSMCache]:
    """One-token recurrent step.  x: (B,1,d)."""
    b, _, d = x.shape
    din, h, n = d_inner(cfg), n_ssd_heads(cfg), cfg.ssm_state
    hp = cfg.ssm_head_dim
    zxbcdt = ops.matmul(x, p["w_in"], out_dtype=x.dtype)
    z, xbc_new, dt = jnp.split(zxbcdt[:, 0], [din, 2 * din + 2 * n], axis=-1)
    # conv over (cached W-1 inputs, new input)
    hist = jnp.concatenate([cache.conv, xbc_new[:, None]], axis=1)  # (B,W,C)
    w = p["conv_w"].astype(x.dtype)
    xbc = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(xbc)
    xs, B, C = jnp.split(xbc, [din, din + n], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(b, h, hp).astype(jnp.float32)
    dA = jnp.exp(dtv * A)                                   # (b,h)
    Bx = jnp.einsum("bhp,bn->bhpn", xh * dtv[..., None], B.astype(jnp.float32))
    state = dA[..., None, None] * cache.state + Bx
    y = jnp.einsum("bhpn,bn->bhp", state, C.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, din).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = ops.matmul(y, p["w_out"], out_dtype=x.dtype)[:, None]
    new_conv = hist[:, 1:]
    return out, SSMCache(conv=new_conv, state=state)


def default_ssd_chunk(cfg: ArchConfig) -> int:
    """.. deprecated:: the chunk length is now derived by
    ``solve_recurrence_blocks`` (see ``ops.default_ssd_chunk``) with the
    carried state and chunk intermediates in the VMEM working-set model;
    this config-front wrapper is kept for one release."""
    return ops.default_ssd_chunk(4096, n_ssd_heads(cfg),
                                 cfg.ssm_head_dim, cfg.ssm_state)
