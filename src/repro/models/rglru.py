"""RG-LRU recurrent block (Griffin / RecurrentGemma).  [arXiv:2402.19427]

Recurrence (elementwise over the lru_width channels, f32):

    r_t = sigmoid(W_a x_t)            recurrence gate
    i_t = sigmoid(W_x x_t)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training hands the scan to the derived carried-state recurrence subsystem
(``ops.gated_scan``: the chunked kernel from ``expr.rglru_form`` on Pallas
backends, the log-depth associative-scan oracle on "xla" entries — the
latter is what makes the 512k-token long-context cell tractable); decode is
the single step.  The full block is: (x-branch: linear -> causal conv(4) ->
RG-LRU) gated by (gate-branch: linear -> gelu), then an output projection.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.common import ArchConfig, Collector

_C = 8.0


def lru_width(cfg: ArchConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init_rglru(col: Collector, path: str, cfg: ArchConfig,
               stack: tuple[tuple[int, str], ...] = ()):
    d, w = cfg.d_model, lru_width(cfg)
    lead = tuple(s for s, _ in stack)
    laxes = tuple(a for _, a in stack)
    col.param(f"{path}/w_x", lead + (d, w), laxes + ("d_model", "lru"), scale=d ** -0.5)
    col.param(f"{path}/w_gate", lead + (d, w), laxes + ("d_model", "lru"), scale=d ** -0.5)
    col.param(f"{path}/conv_w", lead + (cfg.conv_width, w), laxes + (None, "lru"),
              scale=cfg.conv_width ** -0.5)
    col.param(f"{path}/conv_b", lead + (w,), laxes + ("lru",), init="zeros")
    col.param(f"{path}/wa", lead + (w, w), laxes + (None, "lru"), scale=w ** -0.5)
    col.param(f"{path}/wi", lead + (w, w), laxes + (None, "lru"), scale=w ** -0.5)
    col.param(f"{path}/ba", lead + (w,), laxes + ("lru",), init="zeros")
    col.param(f"{path}/bi", lead + (w,), laxes + ("lru",), init="zeros")
    col.param(f"{path}/lam", lead + (w,), laxes + ("lru",), init="ones")
    col.param(f"{path}/w_out", lead + (w, d), laxes + ("lru", "d_model"),
              scale=w ** -0.5)


class RGLRUCache(NamedTuple):
    h: jax.Array          # (B, lru) f32 recurrent state
    conv: jax.Array       # (B, conv_width-1, lru) conv history


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> RGLRUCache:
    w = lru_width(cfg)
    return RGLRUCache(h=jnp.zeros((batch, w), jnp.float32),
                      conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    wwidth = w.shape[0]
    out = x * w[-1]
    for i in range(1, wwidth):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[wwidth - 1 - i]
    return out + b


def _gates(p: dict, xc: jax.Array):
    """Returns ``(log_a, b)`` — the gate *log* (the scan entries cumsum it
    stably in-chunk) and the gated input."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["wa"],
                                  preferred_element_type=jnp.float32)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["wi"],
                                  preferred_element_type=jnp.float32)
                       + p["bi"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return log_a, mult * i * xc.astype(jnp.float32)


def apply_rglru(p: dict, x: jax.Array, cfg: ArchConfig
                ) -> tuple[jax.Array, RGLRUCache]:
    """Full-sequence block.  x: (B,S,d).  The recurrence itself is the
    derived ``gated`` carried-state scan (``ops.gated_scan``) — this module
    hand-rolls no scan loop."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate"],
                      preferred_element_type=jnp.float32)
    xc = _causal_conv(xb, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xc = constrain(xc, "batch", None, "lru")
    log_a, b_in = _gates(p, xc)
    h, h_last = ops.gated_scan(log_a, b_in)
    y = (h * jax.nn.gelu(gate, approximate=True)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    cache = RGLRUCache(h=h_last, conv=xb[:, -(cfg.conv_width - 1):])
    return out, cache


def decode_rglru(p: dict, x: jax.Array, cache: RGLRUCache, cfg: ArchConfig
                 ) -> tuple[jax.Array, RGLRUCache]:
    """One-token step.  x: (B,1,d)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate"],
                      preferred_element_type=jnp.float32)
    hist = jnp.concatenate([cache.conv, xb], axis=1)         # (B,W,lru)
    w = p["conv_w"].astype(x.dtype)
    xc = (jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(x.dtype))[:, None]
    log_a, b_in = _gates(p, xc)
    h = jnp.exp(log_a[:, 0]) * cache.h + b_in[:, 0]
    y = (h[:, None] * jax.nn.gelu(gate, approximate=True)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, RGLRUCache(h=h, conv=hist[:, 1:])
