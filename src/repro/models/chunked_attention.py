"""Chunked (flash-style) attention: online softmax over K/V chunks, scanned
over Q chunks — never materializes the (S_q x S_k) score matrix.

This is the same dimension lifting as the GEMM kernel applied to attention:
``S_q -> (q_chunks, Qc)`` and ``S_k -> (k_chunks, Kc)`` with the softmax
turned into a streaming reduction (running max m, denominator l).  The
Pallas TPU kernel in ``repro.kernels.flash_attention`` implements the same
schedule with explicit VMEM BlockSpecs; this jnp version is the XLA path the
dry-run lowers (and the kernel's oracle).

Supports: causal masking with arbitrary query offset, local windows,
bidirectional prefix (PaLI), GQA grouping (never repeats K/V heads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.semiring import MASK_NEG_INF as NEG_INF


def _chunk_mask(qpos: jax.Array, kpos: jax.Array, *, causal: bool,
                window: int, prefix_len: int) -> jax.Array:
    """(Qc, Kc) mask from absolute positions.

    ``window`` and ``prefix_len`` are defined relative to the causal
    diagonal; with ``causal=False`` they have no meaning here, and silently
    returning the full bidirectional mask would turn windowed attention
    into full attention — raise instead of mis-masking.
    """
    if not causal and (window > 0 or prefix_len > 0):
        raise ValueError(
            f"window={window} / prefix_len={prefix_len} require causal "
            "attention: non-causal windowed/prefix masking is not defined "
            "here, and ignoring them would silently attend to everything")
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m = kpos[None, :] <= qpos[:, None]
        if window > 0:
            m &= kpos[None, :] > (qpos[:, None] - window)
        if prefix_len > 0:
            m |= (qpos[:, None] < prefix_len) & (kpos[None, :] < prefix_len)
    return m


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      scale: float, causal: bool = True, window: int = 0,
                      prefix_len: int = 0, q_chunk: int = 1024,
                      k_chunk: int = 1024, remat_kstep: bool = False) -> jax.Array:
    """q: (B, Sq, KV, G, hd); k/v: (B, Sk, KV, hd) -> (B, Sq, KV*G, hd).

    Sq/Sk are padded internally to chunk multiples; positions are absolute
    (q at offset 0 — full-sequence forward/prefill use).
    """
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    vd = v.shape[-1]                 # may differ from hd (MLA latent values)
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    pad_q = (-sq) % qc
    pad_k = (-sk) % kc
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (sq + pad_q) // qc, (sk + pad_k) // kc

    kp = kp.reshape(b, nk, kc, kvh, hd)
    vp = vp.reshape(b, nk, kc, kvh, vd)

    def q_block(qi, q_blk):
        qpos = qi * qc + jnp.arange(qc)

        def k_step(carry, kin):
            m_run, l_run, acc = carry
            ki, k_blk, v_blk = kin
            kpos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = _chunk_mask(qpos, kpos, causal=causal, window=window,
                               prefix_len=prefix_len)
            mask &= (kpos < sk)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, vd), jnp.float32)
        # remat the k-step: the backward pass recomputes each chunk's
        # probabilities instead of saving nk of them (the dominant training
        # temp once layers themselves are rematted)
        step = jax.checkpoint(k_step) if remat_kstep else k_step
        (m_f, l_f, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (jnp.arange(nk), kp.transpose(1, 0, 2, 3, 4),
             vp.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l_f[..., None], 1e-30)
        return out.astype(q.dtype)                    # (b, kv, g, qc, hd)

    qp = qp.reshape(b, nq, qc, kvh, g, hd)
    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), qp.transpose(1, 0, 2, 3, 4, 5)))
    # outs: (nq, b, kv, g, qc, vd) -> (b, nq*qc, kv*g, vd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qc, kvh * g, vd)
    return out[:, :sq]


def chunked_attention_ref(q, k, v, *, scale, causal=True, window=0,
                          prefix_len=0):
    """Unchunked oracle (same signature, materializes scores)."""
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = _chunk_mask(jnp.arange(sq), jnp.arange(sk), causal=causal,
                       window=window, prefix_len=prefix_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return out.reshape(b, sq, kvh * g, v.shape[-1])
