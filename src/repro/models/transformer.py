"""Decoder-LM assembly for all families except enc-dec (see encdec.py).

Layer stacks are *scanned* (params stacked on a leading "layers" axis) so the
HLO stays O(1) in depth; hybrids scan over repeating layer *groups*
(dimension lifting of the layer axis: L -> (groups, pattern)).  Bodies are
rematerialized (``jax.checkpoint``) and layer-boundary activations carry a
sequence-parallel sharding constraint so saved activations shard over the
"model" axis too.

Entry points (used by train/serve steps and the dry-run):

    init_lm(cfg, key)                       -> (params, logical_axes)
    forward(params, cfg, tokens, patches)   -> (hidden, aux)       train fwd
    prefill(params, cfg, tokens, patches)   -> (logits, cache)
    init_cache(cfg, batch, cache_len)       -> cache pytree
    decode_step(params, cfg, tokens, pos, cache) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ArchConfig, Collector
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens, init_embed,
                                 init_mlp, init_norm, logits_from_hidden)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack(n: int) -> tuple[tuple[int, str], ...]:
    return ((n, "layers"),)


def _scan(cfg: ArchConfig, f, init, xs):
    """lax.scan honoring cfg.scan_unroll (the dry-run cost extraction
    unrolls bodies so XLA cost_analysis counts every layer)."""
    return jax.lax.scan(f, init, xs, unroll=bool(cfg.scan_unroll))


def init_lm(cfg: ArchConfig, key: jax.Array) -> tuple[dict, dict]:
    col = Collector(key, dtype=jnp.dtype(cfg.dtype))
    init_embed(col, cfg)
    init_norm(col, "final_norm", cfg.d_model, cfg)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        L = cfg.n_layers
        init_norm(col, "layers/ln1", cfg.d_model, cfg, _stack(L))
        if not cfg.parallel_block:
            init_norm(col, "layers/ln2", cfg.d_model, cfg, _stack(L))
        if cfg.attention == "mla":
            attn.init_mla(col, "layers/attn", cfg, _stack(L))
        else:
            attn.init_attention(col, "layers/attn", cfg, _stack(L))
        init_mlp(col, "layers/mlp", cfg, stack=_stack(L))
        if fam == "vlm":
            col.param("frontend/adapter", (cfg.d_model, cfg.d_model),
                      ("d_model", None), scale=cfg.d_model ** -0.5)
    elif fam == "moe":
        if cfg.layer_pattern:                      # llama4: groups of 4 attn
            g = cfg.n_layers // len(cfg.layer_pattern)
            pat = len(cfg.layer_pattern)
            st = ((g, "layers"), (pat, None))
            init_norm(col, "groups/ln1", cfg.d_model, cfg, st)
            init_norm(col, "groups/ln2", cfg.d_model, cfg, st)
            attn.init_attention(col, "groups/attn", cfg, st)
            moe_mod.init_moe(col, "groups/moe", cfg, st)
        else:                                       # deepseek: dense first
            nd = cfg.first_dense_layers
            if nd:
                init_norm(col, "dense_layers/ln1", cfg.d_model, cfg, _stack(nd))
                init_norm(col, "dense_layers/ln2", cfg.d_model, cfg, _stack(nd))
                attn.init_attention(col, "dense_layers/attn", cfg, _stack(nd))
                init_mlp(col, "dense_layers/mlp", cfg, stack=_stack(nd))
            L = cfg.n_layers - nd
            init_norm(col, "layers/ln1", cfg.d_model, cfg, _stack(L))
            init_norm(col, "layers/ln2", cfg.d_model, cfg, _stack(L))
            attn.init_attention(col, "layers/attn", cfg, _stack(L))
            moe_mod.init_moe(col, "layers/moe", cfg, _stack(L))
    elif fam == "ssm":
        L = cfg.n_layers
        init_norm(col, "layers/ln1", cfg.d_model, cfg, _stack(L))
        ssm_mod.init_mamba2(col, "layers/mixer", cfg, _stack(L))
    elif fam == "hybrid":
        pat = cfg.layer_pattern                     # e.g. (rglru, rglru, local)
        g = cfg.n_layers // len(pat)
        tail = cfg.n_layers - g * len(pat)
        n_rec = sum(1 for p in pat if p == "rglru")
        n_att = len(pat) - n_rec
        init_norm(col, "groups/rec_ln1", cfg.d_model, cfg, ((g, "layers"), (n_rec, None)))
        init_norm(col, "groups/rec_ln2", cfg.d_model, cfg, ((g, "layers"), (n_rec, None)))
        rglru_mod.init_rglru(col, "groups/rec", cfg, ((g, "layers"), (n_rec, None)))
        init_mlp(col, "groups/rec_mlp", cfg, stack=((g, "layers"), (n_rec, None)))
        init_norm(col, "groups/att_ln1", cfg.d_model, cfg, ((g, "layers"), (n_att, None)))
        init_norm(col, "groups/att_ln2", cfg.d_model, cfg, ((g, "layers"), (n_att, None)))
        attn.init_attention(col, "groups/att", cfg, ((g, "layers"), (n_att, None)))
        init_mlp(col, "groups/att_mlp", cfg, stack=((g, "layers"), (n_att, None)))
        if tail:
            init_norm(col, "tail/ln1", cfg.d_model, cfg, _stack(tail))
            init_norm(col, "tail/ln2", cfg.d_model, cfg, _stack(tail))
            rglru_mod.init_rglru(col, "tail/rec", cfg, _stack(tail))
            init_mlp(col, "tail/mlp", cfg, stack=_stack(tail))
    else:
        raise ValueError(f"init_lm does not handle family {fam!r}")
    return col.done()


# ---------------------------------------------------------------------------
# layer bodies (full-sequence)
# ---------------------------------------------------------------------------

class Aux(NamedTuple):
    moe_aux: jax.Array
    moe_z: jax.Array
    dropped: jax.Array

    @staticmethod
    def zero() -> "Aux":
        z = jnp.zeros((), jnp.float32)
        return Aux(z, z, z)

    def __add__(self, o: "Aux") -> "Aux":
        return Aux(self.moe_aux + o.moe_aux, self.moe_z + o.moe_z,
                   self.dropped + o.dropped)


def _dense_block(lp: dict, x: jax.Array, cfg: ArchConfig, positions,
                 window: int = 0, prefix_len: int = 0):
    h = apply_norm(lp["ln1"], x, cfg)
    if cfg.attention == "mla":
        a_out, kv = attn.mla_fwd(lp["attn"], h, cfg, positions=positions)
    else:
        a_out, kv = attn.attention_fwd(lp["attn"], h, cfg, positions=positions,
                                       window=window, prefix_len=prefix_len)
    if cfg.parallel_block:
        m_out = apply_mlp(lp["mlp"], h, cfg)
        x = x + a_out + m_out
    else:
        x = x + a_out
        h2 = apply_norm(lp["ln2"], x, cfg)
        x = x + apply_mlp(lp["mlp"], h2, cfg)
    x = constrain(x, "batch", "seq_sp", None)
    return x, kv


def _moe_block(lp: dict, x: jax.Array, cfg: ArchConfig, positions,
               window: int = 0):
    h = apply_norm(lp["ln1"], x, cfg)
    a_out, kv = attn.attention_fwd(lp["attn"], h, cfg, positions=positions,
                                   window=window)
    x = x + a_out
    h2 = apply_norm(lp["ln2"], x, cfg)
    m_out, stats = moe_mod.apply_moe(lp["moe"], h2, cfg)
    x = x + m_out
    x = constrain(x, "batch", "seq_sp", None)
    return x, kv, Aux(stats.aux_loss, stats.z_loss, stats.dropped_frac)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill) — returns caches per layer
# ---------------------------------------------------------------------------

def forward(params: dict, cfg: ArchConfig, tokens: jax.Array,
            patches: Optional[jax.Array] = None
            ) -> tuple[jax.Array, Any, Aux]:
    """Returns (hidden (B,S,d), cache pytree (stacked per layer), aux)."""
    x = embed_tokens(params, tokens, cfg)
    prefix_len = 0
    if cfg.family == "vlm":
        assert patches is not None
        pe = jnp.einsum("bpd,de->bpe", patches.astype(x.dtype),
                        params["frontend"]["adapter"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        prefix_len = patches.shape[1]
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    if not cfg.remat:
        rm = lambda f: f
    elif cfg.remat_policy == "dots":
        rm = functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        rm = jax.checkpoint
    fam = cfg.family
    aux = Aux.zero()

    if fam in ("dense", "vlm"):
        @rm
        def body(xc, lp):
            return _dense_block(lp, xc, cfg, positions, cfg.local_window,
                                prefix_len)
        x, kvs = _scan(cfg, lambda xc, lp: body(xc, lp), x, params["layers"])
        cache = kvs
    elif fam == "moe" and cfg.layer_pattern:
        pat = cfg.layer_pattern

        @rm
        def body(xc, lp):
            kvs, auxes = [], Aux.zero()
            for i, kind in enumerate(pat):
                sub = jax.tree.map(lambda t: t[i], lp)
                win = cfg.local_window if kind == "local" else 0
                # nested remat per sublayer: the group body unrolls
                # len(pattern) layers — without this all their backward
                # transients are live at once
                blk = (jax.checkpoint(_moe_block, static_argnums=(2, 4))
                       if cfg.remat else _moe_block)
                xc, kv, a = blk(sub, xc, cfg, positions, win)
                kvs.append(kv)
                auxes = auxes + a
            return xc, (jax.tree.map(lambda *t: jnp.stack(t), *kvs), auxes)
        x, (kvs, auxes) = _scan(cfg, body, x, params["groups"])
        aux = Aux(auxes.moe_aux.sum(), auxes.moe_z.sum(), auxes.dropped.mean())
        cache = kvs
    elif fam == "moe":
        dense_kvs = []
        if cfg.first_dense_layers:
            for i in range(cfg.first_dense_layers):
                lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
                x, kv = _dense_block(lp, x, cfg, positions)
                dense_kvs.append(kv)

        @rm
        def body(xc, lp):
            xc, kv, a = _moe_block(lp, xc, cfg, positions)
            return xc, (kv, a)
        x, (kvs, auxes) = _scan(cfg, body, x, params["layers"])
        aux = Aux(auxes.moe_aux.sum(), auxes.moe_z.sum(), auxes.dropped.mean())
        cache = {"moe": kvs}
        if dense_kvs:
            cache["dense"] = jax.tree.map(lambda *t: jnp.stack(t), *dense_kvs)
    elif fam == "ssm":
        @rm
        def body(xc, lp):
            h = apply_norm(lp["ln1"], xc, cfg)
            out, c = ssm_mod.apply_mamba2(lp["mixer"], h, cfg)
            xc = constrain(xc + out, "batch", "seq_sp", None)
            return xc, c
        x, cache = _scan(cfg, body, x, params["layers"])
    elif fam == "hybrid":
        pat = cfg.layer_pattern

        @rm
        def body(xc, lp):
            rec_caches, att_caches = [], []
            ri, ai = 0, 0
            for kind in pat:
                if kind == "rglru":
                    sub = jax.tree.map(lambda t: t[ri], {
                        "ln1": lp["rec_ln1"], "ln2": lp["rec_ln2"],
                        "rec": lp["rec"], "mlp": lp["rec_mlp"]})
                    h = apply_norm(sub["ln1"], xc, cfg)
                    out, c = rglru_mod.apply_rglru(sub["rec"], h, cfg)
                    xc = xc + out
                    h2 = apply_norm(sub["ln2"], xc, cfg)
                    xc = xc + apply_mlp(sub["mlp"], h2, cfg)
                    rec_caches.append(c)
                    ri += 1
                else:
                    sub = jax.tree.map(lambda t: t[ai], {
                        "ln1": lp["att_ln1"], "ln2": lp["att_ln2"],
                        "attn": lp["att"], "mlp": lp["att_mlp"]})
                    h = apply_norm(sub["ln1"], xc, cfg)
                    a_out, kv = attn.attention_fwd(
                        sub["attn"], h, cfg, positions=positions,
                        window=cfg.local_window)
                    xc = xc + a_out
                    h2 = apply_norm(sub["ln2"], xc, cfg)
                    xc = xc + apply_mlp(sub["mlp"], h2, cfg)
                    att_caches.append(kv)
                    ai += 1
                xc = constrain(xc, "batch", "seq_sp", None)
            rc = jax.tree.map(lambda *t: jnp.stack(t), *rec_caches)
            ac = jax.tree.map(lambda *t: jnp.stack(t), *att_caches)
            return xc, (rc, ac)
        x, (rec_c, att_c) = _scan(cfg, body, x, params["groups"])
        tail_caches = []
        if "tail" in params:
            nt = params["tail"]["ln1"]["scale"].shape[0]
            for i in range(nt):
                lp = jax.tree.map(lambda t: t[i], params["tail"])
                h = apply_norm(lp["ln1"], x, cfg)
                out, c = rglru_mod.apply_rglru(lp["rec"], h, cfg)
                x = x + out
                h2 = apply_norm(lp["ln2"], x, cfg)
                x = x + apply_mlp(lp["mlp"], h2, cfg)
                tail_caches.append(c)
        cache = {"rec": rec_c, "att": att_c}
        if tail_caches:
            cache["tail"] = jax.tree.map(lambda *t: jnp.stack(t), *tail_caches)
    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg)
    return x, cache, aux


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def _kv_cache(shape_lead: tuple[int, ...], b: int, s: int, kv: int, hd: int,
              dtype) -> attn.KV:
    return attn.KV(k=jnp.zeros(shape_lead + (b, s, kv, hd), dtype),
                   v=jnp.zeros(shape_lead + (b, s, kv, hd), dtype))


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode-cache pytree.  Windowed layers get RING caches of length
    min(window, cache_len); full-attention layers get full-length caches."""
    hd, kv = cfg.head_dim_, cfg.n_kv_heads
    fam = cfg.family
    wlen = min(cfg.local_window, cache_len) if cfg.local_window else cache_len
    if fam in ("dense", "vlm"):
        if cfg.attention == "mla":
            _, kvr, _, rope, _ = attn.MLA_DIMS
            return {"layers": attn.MLACache(
                c_kv=jnp.zeros((cfg.n_layers, batch, cache_len, kvr), dtype),
                k_pe=jnp.zeros((cfg.n_layers, batch, cache_len, rope), dtype))}
        return {"layers": _kv_cache((cfg.n_layers,), batch, cache_len, kv, hd, dtype)}
    if fam == "moe" and cfg.layer_pattern:
        pat = cfg.layer_pattern
        g = cfg.n_layers // len(pat)
        nl = sum(1 for p in pat if p == "local")
        nf = len(pat) - nl
        return {"local": _kv_cache((g, nl), batch, wlen, kv, hd, dtype),
                "full": _kv_cache((g, nf), batch, cache_len, kv, hd, dtype)}
    if fam == "moe":
        nd = cfg.first_dense_layers
        out = {"moe": _kv_cache((cfg.n_layers - nd,), batch, cache_len, kv, hd, dtype)}
        if nd:
            out["dense"] = _kv_cache((nd,), batch, cache_len, kv, hd, dtype)
        return out
    if fam == "ssm":
        c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        return {"layers": jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (cfg.n_layers,) + t.shape), c)}
    if fam == "hybrid":
        pat = cfg.layer_pattern
        g = cfg.n_layers // len(pat)
        tail = cfg.n_layers - g * len(pat)
        n_rec = sum(1 for p in pat if p == "rglru")
        n_att = len(pat) - n_rec
        rc = rglru_mod.init_rglru_cache(cfg, batch, dtype)
        out = {
            "rec": jax.tree.map(
                lambda t: jnp.broadcast_to(t[None, None], (g, n_rec) + t.shape), rc),
            "att": _kv_cache((g, n_att), batch, wlen, kv, hd, dtype),
        }
        if tail:
            out["tail"] = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (tail,) + t.shape), rc)
        return out
    raise ValueError(fam)


def _dense_decode_block(lp: dict, x: jax.Array, kvc, pos, cfg: ArchConfig,
                        window: int = 0, ring: bool = False):
    h = apply_norm(lp["ln1"], x, cfg)
    if cfg.attention == "mla":
        a_out, kvc = attn.mla_decode(lp["attn"], h, kvc, pos, cfg)
    elif ring:
        a_out, kvc = attn.attention_decode_ring(lp["attn"], h, kvc, pos, cfg)
    else:
        a_out, kvc = attn.attention_decode(lp["attn"], h, kvc, pos, cfg,
                                           window=window)
    if cfg.parallel_block:
        m_out = apply_mlp(lp["mlp"], h, cfg)
        x = x + a_out + m_out
    else:
        x = x + a_out
        h2 = apply_norm(lp["ln2"], x, cfg)
        x = x + apply_mlp(lp["mlp"], h2, cfg)
    return x, kvc


def decode_step(params: dict, cfg: ArchConfig, tokens: jax.Array,
                pos: jax.Array, cache: dict) -> tuple[jax.Array, dict]:
    """One decode step.  tokens: (B,) int32; pos: (B,) absolute positions.
    Returns (logits (B, vocab), new cache)."""
    x = embed_tokens(params, tokens[:, None], cfg)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        ring = bool(cfg.local_window)

        def body(xc, scan_in):
            lp, kvc = scan_in
            xc, kvc = _dense_decode_block(lp, xc, kvc, pos, cfg,
                                          window=cfg.local_window, ring=ring)
            return xc, kvc
        x, new_kv = _scan(cfg, body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_kv}
    elif fam == "moe" and cfg.layer_pattern:
        pat = cfg.layer_pattern

        def body(xc, scan_in):
            lp, cl, cf = scan_in
            li, fi = 0, 0
            new_l, new_f = [], []
            for i, kind in enumerate(pat):
                sub = jax.tree.map(lambda t: t[i], lp)
                h = apply_norm(sub["ln1"], xc, cfg)
                if kind == "local":
                    kvc = jax.tree.map(lambda t: t[li], cl)
                    a_out, kvc = attn.attention_decode_ring(sub["attn"], h,
                                                            kvc, pos, cfg)
                    new_l.append(kvc)
                    li += 1
                else:
                    kvc = jax.tree.map(lambda t: t[fi], cf)
                    a_out, kvc = attn.attention_decode(sub["attn"], h, kvc,
                                                       pos, cfg)
                    new_f.append(kvc)
                    fi += 1
                xc = xc + a_out
                h2 = apply_norm(sub["ln2"], xc, cfg)
                m_out, _ = moe_mod.apply_moe(sub["moe"], h2, cfg)
                xc = xc + m_out
            stk = lambda lst: jax.tree.map(lambda *t: jnp.stack(t), *lst)
            return xc, (stk(new_l), stk(new_f))
        x, (nl, nf) = _scan(
            cfg, body, x, (params["groups"], cache["local"], cache["full"]))
        new_cache = {"local": nl, "full": nf}
    elif fam == "moe":
        new_cache = {}
        if cfg.first_dense_layers:
            nd_kvs = []
            for i in range(cfg.first_dense_layers):
                lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
                kvc = jax.tree.map(lambda t: t[i], cache["dense"])
                x, kvc = _dense_decode_block(lp, x, kvc, pos, cfg)
                nd_kvs.append(kvc)
            new_cache["dense"] = jax.tree.map(lambda *t: jnp.stack(t), *nd_kvs)

        def body(xc, scan_in):
            lp, kvc = scan_in
            h = apply_norm(lp["ln1"], xc, cfg)
            a_out, kvc = attn.attention_decode(lp["attn"], h, kvc, pos, cfg)
            xc = xc + a_out
            h2 = apply_norm(lp["ln2"], xc, cfg)
            m_out, _ = moe_mod.apply_moe(lp["moe"], h2, cfg)
            return xc + m_out, kvc
        x, nm = _scan(cfg, body, x, (params["layers"], cache["moe"]))
        new_cache["moe"] = nm
    elif fam == "ssm":
        def body(xc, scan_in):
            lp, c = scan_in
            h = apply_norm(lp["ln1"], xc, cfg)
            out, c = ssm_mod.decode_mamba2(lp["mixer"], h, c, cfg)
            return xc + out, c
        x, nc = _scan(cfg, body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": nc}
    elif fam == "hybrid":
        pat = cfg.layer_pattern

        def body(xc, scan_in):
            lp, crec, catt = scan_in
            ri, ai = 0, 0
            new_r, new_a = [], []
            for kind in pat:
                if kind == "rglru":
                    sub = jax.tree.map(lambda t: t[ri], {
                        "ln1": lp["rec_ln1"], "ln2": lp["rec_ln2"],
                        "rec": lp["rec"], "mlp": lp["rec_mlp"]})
                    c = jax.tree.map(lambda t: t[ri], crec)
                    h = apply_norm(sub["ln1"], xc, cfg)
                    out, c = rglru_mod.decode_rglru(sub["rec"], h, c, cfg)
                    xc = xc + out
                    h2 = apply_norm(sub["ln2"], xc, cfg)
                    xc = xc + apply_mlp(sub["mlp"], h2, cfg)
                    new_r.append(c)
                    ri += 1
                else:
                    sub = jax.tree.map(lambda t: t[ai], {
                        "ln1": lp["att_ln1"], "ln2": lp["att_ln2"],
                        "attn": lp["att"], "mlp": lp["att_mlp"]})
                    c = jax.tree.map(lambda t: t[ai], catt)
                    h = apply_norm(sub["ln1"], xc, cfg)
                    a_out, c = attn.attention_decode_ring(sub["attn"], h, c,
                                                          pos, cfg)
                    xc = xc + a_out
                    h2 = apply_norm(sub["ln2"], xc, cfg)
                    xc = xc + apply_mlp(sub["mlp"], h2, cfg)
                    new_a.append(c)
                    ai += 1
            stk = lambda lst: jax.tree.map(lambda *t: jnp.stack(t), *lst)
            return xc, (stk(new_r), stk(new_a))
        x, (nr, na) = _scan(
            cfg, body, x, (params["groups"], cache["rec"], cache["att"]))
        new_cache = {"rec": nr, "att": na}
        if "tail" in cache:
            nt_list = []
            nt = params["tail"]["ln1"]["scale"].shape[0]
            for i in range(nt):
                lp = jax.tree.map(lambda t: t[i], params["tail"])
                c = jax.tree.map(lambda t: t[i], cache["tail"])
                h = apply_norm(lp["ln1"], x, cfg)
                out, c = rglru_mod.decode_rglru(lp["rec"], h, c, cfg)
                x = x + out
                h2 = apply_norm(lp["ln2"], x, cfg)
                x = x + apply_mlp(lp["mlp"], h2, cfg)
                nt_list.append(c)
            new_cache["tail"] = jax.tree.map(lambda *t: jnp.stack(t), *nt_list)
    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, x, cfg)[:, 0]
    return logits, new_cache


def prefill(params: dict, cfg: ArchConfig, tokens: jax.Array,
            patches: Optional[jax.Array] = None) -> tuple[jax.Array, Any]:
    """Full-prompt forward; returns (last-position logits (B, vocab), the
    per-layer cache in forward layout)."""
    hidden, cache, _ = forward(params, cfg, tokens, patches)
    logits = logits_from_hidden(params, hidden[:, -1:], cfg)[:, 0]
    return logits, cache


def has_prefill_decode_relayout(cfg: ArchConfig) -> bool:
    """True when ``prefill_cache_to_decode`` can re-lay this family's
    forward cache (the policy is config-only, so callers can decide
    before paying for the prefill pass)."""
    return ((cfg.family == "dense" and not cfg.local_window)
            or cfg.family == "ssm")


def prefill_cache_to_decode(cfg: ArchConfig, cache, cache_len: int):
    """Re-lay a forward-layout prefill cache as a decode cache.

    Returns None for families whose decode cache has no direct forward
    equivalent — ring caches (windowed dense), grouped layer patterns,
    hybrid stacks, vlm (prefill takes patches) — which must keep the
    token-by-token ingestion scan.  Dense full-attention KV/MLA caches pad
    the sequence axis out to ``cache_len`` (later positions are masked
    until written); ssm caches carry forward unchanged — the final state
    IS the decode state."""
    if cfg.family == "dense" and not cfg.local_window:
        def pad(t):
            return jnp.pad(t, [(0, 0), (0, 0),
                               (0, cache_len - t.shape[2])] +
                           [(0, 0)] * (t.ndim - 3))
        return {"layers": jax.tree.map(pad, cache)}
    if cfg.family == "ssm":
        return {"layers": cache}
    return None


def init_paged_pools(cfg: ArchConfig, pool_tokens: int,
                     dtype=jnp.float32) -> dict:
    """Per-layer stacked K/V slab pools for paged decode: one sequence's
    logical cache is a psi view over these, described by its page table
    (shared across layers — every layer writes the same positions)."""
    if cfg.family not in ("dense", "vlm") or cfg.attention == "mla":
        raise ValueError(
            f"paged pools cover dense/vlm GQA decode, not "
            f"family={cfg.family!r} attention={cfg.attention!r}")
    shape = (cfg.n_layers, pool_tokens, cfg.n_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step_paged(params: dict, cfg: ArchConfig, tokens: jax.Array,
                      pos: jax.Array, pools: dict, *, page_table: tuple,
                      page: int, interpret=None) -> tuple[jax.Array, dict]:
    """One decode step for ONE sequence through its paged KV view.

    tokens/pos: (1,) int32 (position is runtime data — one compiled program
    per page table, not per token).  ``page_table`` is static: it re-keys
    the derived decode kernel only when the engine allocates a page.
    Returns (logits (1, vocab), updated pools).
    """
    if cfg.family not in ("dense", "vlm") or cfg.attention == "mla":
        raise ValueError(f"decode_step_paged does not handle "
                         f"family={cfg.family!r}/{cfg.attention!r}")
    x = embed_tokens(params, tokens[:, None], cfg)

    def body(xc, scan_in):
        lp, kp, vp = scan_in
        h = apply_norm(lp["ln1"], xc, cfg)
        a_out, kp, vp = attn.attention_decode_paged(
            lp["attn"], h, kp, vp, pos, cfg, page_table=page_table,
            page=page, window=cfg.local_window, interpret=interpret)
        if cfg.parallel_block:
            m_out = apply_mlp(lp["mlp"], h, cfg)
            xc = xc + a_out + m_out
        else:
            xc = xc + a_out
            h2 = apply_norm(lp["ln2"], xc, cfg)
            xc = xc + apply_mlp(lp["mlp"], h2, cfg)
        return xc, (kp, vp)

    x, (nk, nv) = _scan(cfg, body, x, (params["layers"],
                                       pools["k"], pools["v"]))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, x, cfg)[:, 0]
    return logits, {"k": nk, "v": nv}


def decode_step_paged_batched(params: dict, cfg: ArchConfig,
                              tokens: jax.Array, pos: jax.Array,
                              pools: dict, *, page_tables: tuple, page: int,
                              interpret=None) -> tuple[jax.Array, dict]:
    """One decode step for EVERY serving slot through the stacked paged
    view — one derived kernel launch per layer covers all slots.

    tokens/pos: (slots,) int32; a dead (padded) slot carries pos -1 —
    its K/V write drops and no key folds, whatever its table row says —
    so slot-count changes re-key nothing.  ``page_tables`` is the static
    stacked ``[slot][k]`` map; it re-keys the derived kernel only when
    the engine allocates a page.  Returns (logits (slots, vocab),
    updated pools); dead rows are garbage the engine drops.
    """
    if cfg.family not in ("dense", "vlm") or cfg.attention == "mla":
        raise ValueError(f"decode_step_paged does not handle "
                         f"family={cfg.family!r}/{cfg.attention!r}")
    x = embed_tokens(params, tokens[:, None], cfg)

    def body(xc, scan_in):
        lp, kp, vp = scan_in
        h = apply_norm(lp["ln1"], xc, cfg)
        a_out, kp, vp = attn.attention_decode_paged_batched(
            lp["attn"], h, kp, vp, pos, cfg, page_tables=page_tables,
            page=page, window=cfg.local_window, interpret=interpret)
        if cfg.parallel_block:
            m_out = apply_mlp(lp["mlp"], h, cfg)
            xc = xc + a_out + m_out
        else:
            xc = xc + a_out
            h2 = apply_norm(lp["ln2"], xc, cfg)
            xc = xc + apply_mlp(lp["mlp"], h2, cfg)
        return xc, (kp, vp)

    x, (nk, nv) = _scan(cfg, body, x, (params["layers"],
                                       pools["k"], pools["v"]))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, x, cfg)[:, 0]
    return logits, {"k": nk, "v": nv}


def lm_loss(params: dict, cfg: ArchConfig, tokens: jax.Array,
            targets: jax.Array, patches: Optional[jax.Array] = None,
            aux_weight: float = 0.01, z_weight: float = 1e-3
            ) -> tuple[jax.Array, dict]:
    hidden, _, aux = forward(params, cfg, tokens, patches)
    if cfg.family == "vlm":                       # loss on text positions only
        hidden = hidden[:, patches.shape[1]:]
    logits = logits_from_hidden(params, hidden, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    total = loss + aux_weight * aux.moe_aux + z_weight * aux.moe_z
    return total, {"nll": loss, "moe_aux": aux.moe_aux, "moe_z": aux.moe_z,
                   "dropped": aux.dropped}
