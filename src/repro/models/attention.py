"""Attention variants: MHA / GQA / MQA, windowed (local), and MLA.

Grouped-query attention never materializes repeated K/V heads: queries are
reshaped to (kv_heads, group) and contracted against un-repeated K/V.

Decode paths take a KV cache of static length ``cache_len`` and per-row
positions; masking handles validity.  MLA decode uses the *absorbed* form
with the compressed latent cache (kv_rank + rope_dim per token), which is
the memory story that makes 32k x 128-batch decoding feasible.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.chunked_attention import chunked_attention
from repro.models.common import ArchConfig, Collector
from repro.models.layers import apply_rope, rope_tables
from repro.core.semiring import MASK_NEG_INF as NEG_INF


def _proj(x: jax.Array, w: jax.Array) -> jax.Array:
    '''bsd,d...->bs... through the unified MoA matmul entry.'''
    return ops.matmul(x, w, out_dtype=x.dtype)


def _out_proj(out: jax.Array, wo: jax.Array, out_dtype) -> jax.Array:
    '''bshk,hkd->bsd: collapse (heads, head_dim), one derived GEMM.'''
    b, s = out.shape[:2]
    return ops.matmul(out.reshape(b, s, -1),
                      wo.reshape(-1, wo.shape[-1]), out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(col: Collector, path: str, cfg: ArchConfig,
                   stack: tuple[tuple[int, str], ...] = (),
                   n_heads: Optional[int] = None,
                   n_kv_heads: Optional[int] = None):
    d, hd = cfg.d_model, cfg.head_dim_
    h = n_heads or cfg.n_heads
    kv = n_kv_heads or cfg.n_kv_heads
    lead = tuple(s for s, _ in stack)
    laxes = tuple(a for _, a in stack)
    col.param(f"{path}/wq", lead + (d, h, hd), laxes + ("d_model", "heads", None),
              scale=d ** -0.5)
    col.param(f"{path}/wk", lead + (d, kv, hd), laxes + ("d_model", "kv_heads", None),
              scale=d ** -0.5)
    col.param(f"{path}/wv", lead + (d, kv, hd), laxes + ("d_model", "kv_heads", None),
              scale=d ** -0.5)
    col.param(f"{path}/wo", lead + (h, hd, d), laxes + ("heads", None, "d_model"),
              scale=(h * hd) ** -0.5)
    if cfg.use_bias:
        col.param(f"{path}/bq", lead + (h, hd), laxes + ("heads", None), init="zeros")
        col.param(f"{path}/bk", lead + (kv, hd), laxes + ("kv_heads", None), init="zeros")
        col.param(f"{path}/bv", lead + (kv, hd), laxes + ("kv_heads", None), init="zeros")
        col.param(f"{path}/bo", lead + (d,), laxes + ("d_model",), init="zeros")


def init_mla(col: Collector, path: str, cfg: ArchConfig,
             stack: tuple[tuple[int, str], ...] = ()):
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr, nope, rope, vd = MLA_DIMS
    lead = tuple(s for s, _ in stack)
    laxes = tuple(a for _, a in stack)
    col.param(f"{path}/wq_a", lead + (d, qr), laxes + ("d_model", None), scale=d ** -0.5)
    col.param(f"{path}/q_norm", lead + (qr,), laxes + (None,), init="ones")
    col.param(f"{path}/wq_b", lead + (qr, h, nope + rope),
              laxes + (None, "heads", None), scale=qr ** -0.5)
    col.param(f"{path}/wkv_a", lead + (d, kvr + rope), laxes + ("d_model", None),
              scale=d ** -0.5)
    col.param(f"{path}/kv_norm", lead + (kvr,), laxes + (None,), init="ones")
    col.param(f"{path}/wkv_b", lead + (kvr, h, nope + vd),
              laxes + (None, "heads", None), scale=kvr ** -0.5)
    col.param(f"{path}/wo", lead + (h, vd, d), laxes + ("heads", None, "d_model"),
              scale=(h * vd) ** -0.5)


# MLA dims (MiniCPM3-4B): q_rank, kv_rank, qk_nope, qk_rope, v_head
MLA_DIMS = (768, 256, 64, 32, 64)


# ---------------------------------------------------------------------------
# core scores/combine (grouped, never repeats KV)
# ---------------------------------------------------------------------------

def _split_groups(q: jax.Array, kv_heads: int) -> jax.Array:
    b, s, h, hd = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, hd)


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
            scale: float) -> jax.Array:
    """q: (B,Sq,KV,G,hd); k/v: (B,Sk,KV,hd); mask: (B,1,1,Sq,Sk) or bcastable.
    Returns (B,Sq,KV*G,hd)."""
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    b, sq, kv, g, hd = out.shape
    return out.reshape(b, sq, kv * g, hd)


def _causal_mask(sq: int, sk: int, q_off: jax.Array | int = 0,
                 window: int = 0) -> jax.Array:
    """(sq, sk) boolean mask; query i at absolute pos q_off+i may see keys
    j <= pos, and > pos - window when window > 0."""
    qpos = jnp.arange(sq) + q_off
    kpos = jnp.arange(sk)
    m = kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


# ---------------------------------------------------------------------------
# train/prefill forward (full sequence) — returns per-layer K/V for caching
# ---------------------------------------------------------------------------

class KV(NamedTuple):
    k: jax.Array
    v: jax.Array


def attention_fwd(p: dict, x: jax.Array, cfg: ArchConfig, *,
                  positions: jax.Array, window: int = 0,
                  causal: bool = True, prefix_len: int = 0,
                  kv_override: Optional[KV] = None) -> tuple[jax.Array, KV]:
    """Full-sequence attention.  ``prefix_len``: leading positions attend
    bidirectionally (PaLI-style prefix-LM over image patches).  ``causal``
    False -> fully bidirectional (whisper encoder).  ``kv_override``: use
    given K/V (whisper cross-attention)."""
    if not causal and (window > 0 or prefix_len > 0):
        # mirror _chunk_mask's honor-or-raise contract on EVERY branch —
        # the dense (materialized) path used to silently attend to all keys
        raise ValueError(
            f"window={window} / prefix_len={prefix_len} require causal "
            "attention")
    b, s, d = x.shape
    hd = p["wq"].shape[-1]
    scale = hd ** -0.5
    q = _proj(x, p["wq"])
    q = constrain(q, "batch", "seq_sp", None, None) \
        if cfg.attn_sharding == "sp" else constrain(q, "batch", None, "heads", None)
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
    if kv_override is None:
        k = _proj(x, p["wk"])
        k = constrain(k, "batch", "seq_sp", None, None)
        v = _proj(x, p["wv"])
        v = constrain(v, "batch", "seq_sp", None, None)
        if cfg.use_bias:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        # RoPE is a property of the positions, not of the masking mode:
        # bidirectional/encoder passes with rope_pct > 0 get rotated too
        if cfg.rope_pct > 0:
            sin, cos = rope_tables(positions, int(hd * cfg.rope_pct), cfg.rope_theta)
            q = apply_rope(q, sin, cos, 1.0 if cfg.rope_pct == 1.0 else
                           (hd * cfg.rope_pct) / hd)
            k = apply_rope(k, sin, cos, 1.0 if cfg.rope_pct == 1.0 else
                           (hd * cfg.rope_pct) / hd)
    else:
        k, v = kv_override
    # sequence-parallel attention sharding: q/k/v and the output shard on the
    # seq axis (clean lifting even when kv_heads don't divide the model axis;
    # avoids SPMD involuntary remats on the grouped-head reshape).  "heads"
    # mode is the Megatron-style alternative.
    if cfg.attn_sharding == "sp":
        q = constrain(q, "batch", "seq_sp", None, None)
        k = constrain(k, "batch", "seq_sp", None, None)
        v = constrain(v, "batch", "seq_sp", None, None)
    else:
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, "kv_heads", None)
    kvh = k.shape[2]
    qg = _split_groups(q, kvh)
    sk = k.shape[1]
    if cfg.attn_impl == "pallas" and causal:
        # execution path: the flash kernel from the derived recurrent
        # schedule, via the ops-level wrapper whose pad/slice contract
        # accepts ANY sequence length (no silent jnp fallback off block
        # multiples; interpret-mode Pallas on CPU, oracle on "xla").
        # window/prefix_len ride the form as streamed-axis masking metadata
        # — windowed and prefix-LM causal shapes derive their schedules
        # (block-skip included) instead of falling back to the jnp path.
        out = ops.attention(qg, k, v, scale=scale, causal=True,
                            window=window, prefix_len=prefix_len)
    elif s >= cfg.attn_chunk_min_seq and causal:
        out = chunked_attention(qg, k, v, scale=scale, causal=True,
                                window=window, prefix_len=prefix_len,
                                q_chunk=cfg.attn_q_chunk or s,
                                k_chunk=cfg.attn_chunk)
    else:
        if causal:
            m = _causal_mask(s, sk, 0, window)
            if prefix_len > 0:
                bidir = (jnp.arange(s)[:, None] < prefix_len) & \
                        (jnp.arange(sk)[None, :] < prefix_len)
                m = m | bidir
            mask = m[None, None, None]
        else:
            mask = jnp.ones((1, 1, 1, s, sk), bool)
        out = _attend(qg, k, v, mask, scale)
    if cfg.attn_sharding == "sp":
        out = constrain(out, "batch", "seq_sp", None, None)
    else:
        out = constrain(out, "batch", None, "heads", None)
    o = _out_proj(out, p["wo"], x.dtype)
    o = constrain(o, "batch", "seq_sp", None)
    if cfg.use_bias:
        o = o + p["bo"].astype(x.dtype)
    return o, KV(k, v)


def attention_decode(p: dict, x: jax.Array, cache: KV, pos: jax.Array,
                     cfg: ArchConfig, *, window: int = 0
                     ) -> tuple[jax.Array, KV]:
    """One-token decode.  x: (B,1,d); cache k/v: (B,cache_len,KV,hd);
    pos: (B,) absolute position of the new token."""
    b, _, d = x.shape
    hd = p["wq"].shape[-1]
    scale = hd ** -0.5
    q = _proj(x, p["wq"])
    k = _proj(x, p["wk"])
    v = _proj(x, p["wv"])
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.rope_pct > 0:
        sin, cos = rope_tables(pos[:, None], int(hd * cfg.rope_pct), cfg.rope_theta)
        pct = 1.0 if cfg.rope_pct == 1.0 else (hd * cfg.rope_pct) / hd
        q = apply_rope(q, sin, cos, pct)
        k = apply_rope(k, sin, cos, pct)
    # cache update at pos (per-row dynamic index via one-hot to stay static)
    ck = _cache_write(cache.k, k, pos)
    cv = _cache_write(cache.v, v, pos)
    kvh = ck.shape[2]
    qg = _split_groups(q, kvh)
    sk = ck.shape[1]
    kpos = jnp.arange(sk)
    valid = kpos[None, :] <= pos[:, None]
    if window > 0:
        valid &= kpos[None, :] > (pos[:, None] - window)
    mask = valid[:, None, None, None, :]
    out = _attend(qg, ck, cv, mask, scale)
    o = _out_proj(out, p["wo"], x.dtype)
    if cfg.use_bias:
        o = o + p["bo"].astype(x.dtype)
    return o, KV(ck, cv)


def attention_decode_ring(p: dict, x: jax.Array, cache: KV, pos: jax.Array,
                          cfg: ArchConfig) -> tuple[jax.Array, KV]:
    """One-token decode against a RING cache for windowed (local) attention.

    The cache holds exactly the last W tokens: slot j carries the key/value
    of absolute position  kpos_j = pos - ((pos - j) mod W)  (after the write
    at slot pos % W).  This keeps local-attention decode O(W) in both memory
    and compute — the property that makes the 500k-token cells tractable.
    """
    b, _, d = x.shape
    hd = p["wq"].shape[-1]
    scale = hd ** -0.5
    wlen = cache.k.shape[1]
    q = _proj(x, p["wq"])
    k = _proj(x, p["wk"])
    v = _proj(x, p["wv"])
    if cfg.rope_pct > 0:
        sin, cos = rope_tables(pos[:, None], int(hd * cfg.rope_pct), cfg.rope_theta)
        pct = 1.0 if cfg.rope_pct == 1.0 else (hd * cfg.rope_pct) / hd
        q = apply_rope(q, sin, cos, pct)
        k = apply_rope(k, sin, cos, pct)
    slot = pos % wlen
    ck = _cache_write(cache.k, k, slot)
    cv = _cache_write(cache.v, v, slot)
    j = jnp.arange(wlen)[None, :]
    kpos = pos[:, None] - ((pos[:, None] - j) % wlen)
    valid = kpos >= 0
    mask = valid[:, None, None, None, :]
    kvh = ck.shape[2]
    out = _attend(_split_groups(q, kvh), ck, cv, mask, scale)
    o = _out_proj(out, p["wo"], x.dtype)
    if cfg.use_bias:
        o = o + p["bo"].astype(x.dtype)
    return o, KV(ck, cv)


def attention_decode_paged(p: dict, x: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, pos: jax.Array,
                           cfg: ArchConfig, *, page_table: tuple, page: int,
                           window: int = 0, interpret=None
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a PAGED KV cache (one sequence).

    x: (1, 1, d); k_pool/v_pool: (pool_tokens, KV, hd) slab pools; pos: (1,)
    absolute position.  ``page_table`` (static) maps view page -> pool slab;
    the logical cache is the psi view the table describes and the kernel's
    BlockSpec index maps read through it — no gather-copy.  The view starts
    at token 0, so the view-relative position equals ``pos``; with a
    ``window`` the engine may retarget expired view pages at a recycled
    slab, because masking keeps everything outside the window inert.

    The new token's K/V land in the pool by slab arithmetic (a dynamic
    two-step psi index: table[pos // page] picks the slab, pos % page the
    row) — position is runtime data, so this stays one compiled program
    across tokens.  Returns ``(out (1, 1, d), k_pool, v_pool)``.
    """
    hd = p["wq"].shape[-1]
    scale = hd ** -0.5
    q = _proj(x, p["wq"])
    k = _proj(x, p["wk"])
    v = _proj(x, p["wv"])
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.rope_pct > 0:
        sin, cos = rope_tables(pos[:, None], int(hd * cfg.rope_pct),
                               cfg.rope_theta)
        pct = 1.0 if cfg.rope_pct == 1.0 else (hd * cfg.rope_pct) / hd
        q = apply_rope(q, sin, cos, pct)
        k = apply_rope(k, sin, cos, pct)
    table_arr = jnp.asarray(page_table, jnp.int32)
    vpos = pos[0]
    row = table_arr[vpos // page] * page + vpos % page
    k_pool = jax.lax.dynamic_update_slice(
        k_pool, k[0].astype(k_pool.dtype), (row, 0, 0))
    v_pool = jax.lax.dynamic_update_slice(
        v_pool, v[0].astype(v_pool.dtype), (row, 0, 0))
    kvh = k_pool.shape[1]
    h = q.shape[2]
    qg = q[0, 0].reshape(kvh, h // kvh, hd)
    pos_aux = jnp.stack([vpos.astype(jnp.int32), jnp.int32(0)])[None]
    ctx = ops.paged_decode(qg, k_pool, v_pool, pos_aux,
                           page_table=page_table, page=page, scale=scale,
                           window=window, interpret=interpret)
    out = ctx.reshape(1, 1, h, hd).astype(x.dtype)
    o = _out_proj(out, p["wo"], x.dtype)
    if cfg.use_bias:
        o = o + p["bo"].astype(x.dtype)
    return o, k_pool, v_pool


def attention_decode_paged_batched(p: dict, x: jax.Array,
                                   k_pool: jax.Array, v_pool: jax.Array,
                                   pos: jax.Array, cfg: ArchConfig, *,
                                   page_tables: tuple, page: int,
                                   window: int = 0, interpret=None
                                   ) -> tuple[jax.Array, jax.Array,
                                              jax.Array]:
    """One-token decode for EVERY serving slot against the shared slab
    pools — ``attention_decode_paged`` with the slot axis lifted.

    x: (slots, 1, d); pos: (slots,) absolute positions, -1 for a dead
    (padded) slot; ``page_tables`` is the STATIC stacked ``[slot][k]``
    view->slab map.  Each live slot's new K/V land by its own slab
    arithmetic (rows are disjoint across live slots — live tables never
    share a slab).  A dead slot is inert by runtime data, not by its
    table row: its K/V write is routed past the pool and dropped
    (``mode="drop"``), and POS -1 fails every block-skip guard so no key
    it can address ever folds — which is why dead rows may carry ANY
    in-pool entries (stale slabs of a retired slot included) without
    affecting a single live value.  One ``paged_decode_batched`` launch
    serves all slots."""
    hd = p["wq"].shape[-1]
    scale = hd ** -0.5
    q = _proj(x, p["wq"])
    k = _proj(x, p["wk"])
    v = _proj(x, p["wv"])
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.rope_pct > 0:
        sin, cos = rope_tables(pos[:, None], int(hd * cfg.rope_pct),
                               cfg.rope_theta)
        pct = 1.0 if cfg.rope_pct == 1.0 else (hd * cfg.rope_pct) / hd
        q = apply_rope(q, sin, cos, pct)
        k = apply_rope(k, sin, cos, pct)
    slots = x.shape[0]
    table_arr = jnp.asarray(page_tables, jnp.int32)     # (slots, view)
    vpos = pos.astype(jnp.int32)
    rows = table_arr[jnp.arange(slots), vpos // page] * page + vpos % page
    # dead slots (vpos -1) route their write past the pool; drop it there
    rows = jnp.where(vpos >= 0, rows, k_pool.shape[0])
    k_pool = k_pool.at[rows].set(k[:, 0].astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[rows].set(v[:, 0].astype(v_pool.dtype), mode="drop")
    kvh = k_pool.shape[1]
    h = q.shape[2]
    qg = q[:, 0].reshape(slots, kvh, h // kvh, hd)
    pos_aux = jnp.stack([vpos, jnp.zeros_like(vpos)], axis=-1)
    ctx = ops.paged_decode_batched(qg, k_pool, v_pool, pos_aux,
                                   page_tables=page_tables, page=page,
                                   scale=scale, window=window,
                                   interpret=interpret)
    out = ctx.reshape(slots, 1, h, hd).astype(x.dtype)
    o = _out_proj(out, p["wo"], x.dtype)
    if cfg.use_bias:
        o = o + p["bo"].astype(x.dtype)
    return o, k_pool, v_pool


def _cache_write(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write new (B,1,...) into cache (B,S,...) at per-row pos (B,)."""
    b, s = cache.shape[:2]
    oh = jax.nn.one_hot(pos, s, dtype=cache.dtype)          # (B,S)
    oh = oh.reshape(b, s, *([1] * (cache.ndim - 2)))
    return cache * (1 - oh) + new.astype(cache.dtype) * oh


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array       # (B, S, kv_rank)
    k_pe: jax.Array       # (B, S, rope_dim)


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def mla_fwd(p: dict, x: jax.Array, cfg: ArchConfig, *, positions: jax.Array
            ) -> tuple[jax.Array, MLACache]:
    """Full-sequence MLA (training/prefill): non-absorbed expansion."""
    qr, kvr, nope, rope, vd = MLA_DIMS
    b, s, d = x.shape
    h = cfg.n_heads
    scale = (nope + rope) ** -0.5
    cq = _rms(_proj(x, p["wq_a"]), p["q_norm"])
    q = _proj(cq, p["wq_b"])
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    kv_all = _proj(x, p["wkv_a"])
    c_kv = _rms(kv_all[..., :kvr], p["kv_norm"])
    k_pe = kv_all[..., kvr:]
    sin, cos = rope_tables(positions, rope, cfg.rope_theta)
    q_pe = apply_rope(q_pe, sin, cos)
    k_pe = apply_rope(k_pe[:, :, None, :], sin, cos)[:, :, 0, :]
    kv = _proj(c_kv, p["wkv_b"])
    k_nope, v = kv[..., :nope], kv[..., nope:]
    if s >= cfg.attn_chunk_min_seq:
        # chunked path: fold both score terms into one contraction —
        # q'' = [q_nope, q_pe], k'' = [k_nope, k_pe (broadcast over heads)]
        qq = jnp.concatenate([q_nope, q_pe], axis=-1)        # (b,s,h,nope+rope)
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                      k_pe.shape[:2] + (h, rope))], axis=-1)
        qq = constrain(qq, "batch", "seq_sp", None, None)
        kk = constrain(kk, "batch", "seq_sp", None, None)
        out = chunked_attention(qq.reshape(b, s, h, 1, nope + rope),
                                kk, v, scale=scale, causal=True,
                                q_chunk=cfg.attn_q_chunk or s,
                                k_chunk=cfg.attn_chunk)
        out = out.reshape(b, s, h, vd)
    else:
        sc = jnp.einsum("bqhn,bkhn->bhqk", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
        sp = jnp.einsum("bqhr,bkr->bhqk", q_pe, k_pe,
                        preferred_element_type=jnp.float32)
        scores = (sc + sp) * scale
        mask = _causal_mask(s, s)[None, None]
        scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhn->bqhn", w, v,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    o = _out_proj(out, p["wo"], x.dtype)
    return o, MLACache(c_kv, k_pe)


def mla_decode(p: dict, x: jax.Array, cache: MLACache, pos: jax.Array,
               cfg: ArchConfig) -> tuple[jax.Array, MLACache]:
    """Absorbed one-token MLA decode over the compressed latent cache."""
    qr, kvr, nope, rope, vd = MLA_DIMS
    b, _, d = x.shape
    h = cfg.n_heads
    scale = (nope + rope) ** -0.5
    cq = _rms(_proj(x, p["wq_a"]), p["q_norm"])
    q = _proj(cq, p["wq_b"])
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    kv_all = _proj(x, p["wkv_a"])
    c_new = _rms(kv_all[..., :kvr], p["kv_norm"])
    kpe_new = kv_all[..., kvr:]
    sin, cos = rope_tables(pos[:, None], rope, cfg.rope_theta)
    q_pe = apply_rope(q_pe, sin, cos)
    kpe_new = apply_rope(kpe_new[:, :, None, :], sin, cos)[:, :, 0, :]
    c_kv = _cache_write(cache.c_kv, c_new, pos)
    k_pe = _cache_write(cache.k_pe, kpe_new, pos)
    # absorb W_UK:  q_tilde[h] = q_nope[h] @ W_UK[:, h, :].T  -> latent
    # space.  The head axis batches independent GEMMs — one more dimension
    # lift, like the expert axis — and ops.head_matmul reads the
    # head-middle (kvr, h, nope) table in its STORED layout through the
    # derived batched-transpose_b schedule: no per-step weight relayout.
    w_uk = p["wkv_b"][..., :nope]                       # (kvr, h, nope)
    w_uv = p["wkv_b"][..., nope:]                       # (kvr, h, vd)
    q_lat = ops.head_matmul(q_nope, w_uk, transpose_b=True,
                            out_dtype=x.dtype)          # (b, s, h, kvr)
    sc = jnp.einsum("bshr,bkr->bhsk", q_lat, c_kv,
                    preferred_element_type=jnp.float32)
    sp = jnp.einsum("bshr,bkr->bhsk", q_pe, k_pe,
                    preferred_element_type=jnp.float32)
    skl = c_kv.shape[1]
    valid = jnp.arange(skl)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], (sc + sp) * scale, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhsk,bkr->bshr", w, c_kv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    # un-absorb W_UV: the bshr,rhn->bshn contraction is the same per-head
    # batched schedule (no einsum fallback, no relayout of w_uv)
    out = ops.head_matmul(ctx, w_uv, out_dtype=x.dtype)
    o = _out_proj(out, p["wo"], x.dtype)
    return o, MLACache(c_kv, k_pe)
