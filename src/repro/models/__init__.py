"""Model zoo: all ten assigned architectures behind one registry interface."""
from repro.models import registry  # noqa: F401
from repro.models.common import ArchConfig, ShapeConfig, SHAPES  # noqa: F401
