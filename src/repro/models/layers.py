"""Building-block layers: norms, gated MLPs, rotary embeddings, vocab heads.

Pure functions over param subtrees created via ``common.Collector``.
Norms and softmax run in f32; matmuls accumulate in f32 (bf16 storage).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import plan as dplan
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.common import ArchConfig, Collector


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(col: Collector, path: str, d: int, cfg: ArchConfig,
              stack: tuple[tuple[int, str], ...] = ()):
    lead_shape = tuple(s for s, _ in stack)
    lead_axes = tuple(a for _, a in stack)
    col.param(f"{path}/scale", lead_shape + (d,), lead_axes + ("d_model",),
              init="ones")
    if cfg.norm == "layernorm" and cfg.use_bias:
        col.param(f"{path}/bias", lead_shape + (d,), lead_axes + ("d_model",),
                  init="zeros")


def apply_norm(p: dict, x: jax.Array, cfg: ArchConfig, eps: float = 1e-6
               ) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32)
        if "bias" in p:
            out = out + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (dense)
# ---------------------------------------------------------------------------

def init_mlp(col: Collector, path: str, cfg: ArchConfig, d_ff: int | None = None,
             stack: tuple[tuple[int, str], ...] = ()):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    lead = tuple(s for s, _ in stack)
    laxes = tuple(a for _, a in stack)
    if cfg.mlp in ("swiglu", "geglu"):
        col.param(f"{path}/wi", lead + (d, 2 * f), laxes + ("d_model", "d_ff"),
                  scale=d ** -0.5)
    else:
        col.param(f"{path}/wi", lead + (d, f), laxes + ("d_model", "d_ff"),
                  scale=d ** -0.5)
    col.param(f"{path}/wo", lead + (f, d), laxes + ("d_ff", "d_model"),
              scale=f ** -0.5)
    if cfg.use_bias:
        col.param(f"{path}/bi", lead + ((2 * f) if cfg.mlp in ("swiglu", "geglu") else f,),
                  laxes + ("d_ff",), init="zeros")
        col.param(f"{path}/bo", lead + (d,), laxes + ("d_model",), init="zeros")


def _gate_act(cfg: ArchConfig, u: jax.Array) -> jax.Array:
    if cfg.mlp == "swiglu":
        return jax.nn.silu(u)
    if cfg.mlp == "geglu":
        return jax.nn.gelu(u, approximate=True)
    return jax.nn.gelu(u, approximate=True)


def apply_mlp(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    # with a planned mesh active, both GEMMs run through derived
    # DistributedPlans: wi column-sharded over "model" (no collective), wo
    # sigma-sharded over "model" (the TP psum, derived not hand-placed)
    mesh = dplan.current_planned_mesh()
    if mesh is not None:
        h = ops.matmul(x, p["wi"], out_dtype=jnp.float32, mesh=mesh,
                       shard=dplan.tp_matmul_shard(mesh, "col"))
    else:
        h = ops.matmul(x, p["wi"], out_dtype=jnp.float32)
    # NOTE: do NOT with_sharding_constraint the f32 pre-activation — measured
    # to make SPMD replicate the FFN over "model" (7x flops at decode, ~6x at
    # train).  The bf16 post-activation constraint below is sufficient.
    if cfg.use_bias:
        h = h + p["bi"].astype(jnp.float32)
    if cfg.mlp in ("swiglu", "geglu"):
        u, v = jnp.split(h, 2, axis=-1)
        h = _gate_act(cfg, u) * v
    else:
        h = _gate_act(cfg, h)
    h = h.astype(x.dtype)
    h = constrain(h, "batch", None, "d_ff")
    if mesh is not None:
        out = ops.matmul(h, p["wo"], out_dtype=x.dtype, mesh=mesh,
                         shard=dplan.tp_matmul_shard(mesh, "sigma"))
    else:
        out = ops.matmul(h, p["wo"], out_dtype=x.dtype)
    if x.shape[1] > 1:
        # seq-sharded output (train/prefill): the TP partial-sum becomes a
        # reduce-scatter.  NEVER at decode (s=1): forcing a replicated-spec
        # constraint there makes SPMD replicate the whole FFN over "model"
        out = constrain(out, "batch", "seq_sp", None)
    if cfg.use_bias:
        out = out + p["bo"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """sin/cos tables for integer positions (any leading shape) x dim/2."""
    half = dim // 2
    freqs = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array,
               rope_pct: float = 1.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); sin/cos: (..., seq, rot/2) broadcast
    over heads.  Partial rotary (stablelm) rotates the leading rope_pct dims.
    """
    hd = x.shape[-1]
    rot = int(hd * rope_pct)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    s = sin[..., None, :rot // 2]
    c = cos[..., None, :rot // 2]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1) if rot < hd \
        else out.astype(x.dtype)


def sinusoid_positions(positions: jax.Array, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position encodings."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / (half - 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def init_embed(col: Collector, cfg: ArchConfig):
    # d^-1/2 scale: with the sqrt(d) input multiplier (tied/gemma convention)
    # token inputs arrive unit-RMS AND tied logits start ~N(0,1)
    col.param("embed/table", (cfg.vocab_size, cfg.d_model), ("vocab", "d_model"),
              scale=cfg.d_model ** -0.5)
    if not cfg.tie_embeddings:
        col.param("unembed/w", (cfg.d_model, cfg.vocab_size), ("d_model", "vocab"),
                  scale=cfg.d_model ** -0.5)


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = params["embed"]["table"][tokens]
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)   # gemma convention
    return constrain(x, "batch", None, None)


def logits_from_hidden(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    # with a planned mesh, the vocab head is column-sharded over "model":
    # the derived plan lands the spec on the right STORED dim of the tied
    # (vocab, d) table automatically (the coefficients know the layout)
    mesh = dplan.current_planned_mesh()
    mesh_kw = (dict(mesh=mesh, shard=dplan.tp_matmul_shard(mesh, "col"))
               if mesh is not None else {})
    if cfg.tie_embeddings:
        # tied head contracts the (vocab, d) table in its STORED layout:
        # matmul(transpose_b=True) lowers to a transposed-operand derived
        # schedule (column-gamma coefficients on the table), so the largest
        # tensor in the model is never transpose-copied.
        logits = ops.matmul(x, params["embed"]["table"], transpose_b=True,
                            out_dtype=jnp.float32, **mesh_kw)
    else:
        logits = ops.matmul(x, params["unembed"]["w"], out_dtype=jnp.float32,
                            **mesh_kw)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return constrain(logits, "batch", None, "vocab")
