"""Shared model-configuration schema + parameter collection utilities.

One ``ArchConfig`` dataclass covers all ten assigned architecture families
(dense / MLA / MoE / SSM / hybrid / enc-dec / VLM-stub / audio-stub); the
per-arch files in ``repro.configs`` instantiate it.

Parameters are plain nested-dict pytrees.  Every leaf is declared through a
``Collector`` with *logical axis names* (e.g. ``("layers", "d_model",
"d_ff")``); ``repro.distributed.sharding`` later maps logical names to mesh
axes — that mapping IS the paper's dimension lifting applied at the mesh
level, kept in one place.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | ssm | moe | vlm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # attention flavor
    attention: str = "full"          # full | mla | none (ssm)
    local_window: int = 0            # >0 enables windowed attention layers
    layer_pattern: tuple[str, ...] = ()   # repeating group for hybrids, e.g.
                                          # ("rglru","rglru","local") or
                                          # ("local","local","local","full")
    rope_theta: float = 10000.0
    rope_pct: float = 1.0            # partial rotary (stablelm: 0.25)

    # MLP
    mlp: str = "swiglu"              # swiglu | geglu | gelu
    use_bias: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    parallel_block: bool = False     # attn+mlp in parallel (command-r style)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # expert hidden size (d_ff used for dense)
    first_dense_layers: int = 0      # deepseek: leading dense layer(s)
    capacity_factor: float = 1.25

    # SSM (mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (hybrid)
    lru_width: int = 0               # 0 -> d_model

    # encoder-decoder (audio) / VLM stub frontends
    encoder_layers: int = 0
    encoder_seq: int = 0             # whisper: 1500 frames (stub embeddings)
    num_patches: int = 0             # paligemma: 256 patch embeddings (stub)

    train_microbatches: int = 0      # 0 = heuristic (launch.dryrun)
    dtype: Any = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"       # "full" | "dots" (save matmul outputs)
    scan_unroll: bool = False   # unroll lax.scan bodies (dry-run cost extraction)
    attn_chunk_min_seq: int = 8192   # use chunked (flash-style) attention at/above
    attn_chunk: int = 1024           # k chunk length for chunked attention
    attn_q_chunk: int = 0            # q chunk length (0 = whole seq: k-only streaming)
    attn_sharding: str = "sp"        # "sp" (seq-parallel) | "heads" (Megatron)
    attn_impl: str = "xla"           # "xla" (chunked jnp) | "pallas" (flash kernel)

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts — analytic, used for MODEL_FLOPS."""
        d, v, hd = self.d_model, self.vocab_size, self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.attention == "mla":
            # q: d->q_rank->h*(nope+rope); kv: d->kv_rank(+rope)->h*(nope+v)
            att = (d * 768 + 768 * self.n_heads * 96
                   + d * (256 + 32) + 256 * self.n_heads * (64 + 64)
                   + self.n_heads * 64 * d)
        mlp_mult = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.mlp]
        dense_mlp = mlp_mult * d * self.d_ff
        total = emb
        active = emb
        n_att_layers = self.n_layers
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            n_h = d_in // self.ssm_head_dim
            per = (d * (2 * d_in + 2 * self.ssm_state * 1 + n_h)  # in_proj-ish
                   + d_in * d)
            total += self.n_layers * per
            active = total
            return int(total), int(active)
        if self.layer_pattern:
            n_rec = sum(1 for p in self.layer_pattern if p == "rglru")
            frac_rec = n_rec / len(self.layer_pattern)
            lw = self.lru_width or d
            rec_per = 2 * d * lw + lw * d + 2 * lw  # gates + in/out proj
            total += int(self.n_layers * frac_rec) * (rec_per + dense_mlp)
            n_att_layers = self.n_layers - int(self.n_layers * frac_rec)
        if self.moe:
            moe_layers = self.n_layers - self.first_dense_layers
            expert_mlp = mlp_mult * d * self.moe_ff
            shared = self.n_shared_experts * expert_mlp
            router = d * self.n_experts
            total += moe_layers * (att + self.n_experts * expert_mlp + shared + router)
            total += self.first_dense_layers * (att + dense_mlp)
            active += moe_layers * (att + self.top_k * expert_mlp + shared + router)
            active += self.first_dense_layers * (att + dense_mlp)
            return int(total), int(active)
        total += n_att_layers * (att + dense_mlp)
        if self.encoder_layers:
            total += self.encoder_layers * (att + dense_mlp) \
                + self.n_layers * (d * 2 * (self.n_kv_heads * hd) + 0)  # cross kv
        active = total
        return int(total), int(active)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# parameter collection (params pytree + logical-axis pytree, same structure)
# ---------------------------------------------------------------------------

class Collector:
    """Builds a params pytree and a parallel logical-axes pytree.

    ``col.param("attn/wq", (L, d, h, hd), ("layers","d_model","heads","head_dim"),
    scale)`` creates a normal(0, scale)-initialized leaf.  Axes drive both
    sharding (distributed/sharding.py) and documentation.
    """

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self.key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    def _split(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def _set(self, tree: dict, path: str, value):
        parts = path.split("/")
        for p in parts[:-1]:
            tree = tree.setdefault(p, {})
        assert parts[-1] not in tree, f"duplicate param {path}"
        tree[parts[-1]] = value

    def param(self, path: str, shape: tuple[int, ...], axes: tuple[str, ...],
              scale: float | None = None, init: str = "normal", dtype=None):
        assert len(shape) == len(axes), (path, shape, axes)
        dtype = dtype or self.dtype
        if init == "zeros":
            val = jnp.zeros(shape, dtype)
        elif init == "ones":
            val = jnp.ones(shape, dtype)
        else:
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = fan_in ** -0.5
            val = (jax.random.normal(self._split(), shape, jnp.float32)
                   * scale).astype(dtype)
        self._set(self.params, path, val)
        self._set(self.axes, path, axes)
        return val

    def done(self) -> tuple[dict, dict]:
        return self.params, self.axes


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
