"""Mixture-of-Experts FFN: shared + fine-grained routed experts (DeepSeekMoE /
Llama-4 style), with sort-based capacity-padded dispatch.

Dispatch is the MoA story again: the token axis is dimension-lifted
``tokens -> (experts, capacity)`` — a data-dependent lifting realized with a
static-shaped sort + scatter so it pjit-compiles on any mesh.  Expert weights
carry the logical axis "experts", which the sharding rules lift onto the
"model" mesh axis (expert parallelism); the expert GEMM itself is the same
blocked MoA kernel, batched over the lifted expert axis
(``repro.kernels.expert_gemm``).

Aux losses: load-balance (Switch-style) + router z-loss, returned for logging.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, shard_map
from repro.kernels import ops
from repro.models.common import ArchConfig, Collector
from repro.models.layers import _gate_act


def init_moe(col: Collector, path: str, cfg: ArchConfig,
             stack: tuple[tuple[int, str], ...] = ()):
    d, f, e = cfg.d_model, cfg.moe_ff, cfg.n_experts
    lead = tuple(s for s, _ in stack)
    laxes = tuple(a for _, a in stack)
    col.param(f"{path}/router", lead + (d, e), laxes + ("d_model", "experts"),
              scale=d ** -0.5, dtype=jnp.float32)
    col.param(f"{path}/wi", lead + (e, d, 2 * f),
              laxes + ("experts", "d_model", "moe_ff"), scale=d ** -0.5)
    col.param(f"{path}/wo", lead + (e, f, d),
              laxes + ("experts", "moe_ff", "d_model"), scale=f ** -0.5)
    if cfg.n_shared_experts:
        fs = cfg.moe_ff * cfg.n_shared_experts
        col.param(f"{path}/shared_wi", lead + (d, 2 * fs),
                  laxes + ("d_model", "d_ff"), scale=d ** -0.5)
        col.param(f"{path}/shared_wo", lead + (fs, d),
                  laxes + ("d_ff", "d_model"), scale=fs ** -0.5)


class MoEStats(NamedTuple):
    aux_loss: jax.Array
    z_loss: jax.Array
    dropped_frac: jax.Array


def apply_moe(p: dict, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, MoEStats]:
    """x: (B, S, d) -> (B, S, d).

    Dispatches to the shard-local (shard_map) implementation whenever a mesh
    with a >1 "model" axis is active: routing is token-local and experts are
    model-sharded, so the only cross-device communication is the same psum
    TP already pays — the global-sort/scatter collectives of the naive pjit
    lowering (which dominated the baseline roofline) disappear.
    """
    from repro.distributed.sharding import _current_mesh
    mesh = _current_mesh()
    if mesh is not None and dict(zip(mesh.axis_names,
                                     mesh.devices.shape)).get("model", 1) > 1:
        return _apply_moe_shardmap(p, x, cfg, mesh)
    return _apply_moe_global(p, x, cfg)


def _apply_moe_global(p: dict, x: jax.Array, cfg: ArchConfig
                      ) -> tuple[jax.Array, MoEStats]:
    """Reference pjit-global dispatch (single-device and baseline path)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = ops.matmul(xt.astype(jnp.float32), p["router"],
                        out_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                  # (t, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ----
    me = probs.mean(0)                                        # (e,)
    ce = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)

    # ---- sort-based dispatch: lift tokens -> (experts, capacity) ----
    cap = int(max(cfg.capacity_factor * t * k / e, 1))
    cap = -(-cap // 8) * 8                                    # sublane-align
    flat_e = idx.reshape(-1)                                  # (t*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)                               # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros(e, jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[se]
    keep = pos_in_e < cap
    slot = se * cap + jnp.clip(pos_in_e, 0, cap - 1)

    xe = jnp.zeros((e * cap, d), x.dtype)
    xe = xe.at[slot].add(jnp.where(keep[:, None], xt[st], 0))
    xe = xe.reshape(e, cap, d)
    xe = constrain(xe, "experts", None, None)

    # ---- expert FFN (gated) — the derived expert-GEMM schedule, batched
    # over the lifted expert axis (repro.kernels.ops.expert_matmul)
    h = ops.expert_matmul(xe, p["wi"], out_dtype=jnp.float32)
    u, v = jnp.split(h, 2, axis=-1)
    h = (_gate_act(cfg, u) * v).astype(x.dtype)
    h = constrain(h, "experts", None, "moe_ff")
    ye = ops.expert_matmul(h, p["wo"], out_dtype=x.dtype)
    ye = constrain(ye, "experts", None, None)

    # ---- combine ----
    contrib = ye.reshape(e * cap, d)[slot]
    contrib = contrib * (sg * keep).astype(x.dtype)[:, None]
    yt = jnp.zeros((t, d), x.dtype).at[st].add(contrib)
    y = yt.reshape(b, s, d)
    y = constrain(y, "batch", None, None)

    if cfg.n_shared_experts:
        hs = ops.matmul(x, p["shared_wi"], out_dtype=jnp.float32)
        us, vs = jnp.split(hs, 2, axis=-1)
        hs = (_gate_act(cfg, us) * vs).astype(x.dtype)
        y = y + ops.matmul(hs, p["shared_wo"], out_dtype=x.dtype)

    dropped = 1.0 - jnp.sum(keep) / (t * k)
    return y, MoEStats(aux, z, dropped)


# ---------------------------------------------------------------------------
# shard-local dispatch (expert parallelism without global sort collectives)
# ---------------------------------------------------------------------------

def _apply_moe_shardmap(p: dict, x: jax.Array, cfg: ArchConfig, mesh
                        ) -> tuple[jax.Array, MoEStats]:
    """Token-local routing + model-sharded experts via shard_map.

    Per device: route ITS tokens, keep assignments to ITS expert shard,
    sort/scatter locally (static shapes), run the local expert FFNs, combine,
    then one psum over "model" sums each token's expert contributions — the
    same collective TP pays for a dense FFN.  DP axes never exchange tokens.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes["model"]
    e_loc = e // tp
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes and sizes[a] > 1)
    dp_size = _np_prod([sizes[a] for a in dp_axes]) if dp_axes else 1
    if b % max(dp_size, 1):
        dp_axes, dp_size = (), 1
    batch_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    t_loc = (b // max(dp_size, 1)) * s
    cap = int(max(cfg.capacity_factor * t_loc * k / e, 1))
    cap = -(-cap // 8) * 8

    all_axes = tuple(n for n in mesh.axis_names if sizes[n] > 1)

    def body(x_blk, router, wi, wo):
        bl, sl, _ = x_blk.shape
        tl = bl * sl
        xt = x_blk.reshape(tl, d)
        logits = ops.matmul(xt.astype(jnp.float32), router,
                            out_dtype=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(0)
        ce = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / (tl * k)
        aux = e * jnp.sum(me * ce)
        z = jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)

        e0 = jax.lax.axis_index("model") * e_loc
        flat_e_all = idx.reshape(-1)
        local = (flat_e_all >= e0) & (flat_e_all < e0 + e_loc)
        flat_e = jnp.where(local, flat_e_all - e0, e_loc)     # e_loc = drop bucket
        flat_t = jnp.repeat(jnp.arange(tl), k)
        flat_g = gate_vals.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        counts = jnp.zeros(e_loc + 1, jnp.int32).at[se].add(1)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(tl * k) - starts[se]
        keep = (se < e_loc) & (pos_in_e < cap)
        slot = jnp.where(keep, se * cap + jnp.clip(pos_in_e, 0, cap - 1),
                         e_loc * cap)                          # overflow slot
        xe = jnp.zeros((e_loc * cap + 1, d), x.dtype)
        xe = xe.at[slot].add(jnp.where(keep[:, None], xt[st], 0))
        xe = xe[:-1].reshape(e_loc, cap, d)

        h = ops.expert_matmul(xe, wi, out_dtype=jnp.float32)
        u, v = jnp.split(h, 2, axis=-1)
        h = (_gate_act(cfg, u) * v).astype(x.dtype)
        ye = ops.expert_matmul(h, wo, out_dtype=x.dtype)

        contrib = jnp.concatenate([ye.reshape(e_loc * cap, d),
                                   jnp.zeros((1, d), x.dtype)])[slot]
        contrib = contrib * (sg * keep).astype(x.dtype)[:, None]
        yt = jnp.zeros((tl, d), x.dtype).at[st].add(contrib)
        yt = jax.lax.psum(yt, "model")
        # drops among THIS rank's local assignments (sorted order throughout)
        dropped_loc = jnp.sum((se < e_loc) & (pos_in_e >= cap)).astype(jnp.float32)
        # aux/z identical across "model"; average over the other axes
        if all_axes:
            denom = _np_prod([sizes[a] for a in all_axes])
            aux = jax.lax.psum(aux, all_axes) / denom
            z = jax.lax.psum(z, all_axes) / denom
            dropped = jax.lax.psum(dropped_loc, all_axes) / (tl * k * max(dp_size, 1))
        else:
            dropped = dropped_loc / (tl * k)
        return yt.reshape(bl, sl, d), aux, z, dropped

    # checkpoint INSIDE the shard_map: outer remat treats the shard_map call
    # as opaque and would otherwise save every internal expert intermediate
    # (measured: 0.94 GiB f32 per layer on llama4-scout)
    y, aux, z, dropped = shard_map(
        jax.checkpoint(body), mesh=mesh,
        in_specs=(P(batch_spec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(batch_spec, None, None), P(), P(), P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wo"])

    if cfg.n_shared_experts:
        hs = ops.matmul(x, p["shared_wi"], out_dtype=jnp.float32)
        us, vs = jnp.split(hs, 2, axis=-1)
        hs = (_gate_act(cfg, us) * vs).astype(x.dtype)
        y = y + ops.matmul(hs, p["shared_wo"], out_dtype=x.dtype)
    return y, MoEStats(aux, z, dropped)


def _np_prod(xs):
    out = 1
    for v in xs:
        out *= int(v)
    return out
