import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.
# (No __future__ import in this file for the same reason: these two lines
# must be the first statements.)

_DOC = """Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against abstract inputs, and extract the roofline terms.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. derives every sharding from the lifting rules (repro.distributed.sharding),
  3. ``jax.jit(fn, in_shardings, out_shardings).lower(*abstract).compile()``,
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs/bytes) and the collective-byte breakdown
     parsed from the post-SPMD HLO,
  5. emits one JSON record per cell into --out (consumed by
     benchmarks/bench_roofline.py and EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_cells, cell_applicable, get_config
from repro.core import cost as cost_mod
from repro.core.cost import collective_bytes_from_hlo, from_quantities
from repro.core.lifting import TPU_V5E, TPU_V5E_2POD
from repro.distributed import sharding as shard_rules
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.common import ArchConfig, ShapeConfig
from repro.optim import adamw
from repro.train import train_step as ts_mod


def _abstract_init(cfg: ArchConfig, key):
    """Abstract param shapes + the logical-axes tree (no allocation)."""
    captured = {}

    def f(k):
        p, a = registry.init(cfg, k)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, captured["axes"]


def _batch_pspec(batch_specs: dict, mesh) -> dict:
    out = {}
    for k, v in batch_specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = shard_rules.act_spec(axes, v.shape, mesh)
    return out


def _named(tree, mesh):
    """PartitionSpec pytree -> NamedSharding pytree (jit in 0.8 wants
    Shardings unless a context mesh is set)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree,
        is_leaf=lambda s: isinstance(s, P) or s is None)


def lower_cell(cfg, shape_name: str, multi_pod: bool,
               microbatches: int | None = None, donate: bool = True):
    """Returns (lowered, aux_info).  ``cfg`` may be an ArchConfig or an
    arch-id string."""
    if isinstance(cfg, str):
        cfg = get_config(cfg)
    shp = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.PRNGKey(0)
    specs = registry.input_specs(cfg, shp)

    with mesh:
        p_shapes, p_axes = _abstract_init(cfg, key)
        p_pspecs = shard_rules.param_pspecs(p_shapes, p_axes, mesh)

        if shp.kind == "train":
            mb = microbatches if microbatches is not None else default_microbatches(cfg, shp)
            # each microbatch must still shard over the DP axes
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            dp_total = sizes.get("pod", 1) * sizes.get("data", 1)
            while mb > 1 and (shp.global_batch // mb) % dp_total:
                mb -= 1
            step_fn = ts_mod.make_train_step(cfg, microbatches=mb)
            state_shapes = jax.eval_shape(
                lambda p: ts_mod.TrainState(
                    params=p, opt=adamw.init(p), err_fb=None,
                    step=jnp.zeros((), jnp.int32)), p_shapes)
            state_pspecs = ts_mod.TrainState(
                params=p_pspecs,
                opt=adamw.AdamWState(step=P(), master=p_pspecs, m=p_pspecs,
                                     v=p_pspecs),
                err_fb=None, step=P())
            batch_ps = _batch_pspec(specs["batch"], mesh)
            jf = jax.jit(step_fn,
                         in_shardings=_named((state_pspecs, batch_ps), mesh),
                         out_shardings=_named((state_pspecs, None), mesh),
                         donate_argnums=(0,) if donate else ())
            lowered = jf.lower(state_shapes, specs["batch"])
            extra = {"microbatches": mb}
        elif shp.kind == "prefill":
            def prefill_fn(params, batch):
                return registry.prefill(params, cfg, batch)
            batch_ps = _batch_pspec(specs["batch"], mesh)
            jf = jax.jit(prefill_fn,
                         in_shardings=_named((p_pspecs, batch_ps), mesh))
            lowered = jf.lower(p_shapes, specs["batch"])
            extra = {}
        else:  # decode
            cache_shapes = specs["cache"]
            cache_axes = registry.cache_logical_axes(cache_shapes)
            cache_ps = jax.tree.map(
                lambda leaf, ax: shard_rules.act_spec(ax, leaf.shape, mesh),
                cache_shapes, cache_axes)

            def decode_fn(params, tokens, pos, cache):
                return registry.decode_step(params, cfg, tokens, pos, cache)
            tok_ps = shard_rules.act_spec(("batch",), specs["tokens"].shape, mesh)
            jf = jax.jit(decode_fn,
                         in_shardings=_named((p_pspecs, tok_ps, tok_ps, cache_ps), mesh),
                         out_shardings=_named((None, cache_ps), mesh),
                         donate_argnums=(3,) if donate else ())
            lowered = jf.lower(p_shapes, specs["tokens"], specs["pos"],
                               cache_shapes)
            extra = {}
    return lowered, {"cfg": cfg, "shape": shp, "mesh": mesh, **extra}


def default_microbatches(cfg: ArchConfig, shp: ShapeConfig,
                         dp: int = 32, tp: int = 16,
                         logit_budget: int = 2 * 2**30) -> int:
    """Activation-memory heuristic (the lifting view of the batch axis).

    The dominant per-device temp for training is the f32 logits+grad buffer
    ~ 2 x B_local x S x vocab/tp x 4B; choose the microbatch count that
    keeps it under ``logit_budget``, then round to a divisor of B_local."""
    if cfg.train_microbatches:
        return cfg.train_microbatches
    b_local = max(shp.global_batch // dp, 1)
    logit_bytes = 2.0 * b_local * shp.seq_len * (cfg.vocab_size / tp) * 4
    act_bytes = 0.0
    if cfg.moe:
        # dispatch replicates tokens x top_k: (t_loc*k, d) gather/scatter
        # buffers live through the layer backward
        act_bytes = 6.0 * b_local * shp.seq_len * cfg.top_k * cfg.d_model * 2
    mb = max(1, int(-(-max(logit_bytes, act_bytes) // logit_budget)))
    while b_local % mb:
        mb += 1
    return min(mb, b_local)


def layer_variants(cfg: ArchConfig) -> tuple[list[tuple[ArchConfig, int]], int]:
    """Two reduced-depth configs + the full unit count, for the linear
    cost regression (XLA cost_analysis counts a scanned layer body ONCE —
    metric(units) = a + b*units recovers the per-layer slope, then we
    extrapolate to full depth)."""
    if cfg.family == "audio":
        mk = lambda k: cfg.with_(n_layers=k, encoder_layers=k, scan_unroll=True)
        return [(mk(1), 1), (mk(2), 2)], cfg.n_layers
    if cfg.family == "hybrid" and cfg.layer_pattern:
        per = len(cfg.layer_pattern)
        tail = cfg.n_layers % per
        mk = lambda g: cfg.with_(n_layers=per * g + tail, scan_unroll=True)
        return [(mk(1), 1), (mk(2), 2)], (cfg.n_layers - tail) // per
    if cfg.layer_pattern:
        per = len(cfg.layer_pattern)
        mk = lambda g: cfg.with_(n_layers=per * g, scan_unroll=True)
        return [(mk(1), 1), (mk(2), 2)], cfg.n_layers // per
    base = cfg.first_dense_layers
    mk = lambda L: cfg.with_(n_layers=L, scan_unroll=True)
    return [(mk(base + 1), base + 1), (mk(base + 2), base + 2)], cfg.n_layers


def analyze(lowered, info, hardware) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    cfg, shp, mesh = info["cfg"], info["shape"], info["mesh"]
    n_chips = mesh.devices.size

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):        # older jax: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:                      # CPU backend may not support
        mem["error"] = str(e)

    total, active = cfg.param_count()
    if shp.kind == "train":
        tokens = shp.tokens
        mf = cost_mod.model_flops_lm(total, tokens, active_params=active,
                                     training=True)
    elif shp.kind == "prefill":
        mf = cost_mod.model_flops_lm(total, shp.tokens, active_params=active,
                                     training=False)
    else:
        mf = cost_mod.model_flops_lm(total, shp.global_batch,
                                     active_params=active, training=False)

    rl = from_quantities(f"{cfg.name}/{shp.name}", n_chips=n_chips,
                         per_device_flops=flops, per_device_hbm_bytes=bytes_,
                         collective_stats=coll, hardware=hardware,
                         model_flops=mf)
    rec = {
        "arch": cfg.name, "shape": shp.name, "kind": shp.kind,
        "n_chips": n_chips, "compile_s": round(compile_s, 1),
        "params_total": total, "params_active": active,
        "memory": mem, "cost_analysis": {k: ca[k] for k in
                                         ("flops", "bytes accessed")
                                         if k in ca},
        "collectives_bytes": coll.bytes_by_op,
        "collectives_count": coll.count_by_op,
        "roofline": rl.to_dict(),
    }
    for k, v in info.items():
        if k in ("microbatches",):
            rec[k] = v
    return rec


def _cost_metrics(lowered) -> dict:
    """flops / bytes / per-op collective bytes of one compiled variant."""
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):        # older jax: one dict per device
        ca = ca[0] if ca else {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": dict(coll.bytes_by_op)}


def _extrapolate(m_small: dict, u_small: int, m_mid: dict, u_mid: int,
                 u_full: int) -> dict:
    """Linear metric(units) = a + b*units -> value at u_full (clamped >=0)."""
    du = max(u_mid - u_small, 1)

    def ext(a, b):
        slope = (b - a) / du
        return max(a + slope * (u_full - u_small), 0.0)

    ops = set(m_small["coll"]) | set(m_mid["coll"])
    return {
        "flops": ext(m_small["flops"], m_mid["flops"]),
        "bytes": ext(m_small["bytes"], m_mid["bytes"]),
        "coll": {op: ext(m_small["coll"].get(op, 0.0),
                         m_mid["coll"].get(op, 0.0)) for op in ops},
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str | None,
             donate: bool = True, regress: bool = True) -> dict:
    multi = mesh_kind == "multi"
    hardware = TPU_V5E_2POD if multi else TPU_V5E
    ok, why = cell_applicable(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "SKIP", "reason": why}
    else:
        try:
            cfg = get_config(arch)
            lowered, info = lower_cell(cfg, shape_name, multi, donate=donate)
            rec = analyze(lowered, info, hardware)
            rec.update(mesh=mesh_kind, status="OK")
            if regress:
                # depth regression: XLA counts scanned layer bodies once, so
                # extract per-layer slopes from two reduced-depth compiles
                # and extrapolate flops/bytes/collectives to full depth.
                variants, u_full = layer_variants(cfg)
                (vcfg_s, us), (vcfg_m, um) = variants
                ls, _ = lower_cell(vcfg_s, shape_name, multi,
                                   microbatches=1, donate=False)
                lm, _ = lower_cell(vcfg_m, shape_name, multi,
                                   microbatches=1, donate=False)
                ext = _extrapolate(_cost_metrics(ls), us, _cost_metrics(lm),
                                   um, u_full)
                stats = cost_mod.CollectiveStats(
                    bytes_by_op={k: int(v) for k, v in ext["coll"].items()})
                n_chips = rec["n_chips"]
                rl = from_quantities(
                    f"{arch}/{shape_name}", n_chips=n_chips,
                    per_device_flops=ext["flops"],
                    per_device_hbm_bytes=ext["bytes"],
                    collective_stats=stats, hardware=hardware,
                    model_flops=rec["roofline"]["model_flops"])
                rec["roofline_raw_scan_body"] = rec["roofline"]
                rec["roofline"] = rl.to_dict()
                rec["regression"] = {"units": [us, um, u_full],
                                     "extrapolated": ext}
        except Exception as e:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[None, *SHAPES])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-donate", action="store_true")
    args = ap.parse_args()

    cells = all_cells() if args.all else [
        (a, s) for a, s in all_cells()
        if (args.arch in (None, a)) and (args.shape in (None, s))]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch, shape_name in cells:
        for mk in meshes:
            t0 = time.time()
            rec = run_cell(arch, shape_name, mk, args.out,
                           donate=not args.no_donate)
            status = rec.get("status")
            dom = rec.get("roofline", {}).get("dominant", "-")
            print(f"[{time.time()-t0:7.1f}s] {arch:28s} {shape_name:12s} "
                  f"{mk:6s} {status:5s} dominant={dom}", flush=True)
            if status == "FAIL":
                print(rec.get("error"), flush=True)


if __name__ == "__main__":
    main()
