"""Launchers: mesh construction, multi-pod dry-run, train and serve drivers.
(Do not import dryrun from here: it sets XLA_FLAGS at import time.)"""
from repro.launch import mesh  # noqa: F401
