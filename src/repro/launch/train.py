"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 50

Wires together: config registry -> model init -> lifting-derived shardings ->
pjit'd train step -> synthetic data pipeline -> async checkpointing with
restart-resume -> straggler watchdog.  On a real cluster the same driver runs
under ``jax.distributed.initialize`` with the production mesh; here it uses
whatever local devices exist.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import PipelineConfig, SyntheticLM
from repro.distributed import sharding as shard_rules
from repro.distributed.compression import CompressionConfig
from repro.distributed.fault import Coordinator, ElasticManager, StepWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.train import train_step as ts_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--dp", type=int, default=0, help="0 = all local devices")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    dp = args.dp or max(len(jax.devices()) // args.tp, 1)
    mesh = make_host_mesh(dp=dp, tp=args.tp)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch={cfg.name} reduced={args.reduced}")

    comp = CompressionConfig(enabled=args.compress_grads)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=args.warmup,
                          decay_steps=max(args.steps, 2 * args.warmup))
    key = jax.random.PRNGKey(args.seed)

    with mesh:
        state, p_axes = ts_mod.init_state(cfg, key, comp)
        state_axes = ts_mod.state_logical_axes(state, p_axes)
        state_shardings = shard_rules.param_shardings(state, state_axes, mesh)
        state = jax.tree.map(jax.device_put, state, state_shardings)

        data = SyntheticLM(PipelineConfig(cfg.vocab_size, args.seq,
                                          args.batch, seed=args.seed), cfg)
        step_fn = jax.jit(
            ts_mod.make_train_step(cfg, opt_cfg, comp, args.microbatches),
            donate_argnums=(0,))

        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if ckpt and ckpt.all_steps():
            state, manifest = ckpt.restore(state, shardings=state_shardings)
            start = manifest["metadata"].get("data_step", manifest["step"])
            print(f"resumed from step {start}")

        coord = Coordinator()
        watchdog = StepWatchdog(coord)
        losses = []
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, data.global_batch(step))
            watchdog.start()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            watchdog.stop(step)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{watchdog.ema_s or 0:6.3f}s/step", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, state,
                                metadata=SyntheticLM.state_dict(step + 1))
        if ckpt:
            ckpt.wait()
        if coord.events:
            print(f"watchdog events: {len(coord.events)}")
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return losses


if __name__ == "__main__":
    main()
