"""Serving driver: a thin CLI over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 8 --prompt-len 16 --new-tokens 32

Requests with random prompts stream into ``serving.ServeEngine`` —
admission, page allocation and prefill/decode interleaving happen inside
the engine; this file only builds the model, submits, and reports.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import registry
from repro.serving import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page", type=int, default=None,
                    help="KV page size (default: solve_recurrence_blocks)")
    ap.add_argument("--pool-pages", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    params, _ = registry.init(cfg, jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.new_tokens
    engine = ServeEngine(cfg, params, max_slots=args.max_slots,
                         max_len=max_len, page=args.page,
                         pool_pages=args.pool_pages)
    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1),
        (args.requests, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    rids = [engine.submit(row.tolist(), args.new_tokens,
                          now=time.perf_counter() - t0)
            for row in prompts]
    results = engine.run(clock=lambda: time.perf_counter() - t0)
    wall = time.perf_counter() - t0
    n_tok = sum(len(results[r]["tokens"]) for r in rids)
    print(f"arch={cfg.name} paged={engine.paged} page={engine.page} "
          f"slots={engine.max_slots}")
    print(f"{args.requests} requests, {n_tok} tokens in {wall:.2f}s "
          f"(incl. compile) = {n_tok / wall:.1f} tok/s")
    print("sample output ids:", results[rids[0]]["tokens"][:16])
    return results


if __name__ == "__main__":
    main()
