"""Batched serving driver: prefill a batch of prompts, then decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import registry
from repro.train.serve_step import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params, _ = registry.init(cfg, key)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    cache_len = args.prompt_len + args.new_tokens
    gen = jax.jit(lambda p, pr: greedy_generate(p, cfg, pr, args.new_tokens,
                                                cache_len))
    t0 = time.time()
    out = gen(params, prompt)
    out.block_until_ready()
    compile_and_first = time.time() - t0
    t0 = time.time()
    out = gen(params, prompt)
    out.block_until_ready()
    steady = time.time() - t0
    tok_s = args.batch * args.new_tokens / steady
    print(f"arch={cfg.name} batch={args.batch} new={args.new_tokens}")
    print(f"first call (incl. compile): {compile_and_first:.2f}s; "
          f"steady: {steady:.3f}s = {tok_s:.1f} tok/s")
    print("sample output ids:", out[0, args.prompt_len:][:16].tolist())
    return out


if __name__ == "__main__":
    main()
