"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Mesh axes are the outermost level of the paper's
dimension lifting: "pod" (DP across pods), "data" (DP/FSDP within a pod),
"model" (TP/EP/SP).  The v5e pod-slice is 16x16 = 256 chips; multi-pod runs
2 pods = 512 chips.
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(dp: int = 1, tp: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    import jax
    devices = jax.devices()[:dp * tp]
    return jax.make_mesh((dp, tp), ("data", "model"), devices=devices)
