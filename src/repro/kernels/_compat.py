"""Version shims for the Pallas TPU API surface used by this package."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both so the
# kernels run on every jax this repo targets.
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics) -> object:
    return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))
