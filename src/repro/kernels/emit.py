"""Generic Pallas emitter: an executable kernel from a derived ``Schedule``.

``emit_pallas(schedule)`` is the single code generator behind every derived
op: the grid, BlockSpecs, dimension semantics and scratch accumulator all
come from the schedule (which in turn was derived from the normalized, lifted
expression), so no kernel hand-writes its layout.  The in-block body is the
schedule's semiring:

* ``(mul, add)`` — the einsum the axis structure implies (a plain MXU dot
  for GEMM, elementwise multiply for Hadamard, a batched dot for the lifted
  expert axis), with f32 accumulation across the sigma (reduce) grid steps;
* any other registered combine/reduce pair (max-plus, min-plus) — operands
  are aligned to (out axes + contracted axes), paired with the combine op,
  folded with the reduce op in-block, and accumulated across sigma steps
  with the same reduce op from its identity element.

The accumulator flushes to the output dtype on the last sigma step.

Psi views ride as index-map offsets: an operand whose Access carried a
constant term gets a leading block-1 dimension whose block index is pinned
at the viewed slab (``OperandSpec.offsets``) — sliced operands run derived
kernels with no materialized copy.

``emit_bundle`` wraps a cached ``ScheduleBundle`` into the full executable
contract the ops layer uses (pad with the semiring's inert element, run,
slice the logical result), and ``emit_shard_map`` stacks the mesh level on
top: the same derived kernel (or the jnp oracle) runs per shard inside
``shard_map`` with a ``DistributedPlan``'s partition specs and collectives.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring
from repro.core.schedule import Schedule, ScheduleBundle, StreamingSchedule
from repro.core.semiring import MASK_NEG_INF as NEG_INF

# jax renamed TPUCompilerParams -> CompilerParams; support both so the
# kernels run on every jax this repo targets.
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics) -> object:
    return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))


def _index_map(grid_dims: tuple[Optional[int], ...],
               offsets: tuple[int, ...] = ()) -> Callable:
    offs = offsets or (0,) * len(grid_dims)

    def imap(*gids):
        return tuple((gids[d] if d is not None else 0) + off
                     for d, off in zip(grid_dims, offs))
    return imap


def _jnp_combine(name: str) -> Callable:
    return getattr(jnp, semiring.combine_def(name).jnp_name)


def _general_combine(schedule: Schedule, combine_fn, reducer, vals):
    """Body for non-(mul, add) semirings: align every block to (out axes +
    contracted axes), pair with ``combine_fn``, fold the contraction with
    the reduce op's axis reducer."""
    joint = tuple(schedule.out.axes) + tuple(schedule.contracted)
    aligned = []
    for opn, v in zip(schedule.ins, vals):
        # squeeze block-1 dims outside the joint axes (the psi slab dim)
        keep = [i for i, ax in enumerate(opn.axes) if ax in joint]
        v = v.reshape(tuple(v.shape[i] for i in keep))
        src = {opn.axes[i]: pos for pos, i in enumerate(keep)}
        v = jnp.transpose(v, [src[ax] for ax in joint if ax in src])
        for pos, ax in enumerate(joint):
            if ax not in src:
                v = jnp.expand_dims(v, pos)
        aligned.append(v.astype(jnp.float32))
    out = functools.reduce(combine_fn, aligned)
    if schedule.contracted:
        red = tuple(range(len(schedule.out.axes), len(joint)))
        out = reducer(out, axis=red)
    return out


def emit_pallas(schedule: Schedule, combine=None, *, out_dtype=None,
                interpret: bool = False) -> Callable:
    """Build the ``pl.pallas_call`` a schedule describes.

    Returns ``fn(*operands) -> out`` over arrays of exactly the schedule's
    (padded) operand shapes.  ``combine`` overrides the schedule's pairing op
    by name (it defaults to ``schedule.combine``, which ``derive_schedule``
    copied from the expression's normal form).
    """
    ni = len(schedule.ins)
    out_dtype = jnp.dtype(out_dtype or jnp.float32)
    spec, in_keep = schedule.einsum_plan()
    red = schedule.reduce_grid_dim
    gk = schedule.grid[red].extent if red is not None else 0
    combine_name = combine or schedule.combine
    reduce_name = schedule.reduce_op
    multiplicative = (combine_name, reduce_name) == ("mul", "add")
    out_block = schedule.out.block
    if not multiplicative:
        combine_fn = _jnp_combine(combine_name)
        rdef = semiring.reduce_def(reduce_name)
        reducer = getattr(jnp, rdef.jnp_reducer)
        acc_step = getattr(jnp, rdef.jnp_name)
        identity = rdef.identity

    def body(*refs):
        o_ref = refs[ni]
        if multiplicative:
            squeezed = [
                refs[i][...].reshape(tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(schedule.ins, in_keep))
            ]
            val = jnp.einsum(spec, *squeezed,
                             preferred_element_type=jnp.float32)
        else:
            val = _general_combine(schedule, combine_fn, reducer,
                                   [refs[i][...] for i in range(ni)])
        val = val.reshape(out_block)
        if red is None:
            o_ref[...] = val.astype(out_dtype)
        else:
            acc_ref = refs[ni + 1]
            kk = pl.program_id(red)

            @pl.when(kk == 0)
            def _init():
                if multiplicative:
                    acc_ref[...] = jnp.zeros_like(acc_ref)
                else:
                    acc_ref[...] = jnp.full_like(acc_ref, identity)

            if multiplicative:
                acc_ref[...] += val
            else:
                acc_ref[...] = acc_step(acc_ref[...], val)

            @pl.when(kk == gk - 1)
            def _flush():
                o_ref[...] = acc_ref[...].astype(out_dtype)

    call = pl.pallas_call(
        body,
        grid=schedule.grid_extents,
        in_specs=[pl.BlockSpec(opn.block, _index_map(opn.grid_dims,
                                                     opn.offsets))
                  for opn in schedule.ins],
        out_specs=pl.BlockSpec(out_block, _index_map(schedule.out.grid_dims,
                                                     schedule.out.offsets)),
        out_shape=jax.ShapeDtypeStruct(schedule.out.shape, out_dtype),
        scratch_shapes=([pltpu.VMEM(out_block, jnp.float32)]
                        if red is not None else []),
        compiler_params=compiler_params(
            dimension_semantics=schedule.dimension_semantics),
        interpret=interpret,
    )

    def fn(*arrays):
        if len(arrays) != ni:
            raise ValueError(f"{schedule.name}: expected {ni} operands")
        for arr, opn in zip(arrays, schedule.ins):
            if not _shape_ok(tuple(arr.shape), opn):
                raise ValueError(
                    f"{schedule.name}: operand {opn.array} has shape "
                    f"{arr.shape}, schedule derived {opn.shape} — pad first")
        return call(*arrays)

    return fn


def _shape_ok(shp: tuple[int, ...], opn) -> bool:
    """A psi-view operand may be bound with MORE leading slabs than the
    pinned index needs; every other dim must match the schedule exactly."""
    if len(shp) != len(opn.shape):
        return False
    if shp == opn.shape:
        return True
    return (opn.is_psi_view and shp[0] >= opn.shape[0]
            and shp[1:] == opn.shape[1:])


# ---------------------------------------------------------------------------
# streaming emitter: the sigma accumulator generalized to rescale-carrying
# state (online softmax) — flash attention's init/step/flush, derived
# ---------------------------------------------------------------------------

def emit_streaming(ss: StreamingSchedule, *, scale: float = 1.0,
                   causal: bool = False, logical_stream: Optional[int] = None,
                   out_dtype=None, interpret: bool = False) -> Callable:
    """Build the ``pl.pallas_call`` a ``StreamingSchedule`` describes.

    The in-block body generalizes ``emit_pallas``'s sigma init/step/flush
    contract: instead of ``acc += block``, each step of the streamed grid
    axis computes one block of the first contraction (q·kᵀ), folds it into
    the carried softmax state — running max ``m``, denominator ``l``, and
    the accumulator *rescaled* by ``exp(m_prev - m_new)`` — and adds the
    second contraction (p·v); the flush divides by ``l``.  Masking is
    positional: ``causal`` keeps keys at or before the query's absolute
    position (and skips fully-masked streamed blocks), and
    ``logical_stream`` masks keys the pad added (the ``kpos < sk`` guard).

    Grid, BlockSpecs, dimension semantics, scratch shapes and both in-block
    einsums all come from the schedule — nothing here is hand-written.
    """
    out_dtype = jnp.dtype(out_dtype or jnp.float32)
    ni = len(ss.ins)
    bq, bk = ss.row_block, ss.stream_block
    stream_dim = ss.stream_grid_dim
    nk = ss.grid[stream_dim].extent
    row_dim = ss.out.grid_dims[ss.out.axes.index(ss.row_axis)]
    sk_pad = nk * bk
    masked_pad = logical_stream is not None and logical_stream < sk_pad

    # both in-block contractions as derived einsum plans (the axis structure
    # of the blocks, not a hand-chosen spec)
    scores_plan, scores_keep = Schedule(
        ss.name, ss.grid, ss.ins[:2], ss.inter, ss.contracted, None,
    ).einsum_plan()
    ctx_plan, ctx_keep = Schedule(
        ss.name, ss.grid, (ss.inter,) + ss.ins[2:], ss.out,
        (ss.stream_axis,), None,
    ).einsum_plan()
    acc_block = ss.acc_block

    def body(*refs):
        o_ref = refs[ni]
        m_ref, l_ref, acc_ref = refs[ni + 1:ni + 4]
        qi = pl.program_id(row_dim)
        ki = pl.program_id(stream_dim)

        @pl.when(ki == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # skip streamed blocks that are entirely masked: strictly above the
        # causal diagonal, or entirely inside the key padding
        run = True
        if causal:
            run = ki * bk <= qi * bq + bq - 1
        if masked_pad:
            run = jnp.logical_and(run, ki * bk < logical_stream)

        @pl.when(run)
        def _step():
            q, k = (refs[i][...].reshape(
                tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(ss.ins[:2], scores_keep)))
            s = jnp.einsum(scores_plan, q, k,
                           preferred_element_type=jnp.float32) * scale
            need_mask = causal or masked_pad
            if need_mask:
                qpos = qi * bq + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 0)
                kpos = ki * bk + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 1)
                mask = jnp.ones((bq, bk), bool)
                if causal:
                    mask = kpos <= qpos
                if masked_pad:
                    mask = jnp.logical_and(mask, kpos < logical_stream)
                s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[:, 0]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[:, None])
            corr = jnp.exp(m_prev - m_new)
            l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
            m_ref[:, 0] = m_new
            v = refs[2][...].reshape(
                tuple(ss.ins[2].block[d] for d in ctx_keep[1]))
            acc_ref[...] = (
                acc_ref[...] * corr[:, None]
                + jnp.einsum(ctx_plan, p.astype(v.dtype), v,
                             preferred_element_type=jnp.float32
                             ).reshape(acc_block))

        @pl.when(ki == nk - 1)
        def _flush():
            o_ref[...] = (acc_ref[...] /
                          jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                          ).astype(out_dtype).reshape(ss.out.block)

    call = pl.pallas_call(
        body,
        grid=ss.grid_extents,
        in_specs=[pl.BlockSpec(opn.block, _index_map(opn.grid_dims,
                                                     opn.offsets))
                  for opn in ss.ins],
        out_specs=pl.BlockSpec(ss.out.block, _index_map(ss.out.grid_dims,
                                                        ss.out.offsets)),
        out_shape=jax.ShapeDtypeStruct(ss.out.shape, out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),            # running max m
            pltpu.VMEM((bq, 1), jnp.float32),            # denominator l
            pltpu.VMEM(acc_block, jnp.float32),          # rescaled acc
        ],
        compiler_params=compiler_params(
            dimension_semantics=ss.dimension_semantics),
        interpret=interpret,
    )

    def fn(*arrays):
        if len(arrays) != ni:
            raise ValueError(f"{ss.name}: expected {ni} operands")
        for arr, opn in zip(arrays, ss.ins):
            if tuple(arr.shape) != opn.shape:
                raise ValueError(
                    f"{ss.name}: operand {opn.array} has shape {arr.shape}, "
                    f"schedule derived {opn.shape} — pad first")
        return call(*arrays)

    return fn


def emit_streaming_bundle(bundle: ScheduleBundle, *, scale: float,
                          causal: bool, out_dtype=None,
                          interpret: bool = False) -> Callable:
    """Executable for a cached streaming derivation over *logical* operands:
    pad the sequence axes to the derived block multiples (padded keys are
    inert — the emitter's ``kpos < sk`` guard masks them), run the emitted
    kernel, slice the logical result back out."""
    ss = bundle.schedule
    logical_stream = bundle.shapes[-1]
    kern = emit_streaming(ss, scale=scale, causal=causal,
                          logical_stream=logical_stream,
                          out_dtype=out_dtype, interpret=interpret)
    out_slices = tuple(slice(0, d) for d in bundle.out_shape)

    def call(*arrays):
        padded = [_pad_to_shape(x, spec.shape)
                  for x, spec in zip(arrays, ss.ins)]
        return kern(*padded)[out_slices]

    return call


# ---------------------------------------------------------------------------
# bundle executor: the ops-layer contract (collapse psi slabs, pad, run,
# slice) in one place, reused by the single-chip and shard_map paths
# ---------------------------------------------------------------------------

def _pad_to_shape(x: jax.Array, shape: tuple[int, ...],
                  value: float = 0.0) -> jax.Array:
    pads = [(0, t - d) for d, t in zip(x.shape, shape)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads, constant_values=value)
    return x


def emit_bundle(bundle: ScheduleBundle, *, out_dtype=None,
                interpret: bool = False) -> Callable:
    """Executable for a cached derivation over *logical storage* operands.

    Collapses a psi view's fixed leading dims to the flat slab dim the
    schedule pinned, pads every operand to the schedule's (padded) storage
    shape with the semiring's inert element, runs the emitted kernel, and
    slices the logical result back out.  The missing-inert-element error
    is only raised when padding is actually required.
    """
    sch = bundle.schedule
    kern = emit_pallas(sch, out_dtype=out_dtype, interpret=interpret)

    prep, needs_pad = [], False
    for spec, logical in zip(sch.ins, bundle.in_shapes):
        sym_rank = len(spec.shape) - (1 if spec.is_psi_view else 0)
        lead = len(logical) - sym_rank
        tail = tuple(logical[lead:])
        needs_pad |= tail != (spec.shape[1:] if spec.is_psi_view
                              else spec.shape)
        prep.append((lead, spec))
    if not needs_pad:
        pad_val = 0.0                        # nothing is ever padded
    elif len(sch.ins) == 1:
        # single operand: no pairing happens, so the inert pad is just the
        # reduce identity (e.g. -inf for a lone max-reduce)
        pad_val = semiring.reduce_def(sch.reduce_op).identity
    else:
        pad_val = semiring.pad_value(sch.combine, sch.reduce_op)
    out_slices = tuple(slice(0, d) for d in bundle.out_shape)

    def call(*arrays):
        padded = []
        for x, (lead, spec) in zip(arrays, prep):
            if spec.is_psi_view:
                if lead > 1:                 # several fixed dims -> one slab
                    x = x.reshape((-1,) + x.shape[lead:])
                target = (x.shape[0],) + spec.shape[1:]
            else:
                if lead:                     # all-zero psi index: slab 0
                    x = x.reshape((-1,) + x.shape[lead:])[0]
                target = spec.shape
            padded.append(_pad_to_shape(x, target, pad_val))
        return kern(*padded)[out_slices]

    return call


# ---------------------------------------------------------------------------
# the mesh level: the same derived kernel per shard, inside shard_map
# ---------------------------------------------------------------------------

def emit_shard_map(plan, mesh, local_fn: Optional[Callable] = None, *,
                   out_dtype=None, interpret: bool = False,
                   use_kernel: bool = True) -> Callable:
    """Run a ``DistributedPlan``: the plan's per-shard derived kernel (or a
    caller-supplied differentiable local function, or the jnp oracle when
    ``use_kernel`` is False) inside ``shard_map`` with the plan's partition
    specs, followed by the plan's collective schedule.

    ``mesh`` is a live ``jax.sharding.Mesh`` whose axis names and sizes must
    match the plan's ``MeshShape``.  Returns ``fn(*global_operands) ->
    global_out``; operands bind exactly as in the single-chip path (storage
    shapes), only globally sized.
    """
    from repro.distributed.sharding import shard_map

    plan.check_mesh(mesh)
    if local_fn is None:
        if use_kernel:
            local_fn = emit_bundle(plan.bundle, out_dtype=jnp.float32,
                                   interpret=interpret)
        else:
            from repro.kernels import ref
            local_fn = functools.partial(ref.eval_nf, plan.local_nf)

    def body(*shards):
        y = local_fn(*shards)
        for step in plan.collectives:
            if step.kind == "psum":
                y = jax.lax.psum(y, step.mesh_axis)
            elif step.kind == "reduce_scatter":
                y = jax.lax.psum_scatter(y, step.mesh_axis,
                                         scatter_dimension=step.out_dim,
                                         tiled=True)
            elif step.kind == "all_gather":
                y = jax.lax.all_gather(y, step.mesh_axis, axis=step.out_dim,
                                       tiled=True)
            else:
                raise ValueError(f"unknown collective kind {step.kind!r}")
        return y if out_dtype is None else y.astype(out_dtype)

    return shard_map(body, mesh, in_specs=plan.jax_in_specs(),
                     out_specs=plan.jax_out_spec())
