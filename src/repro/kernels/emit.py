"""Generic Pallas emitter: an executable kernel from a derived ``Schedule``.

``emit_pallas(schedule)`` is the single code generator behind every derived
op: the grid, BlockSpecs, dimension semantics and scratch accumulator all
come from the schedule (which in turn was derived from the normalized, lifted
expression), so no kernel hand-writes its layout.  The in-block body is the
schedule's semiring:

* ``(mul, add)`` — the einsum the axis structure implies (a plain MXU dot
  for GEMM, elementwise multiply for Hadamard, a batched dot for the lifted
  expert axis), with f32 accumulation across the sigma (reduce) grid steps;
* any other registered combine/reduce pair (max-plus, min-plus) — operands
  are aligned to (out axes + contracted axes), paired with the combine op,
  folded with the reduce op in-block, and accumulated across sigma steps
  with the same reduce op from its identity element.

The accumulator flushes to the output dtype on the last sigma step.

Psi views ride as index-map offsets: an operand whose Access carried a
constant term gets a leading block-1 dimension whose block index is pinned
at the viewed slab (``OperandSpec.offsets``) — sliced operands run derived
kernels with no materialized copy.

``emit_bundle`` wraps a cached ``ScheduleBundle`` into the full executable
contract the ops layer uses (pad with the semiring's inert element, run,
slice the logical result), and ``emit_shard_map`` stacks the mesh level on
top: the same derived kernel (or the jnp oracle) runs per shard inside
``shard_map`` with a ``DistributedPlan``'s partition specs and collectives.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring
from repro.core.schedule import Schedule, ScheduleBundle

# jax renamed TPUCompilerParams -> CompilerParams; support both so the
# kernels run on every jax this repo targets.
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics) -> object:
    return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))


def _index_map(grid_dims: tuple[Optional[int], ...],
               offsets: tuple[int, ...] = ()) -> Callable:
    offs = offsets or (0,) * len(grid_dims)

    def imap(*gids):
        return tuple((gids[d] if d is not None else 0) + off
                     for d, off in zip(grid_dims, offs))
    return imap


def _jnp_combine(name: str) -> Callable:
    return getattr(jnp, semiring.combine_def(name).jnp_name)


def _general_combine(schedule: Schedule, combine_fn, reducer, vals):
    """Body for non-(mul, add) semirings: align every block to (out axes +
    contracted axes), pair with ``combine_fn``, fold the contraction with
    the reduce op's axis reducer."""
    joint = tuple(schedule.out.axes) + tuple(schedule.contracted)
    aligned = []
    for opn, v in zip(schedule.ins, vals):
        # squeeze block-1 dims outside the joint axes (the psi slab dim)
        keep = [i for i, ax in enumerate(opn.axes) if ax in joint]
        v = v.reshape(tuple(v.shape[i] for i in keep))
        src = {opn.axes[i]: pos for pos, i in enumerate(keep)}
        v = jnp.transpose(v, [src[ax] for ax in joint if ax in src])
        for pos, ax in enumerate(joint):
            if ax not in src:
                v = jnp.expand_dims(v, pos)
        aligned.append(v.astype(jnp.float32))
    out = functools.reduce(combine_fn, aligned)
    if schedule.contracted:
        red = tuple(range(len(schedule.out.axes), len(joint)))
        out = reducer(out, axis=red)
    return out


def emit_pallas(schedule: Schedule, combine=None, *, out_dtype=None,
                interpret: bool = False) -> Callable:
    """Build the ``pl.pallas_call`` a schedule describes.

    Returns ``fn(*operands) -> out`` over arrays of exactly the schedule's
    (padded) operand shapes.  ``combine`` overrides the schedule's pairing op
    by name (it defaults to ``schedule.combine``, which ``derive_schedule``
    copied from the expression's normal form).
    """
    ni = len(schedule.ins)
    out_dtype = jnp.dtype(out_dtype or jnp.float32)
    spec, in_keep = schedule.einsum_plan()
    red = schedule.reduce_grid_dim
    gk = schedule.grid[red].extent if red is not None else 0
    combine_name = combine or schedule.combine
    reduce_name = schedule.reduce_op
    multiplicative = (combine_name, reduce_name) == ("mul", "add")
    out_block = schedule.out.block
    if not multiplicative:
        combine_fn = _jnp_combine(combine_name)
        rdef = semiring.reduce_def(reduce_name)
        reducer = getattr(jnp, rdef.jnp_reducer)
        acc_step = getattr(jnp, rdef.jnp_name)
        identity = rdef.identity

    def body(*refs):
        o_ref = refs[ni]
        if multiplicative:
            squeezed = [
                refs[i][...].reshape(tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(schedule.ins, in_keep))
            ]
            val = jnp.einsum(spec, *squeezed,
                             preferred_element_type=jnp.float32)
        else:
            val = _general_combine(schedule, combine_fn, reducer,
                                   [refs[i][...] for i in range(ni)])
        val = val.reshape(out_block)
        if red is None:
            o_ref[...] = val.astype(out_dtype)
        else:
            acc_ref = refs[ni + 1]
            kk = pl.program_id(red)

            @pl.when(kk == 0)
            def _init():
                if multiplicative:
                    acc_ref[...] = jnp.zeros_like(acc_ref)
                else:
                    acc_ref[...] = jnp.full_like(acc_ref, identity)

            if multiplicative:
                acc_ref[...] += val
            else:
                acc_ref[...] = acc_step(acc_ref[...], val)

            @pl.when(kk == gk - 1)
            def _flush():
                o_ref[...] = acc_ref[...].astype(out_dtype)

    call = pl.pallas_call(
        body,
        grid=schedule.grid_extents,
        in_specs=[pl.BlockSpec(opn.block, _index_map(opn.grid_dims,
                                                     opn.offsets))
                  for opn in schedule.ins],
        out_specs=pl.BlockSpec(out_block, _index_map(schedule.out.grid_dims,
                                                     schedule.out.offsets)),
        out_shape=jax.ShapeDtypeStruct(schedule.out.shape, out_dtype),
        scratch_shapes=([pltpu.VMEM(out_block, jnp.float32)]
                        if red is not None else []),
        compiler_params=compiler_params(
            dimension_semantics=schedule.dimension_semantics),
        interpret=interpret,
    )

    def fn(*arrays):
        if len(arrays) != ni:
            raise ValueError(f"{schedule.name}: expected {ni} operands")
        for arr, opn in zip(arrays, schedule.ins):
            if not _shape_ok(tuple(arr.shape), opn):
                raise ValueError(
                    f"{schedule.name}: operand {opn.array} has shape "
                    f"{arr.shape}, schedule derived {opn.shape} — pad first")
        return call(*arrays)

    return fn


def _shape_ok(shp: tuple[int, ...], opn) -> bool:
    """A psi-view operand may be bound with MORE leading slabs than the
    pinned index needs; every other dim must match the schedule exactly."""
    if len(shp) != len(opn.shape):
        return False
    if shp == opn.shape:
        return True
    return (opn.is_psi_view and shp[0] >= opn.shape[0]
            and shp[1:] == opn.shape[1:])


# ---------------------------------------------------------------------------
# bundle executor: the ops-layer contract (collapse psi slabs, pad, run,
# slice) in one place, reused by the single-chip and shard_map paths
# ---------------------------------------------------------------------------

def _pad_to_shape(x: jax.Array, shape: tuple[int, ...],
                  value: float = 0.0) -> jax.Array:
    pads = [(0, t - d) for d, t in zip(x.shape, shape)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads, constant_values=value)
    return x


def emit_bundle(bundle: ScheduleBundle, *, out_dtype=None,
                interpret: bool = False) -> Callable:
    """Executable for a cached derivation over *logical storage* operands.

    Collapses a psi view's fixed leading dims to the flat slab dim the
    schedule pinned, pads every operand to the schedule's (padded) storage
    shape with the semiring's inert element, runs the emitted kernel, and
    slices the logical result back out.  The missing-inert-element error
    is only raised when padding is actually required.
    """
    sch = bundle.schedule
    kern = emit_pallas(sch, out_dtype=out_dtype, interpret=interpret)

    prep, needs_pad = [], False
    for spec, logical in zip(sch.ins, bundle.in_shapes):
        sym_rank = len(spec.shape) - (1 if spec.is_psi_view else 0)
        lead = len(logical) - sym_rank
        tail = tuple(logical[lead:])
        needs_pad |= tail != (spec.shape[1:] if spec.is_psi_view
                              else spec.shape)
        prep.append((lead, spec))
    if not needs_pad:
        pad_val = 0.0                        # nothing is ever padded
    elif len(sch.ins) == 1:
        # single operand: no pairing happens, so the inert pad is just the
        # reduce identity (e.g. -inf for a lone max-reduce)
        pad_val = semiring.reduce_def(sch.reduce_op).identity
    else:
        pad_val = semiring.pad_value(sch.combine, sch.reduce_op)
    out_slices = tuple(slice(0, d) for d in bundle.out_shape)

    def call(*arrays):
        padded = []
        for x, (lead, spec) in zip(arrays, prep):
            if spec.is_psi_view:
                if lead > 1:                 # several fixed dims -> one slab
                    x = x.reshape((-1,) + x.shape[lead:])
                target = (x.shape[0],) + spec.shape[1:]
            else:
                if lead:                     # all-zero psi index: slab 0
                    x = x.reshape((-1,) + x.shape[lead:])[0]
                target = spec.shape
            padded.append(_pad_to_shape(x, target, pad_val))
        return kern(*padded)[out_slices]

    return call


# ---------------------------------------------------------------------------
# the mesh level: the same derived kernel per shard, inside shard_map
# ---------------------------------------------------------------------------

def emit_shard_map(plan, mesh, local_fn: Optional[Callable] = None, *,
                   out_dtype=None, interpret: bool = False,
                   use_kernel: bool = True) -> Callable:
    """Run a ``DistributedPlan``: the plan's per-shard derived kernel (or a
    caller-supplied differentiable local function, or the jnp oracle when
    ``use_kernel`` is False) inside ``shard_map`` with the plan's partition
    specs, followed by the plan's collective schedule.

    ``mesh`` is a live ``jax.sharding.Mesh`` whose axis names and sizes must
    match the plan's ``MeshShape``.  Returns ``fn(*global_operands) ->
    global_out``; operands bind exactly as in the single-chip path (storage
    shapes), only globally sized.
    """
    from repro.distributed.sharding import shard_map

    plan.check_mesh(mesh)
    if local_fn is None:
        if use_kernel:
            local_fn = emit_bundle(plan.bundle, out_dtype=jnp.float32,
                                   interpret=interpret)
        else:
            from repro.kernels import ref
            local_fn = functools.partial(ref.eval_nf, plan.local_nf)

    def body(*shards):
        y = local_fn(*shards)
        for step in plan.collectives:
            if step.kind == "psum":
                y = jax.lax.psum(y, step.mesh_axis)
            elif step.kind == "reduce_scatter":
                y = jax.lax.psum_scatter(y, step.mesh_axis,
                                         scatter_dimension=step.out_dim,
                                         tiled=True)
            elif step.kind == "all_gather":
                y = jax.lax.all_gather(y, step.mesh_axis, axis=step.out_dim,
                                       tiled=True)
            else:
                raise ValueError(f"unknown collective kind {step.kind!r}")
        return y if out_dtype is None else y.astype(out_dtype)

    return shard_map(body, mesh, in_specs=plan.jax_in_specs(),
                     out_specs=plan.jax_out_spec())
