"""Generic Pallas emitter: an executable kernel from a derived ``Schedule``.

``emit_pallas(schedule)`` is the single code generator behind every derived
op: the grid, BlockSpecs, dimension semantics and scratch accumulator all
come from the schedule (which in turn was derived from the normalized, lifted
expression), so no kernel hand-writes its layout.  The in-block body is the
schedule's semiring:

* ``(mul, add)`` — the einsum the axis structure implies (a plain MXU dot
  for GEMM, elementwise multiply for Hadamard, a batched dot for the lifted
  expert axis), with f32 accumulation across the sigma (reduce) grid steps;
* any other registered combine/reduce pair (max-plus, min-plus) — operands
  are aligned to (out axes + contracted axes), paired with the combine op,
  folded with the reduce op in-block, and accumulated across sigma steps
  with the same reduce op from its identity element.

The accumulator flushes to the output dtype on the last sigma step.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring
from repro.core.schedule import Schedule

# jax renamed TPUCompilerParams -> CompilerParams; support both so the
# kernels run on every jax this repo targets.
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics) -> object:
    return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))


def _index_map(grid_dims: tuple[Optional[int], ...]) -> Callable:
    def imap(*gids):
        return tuple(gids[d] if d is not None else 0 for d in grid_dims)
    return imap


def _jnp_combine(name: str) -> Callable:
    return getattr(jnp, semiring.combine_def(name).jnp_name)


def _general_combine(schedule: Schedule, combine_fn, reducer, vals):
    """Body for non-(mul, add) semirings: align every block to (out axes +
    contracted axes), pair with ``combine_fn``, fold the contraction with
    the reduce op's axis reducer."""
    joint = tuple(schedule.out.axes) + tuple(schedule.contracted)
    aligned = []
    for opn, v in zip(schedule.ins, vals):
        src = {ax: i for i, ax in enumerate(opn.axes)}
        v = jnp.transpose(v, [src[ax] for ax in joint if ax in src])
        for pos, ax in enumerate(joint):
            if ax not in src:
                v = jnp.expand_dims(v, pos)
        aligned.append(v.astype(jnp.float32))
    out = functools.reduce(combine_fn, aligned)
    if schedule.contracted:
        red = tuple(range(len(schedule.out.axes), len(joint)))
        out = reducer(out, axis=red)
    return out


def emit_pallas(schedule: Schedule, combine=None, *, out_dtype=None,
                interpret: bool = False) -> Callable:
    """Build the ``pl.pallas_call`` a schedule describes.

    Returns ``fn(*operands) -> out`` over arrays of exactly the schedule's
    (padded) operand shapes.  ``combine`` overrides the schedule's pairing op
    by name (it defaults to ``schedule.combine``, which ``derive_schedule``
    copied from the expression's normal form).
    """
    ni = len(schedule.ins)
    out_dtype = jnp.dtype(out_dtype or jnp.float32)
    spec, in_keep = schedule.einsum_plan()
    red = schedule.reduce_grid_dim
    gk = schedule.grid[red].extent if red is not None else 0
    combine_name = combine or schedule.combine
    reduce_name = schedule.reduce_op
    multiplicative = (combine_name, reduce_name) == ("mul", "add")
    out_block = schedule.out.block
    if not multiplicative:
        combine_fn = _jnp_combine(combine_name)
        rdef = semiring.reduce_def(reduce_name)
        reducer = getattr(jnp, rdef.jnp_reducer)
        acc_step = getattr(jnp, rdef.jnp_name)
        identity = rdef.identity

    def body(*refs):
        o_ref = refs[ni]
        if multiplicative:
            squeezed = [
                refs[i][...].reshape(tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(schedule.ins, in_keep))
            ]
            val = jnp.einsum(spec, *squeezed,
                             preferred_element_type=jnp.float32)
        else:
            val = _general_combine(schedule, combine_fn, reducer,
                                   [refs[i][...] for i in range(ni)])
        val = val.reshape(out_block)
        if red is None:
            o_ref[...] = val.astype(out_dtype)
        else:
            acc_ref = refs[ni + 1]
            kk = pl.program_id(red)

            @pl.when(kk == 0)
            def _init():
                if multiplicative:
                    acc_ref[...] = jnp.zeros_like(acc_ref)
                else:
                    acc_ref[...] = jnp.full_like(acc_ref, identity)

            if multiplicative:
                acc_ref[...] += val
            else:
                acc_ref[...] = acc_step(acc_ref[...], val)

            @pl.when(kk == gk - 1)
            def _flush():
                o_ref[...] = acc_ref[...].astype(out_dtype)

    call = pl.pallas_call(
        body,
        grid=schedule.grid_extents,
        in_specs=[pl.BlockSpec(opn.block, _index_map(opn.grid_dims))
                  for opn in schedule.ins],
        out_specs=pl.BlockSpec(out_block, _index_map(schedule.out.grid_dims)),
        out_shape=jax.ShapeDtypeStruct(schedule.out.shape, out_dtype),
        scratch_shapes=([pltpu.VMEM(out_block, jnp.float32)]
                        if red is not None else []),
        compiler_params=compiler_params(
            dimension_semantics=schedule.dimension_semantics),
        interpret=interpret,
    )

    def fn(*arrays):
        if len(arrays) != ni:
            raise ValueError(f"{schedule.name}: expected {ni} operands")
        for arr, opn in zip(arrays, schedule.ins):
            if tuple(arr.shape) != opn.shape:
                raise ValueError(
                    f"{schedule.name}: operand {opn.array} has shape "
                    f"{arr.shape}, schedule derived {opn.shape} — pad first")
        return call(*arrays)

    return fn
