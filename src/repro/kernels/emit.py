"""Generic Pallas emitter: an executable kernel from a derived ``Schedule``.

``emit_pallas(schedule)`` is the single code generator behind every derived
op: the grid, BlockSpecs, dimension semantics and scratch accumulator all
come from the schedule (which in turn was derived from the normalized, lifted
expression), so no kernel hand-writes its layout.  The in-block body is the
schedule's semiring:

* ``(mul, add)`` — the einsum the axis structure implies (a plain MXU dot
  for GEMM, elementwise multiply for Hadamard, a batched dot for the lifted
  expert axis), with f32 accumulation across the sigma (reduce) grid steps;
* any other registered combine/reduce pair (max-plus, min-plus) — operands
  are aligned to (out axes + contracted axes), paired with the combine op,
  folded with the reduce op in-block, and accumulated across sigma steps
  with the same reduce op from its identity element.

The accumulator flushes to the output dtype on the last sigma step.

Psi views ride as index-map offsets: an operand whose Access carried a
constant term gets a leading block-1 dimension whose block index is pinned
at the viewed slab (``OperandSpec.offsets``) — sliced operands run derived
kernels with no materialized copy.

``emit_bundle`` wraps a cached ``ScheduleBundle`` into the full executable
contract the ops layer uses (pad with the semiring's inert element, run,
slice the logical result), and ``emit_shard_map`` stacks the mesh level on
top: the same derived kernel (or the jnp oracle) runs per shard inside
``shard_map`` with a ``DistributedPlan``'s partition specs and collectives.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring
from repro.core import schedule as sched_mod
from repro.core.schedule import Schedule, ScheduleBundle, StreamingSchedule
from repro.core.semiring import MASK_NEG_INF as NEG_INF

# jax renamed TPUCompilerParams -> CompilerParams; support both so the
# kernels run on every jax this repo targets.
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics) -> object:
    return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))


#: largest page table ``_index_map`` will lower.  The per-page slab lookup
#: unrolls as one ``jnp.where`` select per table entry (Pallas index maps
#: may not capture constant arrays), so the emitted index map grows
#: linearly in the view's page count — past this bound the fold is
#: pathological and the emitter refuses instead of silently producing it.
MAX_PAGE_TABLE_ENTRIES = 1024


def _index_map(grid_dims: tuple[Optional[int], ...],
               offsets: tuple[int, ...] = (),
               page_table: Optional[tuple] = None,
               page_slot_dim: Optional[int] = None) -> Callable:
    """BlockSpec index map from the operand's grid bindings.

    ``offsets`` add a constant block offset per dimension (a psi view's
    slab).  ``page_table`` generalizes the constant to one-per-grid-step on
    the *leading* dimension: streamed block ``k`` reads stored block
    ``page_table[k]`` — the static lookup that lowers a paged psi view's
    per-page slab offsets without a gather-copy.  The lookup is unrolled
    as a ``jnp.where`` fold over integer literals because Pallas index
    maps may not capture constant arrays; tables past
    ``MAX_PAGE_TABLE_ENTRIES`` raise instead of emitting the fold.

    With ``page_slot_dim`` the table is stacked 2-D ``[slot, k]`` (batched
    multi-slot decode): the fold runs over the row-major flattened table on
    the combined key ``s * n_steps + k``, with ``s`` read from grid axis
    ``page_slot_dim`` — same select-fold, two grid axes keying it.  The
    entry budget applies to the flattened table."""
    if page_table is not None and page_slot_dim is not None:
        n_steps = len(page_table[0])
        flat_table = tuple(t for row in page_table for t in row)
    elif page_table is not None:
        n_steps = None
        flat_table = tuple(page_table)
    else:
        n_steps = None
        flat_table = None
    if flat_table is not None and len(flat_table) > MAX_PAGE_TABLE_ENTRIES:
        raise ValueError(
            f"page table with {len(flat_table)} entries: the paged index "
            f"map lowers one jnp.where select per entry, linear in the "
            f"view's page count — past {MAX_PAGE_TABLE_ENTRIES} entries "
            f"the unrolled fold is pathological; split the view or raise "
            f"emit.MAX_PAGE_TABLE_ENTRIES deliberately")
    offs = offsets or (0,) * len(grid_dims)

    def _lookup(i):
        slab = jnp.int32(flat_table[0])
        for k, t in enumerate(flat_table[1:], start=1):
            slab = jnp.where(i == k, jnp.int32(t), slab)
        return slab

    def imap(*gids):
        idx = []
        for dim, (d, off) in enumerate(zip(grid_dims, offs)):
            i = (gids[d] if d is not None else 0) + off
            if dim == 0 and flat_table is not None:
                if n_steps is not None:
                    i = gids[page_slot_dim] * n_steps + i
                i = _lookup(i)
            idx.append(i)
        return tuple(idx)
    return imap


def _jnp_combine(name: str) -> Callable:
    return getattr(jnp, semiring.combine_def(name).jnp_name)


def _general_combine(schedule: Schedule, combine_fn, reducer, vals):
    """Body for non-(mul, add) semirings: align every block to (out axes +
    contracted axes), pair with ``combine_fn``, fold the contraction with
    the reduce op's axis reducer."""
    joint = tuple(schedule.out.axes) + tuple(schedule.contracted)
    aligned = []
    for opn, v in zip(schedule.ins, vals):
        # squeeze block-1 dims outside the joint axes (the psi slab dim)
        keep = [i for i, ax in enumerate(opn.axes) if ax in joint]
        v = v.reshape(tuple(v.shape[i] for i in keep))
        src = {opn.axes[i]: pos for pos, i in enumerate(keep)}
        v = jnp.transpose(v, [src[ax] for ax in joint if ax in src])
        for pos, ax in enumerate(joint):
            if ax not in src:
                v = jnp.expand_dims(v, pos)
        aligned.append(v.astype(jnp.float32))
    out = functools.reduce(combine_fn, aligned)
    if schedule.contracted:
        red = tuple(range(len(schedule.out.axes), len(joint)))
        out = reducer(out, axis=red)
    return out


def emit_pallas(schedule: Schedule, combine=None, *, out_dtype=None,
                interpret: bool = False,
                acc_dtype=None) -> Callable:
    """Build the ``pl.pallas_call`` a schedule describes.

    Returns ``fn(*operands) -> out`` over arrays of exactly the schedule's
    (padded) operand shapes.  ``combine`` overrides the schedule's pairing op
    by name (it defaults to ``schedule.combine``, which ``derive_schedule``
    copied from the expression's normal form).  ``acc_dtype`` is the
    accumulator the solver budgeted for — it becomes the MXU
    ``preferred_element_type`` and the sigma scratch dtype; only the
    (mul, add) semiring has non-f32 accumulation paths.
    """
    ni = len(schedule.ins)
    out_dtype = jnp.dtype(out_dtype or jnp.float32)
    acc_dtype = jnp.dtype(acc_dtype or jnp.float32)
    spec, in_keep = schedule.einsum_plan()
    red = schedule.reduce_grid_dim
    gk = schedule.grid[red].extent if red is not None else 0
    combine_name = combine or schedule.combine
    reduce_name = schedule.reduce_op
    multiplicative = (combine_name, reduce_name) == ("mul", "add")
    if acc_dtype != jnp.float32 and not multiplicative:
        raise ValueError(
            f"acc_dtype={acc_dtype} requires the (mul, add) semiring, got "
            f"({combine_name!r}, {reduce_name!r})")
    out_block = schedule.out.block
    if not multiplicative:
        combine_fn = _jnp_combine(combine_name)
        rdef = semiring.reduce_def(reduce_name)
        reducer = getattr(jnp, rdef.jnp_reducer)
        acc_step = getattr(jnp, rdef.jnp_name)
        identity = rdef.identity

    def body(*refs):
        o_ref = refs[ni]
        if multiplicative:
            squeezed = [
                refs[i][...].reshape(tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(schedule.ins, in_keep))
            ]
            val = jnp.einsum(spec, *squeezed,
                             preferred_element_type=acc_dtype)
        else:
            val = _general_combine(schedule, combine_fn, reducer,
                                   [refs[i][...] for i in range(ni)])
        val = val.reshape(out_block)
        if red is None:
            o_ref[...] = val.astype(out_dtype)
        else:
            acc_ref = refs[ni + 1]
            kk = pl.program_id(red)

            @pl.when(kk == 0)
            def _init():
                if multiplicative:
                    acc_ref[...] = jnp.zeros_like(acc_ref)
                else:
                    acc_ref[...] = jnp.full_like(acc_ref, identity)

            if multiplicative:
                acc_ref[...] += val
            else:
                acc_ref[...] = acc_step(acc_ref[...], val)

            @pl.when(kk == gk - 1)
            def _flush():
                o_ref[...] = acc_ref[...].astype(out_dtype)

    call = pl.pallas_call(
        body,
        grid=schedule.grid_extents,
        in_specs=[pl.BlockSpec(opn.block, _index_map(opn.grid_dims,
                                                     opn.offsets))
                  for opn in schedule.ins],
        out_specs=pl.BlockSpec(out_block, _index_map(schedule.out.grid_dims,
                                                     schedule.out.offsets)),
        out_shape=jax.ShapeDtypeStruct(schedule.out.shape, out_dtype),
        scratch_shapes=([pltpu.VMEM(out_block, acc_dtype)]
                        if red is not None else []),
        compiler_params=compiler_params(
            dimension_semantics=schedule.dimension_semantics),
        interpret=interpret,
    )

    def fn(*arrays):
        if len(arrays) != ni:
            raise ValueError(f"{schedule.name}: expected {ni} operands")
        for arr, opn in zip(arrays, schedule.ins):
            if not _shape_ok(tuple(arr.shape), opn):
                raise ValueError(
                    f"{schedule.name}: operand {opn.array} has shape "
                    f"{arr.shape}, schedule derived {opn.shape} — pad first")
        return call(*arrays)

    return fn


def _shape_ok(shp: tuple[int, ...], opn) -> bool:
    """A psi-view operand may be bound with MORE leading slabs than the
    pinned index needs; every other dim must match the schedule exactly."""
    if len(shp) != len(opn.shape):
        return False
    if shp == opn.shape:
        return True
    return (opn.is_psi_view and shp[0] >= opn.shape[0]
            and shp[1:] == opn.shape[1:])


# ---------------------------------------------------------------------------
# recurrent emitter: the sigma accumulator generalized to a typed carried-
# state monoid — online softmax, the SSD chunked scan and the RG-LRU gated
# scan are registered *kinds* sharing one init/step/flush driver
# ---------------------------------------------------------------------------

def _cell_shape(spec) -> tuple[int, ...]:
    """An operand's per-grid-cell block: its block extents on the dims no
    grid axis drives (the derived analogue of squeezing the lifted dims)."""
    return tuple(b for b, d in zip(spec.block, spec.grid_dims) if d is None)


def _softmax_kind(rs: StreamingSchedule, *, scale, causal, logical_stream,
                  out_dtype, acc_dtype):
    """The online-softmax monoid: running max ``m`` + denominator ``l`` per
    output row and the accumulator *rescaled* by ``exp(m_prev - m_new)``
    each streamed step; the flush divides by ``l``.  Masking is positional
    and derived from the schedule's streamed-axis metadata: ``causal``
    keeps keys at or before the query's absolute position, ``window`` drops
    keys more than ``window`` behind it, ``prefix_len`` re-admits the
    bidirectional prefix block (PaLI prefix-LM), and ``logical_stream``
    masks keys the pad added — each with its block-skip, so fully-masked
    streamed blocks never run.
    """
    ni = len(rs.ins)
    bq, bk = rs.row_block, rs.stream_block
    stream_dim = rs.stream_grid_dim
    nk = rs.grid[stream_dim].extent
    row_dim = rs.out.grid_dims[rs.out.axes.index(rs.row_axis)]
    sk_pad = nk * bk
    masked_pad = logical_stream is not None and logical_stream < sk_pad
    window, prefix_len = rs.window, rs.prefix_len
    if (window or prefix_len) and not causal:
        raise ValueError(
            f"window={window} / prefix_len={prefix_len} require causal "
            "attention (the honor-or-raise contract of _chunk_mask)")

    # both in-block contractions as derived einsum plans (the axis structure
    # of the blocks, not a hand-chosen spec)
    scores_plan, scores_keep = rs.stages[0].einsum_plan()
    ctx_plan, ctx_keep = rs.stages[1].einsum_plan()
    acc_block = rs.acc_block

    ns = len(rs.state_outs)           # 0 (plain) or 2 (exported (m, l))

    def body(*refs):
        o_ref = refs[ni]
        m_ref, l_ref, acc_ref = refs[ni + 1 + ns:ni + 4 + ns]
        qi = pl.program_id(row_dim)
        ki = pl.program_id(stream_dim)

        @pl.when(ki == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # skip streamed blocks that are entirely masked: strictly above the
        # causal diagonal, or entirely behind the local window.  A block
        # touching the bidirectional prefix region (some row AND some key
        # below prefix_len) is re-admitted against BOTH skips — prefix
        # blocks sit above the diagonal too.  Key padding always skips.
        admit = (jnp.logical_and(ki * bk < prefix_len, qi * bq < prefix_len)
                 if prefix_len else None)
        run = True
        if causal:
            run = ki * bk <= qi * bq + bq - 1
            if admit is not None:
                run = jnp.logical_or(run, admit)
        if window:
            below = ki * bk + bk - 1 > qi * bq - window
            if admit is not None:
                below = jnp.logical_or(below, admit)
            run = jnp.logical_and(run, below)
        if masked_pad:
            run = jnp.logical_and(run, ki * bk < logical_stream)

        @pl.when(run)
        def _step():
            q, k = (refs[i][...].reshape(
                tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(rs.ins[:2], scores_keep)))
            s = jnp.einsum(scores_plan, q, k,
                           preferred_element_type=acc_dtype) * scale
            need_mask = causal or masked_pad
            if need_mask:
                qpos = qi * bq + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 0)
                kpos = ki * bk + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 1)
                mask = jnp.ones((bq, bk), bool)
                if causal:
                    mask = kpos <= qpos
                    if window:
                        mask = jnp.logical_and(mask, kpos > qpos - window)
                    if prefix_len:
                        mask = jnp.logical_or(
                            mask, jnp.logical_and(qpos < prefix_len,
                                                  kpos < prefix_len))
                if masked_pad:
                    mask = jnp.logical_and(mask, kpos < logical_stream)
                s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[:, 0]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[:, None])
            corr = jnp.exp(m_prev - m_new)
            l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
            m_ref[:, 0] = m_new
            v = refs[2][...].reshape(
                tuple(rs.ins[2].block[d] for d in ctx_keep[1]))
            acc_ref[...] = (
                acc_ref[...] * corr[:, None]
                + jnp.einsum(ctx_plan, p.astype(v.dtype), v,
                             preferred_element_type=acc_dtype
                             ).reshape(acc_block))

        @pl.when(ki == nk - 1)
        def _flush():
            o_ref[...] = (acc_ref[...] /
                          jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                          ).astype(out_dtype).reshape(rs.out.block)
            if ns:                    # export the final (m, l) statistics
                refs[ni + 1][...] = m_ref[...].reshape(
                    rs.state_outs[0].block)
                refs[ni + 2][...] = l_ref[...].reshape(
                    rs.state_outs[1].block)

    scratch = [
        pltpu.VMEM((bq, 1), acc_dtype),              # running max m
        pltpu.VMEM((bq, 1), acc_dtype),              # denominator l
        pltpu.VMEM(acc_block, acc_dtype),            # rescaled acc
    ]
    return body, scratch


def _ssd_kind(rs: StreamingSchedule, *, scale, causal, logical_stream,
              out_dtype, acc_dtype):
    """The SSD (Mamba-2) monoid: one inter-chunk state ``h`` (head,
    head_dim, state_dim) per grid cell, stepped ``h' = chunk_decay * h +
    B'(decay . x)`` and exported at the last chunk.  Per streamed step the
    two derived stage contractions run on the diagonal chunk — G = C.B'
    and y = P.x — welded through the segsum decay weighting ``P = G . L``
    (the monoid's nonlinearity, exactly where softmax's exp sits), plus the
    monoid's state readout ``C.h`` and state update.  Operand order:
    (C, B, X, dA, H0); outputs (y, h_final)."""
    ni = len(rs.ins)
    stream_dim = rs.stream_grid_dim
    nk = rs.grid[stream_dim].extent
    scores_plan, _ = rs.stages[0].einsum_plan()         # "in,jn->ij"
    ctx_plan, _ = rs.stages[1].einsum_plan()            # "hij,jhp->ihp"
    c_cell = _cell_shape(rs.ins[0])                     # (q, n)
    b_cell = _cell_shape(rs.ins[1])                     # (q, n)
    x_cell = _cell_shape(rs.ins[2])                     # (q, h, p)
    da_cell = _cell_shape(rs.ins[3])                    # (q, h)
    h_cell = _cell_shape(rs.ins[4])                     # (h, p, n)
    q = da_cell[0]
    n_so = len(rs.state_outs)         # 1 (h only) or 2 (+ per-chunk h_in)

    def body(*refs):
        y_ref, hf_ref = refs[ni], refs[ni + 1]
        h_ref = refs[ni + 1 + n_so]
        ki = pl.program_id(stream_dim)

        @pl.when(ki == 0)
        def _init():
            h_ref[...] = refs[4][...].reshape(h_cell).astype(acc_dtype)

        Cb = refs[0][...].reshape(c_cell).astype(acc_dtype)
        Bb = refs[1][...].reshape(b_cell).astype(acc_dtype)
        Xb = refs[2][...].reshape(x_cell).astype(acc_dtype)
        dAb = refs[3][...].reshape(da_cell).astype(acc_dtype)
        h_prev = h_ref[...]
        if n_so == 2:                 # checkpoint the state entering ki
            refs[ni + 2][...] = h_prev.reshape(rs.state_outs[1].block)
        csh = jnp.transpose(jnp.cumsum(dAb, axis=0))        # (h, i)
        seg = csh[:, :, None] - csh[:, None, :]             # (h, i, j)
        tril = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
            jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
        L = jnp.exp(jnp.where(tril[None], seg, NEG_INF))    # (h, i, j)
        G = jnp.einsum(scores_plan, Cb, Bb,
                       preferred_element_type=acc_dtype)    # (i, j)
        P = G[None] * L                                     # (h, i, j)
        y = jnp.einsum(ctx_plan, P, Xb,
                       preferred_element_type=acc_dtype)    # (i, h, p)
        in_decay = jnp.exp(csh)                             # (h, i)
        t_off = jnp.einsum("in,hpn->ihp", Cb, h_prev,
                           preferred_element_type=acc_dtype)
        y = y + t_off * jnp.transpose(in_decay)[:, :, None]
        y_ref[...] = y.astype(out_dtype).reshape(rs.out.block)
        total = csh[:, -1]                                  # (h,)
        decay_states = jnp.exp(total[:, None] - csh)        # (h, j)
        Xd = Xb * jnp.transpose(decay_states)[:, :, None]   # (j, h, p)
        S = jnp.einsum("jn,jhp->hpn", Bb, Xd,
                       preferred_element_type=acc_dtype)
        h_ref[...] = jnp.exp(total)[:, None, None] * h_prev + S

        @pl.when(ki == nk - 1)
        def _flush():
            hf_ref[...] = h_ref[...].reshape(rs.state_outs[0].block)

    scratch = [pltpu.VMEM(h_cell, acc_dtype)]
    return body, scratch


def _gated_kind(rs: StreamingSchedule, *, scale, causal, logical_stream,
                out_dtype, acc_dtype):
    """The gated (RG-LRU) monoid: one state per channel, stepped ``h' = a h
    + b`` — the contraction-free recurrence.  Per streamed chunk the body
    exponentiates the gate log, scans the chunk with the associative gated
    combine, re-bases onto the carried state via the chunk's gate cumprod,
    and exports the final state.  Operand order: (log_a, b, H0); outputs
    (h_seq, h_final)."""
    ni = len(rs.ins)
    stream_dim = rs.stream_grid_dim
    nk = rs.grid[stream_dim].extent
    a_cell = _cell_shape(rs.ins[0])                     # (q, w)
    h_cell = rs.state_blocks()[0]                       # (1, w)

    def body(*refs):
        y_ref, hf_ref = refs[ni], refs[ni + 1]
        h_ref = refs[ni + 2]
        ki = pl.program_id(stream_dim)

        @pl.when(ki == 0)
        def _init():
            h_ref[...] = refs[2][...].reshape(h_cell).astype(acc_dtype)

        a = jnp.exp(refs[0][...].reshape(a_cell).astype(acc_dtype))
        b = refs[1][...].reshape(a_cell).astype(acc_dtype)

        def comb(x, y):
            return (x[0] * y[0], y[0] * x[1] + y[1])

        aa, hh = jax.lax.associative_scan(comb, (a, b), axis=0)
        hh = hh + aa * h_ref[...]                       # re-base on carry
        y_ref[...] = hh.astype(out_dtype).reshape(rs.out.block)
        h_ref[...] = hh[-1:]

        @pl.when(ki == nk - 1)
        def _flush():
            hf_ref[...] = h_ref[...].reshape(rs.state_outs[0].block)

    scratch = [pltpu.VMEM(h_cell, acc_dtype)]
    return body, scratch


def _flash_dq_kind(rs: StreamingSchedule, *, scale, causal, logical_stream,
                   out_dtype, acc_dtype):
    """Flash backward dQ: the same weld orientation as the forward (rows =
    queries, stream = keys) with the carried per-row gradient accumulator.
    Each streamed step recomputes the masked score block from stage 1,
    reconstructs ``p = exp(s - lse)`` from the saved (m, l) statistics,
    forms ``dS = p * (dO.Vᵀ - D)`` and folds stage 2's ``dS . K`` into the
    accumulator; the flush applies the score scale once.  Block-skip and
    in-block masking are byte-for-byte the forward's — the backward visits
    exactly the blocks the forward did.  Operand order:
    (Q, K, K2, dO, V, M, L, D)."""
    ni = len(rs.ins)
    bq, bk = rs.row_block, rs.stream_block
    stream_dim = rs.stream_grid_dim
    nk = rs.grid[stream_dim].extent
    row_dim = rs.out.grid_dims[rs.out.axes.index(rs.row_axis)]
    sk_pad = nk * bk
    masked_pad = logical_stream is not None and logical_stream < sk_pad
    window, prefix_len = rs.window, rs.prefix_len
    if (window or prefix_len) and not causal:
        raise ValueError(
            f"window={window} / prefix_len={prefix_len} require causal "
            "attention (the honor-or-raise contract of _chunk_mask)")
    scores_plan, scores_keep = rs.stages[0].einsum_plan()
    out_plan, out_keep = rs.stages[1].einsum_plan()
    acc_block = rs.acc_block                            # (bq, hd)
    vd = rs.ins[3].block[-1]

    def body(*refs):
        o_ref = refs[ni]
        acc_ref = refs[ni + 1]
        qi = pl.program_id(row_dim)
        ki = pl.program_id(stream_dim)

        @pl.when(ki == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        admit = (jnp.logical_and(ki * bk < prefix_len, qi * bq < prefix_len)
                 if prefix_len else None)
        run = True
        if causal:
            run = ki * bk <= qi * bq + bq - 1
            if admit is not None:
                run = jnp.logical_or(run, admit)
        if window:
            below = ki * bk + bk - 1 > qi * bq - window
            if admit is not None:
                below = jnp.logical_or(below, admit)
            run = jnp.logical_and(run, below)
        if masked_pad:
            run = jnp.logical_and(run, ki * bk < logical_stream)

        @pl.when(run)
        def _step():
            q, k = (refs[i][...].reshape(
                tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(rs.ins[:2], scores_keep)))
            s = jnp.einsum(scores_plan, q, k,
                           preferred_element_type=acc_dtype) * scale
            need_mask = causal or masked_pad
            if need_mask:
                qpos = qi * bq + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 0)
                kpos = ki * bk + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 1)
                mask = jnp.ones((bq, bk), bool)
                if causal:
                    mask = kpos <= qpos
                    if window:
                        mask = jnp.logical_and(mask, kpos > qpos - window)
                    if prefix_len:
                        mask = jnp.logical_or(
                            mask, jnp.logical_and(qpos < prefix_len,
                                                  kpos < prefix_len))
                if masked_pad:
                    mask = jnp.logical_and(mask, kpos < logical_stream)
                s = jnp.where(mask, s, NEG_INF)
            mv = refs[5][...].reshape((bq,))
            lv = refs[6][...].reshape((bq,))
            dl = refs[7][...].reshape((bq,))
            lse = mv + jnp.log(jnp.maximum(lv, 1e-30))
            p = jnp.exp(s - lse[:, None]).astype(acc_dtype)
            do = refs[3][...].reshape((bq, vd)).astype(acc_dtype)
            vb = refs[4][...].reshape((bk, vd)).astype(acc_dtype)
            dp = jnp.einsum("ad,bd->ab", do, vb,
                            preferred_element_type=acc_dtype)
            ds = p * (dp - dl[:, None]).astype(acc_dtype)
            k2 = refs[2][...].reshape(
                tuple(rs.ins[2].block[d] for d in out_keep[1])
                ).astype(acc_dtype)
            acc_ref[...] += jnp.einsum(
                out_plan, ds, k2,
                preferred_element_type=acc_dtype).reshape(acc_block)

        @pl.when(ki == nk - 1)
        def _flush():
            o_ref[...] = (acc_ref[...] * scale).astype(out_dtype).reshape(
                rs.out.block)

    scratch = [pltpu.VMEM(acc_block, acc_dtype)]
    return body, scratch


def _flash_dkv_kind(rs: StreamingSchedule, *, scale, causal, logical_stream,
                    out_dtype, acc_dtype):
    """Flash backward dK/dV: the *transposed* weld — rows are key
    positions, the stream is query positions.  Each streamed step
    recomputes the transposed score block, reconstructs ``p``, contracts
    ``dSᵀ . Q`` into the dK accumulator (the main output) and folds
    ``pᵀ . dO`` into the carried dV, exported per row block.  The
    block-skip conditions mirror the forward's with the roles swapped, and
    padded query positions are always masked (their saved statistics can
    be degenerate).  Operand order: (K, Q, Q2, dO, V, M, L, D)."""
    ni = len(rs.ins)
    bj, bi = rs.row_block, rs.stream_block
    stream_dim = rs.stream_grid_dim
    nk = rs.grid[stream_dim].extent
    row_dim = rs.out.grid_dims[rs.out.axes.index(rs.row_axis)]
    si_pad = nk * bi
    masked_pad = logical_stream is not None and logical_stream < si_pad
    window, prefix_len = rs.window, rs.prefix_len
    if (window or prefix_len) and not causal:
        raise ValueError(
            f"window={window} / prefix_len={prefix_len} require causal "
            "attention (the honor-or-raise contract of _chunk_mask)")
    scores_plan, scores_keep = rs.stages[0].einsum_plan()
    out_plan, out_keep = rs.stages[1].einsum_plan()
    acc_block = rs.acc_block                            # (bj, hd)
    dv_block = rs.state_blocks()[0]                     # (bj, vd)
    vd = rs.ins[3].block[-1]

    def body(*refs):
        o_ref, dv_out = refs[ni], refs[ni + 1]
        dk_ref, dv_ref = refs[ni + 2], refs[ni + 3]
        ji = pl.program_id(row_dim)
        ki = pl.program_id(stream_dim)

        @pl.when(ki == 0)
        def _init():
            dk_ref[...] = jnp.zeros_like(dk_ref)
            dv_ref[...] = jnp.zeros_like(dv_ref)

        admit = (jnp.logical_and(ji * bj < prefix_len, ki * bi < prefix_len)
                 if prefix_len else None)
        run = True
        if causal:
            run = ji * bj <= ki * bi + bi - 1
            if admit is not None:
                run = jnp.logical_or(run, admit)
        if window:
            below = ji * bj + bj - 1 > ki * bi - window
            if admit is not None:
                below = jnp.logical_or(below, admit)
            run = jnp.logical_and(run, below)
        if masked_pad:
            run = jnp.logical_and(run, ki * bi < logical_stream)

        @pl.when(run)
        def _step():
            k, qb = (refs[i][...].reshape(
                tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(rs.ins[:2], scores_keep)))
            s = jnp.einsum(scores_plan, k, qb,
                           preferred_element_type=acc_dtype) * scale
            need_mask = causal or masked_pad
            if need_mask:
                kpos = ji * bj + jax.lax.broadcasted_iota(
                    jnp.int32, (bj, bi), 0)
                qpos = ki * bi + jax.lax.broadcasted_iota(
                    jnp.int32, (bj, bi), 1)
                mask = jnp.ones((bj, bi), bool)
                if causal:
                    mask = kpos <= qpos
                    if window:
                        mask = jnp.logical_and(mask, kpos > qpos - window)
                    if prefix_len:
                        mask = jnp.logical_or(
                            mask, jnp.logical_and(qpos < prefix_len,
                                                  kpos < prefix_len))
                if masked_pad:
                    mask = jnp.logical_and(mask, qpos < logical_stream)
                s = jnp.where(mask, s, NEG_INF)
            mv = refs[5][...].reshape((bi,))
            lv = refs[6][...].reshape((bi,))
            dl = refs[7][...].reshape((bi,))
            lse = mv + jnp.log(jnp.maximum(lv, 1e-30))
            p = jnp.exp(s - lse[None, :]).astype(acc_dtype)   # (bj, bi)
            do = refs[3][...].reshape((bi, vd)).astype(acc_dtype)
            vb = refs[4][...].reshape((bj, vd)).astype(acc_dtype)
            dp = jnp.einsum("ad,bd->ba", do, vb,
                            preferred_element_type=acc_dtype)
            ds = p * (dp - dl[None, :]).astype(acc_dtype)
            q2 = refs[2][...].reshape(
                tuple(rs.ins[2].block[d] for d in out_keep[1])
                ).astype(acc_dtype)
            dk_ref[...] += jnp.einsum(
                out_plan, ds, q2,
                preferred_element_type=acc_dtype).reshape(acc_block)
            dv_ref[...] += jnp.einsum(
                "ab,bd->ad", p, do,
                preferred_element_type=acc_dtype).reshape(dv_block)

        @pl.when(ki == nk - 1)
        def _flush():
            o_ref[...] = (dk_ref[...] * scale).astype(out_dtype).reshape(
                rs.out.block)
            dv_out[...] = dv_ref[...].reshape(rs.state_outs[0].block)

    scratch = [pltpu.VMEM(acc_block, acc_dtype),
               pltpu.VMEM(dv_block, acc_dtype)]
    return body, scratch


def _ssd_backward_kind(rs: StreamingSchedule, *, scale, causal,
                       logical_stream, out_dtype, acc_dtype):
    """The SSD backward monoid over *reversed* chunks (the ops layer flips
    the chunk axis): the carried state is the inter-chunk cotangent ``dh``,
    seeded from the final-state cotangent ``dHf`` at step 0.  Each streamed
    step replays the forward chunk factoring — same einsums, same order —
    from the saved state checkpoint ``Hin``, then chains every cotangent:
    ``dX`` is the main output, ``dB``/``dC``/``ddA`` export per step,
    ``dh`` steps backward and flushes as ``dh0``.  Operand order:
    (C, B, dY, X, dA, Hin, dHf); outputs (dX, dh0, dB, dC, ddA)."""
    ni = len(rs.ins)
    stream_dim = rs.stream_grid_dim
    nk = rs.grid[stream_dim].extent
    scores_plan, _ = rs.stages[0].einsum_plan()         # "in,jn->ij"
    ctx_plan, _ = rs.stages[1].einsum_plan()            # "hij,ihp->jhp"
    c_cell = _cell_shape(rs.ins[0])                     # (q, n)
    b_cell = _cell_shape(rs.ins[1])                     # (q, n)
    dy_cell = _cell_shape(rs.ins[2])                    # (q, h, p)
    x_cell = _cell_shape(rs.ins[3])                     # (q, h, p)
    da_cell = _cell_shape(rs.ins[4])                    # (q, h)
    h_cell = _cell_shape(rs.ins[5])                     # (h, p, n)
    q, hdim = da_cell

    def body(*refs):
        dx_ref = refs[ni]
        dh0_ref, db_ref, dc_ref, dda_ref = refs[ni + 1:ni + 5]
        dh_ref = refs[ni + 5]
        ki = pl.program_id(stream_dim)

        @pl.when(ki == 0)
        def _init():
            dh_ref[...] = refs[6][...].reshape(h_cell).astype(acc_dtype)

        Cb = refs[0][...].reshape(c_cell).astype(acc_dtype)
        Bb = refs[1][...].reshape(b_cell).astype(acc_dtype)
        dYb = refs[2][...].reshape(dy_cell).astype(acc_dtype)
        Xb = refs[3][...].reshape(x_cell).astype(acc_dtype)
        dAb = refs[4][...].reshape(da_cell).astype(acc_dtype)
        Hc = refs[5][...].reshape(h_cell).astype(acc_dtype)
        dh = dh_ref[...]

        # replay the forward chunk factoring (identical order of ops)
        csh = jnp.transpose(jnp.cumsum(dAb, axis=0))        # (h, i)
        seg = csh[:, :, None] - csh[:, None, :]
        tril = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
            jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
        L = jnp.exp(jnp.where(tril[None], seg, NEG_INF))    # (h, i, j)
        G = jnp.einsum(scores_plan, Cb, Bb,
                       preferred_element_type=acc_dtype)
        P = G[None] * L
        in_decay = jnp.exp(csh)                             # (h, i)
        t_off = jnp.einsum("in,hpn->ihp", Cb, Hc,
                           preferred_element_type=acc_dtype)
        total = csh[:, -1]                                  # (h,)
        decay_states = jnp.exp(total[:, None] - csh)        # (h, j)
        Xd = Xb * jnp.transpose(decay_states)[:, :, None]   # (j, h, p)

        # chain the cotangents back through the factoring
        dtotal = jnp.einsum("hpn,hpn->h", dh, Hc,
                            preferred_element_type=acc_dtype) * \
            jnp.exp(total)
        dh_prev = jnp.exp(total)[:, None, None] * dh
        dBb = jnp.einsum("hpn,jhp->jn", dh, Xd,
                         preferred_element_type=acc_dtype)
        dXd = jnp.einsum("jn,hpn->jhp", Bb, dh,
                         preferred_element_type=acc_dtype)
        dXb = dXd * jnp.transpose(decay_states)[:, :, None]
        ddec = jnp.einsum("jhp,jhp->hj", dXd, Xb,
                          preferred_element_type=acc_dtype)
        dtotal = dtotal + jnp.sum(ddec * decay_states, axis=1)
        dcsh = -(ddec * decay_states)                       # (h, j)
        dt_off = dYb * jnp.transpose(in_decay)[:, :, None]  # (i, h, p)
        din_decay = jnp.transpose(jnp.sum(dYb * t_off, axis=-1))  # (h, i)
        dcsh = dcsh + din_decay * in_decay
        dCb = jnp.einsum("ihp,hpn->in", dt_off, Hc,
                         preferred_element_type=acc_dtype)
        dh_prev = dh_prev + jnp.einsum("in,ihp->hpn", Cb, dt_off,
                                       preferred_element_type=acc_dtype)
        dP = jnp.einsum("ihp,jhp->hij", dYb, Xb,
                        preferred_element_type=acc_dtype)
        dXb = dXb + jnp.einsum(ctx_plan, P, dYb,
                               preferred_element_type=acc_dtype)
        dG = jnp.sum(dP * L, axis=0)                        # (i, j)
        dL = dP * G[None]
        dseg = jnp.where(tril[None], dL * L, 0.0)
        dcsh = dcsh + dseg.sum(axis=2) - dseg.sum(axis=1)
        dCb = dCb + jnp.einsum("ij,jn->in", dG, Bb,
                               preferred_element_type=acc_dtype)
        dBb = dBb + jnp.einsum("ij,in->jn", dG, Cb,
                               preferred_element_type=acc_dtype)
        last = jax.lax.broadcasted_iota(jnp.int32, (hdim, q), 1) == q - 1
        dcsh = dcsh + jnp.where(last, dtotal[:, None], 0.0)
        ddAb = jnp.transpose(jnp.flip(
            jnp.cumsum(jnp.flip(dcsh, axis=1), axis=1), axis=1))   # (j, h)

        dx_ref[...] = dXb.astype(out_dtype).reshape(rs.out.block)
        db_ref[...] = dBb.reshape(rs.state_outs[1].block)
        dc_ref[...] = dCb.reshape(rs.state_outs[2].block)
        dda_ref[...] = ddAb.reshape(rs.state_outs[3].block)
        dh_ref[...] = dh_prev

        @pl.when(ki == nk - 1)
        def _flush():
            dh0_ref[...] = dh_ref[...].reshape(rs.state_outs[0].block)

    scratch = [pltpu.VMEM(h_cell, acc_dtype)]
    return body, scratch


def _windowed_decode_kind(rs: StreamingSchedule, *, scale, causal,
                          logical_stream, out_dtype, acc_dtype):
    """The windowed-decode monoid: online softmax over one query token's
    GQA group rows, streamed one KV page per step through the page-table
    index maps.  Operand order (Q, K, V, POS); the carried (m, l, acc)
    state is O(row x value) — with a window, the engine binds only the
    live pages, so a decode step is O(window) work and state no matter how
    long the sequence is.

    Masking is *dynamic*, from the runtime view-relative query position in
    the POS aux (``POS[0, 0]``): the page table is static per executor but
    the position is data, so one compiled kernel serves every token between
    page allocations.  Both the per-key mask and the whole-page block-skip
    derive from it — pages entirely after the query (or entirely behind
    the window) never run, which also keeps stale ring slabs inert."""
    ni = len(rs.ins)
    bq, bk = rs.row_block, rs.stream_block
    stream_dim = rs.stream_grid_dim
    nk = rs.grid[stream_dim].extent
    window = rs.window
    if rs.prefix_len:
        raise ValueError("windowed_decode does not take a prefix_len — "
                         "prefix tokens are all at or before the query")
    scores_plan, scores_keep = rs.stages[0].einsum_plan()
    ctx_plan, ctx_keep = rs.stages[1].einsum_plan()
    acc_block = rs.acc_block

    def body(*refs):
        o_ref = refs[ni]
        m_ref, l_ref, acc_ref = refs[ni + 1:ni + 4]
        ki = pl.program_id(stream_dim)
        vpos = refs[ni - 1][0, 0]          # view-relative query position

        @pl.when(ki == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # dynamic block-skip: the page is after the query, or (windowed)
        # its newest key is already out of the window
        run = ki * bk <= vpos
        if window:
            run = jnp.logical_and(run, ki * bk + bk - 1 > vpos - window)

        @pl.when(run)
        def _step():
            q, k = (refs[i][...].reshape(
                tuple(opn.block[d] for d in keep))
                for i, (opn, keep) in enumerate(zip(rs.ins[:2], scores_keep)))
            s = jnp.einsum(scores_plan, q, k,
                           preferred_element_type=acc_dtype) * scale
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos <= vpos
            if window:
                mask = jnp.logical_and(mask, kpos > vpos - window)
            s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[:, 0]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[:, None])
            corr = jnp.exp(m_prev - m_new)
            l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
            m_ref[:, 0] = m_new
            v = refs[2][...].reshape(
                tuple(rs.ins[2].block[d] for d in ctx_keep[1]))
            acc_ref[...] = (
                acc_ref[...] * corr[:, None]
                + jnp.einsum(ctx_plan, p.astype(v.dtype), v,
                             preferred_element_type=acc_dtype
                             ).reshape(acc_block))

        @pl.when(ki == nk - 1)
        def _flush():
            o_ref[...] = (acc_ref[...] /
                          jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                          ).astype(out_dtype).reshape(rs.out.block)

    scratch = [
        pltpu.VMEM((bq, 1), acc_dtype),              # running max m
        pltpu.VMEM((bq, 1), acc_dtype),              # denominator l
        pltpu.VMEM(acc_block, acc_dtype),            # rescaled acc
    ]
    return body, scratch


#: the carried-state monoid registry: ``expr.StateSpec.kind`` -> body
#: builder.  New recurrences (flash backward, windowed streams) register
#: here instead of growing their own emitters.  ``gated_backward`` IS the
#: forward ``gated`` body — the reversed cotangent recurrence is itself a
#: gated scan on flipped operands (the ops layer does the flip/shift).
RECURRENCE_KINDS: dict[str, Callable] = {
    "online_softmax": _softmax_kind,
    "ssd": _ssd_kind,
    "gated": _gated_kind,
    "flash_dq": _flash_dq_kind,
    "flash_dkv": _flash_dkv_kind,
    "ssd_backward": _ssd_backward_kind,
    "gated_backward": _gated_kind,
    "windowed_decode": _windowed_decode_kind,
}


@dataclasses.dataclass(frozen=True)
class KindContract:
    """The statically-declared guard + state discipline of a recurrence kind.

    Kind bodies used to keep their pad-guard strategy as closure-only state;
    the conformance analyzer (``analysis/conformance.py``) needs it as
    inspectable metadata to prove the emitted jaxpr honors it.

    ``guard`` names how the kind keeps padded streamed positions inert:

    * ``"identity-pad"`` — no in-kernel guard; the bundle executor pads with
      the monoid's identity element, so every step may fold unguarded
      (ssd, gated: zero-padded gates/inputs are the identity step).
    * ``"stream-mask"`` — folds into carried state must be dominated by the
      ``pos < logical_stream`` block-skip or the in-block pad mask
      (online softmax and the flash backwards: pad keys would otherwise
      poison the running max / denominator).
    * ``"dynamic-pos"`` — same, but the bound is *runtime data* read from
      the aux operand at ``pos_input`` (windowed decode: the view-relative
      query position).

    ``pos_input`` indexes ``schedule.ins`` (negative from the end) for the
    int32 position operand of a ``dynamic-pos`` kind.  ``causal_mask``
    marks kinds whose mask machinery also honors ``causal=True``.
    """
    guard: str
    pos_input: Optional[int] = None
    causal_mask: bool = False


#: kind -> declared guard/state contract, consumed by the conformance
#: analyzer.  A kind registered without a contract is skipped by the
#: guard-dominance rule (there is nothing declared to prove).
KIND_CONTRACTS: dict[str, KindContract] = {
    "online_softmax": KindContract(guard="stream-mask", causal_mask=True),
    "ssd": KindContract(guard="identity-pad"),
    "gated": KindContract(guard="identity-pad"),
    "flash_dq": KindContract(guard="stream-mask", causal_mask=True),
    "flash_dkv": KindContract(guard="stream-mask", causal_mask=True),
    "ssd_backward": KindContract(guard="identity-pad"),
    "gated_backward": KindContract(guard="identity-pad"),
    "windowed_decode": KindContract(guard="dynamic-pos", pos_input=-1),
}


def kind_contract(kind: str) -> Optional[KindContract]:
    return KIND_CONTRACTS.get(kind)


def register_recurrence_kind(kind: str, builder: Callable,
                             contract: Optional[KindContract] = None) -> None:
    RECURRENCE_KINDS[kind] = builder
    if contract is not None:
        KIND_CONTRACTS[kind] = contract


def emit_recurrent(rs: StreamingSchedule, *, scale: float = 1.0,
                   causal: bool = False, logical_stream: Optional[int] = None,
                   out_dtype=None, interpret: bool = False,
                   acc_dtype=None) -> Callable:
    """Build the ``pl.pallas_call`` a ``RecurrentSchedule`` describes.

    The driver generalizes ``emit_pallas``'s sigma init/step/flush contract
    to a typed carried-state monoid: the state scratch initializes at step 0
    of the streamed grid axis, every step folds one streamed block through
    the registered kind's body (``RECURRENCE_KINDS``, keyed by the form's
    ``StateSpec.kind``), and the last step flushes — dividing out the
    softmax denominator, or exporting the final scan state as an extra
    kernel output (``state_outs``).

    Grid, BlockSpecs, dimension semantics, scratch shapes, masking metadata
    and every stage's in-block einsum all come from the schedule — nothing
    here is hand-written.  ``acc_dtype`` is the accumulator the solver
    budgeted for: it becomes every kind's carried-state scratch dtype, MXU
    ``preferred_element_type`` and exported-state dtype (default f32).
    """
    out_dtype = jnp.dtype(out_dtype or jnp.float32)
    acc_dtype = jnp.dtype(acc_dtype or jnp.float32)
    ni = len(rs.ins)
    builder = RECURRENCE_KINDS.get(rs.state.kind if rs.state else
                                   "online_softmax")
    if builder is None:
        raise ValueError(f"unregistered recurrence kind "
                         f"{rs.state.kind!r}; known: "
                         f"{sorted(RECURRENCE_KINDS)}")
    body, scratch = builder(rs, scale=scale, causal=causal,
                            logical_stream=logical_stream,
                            out_dtype=out_dtype, acc_dtype=acc_dtype)
    outs = (rs.out,) + rs.state_outs
    out_dtypes = (out_dtype,) + (acc_dtype,) * len(rs.state_outs)
    call = pl.pallas_call(
        body,
        grid=rs.grid_extents,
        in_specs=[pl.BlockSpec(opn.block, _index_map(opn.grid_dims,
                                                     opn.offsets,
                                                     opn.page_table,
                                                     opn.page_slot_dim))
                  for opn in rs.ins],
        out_specs=[pl.BlockSpec(o.block, _index_map(o.grid_dims, o.offsets))
                   for o in outs],
        out_shape=[jax.ShapeDtypeStruct(o.shape, dt)
                   for o, dt in zip(outs, out_dtypes)],
        scratch_shapes=scratch,
        compiler_params=compiler_params(
            dimension_semantics=rs.dimension_semantics),
        interpret=interpret,
    )

    def fn(*arrays):
        if len(arrays) != ni:
            raise ValueError(f"{rs.name}: expected {ni} operands")
        for arr, opn in zip(arrays, rs.ins):
            if tuple(arr.shape) != opn.shape:
                raise ValueError(
                    f"{rs.name}: operand {opn.array} has shape {arr.shape}, "
                    f"schedule derived {opn.shape} — pad first")
        out = call(*arrays)
        return out[0] if len(outs) == 1 else tuple(out)

    return fn


def emit_streaming(ss: StreamingSchedule, *, scale: float = 1.0,
                   causal: bool = False, logical_stream: Optional[int] = None,
                   out_dtype=None, interpret: bool = False) -> Callable:
    """.. deprecated:: the streaming (online-softmax) emitter is now the
    ``online_softmax`` kind of ``emit_recurrent``; kept for one release."""
    return emit_recurrent(ss, scale=scale, causal=causal,
                          logical_stream=logical_stream, out_dtype=out_dtype,
                          interpret=interpret)


def emit_recurrent_bundle(bundle: ScheduleBundle, *, scale: float = 1.0,
                          causal: bool = False, out_dtype=None,
                          interpret: bool = False) -> Callable:
    """Executable for a cached recurrent derivation over *logical* operands:
    pad the streamed axes to the derived block multiples (padded keys/tokens
    are inert — masked by the ``kpos < sk`` guard, or zero-padded into the
    monoid's identity step), run the emitted kernel, slice the logical
    result back out.  Exported state outputs pass through unsliced."""
    rs = bundle.schedule
    logical_stream = bundle.shapes[-1]
    kern = emit_recurrent(rs, scale=scale, causal=causal,
                          logical_stream=logical_stream,
                          out_dtype=out_dtype, interpret=interpret,
                          acc_dtype=getattr(bundle, "acc_dtype", "float32"))
    out_slices = tuple(slice(0, d) for d in bundle.out_shape)
    exports = bool(rs.state_outs)

    def call(*arrays):
        padded = [_pad_to_shape(x, spec.shape)
                  for x, spec in zip(arrays, rs.ins)]
        out = kern(*padded)
        if exports:
            return (out[0][out_slices],) + tuple(out[1:])
        return out[out_slices]

    return call


#: one-release alias of :func:`emit_recurrent_bundle`
emit_streaming_bundle = emit_recurrent_bundle


# ---------------------------------------------------------------------------
# bundle executor: the ops-layer contract (collapse psi slabs, pad, run,
# slice) in one place, reused by the single-chip and shard_map paths
# ---------------------------------------------------------------------------

def _pad_to_shape(x: jax.Array, shape: tuple[int, ...],
                  value: float = 0.0) -> jax.Array:
    pads = [(0, t - d) for d, t in zip(x.shape, shape)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads, constant_values=value)
    return x


def emit_bundle(bundle: ScheduleBundle, *, out_dtype=None,
                interpret: bool = False) -> Callable:
    """Executable for a cached derivation over *logical storage* operands.

    Collapses a psi view's fixed leading dims to the flat slab dim the
    schedule pinned, pads every operand to the schedule's (padded) storage
    shape with the semiring's inert element, runs the emitted kernel, and
    slices the logical result back out.  The missing-inert-element error
    is only raised when padding is actually required.
    """
    sch = bundle.schedule
    kern = emit_pallas(sch, out_dtype=out_dtype, interpret=interpret,
                       acc_dtype=getattr(bundle, "acc_dtype", "float32"))

    prep = []
    for spec, logical in zip(sch.ins, bundle.in_shapes):
        sym_rank = len(spec.shape) - (1 if spec.is_psi_view else 0)
        prep.append((len(logical) - sym_rank, spec))
    # the pad-value policy lives beside the bundle (schedule.py) so the
    # static verifier certifies the exact element this executor pads with
    pad_val = sched_mod.bundle_pad_value(bundle)
    out_slices = tuple(slice(0, d) for d in bundle.out_shape)

    def call(*arrays):
        padded = []
        for x, (lead, spec) in zip(arrays, prep):
            if spec.is_psi_view:
                if lead > 1:                 # several fixed dims -> one slab
                    x = x.reshape((-1,) + x.shape[lead:])
                target = (x.shape[0],) + spec.shape[1:]
            else:
                if lead:                     # all-zero psi index: slab 0
                    x = x.reshape((-1,) + x.shape[lead:])[0]
                target = spec.shape
            padded.append(_pad_to_shape(x, target, pad_val))
        return kern(*padded)[out_slices]

    return call


# ---------------------------------------------------------------------------
# the mesh level: the same derived kernel per shard, inside shard_map
# ---------------------------------------------------------------------------

def emit_shard_map(plan, mesh, local_fn: Optional[Callable] = None, *,
                   out_dtype=None, interpret: bool = False,
                   use_kernel: bool = True) -> Callable:
    """Run a ``DistributedPlan``: the plan's per-shard derived kernel (or a
    caller-supplied differentiable local function, or the jnp oracle when
    ``use_kernel`` is False) inside ``shard_map`` with the plan's partition
    specs, followed by the plan's collective schedule.

    ``mesh`` is a live ``jax.sharding.Mesh`` whose axis names and sizes must
    match the plan's ``MeshShape``.  Returns ``fn(*global_operands) ->
    global_out``; operands bind exactly as in the single-chip path (storage
    shapes), only globally sized.
    """
    from repro.distributed.sharding import shard_map

    plan.check_mesh(mesh)
    if local_fn is None:
        if use_kernel:
            local_fn = emit_bundle(plan.bundle, out_dtype=jnp.float32,
                                   interpret=interpret)
        else:
            from repro.kernels import ref
            local_fn = functools.partial(ref.eval_nf, plan.local_nf)

    def body(*shards):
        y = local_fn(*shards)
        for step in plan.collectives:
            if step.kind == "psum":
                y = jax.lax.psum(y, step.mesh_axis)
            elif step.kind == "reduce_scatter":
                y = jax.lax.psum_scatter(y, step.mesh_axis,
                                         scatter_dimension=step.out_dim,
                                         tiled=True)
            elif step.kind == "all_gather":
                y = jax.lax.all_gather(y, step.mesh_axis, axis=step.out_dim,
                                       tiled=True)
            else:
                raise ValueError(f"unknown collective kind {step.kind!r}")
        return y if out_dtype is None else y.astype(out_dtype)

    return shard_map(body, mesh, in_specs=plan.jax_in_specs(),
                     out_specs=plan.jax_out_spec())
