"""MoA blocked-contiguous GEMM as a Pallas TPU kernel.

This is the paper's fig. 2 schedule on TPU: the lifted loop nest

    for i_o (grid, parallel)            # dimension-lift rows of A/C
      for j_o (grid, parallel)          # dimension-lift cols of B/C
        for k_o (grid, arbitrary)       # the "sigma" block loop — the extra
          C_blk (+)= A_blk @ B_blk      #   addition loop that sums blocks

with block shapes chosen *statically* by the solver in
``repro.core.blocking`` so that the three resident blocks (+double-buffered
inputs, f32 accumulator) fit the VMEM budget and are MXU-aligned — the TPU
re-instantiation of "3 blocks <= L1 per SM".

Contiguity: with row-major layouts, walking the grid (i, j, k-innermost)
makes every HBM->VMEM DMA a dense row-major tile of A, B and C — the MoA
ONF's stride-1 access property lifted from elements to DMA bursts.

The k grid axis accumulates into a VMEM f32 scratch, written to C on the
last k step ("round robin, row-major order ... summing blocks of partial
sums").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params

from repro.core.blocking import BlockChoice


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, gk: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == gk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def moa_gemm_kernel(a: jax.Array, b: jax.Array, blocks: BlockChoice,
                    out_dtype=None, interpret: bool = False) -> jax.Array:
    """Raw kernel: requires m % bm == k % bk == n % bn == 0."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = blocks.bm, blocks.bk, blocks.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (a.shape, b.shape, blocks)
    gm, gn, gk = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype

    return pl.pallas_call(
        functools.partial(_gemm_kernel, gk=gk, out_dtype=out_dtype),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def _expert_gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, gk: int, out_dtype):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == gk - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(out_dtype)


def expert_gemm_kernel(x: jax.Array, w: jax.Array, blocks: BlockChoice,
                       out_dtype=None, interpret: bool = False) -> jax.Array:
    """Capacity-padded MoE expert GEMM: (E, cap, d) x (E, d, f) -> (E, cap, f).

    The expert axis is one more dimension-lift of the same schedule: the
    paper's round-robin block loop, batched over the lifted resource axis
    "expert" (grid-parallel; each grid cell is an independent MoA GEMM).
    """
    e, cap, d = x.shape
    e2, d2, f = w.shape
    assert e == e2 and d == d2, (x.shape, w.shape)
    bm, bk, bn = blocks.bm, blocks.bk, blocks.bn
    assert cap % bm == 0 and d % bk == 0 and f % bn == 0, (x.shape, w.shape, blocks)
    gm, gn, gk = cap // bm, f // bn, d // bk
    out_dtype = out_dtype or x.dtype

    return pl.pallas_call(
        functools.partial(_expert_gemm_kernel, gk=gk, out_dtype=out_dtype),
        grid=(e, gm, gn, gk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda ee, i, j, kk: (ee, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda ee, i, j, kk: (ee, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda ee, i, j, kk: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cap, f), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)


def _hadamard_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] * b_ref[...]


def hadamard_kernel(a: jax.Array, b: jax.Array, block: tuple[int, int],
                    interpret: bool = False) -> jax.Array:
    """Blocked Hadamard product — the degenerate (no-contraction) form of the
    unified ipophp circuit; same lifting, elementwise block body."""
    m, n = a.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _hadamard_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))] * 2,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a, b)
