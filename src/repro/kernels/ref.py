"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition* the kernels are tested against
(tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle).  These are
also the fallback execution path on backends without Pallas.  ``eval_expr``
is the general case: a direct jnp evaluator for any ``repro.core.expr``
expression (the DNF semantics, before any normal-form derivation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import expr as E
from repro.core import semiring


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with f32 accumulation (the MoA inner product on matrices)."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def hadamard_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return a * b


def outer_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """MoA outer product of two matrices: shape (m, n, p, q)."""
    return jnp.einsum("mn,pq->mnpq", a, b)


def kron_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Kronecker product via the MoA lemma: transpose+reshape of the outer."""
    m, n = a.shape
    p, q = b.shape
    return outer_ref(a, b).transpose(0, 2, 1, 3).reshape(m * p, n * q)


def expert_gemm_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    """Grouped (capacity-padded) expert GEMM: (E, cap, d) x (E, d, f)."""
    out_dtype = out_dtype or x.dtype
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(out_dtype)


def _combine_fn(name: str):
    return getattr(jnp, semiring.combine_def(name).jnp_name)


def _reducer_fn(name: str):
    return getattr(jnp, semiring.reduce_def(name).jnp_reducer)


def eval_expr(expr: "E.Expr", *arrays: jax.Array) -> jax.Array:
    """Evaluate a MoA expression directly with jnp (f32 accumulation) —
    the semantic oracle / XLA fallback for ``ops.apply``.  ``arrays`` bind
    leaves in composition order."""
    it = iter(arrays)

    def ev(e: "E.Expr") -> jax.Array:
        if isinstance(e, E.Arr):
            # leaves bind by STORAGE shape (same contract as ops.apply):
            # a column-major leaf takes the reversed-shape row-major buffer
            x = next(it)
            storage = e.shape if e.layout == "row" else tuple(reversed(e.shape))
            if tuple(x.shape) != storage:
                raise ValueError(f"leaf {e.name!r} expects storage shape "
                                 f"{storage}, got {tuple(x.shape)}")
            if e.layout == "col":
                x = jnp.transpose(x, tuple(reversed(range(x.ndim))))
            return x.astype(jnp.float32)
        if isinstance(e, E.Transpose):
            return jnp.transpose(ev(e.x), e.perm)
        if isinstance(e, E.Psi):
            return ev(e.x)[e.idx]
        if isinstance(e, E.Combine):
            return _combine_fn(e.op)(ev(e.a), ev(e.b))
        if isinstance(e, E.Reduce):
            return _reducer_fn(e.op)(ev(e.x), axis=e.axis)
        if isinstance(e, E.Inner):
            a, b = ev(e.a), ev(e.b)
            nb = e.batch
            if (e.plus, e.times) == ("add", "mul"):
                # linear contraction (batched or not): dot_general, so the
                # XLA fallback never materializes the broadcast intermediate
                return jax.lax.dot_general(
                    a, b, (((a.ndim - 1,), (nb,)),
                           (tuple(range(nb)), tuple(range(nb)))))
            # general semiring: broadcast-pair then fold the contraction
            ar = a.reshape(a.shape + (1,) * (b.ndim - nb - 1))
            br = b.reshape(b.shape[:nb] + (1,) * (a.ndim - nb - 1)
                           + b.shape[nb:])
            return _reducer_fn(e.plus)(_combine_fn(e.times)(ar, br),
                                       axis=a.ndim - 1)
        raise TypeError(f"not an Expr node: {e!r}")

    out = ev(expr)
    if next(it, None) is not None:
        raise ValueError("more arrays than expression leaves")
    return out


def ipophp_ref(a: jax.Array, b: jax.Array, mode: str) -> jax.Array:
    """The unified inner/outer/hadamard/kron operator (paper appendix)."""
    if mode == "ip":
        return gemm_ref(a, b)
    if mode == "hp":
        return hadamard_ref(a, b)
    if mode == "op":
        return outer_ref(a, b)
    if mode == "kp":
        return kron_ref(a, b)
    raise ValueError(f"unknown ipophp mode {mode!r}")
