"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition* the kernels are tested against
(tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle).  These are
also the fallback execution path on backends without Pallas.  ``eval_expr``
is the general case: a direct jnp evaluator for any ``repro.core.expr``
expression (the DNF semantics, before any normal-form derivation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import expr as E
from repro.core import semiring


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with f32 accumulation (the MoA inner product on matrices)."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def hadamard_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return a * b


def outer_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """MoA outer product of two matrices: shape (m, n, p, q)."""
    return jnp.einsum("mn,pq->mnpq", a, b)


def kron_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Kronecker product via the MoA lemma: transpose+reshape of the outer."""
    m, n = a.shape
    p, q = b.shape
    return outer_ref(a, b).transpose(0, 2, 1, 3).reshape(m * p, n * q)


def expert_gemm_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    """Grouped (capacity-padded) expert GEMM: (E, cap, d) x (E, d, f)."""
    out_dtype = out_dtype or x.dtype
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(out_dtype)


def _combine_fn(name: str):
    return getattr(jnp, semiring.combine_def(name).jnp_name)


def _reducer_fn(name: str):
    return getattr(jnp, semiring.reduce_def(name).jnp_reducer)


def eval_expr(expr: "E.Expr", *arrays: jax.Array) -> jax.Array:
    """Evaluate a MoA expression directly with jnp (f32 accumulation) —
    the semantic oracle / XLA fallback for ``ops.apply``.  ``arrays`` bind
    leaves in composition order."""
    it = iter(arrays)

    def ev(e: "E.Expr") -> jax.Array:
        if isinstance(e, E.Arr):
            # leaves bind by STORAGE shape (same contract as ops.apply):
            # a column-major leaf takes the reversed-shape row-major buffer
            x = next(it)
            storage = e.shape if e.layout == "row" else tuple(reversed(e.shape))
            if tuple(x.shape) != storage:
                raise ValueError(f"leaf {e.name!r} expects storage shape "
                                 f"{storage}, got {tuple(x.shape)}")
            if e.layout == "col":
                x = jnp.transpose(x, tuple(reversed(range(x.ndim))))
            return x.astype(jnp.float32)
        if isinstance(e, E.Transpose):
            return jnp.transpose(ev(e.x), e.perm)
        if isinstance(e, E.Psi):
            return ev(e.x)[e.idx]
        if isinstance(e, E.Combine):
            return _combine_fn(e.op)(ev(e.a), ev(e.b))
        if isinstance(e, E.Reduce):
            return _reducer_fn(e.op)(ev(e.x), axis=e.axis)
        if isinstance(e, E.Inner):
            a, b = ev(e.a), ev(e.b)
            nb = e.batch
            if (e.plus, e.times) == ("add", "mul"):
                # linear contraction (batched or not): dot_general, so the
                # XLA fallback never materializes the broadcast intermediate
                return jax.lax.dot_general(
                    a, b, (((a.ndim - 1,), (nb,)),
                           (tuple(range(nb)), tuple(range(nb)))))
            # general semiring: broadcast-pair then fold the contraction
            ar = a.reshape(a.shape + (1,) * (b.ndim - nb - 1))
            br = b.reshape(b.shape[:nb] + (1,) * (a.ndim - nb - 1)
                           + b.shape[nb:])
            return _reducer_fn(e.plus)(_combine_fn(e.times)(ar, br),
                                       axis=a.ndim - 1)
        raise TypeError(f"not an Expr node: {e!r}")

    out = ev(expr)
    if next(it, None) is not None:
        raise ValueError("more arrays than expression leaves")
    return out


def eval_nf(nf: "E.NormalForm", *arrays: jax.Array) -> jax.Array:
    """jnp oracle for a *normal form* (not an Expr): the XLA execution path
    of a per-shard computation, where only the local ``NormalForm`` exists.

    Binds leaves by storage shape (col-major leaves take the reversed
    buffer, constant dims are indexed out), then evaluates the semiring:
    an einsum for (mul, add), broadcast-pair-and-fold otherwise — f32
    accumulation either way, matching the emitted kernels.
    """
    if len(arrays) != len(nf.leaves):
        raise ValueError(f"normal form has {len(nf.leaves)} leaves, got "
                         f"{len(arrays)}")
    bound: list[tuple[tuple[str, ...], jax.Array]] = []
    for leaf, x in zip(nf.leaves, arrays):
        storage = leaf.storage_shape()
        if tuple(x.shape) != storage:
            raise ValueError(f"leaf {leaf.array!r} expects storage shape "
                             f"{storage}, got {tuple(x.shape)}")
        if leaf.layout == "col":
            x = jnp.transpose(x, tuple(reversed(range(x.ndim))))
        idx = tuple(t if isinstance(t, int) else slice(None)
                    for t, _ in leaf.dims)
        x = x[idx]
        syms = tuple(t for t, _ in leaf.dims if isinstance(t, str))
        if len(set(syms)) != len(syms):
            raise NotImplementedError(
                f"leaf {leaf.array!r} repeats an index (diagonal access)")
        bound.append((syms, x.astype(jnp.float32)))

    joint = tuple(nf.out_axes) + tuple(nf.reduce_axes)
    if (nf.combine, nf.reduce_op) == ("mul", "add"):
        letters = {s: chr(ord("a") + i) for i, s in enumerate(joint)}
        spec = ",".join("".join(letters[s] for s in syms)
                        for syms, _ in bound)
        spec += "->" + "".join(letters[s] for s in nf.out_axes)
        return jnp.einsum(spec, *(x for _, x in bound),
                          preferred_element_type=jnp.float32)
    # general semiring: align every operand to (out + reduce) axes, pair
    # with the combine op, fold the reduce axes — same shape discipline as
    # the emitted block body
    aligned = []
    for syms, x in bound:
        perm = sorted(range(len(syms)), key=lambda d: joint.index(syms[d]))
        x = jnp.transpose(x, perm)
        have = [syms[p] for p in perm]
        for pos, ax in enumerate(joint):
            if ax not in have:
                x = jnp.expand_dims(x, pos)
        aligned.append(x)
    out = functools.reduce(_combine_fn(nf.combine), aligned)
    if nf.reduce_axes:
        red = tuple(range(len(nf.out_axes), len(joint)))
        out = _reducer_fn(nf.reduce_op)(out, axis=red)
    return out


# ---------------------------------------------------------------------------
# carried-state recurrence oracles (the jnp semantics of emit_recurrent's
# registered kinds; also the VJP recompute bodies of ops.scan_ssd /
# ops.gated_scan and their XLA-entry execution path)
# ---------------------------------------------------------------------------

def ssd_scan_ref(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                 init_state: jax.Array | None = None, *, chunk: int,
                 unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan oracle — the ``ssd`` monoid's jnp semantics.

    ``xdt (b,s,h,p)`` is the dt-folded input, ``dA (b,s,h)`` the per-token
    log decay (``dt * A``, <= 0), ``B/C (b,s,n)`` the state in/out
    projections.  Returns ``(y (b,s,h,p) f32, final state (b,h,p,n) f32)``.
    The per-chunk factoring mirrors the emitted kernel body step for step
    (same einsum structure, same order of operations), which is what makes
    the interpret-mode kernel bit-identical to this oracle.
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, q = s // chunk, chunk
    xc = xdt.astype(jnp.float32).reshape(b, nc, q, h, p)
    dac = dA.astype(jnp.float32).reshape(b, nc, q, h)
    Bc = B.astype(jnp.float32).reshape(b, nc, q, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, q, n)
    tril = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    neg_inf = jnp.float32(semiring.MASK_NEG_INF)

    def step(h_prev, inp):
        xb, dab, Bb, Cb = inp                       # (b,q,h,p) (b,q,h) ...
        csh = jnp.transpose(jnp.cumsum(dab, axis=1), (0, 2, 1))  # (b,h,i)
        seg = csh[..., :, None] - csh[..., None, :]              # (b,h,i,j)
        L = jnp.exp(jnp.where(tril, seg, neg_inf))
        G = jnp.einsum("bin,bjn->bij", Cb, Bb,
                       preferred_element_type=jnp.float32)
        P = G[:, None] * L                                       # (b,h,i,j)
        y = jnp.einsum("bhij,bjhp->bihp", P, xb,
                       preferred_element_type=jnp.float32)
        in_decay = jnp.exp(csh)                                  # (b,h,i)
        t_off = jnp.einsum("bin,bhpn->bihp", Cb, h_prev,
                           preferred_element_type=jnp.float32)
        y = y + t_off * jnp.transpose(in_decay, (0, 2, 1))[..., None]
        total = csh[..., -1]                                     # (b,h)
        decay_states = jnp.exp(total[..., None] - csh)           # (b,h,j)
        xd = xb * jnp.transpose(decay_states, (0, 2, 1))[..., None]
        S = jnp.einsum("bjn,bjhp->bhpn", Bb, xd,
                       preferred_element_type=jnp.float32)
        h_new = jnp.exp(total)[..., None, None] * h_prev + S
        return h_new, y

    init = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, ys = jax.lax.scan(
        step, init,
        (xc.transpose(1, 0, 2, 3, 4), dac.transpose(1, 0, 2, 3),
         Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3)),
        unroll=bool(unroll))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, final


def gated_scan_ref(log_a: jax.Array, b_in: jax.Array,
                   init_state: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Gated linear scan oracle — the ``gated`` (RG-LRU) monoid's jnp
    semantics: ``h_t = a_t h_{t-1} + b_t`` with ``a = exp(log_a)``, via the
    log-depth associative scan over the sequence axis.  ``log_a/b_in``:
    (B, S, w) f32.  Returns ``(h (B,S,w) f32, final (B,w) f32)``."""
    a = jnp.exp(log_a.astype(jnp.float32))
    b = b_in.astype(jnp.float32)

    def comb(x, y):
        return (x[0] * y[0], y[0] * x[1] + y[1])

    aa, hh = jax.lax.associative_scan(comb, (a, b), axis=1)
    if init_state is not None:
        hh = hh + aa * init_state.astype(jnp.float32)[:, None, :]
    return hh, hh[:, -1]


def gated_chunk_ref(log_a: jax.Array, b_in: jax.Array, h0: jax.Array,
                    chunk: int) -> tuple[jax.Array, jax.Array]:
    """Chunked gated-scan mirror of the ``gated`` / ``gated_backward``
    kernel body (the bit-identity reference): per chunk the same
    within-chunk associative scan followed by the carry re-base
    ``hh + aa * h`` — the exact op order of the emitted kernel, so on the
    same operands the outputs match it bit for bit.  ``s`` must be a
    multiple of ``chunk``."""
    b, s, w = log_a.shape
    nc = s // chunk
    a = jnp.exp(log_a.astype(jnp.float32)).reshape(b, nc, chunk, w)
    bb = b_in.astype(jnp.float32).reshape(b, nc, chunk, w)

    def comb(x, y):
        return (x[0] * y[0], y[0] * x[1] + y[1])

    def step(h, inp):
        ac, bc = inp
        aa, hh = jax.lax.associative_scan(comb, (ac, bc), axis=1)
        hh = hh + aa * h[:, None]
        return hh[:, -1], hh

    hf, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                          (a.transpose(1, 0, 2, 3), bb.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).reshape(b, s, w), hf


def flash_dq_ref(q: jax.Array, k: jax.Array, v: jax.Array, do: jax.Array,
                 m: jax.Array, l: jax.Array, delta: jax.Array, *,
                 scale: float, causal: bool, bq: int, bk: int,
                 window: int = 0, prefix_len: int = 0,
                 logical_k: int | None = None) -> jax.Array:
    """Blocked flash-backward dQ oracle — the ``flash_dq`` monoid's jnp
    semantics on *padded* grouped layouts ``q/do (b, sqp, kv, g, ·)``,
    ``k/v (b, skp, kv, ·)``, ``m/l/delta (b, kv, g, sqp)``.

    Mirrors the emitted kernel step for step: the streamed key axis is
    walked sequentially in the kernel's exact ``bk`` blocks (summation
    order over the stream is what bit-identity requires — ``p = exp(·)``
    is irrational even on integer inputs), rows are vectorized (they are
    grid-parallel cells), and the full positional mask is always applied
    (a fully-masked block contributes exact zeros, matching the kernel's
    block-skip).  Returns padded ``dq (b, kv, g, sqp, hd)`` f32."""
    b, sqp, kv, g, hd = q.shape
    skp = k.shape[1]
    neg_inf = jnp.float32(semiring.MASK_NEG_INF)
    qt = q.transpose(0, 2, 3, 1, 4).astype(jnp.float32)    # (b,h,g,i,c)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)       # (b,h,j,c)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)       # (b,h,j,d)
    dot = do.transpose(0, 2, 3, 1, 4).astype(jnp.float32)  # (b,h,g,i,d)
    lse = m.astype(jnp.float32) + \
        jnp.log(jnp.maximum(l.astype(jnp.float32), 1e-30))
    delta = delta.astype(jnp.float32)
    lk = skp if logical_k is None else logical_k
    qpos = jnp.arange(sqp)[:, None]
    acc = jnp.zeros((b, kv, g, sqp, hd), jnp.float32)
    for ki in range(skp // bk):
        kb = kt[:, :, ki * bk:(ki + 1) * bk]
        vb = vt[:, :, ki * bk:(ki + 1) * bk]
        s = jnp.einsum("bhgic,bhjc->bhgij", qt, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jnp.arange(bk)[None, :]
        mask = jnp.ones((sqp, bk), bool)
        if causal:
            mask = kpos <= qpos
            if window:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            if prefix_len:
                mask = jnp.logical_or(
                    mask, jnp.logical_and(qpos < prefix_len,
                                          kpos < prefix_len))
        if lk < skp:
            mask = jnp.logical_and(mask, kpos < lk)
        if causal or lk < skp:
            s = jnp.where(mask, s, neg_inf)
        p = jnp.exp(s - lse[..., None])
        dp = jnp.einsum("bhgid,bhjd->bhgij", dot, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        acc = acc + jnp.einsum("bhgij,bhjc->bhgic", ds, kb,
                               preferred_element_type=jnp.float32)
    return acc * scale


def flash_dkv_ref(q: jax.Array, k: jax.Array, v: jax.Array, do: jax.Array,
                  m: jax.Array, l: jax.Array, delta: jax.Array, *,
                  scale: float, causal: bool, bj: int, bi: int,
                  window: int = 0, prefix_len: int = 0,
                  logical_q: int | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Blocked flash-backward dK/dV oracle — the transposed weld's jnp
    semantics (rows = key positions, stream = query positions in ``bi``
    blocks), mirroring the ``flash_dkv`` kernel's summation order and its
    always-on padded-query mask.  Returns per-group padded
    ``(dk (b, kv, g, skp, hd), dv (b, kv, g, skp, vd))`` f32 — the GQA
    group reduction stays with the caller, as in the kernel path."""
    b, sqp, kv, g, hd = q.shape
    skp, vd = k.shape[1], v.shape[-1]
    neg_inf = jnp.float32(semiring.MASK_NEG_INF)
    qt = q.transpose(0, 2, 3, 1, 4).astype(jnp.float32)    # (b,h,g,i,c)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)       # (b,h,j,c)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)       # (b,h,j,d)
    dot = do.transpose(0, 2, 3, 1, 4).astype(jnp.float32)  # (b,h,g,i,d)
    lse = m.astype(jnp.float32) + \
        jnp.log(jnp.maximum(l.astype(jnp.float32), 1e-30))
    delta = delta.astype(jnp.float32)
    lq = sqp if logical_q is None else logical_q
    kpos = jnp.arange(skp)[:, None]
    dk = jnp.zeros((b, kv, g, skp, hd), jnp.float32)
    dv = jnp.zeros((b, kv, g, skp, vd), jnp.float32)
    for ki in range(sqp // bi):
        qb = qt[:, :, :, ki * bi:(ki + 1) * bi]
        dob = dot[:, :, :, ki * bi:(ki + 1) * bi]
        lseb = lse[..., ki * bi:(ki + 1) * bi]
        db = delta[..., ki * bi:(ki + 1) * bi]
        s = jnp.einsum("bhjc,bhgic->bhgji", kt, qb,
                       preferred_element_type=jnp.float32) * scale
        qpos = ki * bi + jnp.arange(bi)[None, :]
        mask = jnp.ones((skp, bi), bool)
        if causal:
            mask = kpos <= qpos
            if window:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            if prefix_len:
                mask = jnp.logical_or(
                    mask, jnp.logical_and(qpos < prefix_len,
                                          kpos < prefix_len))
        if lq < sqp:
            mask = jnp.logical_and(mask, qpos < lq)
        if causal or lq < sqp:
            s = jnp.where(mask, s, neg_inf)
        p = jnp.exp(s - lseb[:, :, :, None, :])             # (b,h,g,j,bi)
        dp = jnp.einsum("bhgid,bhjd->bhgji", dob, vt,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - db[:, :, :, None, :])
        dk = dk + jnp.einsum("bhgji,bhgic->bhgjc", ds, qb,
                             preferred_element_type=jnp.float32)
        dv = dv + jnp.einsum("bhgji,bhgid->bhgjd", p, dob,
                             preferred_element_type=jnp.float32)
    return dk * scale, dv


def ssd_bwd_ref(C: jax.Array, B: jax.Array, dY: jax.Array, X: jax.Array,
                dA: jax.Array, Hin: jax.Array, dHf: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                           jax.Array]:
    """Chunked SSD backward oracle — the ``ssd_backward`` monoid's jnp
    semantics over kernel-order, *already chunk-reversed* operands
    ``C/B (b,nc,q,n)``, ``dY/X (b,nc,q,h,p)``, ``dA (b,nc,q,h)``,
    ``Hin (b,nc,h,p,n)`` (the saved per-chunk state checkpoints, reversed
    the same way) and ``dHf (b,h,p,n)``.  Mirrors the emitted kernel body
    einsum for einsum (same replay of the forward factoring, same
    cotangent chaining order), batched over the leading b.  Returns
    ``(dX, dh0, dB, dC, ddA)`` f32 in the same reversed chunk order."""
    b, nc, q, n = C.shape
    tril = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    neg_inf = jnp.float32(semiring.MASK_NEG_INF)
    Cc = C.astype(jnp.float32)
    Bc = B.astype(jnp.float32)
    dYc = dY.astype(jnp.float32)
    Xc = X.astype(jnp.float32)
    dAc = dA.astype(jnp.float32)
    Hc_all = Hin.astype(jnp.float32)
    last = jnp.arange(q)[None, :] == q - 1

    def step(dh, inp):
        Cb, Bb, dYb, Xb, dAb, Hc = inp
        csh = jnp.transpose(jnp.cumsum(dAb, axis=1), (0, 2, 1))   # (b,h,i)
        seg = csh[..., :, None] - csh[..., None, :]
        L = jnp.exp(jnp.where(tril, seg, neg_inf))
        G = jnp.einsum("bin,bjn->bij", Cb, Bb,
                       preferred_element_type=jnp.float32)
        P = G[:, None] * L
        in_decay = jnp.exp(csh)
        t_off = jnp.einsum("bin,bhpn->bihp", Cb, Hc,
                           preferred_element_type=jnp.float32)
        total = csh[..., -1]
        decay_states = jnp.exp(total[..., None] - csh)
        Xd = Xb * jnp.transpose(decay_states, (0, 2, 1))[..., None]
        dtotal = jnp.einsum("bhpn,bhpn->bh", dh, Hc,
                            preferred_element_type=jnp.float32) * \
            jnp.exp(total)
        dh_prev = jnp.exp(total)[..., None, None] * dh
        dBb = jnp.einsum("bhpn,bjhp->bjn", dh, Xd,
                         preferred_element_type=jnp.float32)
        dXd = jnp.einsum("bjn,bhpn->bjhp", Bb, dh,
                         preferred_element_type=jnp.float32)
        dXb = dXd * jnp.transpose(decay_states, (0, 2, 1))[..., None]
        ddec = jnp.einsum("bjhp,bjhp->bhj", dXd, Xb,
                          preferred_element_type=jnp.float32)
        dtotal = dtotal + jnp.sum(ddec * decay_states, axis=2)
        dcsh = -(ddec * decay_states)
        dt_off = dYb * jnp.transpose(in_decay, (0, 2, 1))[..., None]
        din_decay = jnp.transpose(jnp.sum(dYb * t_off, axis=-1), (0, 2, 1))
        dcsh = dcsh + din_decay * in_decay
        dCb = jnp.einsum("bihp,bhpn->bin", dt_off, Hc,
                         preferred_element_type=jnp.float32)
        dh_prev = dh_prev + jnp.einsum("bin,bihp->bhpn", Cb, dt_off,
                                       preferred_element_type=jnp.float32)
        dP = jnp.einsum("bihp,bjhp->bhij", dYb, Xb,
                        preferred_element_type=jnp.float32)
        dXb = dXb + jnp.einsum("bhij,bihp->bjhp", P, dYb,
                               preferred_element_type=jnp.float32)
        dG = jnp.sum(dP * L, axis=1)
        dL = dP * G[:, None]
        dseg = jnp.where(tril, dL * L, 0.0)
        dcsh = dcsh + dseg.sum(axis=3) - dseg.sum(axis=2)
        dCb = dCb + jnp.einsum("bij,bjn->bin", dG, Bb,
                               preferred_element_type=jnp.float32)
        dBb = dBb + jnp.einsum("bij,bin->bjn", dG, Cb,
                               preferred_element_type=jnp.float32)
        dcsh = dcsh + jnp.where(last, dtotal[..., None], 0.0)
        ddAb = jnp.transpose(jnp.flip(
            jnp.cumsum(jnp.flip(dcsh, axis=2), axis=2), axis=2), (0, 2, 1))
        return dh_prev, (dXb, dBb, dCb, ddAb)

    dh0, (dX, dB, dC, ddA) = jax.lax.scan(
        step, dHf.astype(jnp.float32),
        (Cc.transpose(1, 0, 2, 3), Bc.transpose(1, 0, 2, 3),
         dYc.transpose(1, 0, 2, 3, 4), Xc.transpose(1, 0, 2, 3, 4),
         dAc.transpose(1, 0, 2, 3), Hc_all.transpose(1, 0, 2, 3, 4)))
    return (dX.transpose(1, 0, 2, 3, 4), dh0, dB.transpose(1, 0, 2, 3),
            dC.transpose(1, 0, 2, 3), ddA.transpose(1, 0, 2, 3))


def ipophp_ref(a: jax.Array, b: jax.Array, mode: str) -> jax.Array:
    """The unified inner/outer/hadamard/kron operator (paper appendix)."""
    if mode == "ip":
        return gemm_ref(a, b)
    if mode == "hp":
        return hadamard_ref(a, b)
    if mode == "op":
        return outer_ref(a, b)
    if mode == "kp":
        return kron_ref(a, b)
    raise ValueError(f"unknown ipophp mode {mode!r}")
