"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition* the kernels are tested against
(tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle).  These are
also the fallback execution path on backends without Pallas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with f32 accumulation (the MoA inner product on matrices)."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def hadamard_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return a * b


def outer_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """MoA outer product of two matrices: shape (m, n, p, q)."""
    return jnp.einsum("mn,pq->mnpq", a, b)


def kron_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Kronecker product via the MoA lemma: transpose+reshape of the outer."""
    m, n = a.shape
    p, q = b.shape
    return outer_ref(a, b).transpose(0, 2, 1, 3).reshape(m * p, n * q)


def expert_gemm_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    """Grouped (capacity-padded) expert GEMM: (E, cap, d) x (E, d, f)."""
    out_dtype = out_dtype or x.dtype
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(out_dtype)


def ipophp_ref(a: jax.Array, b: jax.Array, mode: str) -> jax.Array:
    """The unified inner/outer/hadamard/kron operator (paper appendix)."""
    if mode == "ip":
        return gemm_ref(a, b)
    if mode == "hp":
        return hadamard_ref(a, b)
    if mode == "op":
        return outer_ref(a, b)
    if mode == "kp":
        return kron_ref(a, b)
    raise ValueError(f"unknown ipophp mode {mode!r}")
