"""Public, jit-friendly wrappers around the Pallas kernels.

These handle: static block-size solving (via ``repro.core.blocking``),
padding to block multiples (the grid covers the padded problem; the pad is
sliced away), dtype policy (f32 accumulation), backend dispatch (Pallas on
TPU, interpret-mode Pallas for CPU validation, jnp oracle fallback), and the
``ipophp`` unified-operator dispatcher of the paper's appendix.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blocking import BlockChoice, solve_blocks
from repro.core.lifting import TPU_V5E
from repro.kernels import ref
from repro.kernels import moa_gemm as _k


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads)
    return x


def default_blocks(m: int, k: int, n: int, dtype) -> BlockChoice:
    """Solver defaults tuned for kernel use: quarter-VMEM budget keeps
    double-buffering headroom; caps keep the grid >= a few cells."""
    bc = solve_blocks(min(m, 512), min(k, 2048), min(n, 512), dtype,
                      hardware=TPU_V5E, vmem_budget_frac=0.25)
    return bc


@functools.partial(jax.jit, static_argnames=("blocks", "out_dtype", "interpret"))
def _moa_gemm_impl(a, b, blocks: BlockChoice, out_dtype, interpret: bool):
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (blocks.bm, blocks.bk))
    bp = _pad_to(b, (blocks.bk, blocks.bn))
    out = _k.moa_gemm_kernel(ap, bp, blocks, out_dtype=out_dtype,
                             interpret=interpret)
    return out[:m, :n]


def moa_gemm(a: jax.Array, b: jax.Array, *, blocks: Optional[BlockChoice] = None,
             out_dtype=None, interpret: Optional[bool] = None) -> jax.Array:
    """C = A @ B through the MoA blocked-contiguous Pallas kernel."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    blocks = blocks or default_blocks(m, k, n, a.dtype)
    out_dtype = out_dtype or a.dtype
    return _moa_gemm_impl(a, b, blocks, jnp.dtype(out_dtype),
                          _auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("blocks", "out_dtype", "interpret"))
def _expert_gemm_impl(x, w, blocks: BlockChoice, out_dtype, interpret: bool):
    e, cap, d = x.shape
    _, _, f = w.shape
    xp = _pad_to(x, (1, blocks.bm, blocks.bk))
    wp = _pad_to(w, (1, blocks.bk, blocks.bn))
    out = _k.expert_gemm_kernel(xp, wp, blocks, out_dtype=out_dtype,
                                interpret=interpret)
    return out[:, :cap, :f]


def expert_gemm(x: jax.Array, w: jax.Array, *, blocks: Optional[BlockChoice] = None,
                out_dtype=None, interpret: Optional[bool] = None) -> jax.Array:
    """(E, cap, d) x (E, d, f) -> (E, cap, f) capacity-padded expert GEMM."""
    e, cap, d = x.shape
    e2, d2, f = w.shape
    if e != e2 or d != d2:
        raise ValueError(f"expert gemm mismatch {x.shape} x {w.shape}")
    blocks = blocks or default_blocks(cap, d, f, x.dtype)
    out_dtype = out_dtype or x.dtype
    return _expert_gemm_impl(x, w, blocks, jnp.dtype(out_dtype),
                             _auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _hadamard_impl(a, b, block, interpret: bool):
    m, n = a.shape
    ap = _pad_to(a, block)
    bp = _pad_to(b, block)
    return _k.hadamard_kernel(ap, bp, block, interpret=interpret)[:m, :n]


def hadamard(a: jax.Array, b: jax.Array, *, block: tuple[int, int] = (256, 256),
             interpret: Optional[bool] = None) -> jax.Array:
    if a.shape != b.shape:
        raise ValueError(f"hadamard shape mismatch {a.shape} vs {b.shape}")
    block = (min(block[0], max(a.shape[0], 8)), min(block[1], max(a.shape[1], 128)))
    return _hadamard_impl(a, b, block, _auto_interpret(interpret))


# ---------------------------------------------------------------------------
# the unified operator (paper appendix: "one algorithm/circuit (ipophp)")
# ---------------------------------------------------------------------------

def outer(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None
          ) -> jax.Array:
    """Outer product of matrices through the SAME gemm circuit: the MoA
    degenerate inner product — rav(A) (mn,1) . rav(B)^T (1,pq), reshaped.
    (Contraction extent 1: the sigma loop collapses, nothing else changes.)"""
    m, n = a.shape
    p, q = b.shape
    flat = moa_gemm(a.reshape(m * n, 1), b.reshape(1, p * q),
                    interpret=interpret)
    return flat.reshape(m, n, p, q)


def kron(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None
         ) -> jax.Array:
    """Kronecker product = outer product + gamma re-layout (transpose/reshape):
    the paper's claim that KP shares the MM circuit, realized literally."""
    m, n = a.shape
    p, q = b.shape
    return outer(a, b, interpret=interpret).transpose(0, 2, 1, 3).reshape(m * p, n * q)


def ipophp(a: jax.Array, b: jax.Array, mode: str, *,
           interpret: Optional[bool] = None) -> jax.Array:
    """Unified inner/outer/hadamard/kron dispatcher (single blocked circuit:
    'ip' is the full schedule, 'op'/'kp' are its contraction-degenerate form,
    'hp' its pairing-degenerate form)."""
    if mode == "ip":
        return moa_gemm(a, b, interpret=interpret)
    if mode == "op":
        return outer(a, b, interpret=interpret)
    if mode == "kp":
        return kron(a, b, interpret=interpret)
    if mode == "hp":
        return hadamard(a, b, interpret=interpret)
    raise ValueError(f"unknown ipophp mode {mode!r}")


# convenience: oracle aliases so callers can switch paths uniformly
gemm_ref = ref.gemm_ref
ipophp_ref = ref.ipophp_ref
