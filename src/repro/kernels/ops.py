"""Public, jit-friendly entry points for the derived-schedule Pallas kernels.

Execution pipeline — the paper's derivation end to end, per call:

    shapes ──solve_blocks──► lifted ONF ──derive_schedule──► emit_pallas

Every stage is cached: ``repro.core.schedule`` memoizes the derivation (and
the brute-force block search inside it) on ``(op, shapes, dtype, hardware)``,
and this module memoizes the emitted, jitted callables, so hot serving and
training paths never re-derive.

Dispatch is registry-driven (``repro.core.hardware``): the entry detected
once per process decides whether kernels compile (TPU), run through the
Pallas interpreter (CPU validation), or — for the high-level ``matmul`` /
``expert_matmul`` entries the models call — fall back to the XLA oracle with
identical f32-accumulation semantics.

The hand-written kernels remain available for one release as a numerical
cross-check behind ``REPRO_LEGACY_KERNELS=1`` (or ``legacy=True``).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blocking import BlockChoice
from repro.core import schedule as _sched
from repro.core.hardware import HardwareEntry, current_hardware, get_entry
from repro.kernels import ref
from repro.kernels import moa_gemm as _legacy
from repro.kernels.emit import emit_pallas


def _use_legacy(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return os.environ.get("REPRO_LEGACY_KERNELS", "") not in ("", "0")


def _resolve(hardware, interpret) -> tuple[HardwareEntry, bool]:
    hw = hardware or current_hardware()
    return hw, (hw.interpret if interpret is None else interpret)


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads)
    return x


def default_blocks(m: int, k: int, n: int, dtype,
                   hardware: Optional[HardwareEntry] = None) -> BlockChoice:
    """The registry-aware block policy (see schedule.default_gemm_blocks)."""
    hw = hardware or current_hardware()
    return _sched.default_gemm_blocks(m, k, n, dtype, hw.shape)


# ---------------------------------------------------------------------------
# derived-schedule executors (cached per (op, shapes, dtype, hardware))
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _gemm_callable(m, k, n, dtype_s, out_dtype_s, blocks, hw_name, interpret):
    bundle = _sched.get_schedule("gemm", (m, k, n), dtype_s,
                                 get_entry(hw_name), blocks=blocks)
    kern = emit_pallas(bundle.schedule, out_dtype=out_dtype_s,
                       interpret=interpret)
    bm, bk, bn = bundle.blocks.as_tuple()

    @jax.jit
    def call(a, b):
        out = kern(_pad_to(a, (bm, bk)), _pad_to(b, (bk, bn)))
        return out[:m, :n]

    return call


@functools.lru_cache(maxsize=512)
def _expert_callable(e, cap, d, f, dtype_s, out_dtype_s, blocks, hw_name,
                     interpret):
    bundle = _sched.get_schedule("expert_gemm", (e, cap, d, f), dtype_s,
                                 get_entry(hw_name), blocks=blocks)
    kern = emit_pallas(bundle.schedule, out_dtype=out_dtype_s,
                       interpret=interpret)
    bm, bk, bn = bundle.blocks.as_tuple()

    @jax.jit
    def call(x, w):
        out = kern(_pad_to(x, (1, bm, bk)), _pad_to(w, (1, bk, bn)))
        return out[:, :cap, :f]

    return call


@functools.lru_cache(maxsize=512)
def _hadamard_callable(m, n, block, dtype_s, hw_name, interpret):
    bundle = _sched.get_schedule("hadamard", (m, n), dtype_s,
                                 get_entry(hw_name), blocks=block)
    kern = emit_pallas(bundle.schedule, out_dtype=dtype_s,
                       interpret=interpret)

    @jax.jit
    def call(a, b):
        return kern(_pad_to(a, block), _pad_to(b, block))[:m, :n]

    return call


# ---------------------------------------------------------------------------
# kernel entry points
# ---------------------------------------------------------------------------

def moa_gemm(a: jax.Array, b: jax.Array, *, blocks: Optional[BlockChoice] = None,
             out_dtype=None, interpret: Optional[bool] = None,
             legacy: Optional[bool] = None,
             hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """C = A @ B through the derived MoA blocked-contiguous schedule."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    if _use_legacy(legacy):
        bc = blocks or default_blocks(m, k, n, a.dtype, hw)
        return _legacy_gemm(a, b, bc, out_dtype, interp)
    fn = _gemm_callable(m, k, n, str(jnp.dtype(a.dtype)), str(out_dtype),
                        blocks, hw.name, interp)
    return fn(a, b)


def expert_gemm(x: jax.Array, w: jax.Array, *, blocks: Optional[BlockChoice] = None,
                out_dtype=None, interpret: Optional[bool] = None,
                legacy: Optional[bool] = None,
                hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """(E, cap, d) x (E, d, f) -> (E, cap, f) capacity-padded expert GEMM —
    the same derived schedule with the expert axis as one more lift."""
    e, cap, d = x.shape
    e2, d2, f = w.shape
    if e != e2 or d != d2:
        raise ValueError(f"expert gemm mismatch {x.shape} x {w.shape}")
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    if _use_legacy(legacy):
        bc = blocks or default_blocks(cap, d, f, x.dtype, hw)
        return _legacy_expert(x, w, bc, out_dtype, interp)
    fn = _expert_callable(e, cap, d, f, str(jnp.dtype(x.dtype)),
                          str(out_dtype), blocks, hw.name, interp)
    return fn(x, w)


def hadamard(a: jax.Array, b: jax.Array, *, block: tuple[int, int] = (256, 256),
             interpret: Optional[bool] = None, legacy: Optional[bool] = None,
             hardware: Optional[HardwareEntry] = None) -> jax.Array:
    if a.shape != b.shape:
        raise ValueError(f"hadamard shape mismatch {a.shape} vs {b.shape}")
    m, n = a.shape
    block = (min(block[0], max(m, 8)), min(block[1], max(n, 128)))
    hw, interp = _resolve(hardware, interpret)
    if _use_legacy(legacy):
        return _legacy_hadamard(a, b, block, interp)
    fn = _hadamard_callable(m, n, block, str(jnp.dtype(a.dtype)), hw.name,
                            interp)
    return fn(a, b)


# ---------------------------------------------------------------------------
# legacy hand-written kernels (cross-check path, one release)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("blocks", "out_dtype", "interpret"))
def _legacy_gemm(a, b, blocks: BlockChoice, out_dtype, interpret: bool):
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (blocks.bm, blocks.bk))
    bp = _pad_to(b, (blocks.bk, blocks.bn))
    out = _legacy.moa_gemm_kernel(ap, bp, blocks, out_dtype=out_dtype,
                                  interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("blocks", "out_dtype", "interpret"))
def _legacy_expert(x, w, blocks: BlockChoice, out_dtype, interpret: bool):
    e, cap, d = x.shape
    _, _, f = w.shape
    xp = _pad_to(x, (1, blocks.bm, blocks.bk))
    wp = _pad_to(w, (1, blocks.bk, blocks.bn))
    out = _legacy.expert_gemm_kernel(xp, wp, blocks, out_dtype=out_dtype,
                                     interpret=interpret)
    return out[:, :cap, :f]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _legacy_hadamard(a, b, block, interpret: bool):
    m, n = a.shape
    ap = _pad_to(a, block)
    bp = _pad_to(b, block)
    return _legacy.hadamard_kernel(ap, bp, block, interpret=interpret)[:m, :n]


# ---------------------------------------------------------------------------
# unified model-facing entries: derived schedules on Pallas backends, the
# identical-semantics XLA oracle elsewhere.  These are what the models,
# collectives and benchmarks call — the single execution path.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _pallas_matmul_f32(x2, w2, hw_name, interpret):
    return moa_gemm(x2, w2, out_dtype=jnp.float32, interpret=interpret,
                    hardware=get_entry(hw_name))


def _pallas_matmul_fwd(x2, w2, hw_name, interpret):
    return _pallas_matmul_f32(x2, w2, hw_name, interpret), (x2, w2)


def _pallas_matmul_bwd(hw_name, interpret, resid, g):
    x2, w2 = resid
    hw = get_entry(hw_name)
    dx = moa_gemm(g, w2.T, out_dtype=x2.dtype, interpret=interpret,
                  hardware=hw)
    dw = moa_gemm(x2.T, g, out_dtype=w2.dtype, interpret=interpret,
                  hardware=hw)
    return dx, dw


_pallas_matmul_f32.defvjp(_pallas_matmul_fwd, _pallas_matmul_bwd)


def matmul(x: jax.Array, w: jax.Array, *, out_dtype=None,
           interpret: Optional[bool] = None,
           hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """Unified MoA matmul: ``y[..., :] = x[..., k] @ w[k, ...]``.

    Leading dims of ``x`` and trailing dims of ``w`` collapse to the 2-D MoA
    GEMM (one gamma re-layout each way).  On a Pallas backend this executes
    the derived schedule (differentiable: the VJP is two more derived GEMMs);
    elsewhere it is the XLA oracle with the same f32-accumulation contract,
    so CPU tests and TPU serving share semantics.
    """
    kdim = x.shape[-1]
    if w.shape[0] != kdim:
        raise ValueError(f"matmul contraction mismatch {x.shape} @ {w.shape}")
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    x2 = x.reshape(-1, kdim)
    w2 = w.reshape(kdim, -1)
    if hw.backend == "pallas" or interpret:
        y = _pallas_matmul_f32(x2, w2, hw.name, bool(interp))
    else:
        y = jnp.dot(x2, w2, preferred_element_type=jnp.float32)
    return y.astype(out_dtype).reshape(x.shape[:-1] + w.shape[1:])


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _pallas_expert_f32(x, w, hw_name, interpret):
    return expert_gemm(x, w, out_dtype=jnp.float32, interpret=interpret,
                       hardware=get_entry(hw_name))


def _pallas_expert_fwd(x, w, hw_name, interpret):
    return _pallas_expert_f32(x, w, hw_name, interpret), (x, w)


def _pallas_expert_bwd(hw_name, interpret, resid, g):
    x, w = resid
    hw = get_entry(hw_name)
    dx = expert_gemm(g, jnp.swapaxes(w, 1, 2), out_dtype=x.dtype,
                     interpret=interpret, hardware=hw)
    dw = expert_gemm(jnp.swapaxes(x, 1, 2), g, out_dtype=w.dtype,
                     interpret=interpret, hardware=hw)
    return dx, dw


_pallas_expert_f32.defvjp(_pallas_expert_fwd, _pallas_expert_bwd)


def expert_matmul(x: jax.Array, w: jax.Array, *, out_dtype=None,
                  interpret: Optional[bool] = None,
                  hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """Unified batched expert contraction ``ecd,edf->ecf`` — the MoE dispatch
    hot path, through the derived expert schedule on Pallas backends."""
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    if hw.backend == "pallas" or interpret:
        y = _pallas_expert_f32(x, w, hw.name, bool(interp))
    else:
        y = jnp.einsum("ecd,edf->ecf", x, w,
                       preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# the unified operator (paper appendix: "one algorithm/circuit (ipophp)")
# ---------------------------------------------------------------------------

def outer(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None
          ) -> jax.Array:
    """Outer product of matrices through the SAME gemm circuit: the MoA
    degenerate inner product — rav(A) (mn,1) . rav(B)^T (1,pq), reshaped.
    (Contraction extent 1: the sigma loop collapses, nothing else changes.)"""
    m, n = a.shape
    p, q = b.shape
    flat = moa_gemm(a.reshape(m * n, 1), b.reshape(1, p * q),
                    interpret=interpret)
    return flat.reshape(m, n, p, q)


def kron(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None
         ) -> jax.Array:
    """Kronecker product = outer product + gamma re-layout (transpose/reshape):
    the paper's claim that KP shares the MM circuit, realized literally."""
    m, n = a.shape
    p, q = b.shape
    return outer(a, b, interpret=interpret).transpose(0, 2, 1, 3).reshape(m * p, n * q)


def ipophp(a: jax.Array, b: jax.Array, mode: str, *,
           interpret: Optional[bool] = None) -> jax.Array:
    """Unified inner/outer/hadamard/kron dispatcher (single blocked circuit:
    'ip' is the full schedule, 'op'/'kp' are its contraction-degenerate form,
    'hp' its pairing-degenerate form)."""
    if mode == "ip":
        return moa_gemm(a, b, interpret=interpret)
    if mode == "op":
        return outer(a, b, interpret=interpret)
    if mode == "kp":
        return kron(a, b, interpret=interpret)
    if mode == "hp":
        return hadamard(a, b, interpret=interpret)
    raise ValueError(f"unknown ipophp mode {mode!r}")


# convenience: oracle aliases so callers can switch paths uniformly
gemm_ref = ref.gemm_ref
ipophp_ref = ref.ipophp_ref
