"""Public, jit-friendly entry points for the derived-schedule Pallas kernels.

Execution pipeline — the paper's derivation end to end, per call:

    expression ──normalize──► ONF ──lift/derive_schedule──► emit_pallas

The unit of dispatch is a **MoA expression** (``repro.core.expr``), not a
string op name: ``apply(expr, *arrays)`` runs any normalizable expression
through the derived-schedule pipeline, and the familiar entries
(``matmul``, ``expert_matmul``, ``moa_gemm``, ``hadamard``,
``semiring_matmul``) are one-line expression builders on top of it.  The
schedule cache (``repro.core.schedule``) is keyed on the expression's
*normal form*, and this module memoizes the emitted, jitted callables on the
same key, so hot serving and training paths never re-derive.

Dispatch is registry-driven (``repro.core.hardware``): the entry detected
once per process decides whether kernels compile (TPU), run through the
Pallas interpreter (CPU validation), or — for the high-level ``matmul`` /
``expert_matmul`` entries the models call — fall back to the XLA oracle with
identical f32-accumulation semantics.

``matmul(..., transpose_b=True)`` lowers ``x @ w.T`` to a transposed-operand
schedule: normalize turns the transposed leaf into column-gamma
coefficients, so the stored ``(n, k)`` array is blocked in place — no
relayout copy of (say) a vocab embedding table every step.

``matmul``/``expert_matmul``/``apply`` also accept a ``mesh=`` (a live
``jax.sharding.Mesh``): the call then derives a ``DistributedPlan``
(``repro.distributed.plan``) — partition specs, collective schedule and the
per-shard derived kernel all from the same lifted normal form — and runs it
through ``shard_map``.  ``shard`` names which axes lift onto which mesh
axes (roles ``{"m", "n", "k"}`` for matmul, plus ``"e"`` for experts; plan
axis symbols for ``apply``); non-divisible axes fall back to replication.
"""
from __future__ import annotations

import functools
import threading
from collections import OrderedDict
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import expr as E
from repro.core import schedule as _sched
from repro.core.blocking import BlockChoice
from repro.core.hardware import HardwareEntry, current_hardware, get_entry
from repro.kernels import ref
from repro.kernels.emit import emit_bundle, emit_shard_map


def _resolve(hardware, interpret) -> tuple[HardwareEntry, bool]:
    hw = hardware or current_hardware()
    return hw, (hw.interpret if interpret is None else interpret)


def _use_kernel(hw: HardwareEntry, interp: bool, interpret) -> bool:
    """The one dispatch policy for the streaming/recurrent entries
    (attention, scan_ssd, gated_scan): the derived kernel on compiled-Pallas
    entries, on "interpret" entries (the CPU validation path), or by
    explicit request; "xla" entries use the jnp oracle."""
    return (hw.backend == "pallas"
            or (hw.backend == "interpret" and interp)
            or bool(interpret))


# ---------------------------------------------------------------------------
# the generic executor: expression -> cached, jitted pad/kernel/slice callable
# ---------------------------------------------------------------------------

_CALLABLES: "OrderedDict[tuple, object]" = OrderedDict()
_CALLABLES_LOCK = threading.Lock()
_CALLABLES_SIZE = 512


def _block_key(blocks):
    return tuple(blocks) if isinstance(blocks, (list, tuple)) else blocks


def _cache_put(key, fn):
    with _CALLABLES_LOCK:
        fn = _CALLABLES.setdefault(key, fn)
        _CALLABLES.move_to_end(key)
        while len(_CALLABLES) > _CALLABLES_SIZE:
            _CALLABLES.popitem(last=False)
        return fn


def _cache_get(key):
    with _CALLABLES_LOCK:
        fn = _CALLABLES.get(key)
        if fn is not None:
            _CALLABLES.move_to_end(key)
        return fn


def _expr_callable(expr: "E.Expr", dtype_s: str, out_dtype_s: str,
                   hw_name: str, interpret: bool, blocks=None,
                   acc_dtype: str = "float32"):
    """The memoized executable for one normal form: pad operands to the
    schedule's storage shapes (with the semiring's inert element), run the
    emitted kernel, slice the logical result back out (``emit_bundle``)."""
    nf = expr if isinstance(expr, E.NormalForm) else E.normal_form(expr)
    key = (nf.key(), dtype_s, out_dtype_s, hw_name, interpret,
           _block_key(blocks), acc_dtype)
    fn = _cache_get(key)
    if fn is not None:
        return fn
    bundle = _sched.get_schedule(nf, dtype=dtype_s,
                                 hardware=get_entry(hw_name), blocks=blocks,
                                 acc_dtype=acc_dtype)
    call = jax.jit(emit_bundle(bundle, out_dtype=out_dtype_s,
                               interpret=interpret))
    return _cache_put(key, call)


def _sharded_callable(nf: "E.NormalForm", dtype_s: str, out_dtype_s: str,
                      hw_name: str, interpret: bool, use_kernel: bool,
                      mesh, shard: dict, replicate_out: bool,
                      local_fn=None, local_tag: Optional[str] = None,
                      scatter_axis=None, acc_dtype: str = "float32"):
    """Memoized shard_map executable for one (normal form, mesh, sharding)
    triple: derives (or re-reads from the plan cache) the DistributedPlan,
    then wraps its collectives around the per-shard kernel/oracle."""
    from repro.distributed import plan as dplan

    shard_key = tuple(sorted(shard.items()))
    key = ("shard", nf.key(), dtype_s, out_dtype_s, hw_name, interpret,
           use_kernel, mesh, shard_key, replicate_out, local_tag,
           scatter_axis, acc_dtype)
    fn = _cache_get(key)
    if fn is not None:
        return fn
    plan = dplan.derive_plan(nf, mesh, shard=shard,
                             hardware=get_entry(hw_name), dtype=dtype_s,
                             replicate_out=replicate_out,
                             scatter_axis=scatter_axis, acc_dtype=acc_dtype)
    call = jax.jit(emit_shard_map(plan, mesh, local_fn,
                                  out_dtype=out_dtype_s,
                                  interpret=interpret,
                                  use_kernel=use_kernel))
    return _cache_put(key, call)


def apply(expr: "E.Expr", *arrays: jax.Array, out_dtype=None,
          interpret: Optional[bool] = None,
          hardware: Optional[HardwareEntry] = None,
          blocks=None, mesh=None, shard: Optional[dict] = None,
          replicate_out: bool = False,
          acc_dtype: str = "float32",
          verify: Union[bool, str] = False) -> jax.Array:
    """Evaluate a composed MoA expression — the public derived-kernel entry.

    ``arrays`` bind the expression's leaves in composition order by their
    *storage* shapes: a row-major leaf takes its logical shape, a
    column-major leaf takes the reversed (physical buffer) shape — so
    ``transpose(arr((n, k)))`` and ``arr((k, n), layout="col")`` bind the
    identical ``(n, k)`` array, as they share a normal form.  On a Pallas
    backend the normal form is lifted, scheduled and emitted (cached per
    normal form); elsewhere the jnp oracle (``kernels.ref.eval_expr``)
    evaluates the same semantics.

    With ``mesh=`` (a live ``jax.sharding.Mesh``) the normal form is lifted
    one level further: ``shard`` maps its axis symbols to mesh axes, and the
    derived ``DistributedPlan`` runs the per-shard kernel (or oracle) inside
    ``shard_map`` with the plan's collectives.

    ``verify=True`` runs the static soundness checks (``repro.analysis``)
    on the derived schedule/plan before executing, raising
    ``VerificationError`` on any unsound derivation.  ``verify="kernel"``
    additionally traces the emitted Pallas kernel body and checks its
    effect summary against the schedule contract (single-chip path only;
    the sharded path keeps schedule-level checks).  Results are cached on
    the same normal-form keys as the schedules, so repeated calls — and
    every ``verify=False`` call — pay nothing.
    """
    nf = E.normal_form(expr)
    shapes = nf.leaf_storage_shapes()
    if len(arrays) != len(shapes):
        raise ValueError(f"expression has {len(shapes)} leaves, got "
                         f"{len(arrays)} arrays")
    for i, (a, s) in enumerate(zip(arrays, shapes)):
        if tuple(a.shape) != s:
            raise ValueError(f"leaf {i} ({nf.leaves[i].array!r}) expects "
                             f"storage shape {s}, got {tuple(a.shape)}")
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or arrays[0].dtype)
    # kernel path on Pallas backends or by explicit request; the registry's
    # "interpret"/"xla" entries otherwise use the jnp oracle (interpret-mode
    # Pallas is the validation path, not the default execution path)
    use_kernel = hw.backend == "pallas" or bool(interpret)
    dtype_s = str(jnp.dtype(arrays[0].dtype))
    if mesh is not None:
        if blocks is not None:
            raise ValueError(
                "apply(mesh=...) derives per-shard blocks from the plan; "
                "pinning blocks= is not supported on the sharded path")
        if verify:
            from repro import analysis
            analysis.verify_sharded(nf, mesh, shard or {}, hardware=hw,
                                    dtype=dtype_s,
                                    replicate_out=replicate_out,
                                    acc_dtype=acc_dtype)
        fn = _sharded_callable(nf, dtype_s, str(out_dtype), hw.name, interp,
                               use_kernel, mesh, shard or {}, replicate_out,
                               acc_dtype=acc_dtype)
        return fn(*arrays)
    if verify:
        from repro import analysis
        analysis.verify_expr(nf, dtype=dtype_s, hardware=hw, blocks=blocks,
                             acc_dtype=acc_dtype,
                             kernel=(verify == "kernel"))
    if use_kernel:
        fn = _expr_callable(nf, dtype_s, str(out_dtype), hw.name, interp,
                            blocks, acc_dtype=acc_dtype)
        return fn(*arrays)
    return ref.eval_expr(expr, *arrays).astype(out_dtype)


# ---------------------------------------------------------------------------
# kernel entry points (expression builders over the generic executor)
# ---------------------------------------------------------------------------

def moa_gemm(a: jax.Array, b: jax.Array, *, blocks: Optional[BlockChoice] = None,
             out_dtype=None, interpret: Optional[bool] = None,
             hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """C = A @ B through the derived MoA blocked-contiguous schedule."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    fn = _expr_callable(E.matmul_expr(m, k, n), str(jnp.dtype(a.dtype)),
                        str(out_dtype), hw.name, interp, blocks)
    return fn(a, b)


def expert_gemm(x: jax.Array, w: jax.Array, *, blocks: Optional[BlockChoice] = None,
                out_dtype=None, interpret: Optional[bool] = None,
                hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """(E, cap, d) x (E, d, f) -> (E, cap, f) capacity-padded expert GEMM —
    the same derived schedule with the expert axis as one more lift."""
    e, cap, d = x.shape
    e2, d2, f = w.shape
    if e != e2 or d != d2:
        raise ValueError(f"expert gemm mismatch {x.shape} x {w.shape}")
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    fn = _expr_callable(E.expert_gemm_expr(e, cap, d, f),
                        str(jnp.dtype(x.dtype)), str(out_dtype),
                        hw.name, interp, blocks)
    return fn(x, w)


def hadamard(a: jax.Array, b: jax.Array, *, block: tuple[int, int] = (256, 256),
             interpret: Optional[bool] = None,
             hardware: Optional[HardwareEntry] = None) -> jax.Array:
    if a.shape != b.shape:
        raise ValueError(f"hadamard shape mismatch {a.shape} vs {b.shape}")
    m, n = a.shape
    block = (min(block[0], max(m, 8)), min(block[1], max(n, 128)))
    hw, interp = _resolve(hardware, interpret)
    fn = _expr_callable(E.hadamard_expr(m, n), str(jnp.dtype(a.dtype)),
                        str(jnp.dtype(a.dtype)), hw.name, interp, block)
    return fn(a, b)


def semiring_matmul(a: jax.Array, b: jax.Array, *, plus: str, times: str,
                    interpret: Optional[bool] = None,
                    hardware: Optional[HardwareEntry] = None,
                    blocks=None) -> jax.Array:
    """Matmul over any registered semiring, e.g. ``plus="min", times="add"``
    (tropical shortest path) — the same derived schedule as ``moa_gemm``;
    only the emitted block body changes."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} . {b.shape}")
    hw, interp = _resolve(hardware, interpret)
    expr = E.inner(plus, times, E.arr("A", (m, k)), E.arr("B", (k, n)))
    if hw.backend == "pallas" or interpret:
        fn = _expr_callable(expr, str(jnp.dtype(a.dtype)), "float32",
                            hw.name, interp, blocks)
        return fn(a, b)
    return ref.eval_expr(expr, a, b)


# ---------------------------------------------------------------------------
# unified model-facing entries: derived schedules on Pallas backends, the
# identical-semantics XLA oracle elsewhere.  These are what the models,
# collectives and benchmarks call — the single execution path.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _pallas_matmul_f32(x2, w2, hw_name, interpret, transpose_b):
    m, k = x2.shape
    n = w2.shape[0] if transpose_b else w2.shape[1]
    fn = _expr_callable(E.matmul_expr(m, k, n, transpose_b=transpose_b),
                        str(jnp.dtype(x2.dtype)), "float32", hw_name,
                        interpret)
    return fn(x2, w2)


def _gemm_tb(a, b, out_dtype_s, hw_name, interpret):
    """a (m, k) @ b (n, k).T via the transposed-second-operand schedule."""
    fn = _expr_callable(E.matmul_expr(a.shape[0], a.shape[1], b.shape[0],
                                      transpose_b=True),
                        str(jnp.dtype(a.dtype)), out_dtype_s, hw_name,
                        bool(interpret))
    return fn(a, b)


def _gemm_ta(a, b, out_dtype_s, hw_name, interpret):
    """a (t, m).T @ b (t, n) — the transposed-FIRST-operand schedule (both
    VJP weight gradients have this shape), again with no relayout copy."""
    t, m = a.shape
    t2, n = b.shape
    expr = E.inner("add", "mul", E.transpose(E.arr("A", (t, m))),
                   E.arr("B", (t2, n)))
    fn = _expr_callable(expr, str(jnp.dtype(a.dtype)), out_dtype_s, hw_name,
                        bool(interpret))
    return fn(a, b)


def _pallas_matmul_fwd(x2, w2, hw_name, interpret, transpose_b):
    return _pallas_matmul_f32(x2, w2, hw_name, interpret, transpose_b), (x2, w2)


def _pallas_matmul_bwd(hw_name, interpret, transpose_b, resid, g):
    """Both gradients are two more derived GEMMs, every transposed operand
    read through its gamma coefficients — no transpose copy of either the
    weight or the (often vocab-sized) logits gradient."""
    x2, w2 = resid
    hw = get_entry(hw_name)
    if transpose_b:
        # y = x w^T: dx = g @ w (stored layout); dw = g^T @ x
        dx = moa_gemm(g, w2, out_dtype=x2.dtype, interpret=interpret,
                      hardware=hw)
        dw = _gemm_ta(g, x2, str(w2.dtype), hw_name, interpret)
    else:
        # dx = g @ w^T; dw = x^T @ g
        dx = _gemm_tb(g, w2, str(x2.dtype), hw_name, interpret)
        dw = _gemm_ta(x2, g, str(w2.dtype), hw_name, interpret)
    return dx, dw


_pallas_matmul_f32.defvjp(_pallas_matmul_fwd, _pallas_matmul_bwd)


def _xla_matmul_f32(x2: jax.Array, w2: jax.Array,
                    transpose_b: bool) -> jax.Array:
    """The XLA oracle body with the kernels' f32-accumulation contract."""
    if transpose_b:
        return jax.lax.dot_general(x2, w2, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    return jnp.dot(x2, w2, preferred_element_type=jnp.float32)


def _matmul_sharded(x2, w2, transpose_b, hw, interp, use_kernel, mesh,
                    shard, replicate_out):
    """The mesh path of ``matmul``: derive the DistributedPlan for the 2-D
    GEMM and run the (differentiable) single-device body per shard."""
    from repro.distributed.plan import MATMUL_ROLES, _translate

    m, kdim = x2.shape
    n = w2.shape[0] if transpose_b else w2.shape[1]
    if shard is None:                      # rows over the first mesh axis,
        names = tuple(mesh.axis_names)     # columns over the second
        shard = {"m": names[0]}
        if len(names) > 1:
            shard["n"] = names[1]
    nf = E.normal_form(E.matmul_expr(m, kdim, n, transpose_b=transpose_b),
                       name="matmul")
    if use_kernel:
        local = lambda a, b: _pallas_matmul_f32(a, b, hw.name, bool(interp),
                                                transpose_b)
        tag = "matmul_vjp"
    else:
        local = lambda a, b: _xla_matmul_f32(a, b, transpose_b)
        tag = "matmul_xla"
    fn = _sharded_callable(nf, str(jnp.dtype(x2.dtype)), "float32", hw.name,
                           bool(interp), use_kernel, mesh,
                           _translate(shard, MATMUL_ROLES), replicate_out,
                           local_fn=local, local_tag=tag)
    return fn(x2, w2)


def matmul(x: jax.Array, w: jax.Array, *, transpose_b: bool = False,
           out_dtype=None, interpret: Optional[bool] = None,
           hardware: Optional[HardwareEntry] = None,
           mesh=None, shard: Optional[dict] = None,
           replicate_out: bool = False) -> jax.Array:
    """Unified MoA matmul: ``y[..., :] = x[..., k] @ w[k, ...]``.

    Leading dims of ``x`` and trailing dims of ``w`` collapse to the 2-D MoA
    GEMM (one gamma re-layout each way).  On a Pallas backend this executes
    the derived schedule (differentiable: the VJP is two more derived GEMMs);
    elsewhere it is the XLA oracle with the same f32-accumulation contract,
    so CPU tests and TPU serving share semantics.

    ``transpose_b`` contracts against the *stored* layout of a ``(..., k)``
    weight: ``y[..., :] = x[..., k] @ w[..., k].T``.  The derived schedule
    reads the table through column-gamma coefficients — no transpose copy —
    which is what lets the tied-embeddings logits head share this entry.

    ``mesh``/``shard``/``replicate_out`` lift the GEMM one level further to
    named device axes (roles ``{"m", "n", "k"}``; sharding "k" derives the
    tensor-parallel psum) and run the same body per shard through the
    derived ``DistributedPlan`` — see ``repro.distributed.plan``.
    """
    kdim = x.shape[-1]
    if transpose_b:
        if w.shape[-1] != kdim:
            raise ValueError(
                f"matmul(transpose_b) contraction mismatch {x.shape} @ "
                f"{w.shape}.T")
        w2 = w.reshape(-1, kdim)
        out_tail = w.shape[:-1]
    else:
        if w.shape[0] != kdim:
            raise ValueError(f"matmul contraction mismatch {x.shape} @ {w.shape}")
        w2 = w.reshape(kdim, -1)
        out_tail = w.shape[1:]
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    x2 = x.reshape(-1, kdim)
    use_kernel = hw.backend == "pallas" or bool(interpret)
    if mesh is not None:
        y = _matmul_sharded(x2, w2, transpose_b, hw, interp, use_kernel,
                            mesh, shard, replicate_out)
    elif use_kernel:
        y = _pallas_matmul_f32(x2, w2, hw.name, bool(interp), transpose_b)
    else:
        y = _xla_matmul_f32(x2, w2, transpose_b)
    return y.astype(out_dtype).reshape(x.shape[:-1] + out_tail)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _pallas_expert_f32(x, w, hw_name, interpret):
    return expert_gemm(x, w, out_dtype=jnp.float32, interpret=interpret,
                       hardware=get_entry(hw_name))


def _pallas_expert_fwd(x, w, hw_name, interpret):
    return _pallas_expert_f32(x, w, hw_name, interpret), (x, w)


def _pallas_expert_bwd(hw_name, interpret, resid, g):
    x, w = resid
    hw = get_entry(hw_name)
    dx = expert_gemm(g, jnp.swapaxes(w, 1, 2), out_dtype=x.dtype,
                     interpret=interpret, hardware=hw)
    dw = expert_gemm(jnp.swapaxes(x, 1, 2), g, out_dtype=w.dtype,
                     interpret=interpret, hardware=hw)
    return dx, dw


_pallas_expert_f32.defvjp(_pallas_expert_fwd, _pallas_expert_bwd)


def expert_matmul(x: jax.Array, w: jax.Array, *, out_dtype=None,
                  interpret: Optional[bool] = None,
                  hardware: Optional[HardwareEntry] = None,
                  mesh=None, shard: Optional[dict] = None,
                  replicate_out: bool = False) -> jax.Array:
    """Unified batched expert contraction ``ecd,edf->ecf`` — the MoE dispatch
    hot path, through the derived expert schedule on Pallas backends.

    ``mesh``/``shard`` lift it across device axes (roles ``{"e", "m", "n",
    "k"}``; sharding "e" is expert parallelism) via a DistributedPlan."""
    hw, interp = _resolve(hardware, interpret)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    use_kernel = hw.backend == "pallas" or bool(interpret)
    if mesh is not None:
        from repro.distributed.plan import EXPERT_ROLES, _translate
        e, cap, d = x.shape
        f = w.shape[2]
        if shard is None:
            shard = {"e": tuple(mesh.axis_names)[0]}
        nf = E.normal_form(E.expert_gemm_expr(e, cap, d, f),
                           name="expert_gemm")
        if use_kernel:
            local = lambda a, b: _pallas_expert_f32(a, b, hw.name,
                                                    bool(interp))
            tag = "expert_vjp"
        else:
            local = lambda a, b: jnp.einsum(
                "ecd,edf->ecf", a, b, preferred_element_type=jnp.float32)
            tag = "expert_xla"
        fn = _sharded_callable(nf, str(jnp.dtype(x.dtype)), "float32",
                               hw.name, bool(interp), use_kernel, mesh,
                               _translate(shard, EXPERT_ROLES),
                               replicate_out, local_fn=local, local_tag=tag)
        y = fn(x, w)
    elif use_kernel:
        y = _pallas_expert_f32(x, w, hw.name, bool(interp))
    else:
        y = jnp.einsum("ecd,edf->ecf", x, w,
                       preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def head_matmul(x: jax.Array, w: jax.Array, *, transpose_b: bool = False,
                out_dtype=None, interpret: Optional[bool] = None,
                hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """Per-head contraction ``bshk,khn->bshn`` (``bshk,nhk->bshn`` with
    ``transpose_b``) — the MLA-decode absorbed projections.

    The head axis batches the GEMM (one more dimension lift, like the
    expert axis), and the head-middle weight is read in its stored layout
    through derived strided coefficients — the per-step transpose copy of
    the ``(kv_rank, heads, dim)`` projection tables (and the einsum
    fallback for the output projection) are gone."""
    b, s, h, kdim = x.shape
    if transpose_b:
        n, h2, k2 = w.shape
    else:
        k2, h2, n = w.shape
    if h2 != h or k2 != kdim:
        raise ValueError(f"head_matmul mismatch {x.shape} . {w.shape}"
                         f"{'.T' if transpose_b else ''}")
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    expr = E.head_gemm_expr(h, b * s, kdim, n, transpose_b=transpose_b)
    y = apply(expr, x.reshape(b * s, h, kdim), w, out_dtype=jnp.float32,
              interpret=interpret, hardware=hardware)        # (h, b*s, n)
    return y.transpose(1, 0, 2).reshape(b, s, h, n).astype(out_dtype)


# ---------------------------------------------------------------------------
# attention: the derived streaming schedule behind an ops-level wrapper
# ---------------------------------------------------------------------------

def _oracle_attention(q, k, v, scale, causal, window=0, prefix_len=0):
    """The jnp online-softmax oracle on the grouped model layout (also the
    recompute body of the kernel path's backward pass)."""
    from repro.models.chunked_attention import chunked_attention
    return chunked_attention(q, k, v, scale=scale, causal=causal,
                             window=window, prefix_len=prefix_len)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_grouped(q, k, v, scale, causal, window, prefix_len, hw_name,
                   interpret, blocks):
    """Forward: the derived streaming Pallas kernel over the grouped layout
    ``q (B, Sq, KV, G, hd); k/v (B, Sk, KV, hd)`` -> ``(B, Sq, KV*G, hd)``.
    The schedule was derived on exactly these *stored* layouts (the logical
    grouped views are transposed leaves, pure index rewrites), so operands
    feed the kernel with no relayout copy; padding to the derived blocks and
    the slice back happen inside the cached executor
    (``kernels.flash_attention``).  ``window``/``prefix_len`` ride the
    recurrent form as streamed-axis masking metadata — the kernel derives
    its block-skip from them instead of falling back to the jnp path."""
    from repro.kernels import flash_attention as fa
    b, sq, kv, g, hd = q.shape
    sk, vd = k.shape[1], v.shape[-1]
    fn = fa._executor(b, kv, g, sq, sk, hd, vd, str(jnp.dtype(q.dtype)),
                      str(jnp.dtype(q.dtype)), hw_name, interpret, causal,
                      scale, blocks, window, prefix_len)
    out = fn(q, k, v)                               # (b, kv, g, sq, vd)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, kv * g, vd)


def _flash_grouped_fwd(q, k, v, scale, causal, window, prefix_len, hw_name,
                       interpret, blocks):
    """Forward rule under differentiation: the ``flash_attention_stats``
    derivation — the same schedule as the primal (identical output, bit for
    bit) but with the carried online-softmax ``(m, l)`` statistics exported
    as extra state outputs.  Residuals are ``(q, k, v, out, m, l)``: the
    flash-backward recurrences reconstruct the probabilities from the saved
    statistics, so no jnp oracle recompute appears in the backward jaxpr."""
    from repro.kernels import flash_attention as fa
    b, sq, kv, g, hd = q.shape
    sk, vd = k.shape[1], v.shape[-1]
    fn = fa._stats_executor(b, kv, g, sq, sk, hd, vd, str(jnp.dtype(q.dtype)),
                            str(jnp.dtype(q.dtype)), hw_name, interpret,
                            causal, scale, blocks, window, prefix_len)
    out5, m, l = fn(q, k, v)                        # out (b, kv, g, sq, vd)
    out = out5.transpose(0, 3, 1, 2, 4).reshape(b, sq, kv * g, vd)
    return out, (q, k, v, out5, m, l)


def _flash_grouped_bwd(scale, causal, window, prefix_len, hw_name, interpret,
                       blocks, resid, g_out):
    """Derived flash backward: two recurrence kinds from the same lifted
    pipeline as the forward.  ``flash_dq`` streams key blocks with a carried
    dq accumulator; ``flash_dkv`` is the transposed weld — key rows, query
    stream — carrying dk with an exported dv state.  Both reuse the saved
    ``(m, l)`` row statistics; ``delta = rowsum(dO * O)`` is the one jnp
    reduction (a residual contraction, not a recompute).  Blocks are read
    from the forward's cached derivation so the padded row axes line up."""
    from repro.kernels import flash_attention as fa
    q, k, v, out5, m, l = resid
    b, sq, kv, g, hd = q.shape
    sk, vd = k.shape[1], v.shape[-1]
    dtype_s = str(jnp.dtype(q.dtype))
    do = g_out.reshape(b, sq, kv, g, vd)            # stored dO layout
    do5 = do.transpose(0, 2, 3, 1, 4)               # (b, kv, g, sq, vd)
    delta = jnp.sum(do5.astype(jnp.float32) * out5.astype(jnp.float32),
                    axis=-1)                        # (b, kv, g, sq)
    fwd_blocks = fa.attention_bundle(
        b, kv, g, sq, sk, hd, vd, dtype=dtype_s,
        hardware=get_entry(hw_name), blocks=blocks, window=window,
        prefix_len=prefix_len).blocks
    bq, bk = fwd_blocks.as_tuple()
    # pass StreamBlockChoice objects, not tuples: the forward's solved
    # blocks may exceed the logical extents (tiny sequences), and the
    # saved (m, l) ride the *forward's* padded row axis — the tuple path
    # would clamp and disagree with the residual padding
    from repro.core.blocking import StreamBlockChoice
    dkv_blocks = StreamBlockChoice(bk, bq, 0, 0.0, 1.0)
    dq_fn = fa._dq_executor(b, kv, g, sq, sk, hd, vd, dtype_s, hw_name,
                            interpret, causal, scale, fwd_blocks, window,
                            prefix_len)
    dq5 = dq_fn(q, k, k, do, v, m, l, delta)        # (b, kv, g, sq, hd)
    dkv_fn = fa._dkv_executor(b, kv, g, sq, sk, hd, vd, dtype_s, hw_name,
                              interpret, causal, scale, dkv_blocks, window,
                              prefix_len)
    dk5, dv5 = dkv_fn(k, q, q, do, v, m, l, delta)  # dk (b,kv,g,sk,hd)
    dq = dq5.transpose(0, 3, 1, 2, 4).astype(q.dtype)
    # per-group dk/dv; the GQA reduction over g is a residual sum (K/V's
    # zero group coefficient in the forward becomes a sum in the cotangent)
    dk = dk5.sum(axis=2).transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv5[:, :, :, :sk].sum(axis=2).transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


_flash_grouped.defvjp(_flash_grouped_fwd, _flash_grouped_bwd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
              causal: bool = True, window: int = 0, prefix_len: int = 0,
              interpret: Optional[bool] = None,
              hardware: Optional[HardwareEntry] = None,
              blocks: Optional[tuple[int, int]] = None) -> jax.Array:
    """Unified grouped-query attention — the model-facing entry.

    ``q: (B, Sq, KV, G, hd)`` (GQA grouping, K/V heads never repeated);
    ``k/v: (B, Sk, KV, hd)``.  Returns ``(B, Sq, KV*G, hd)``.

    On a Pallas backend (or under ``interpret=True``) this runs the flash
    kernel from the *derived* streaming schedule, with the ops-level
    pad/slice contract: any sequence length works — operands are padded to
    the solver's ``(bq, bk)`` multiples, padded keys are masked inert by
    the kernel's ``kpos < sk`` guard, and the logical result is sliced
    back.  Differentiable with a fully *derived* VJP: the forward saves
    the (m, l) statistics (``attention_stats_form``) and the backward runs
    the ``flash_dq``/``flash_dkv`` recurrence kinds — no oracle recompute
    appears in a train step's jaxpr.  On "xla" entries the jnp oracle is
    the forward path (and differentiates through itself), so semantics
    are identical everywhere.

    ``window``/``prefix_len`` (causal only — the honor-or-raise contract of
    ``_chunk_mask``) derive windowed / prefix-LM schedules: the masking
    metadata rides the recurrent form, so the kernel block-skips from it
    instead of dispatching those modes to the jnp path.
    """
    hw, interp = _resolve(hardware, interpret)
    # kernel on compiled-Pallas entries, on "interpret" entries (the CPU
    # validation path — this is what attn_impl="pallas" means off-TPU), or
    # by explicit request; "xla" entries use the jnp oracle.
    use_kernel = _use_kernel(hw, interp, interpret)
    if use_kernel:
        return _flash_grouped(q, k, v, float(scale), bool(causal),
                              int(window), int(prefix_len), hw.name,
                              bool(interp), blocks)
    return _oracle_attention(q, k, v, scale, causal, window,
                             prefix_len).astype(q.dtype)


# ---------------------------------------------------------------------------
# carried-state recurrences: the SSD chunked scan and the RG-LRU gated scan
# through the same derived-schedule pipeline (expr.RecurrentForm ->
# derive_recurrent_schedule -> emit_recurrent), with the ops-level contract:
# pad/reshape the sequence into the derived chunks (padded tokens are the
# monoid's identity step), differentiable via derived backward kernels (the
# ssd_backward / gated_backward recurrence kinds — the jnp oracles survive
# only as bit-identity references), "xla" entries dispatch to the oracle
# directly.
# ---------------------------------------------------------------------------

def default_ssd_chunk(s: int, h: int, p: int, n: int, dtype="float32",
                      hardware: Optional[HardwareEntry] = None) -> int:
    """The derived SSD chunk length: ``solve_recurrence_blocks`` with the
    carried (h, p, n) state, the double-buffered per-token operands and the
    quadratic segsum intermediates (scores + the per-head decay mask L) in
    the VMEM working-set model — replacing the old hand-written
    ``models.ssm.default_ssd_chunk`` doubling heuristic."""
    from repro.core.blocking import solve_recurrence_blocks
    hw = hardware or current_hardware()
    choice = solve_recurrence_blocks(
        s,
        token_elems=2 * n + h * (p + 1) + h * p,     # B, C, x, dA in + y out
        state_elems=2 * h * p * n,                   # carried h + H0 operand
        quad_elems=1 + h,                            # scores G + decay L
        lin_elems=4 * h,                             # cumsum/decay vectors
        dtype=dtype, hardware=getattr(hw, "shape", hw))
    return choice.bs


def default_gated_chunk(s: int, w: int, dtype="float32",
                        hardware: Optional[HardwareEntry] = None) -> int:
    """The derived RG-LRU chunk length: per-channel state, three per-token
    streams (gate log, input, output), linear scan intermediates."""
    from repro.core.blocking import solve_recurrence_blocks
    hw = hardware or current_hardware()
    choice = solve_recurrence_blocks(
        s, token_elems=3 * w, state_elems=2 * w, quad_elems=0,
        lin_elems=2 * w, dtype=dtype, hardware=getattr(hw, "shape", hw))
    return choice.bs


@functools.lru_cache(maxsize=128)
def _ssd_executor(b, nc, q, h, p, n, dtype_s, hw_name, interpret):
    """Jitted executable for one chunked SSD shape: the cached derivation
    of ``expr.ssd_form`` through ``emit_recurrent``.  Binds the chunked
    storage views (pure reshapes of the stored model buffers) in schedule
    operand order (C, B, X, dA, H0); returns ``(y, final_state)``."""
    from repro.kernels.emit import emit_recurrent_bundle
    form = E.ssd_form(b, nc, q, h, p, n)
    bundle = _sched.get_schedule(form, dtype=dtype_s,
                                 hardware=get_entry(hw_name), blocks=(q,))
    return jax.jit(emit_recurrent_bundle(bundle, out_dtype="float32",
                                         interpret=interpret))


def _ssd_oracle(xdt, dA, B, C, h0, chunk, unroll=False):
    """The chunked-jnp oracle with the ops-level pad/slice contract (padded
    tokens are inert: zero ``xdt`` adds nothing, zero ``dA`` decays by 1)."""
    s = xdt.shape[1]
    pad = (-s) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, final = ref.ssd_scan_ref(xdt, dA, B, C, h0, chunk=chunk,
                                unroll=unroll)
    return y[:, :s], final


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _ssd_kernel(xdt, dA, B, C, h0, chunk, hw_name, interpret):
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    sp = s + pad
    nc = sp // chunk
    xp = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else xdt
    dp = jnp.pad(dA, ((0, 0), (0, pad), (0, 0))) if pad else dA
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0))) if pad else B
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0))) if pad else C
    fn = _ssd_executor(b, nc, chunk, h, p, n, str(jnp.dtype(xdt.dtype)),
                       hw_name, interpret)
    y, final = fn(Cp.reshape(b, nc, chunk, n), Bp.reshape(b, nc, chunk, n),
                  xp.reshape(b, nc, chunk, h, p),
                  dp.reshape(b, nc, chunk, h), h0)
    return y.reshape(b, sp, h, p)[:, :s], final


@functools.lru_cache(maxsize=128)
def _ssd_chk_executor(b, nc, q, h, p, n, dtype_s, hw_name, interpret):
    """Forward executor under differentiation: the same ``ssd`` monoid with
    the per-chunk *entering* states additionally exported (``h_in (b, nc,
    h, p, n)``) — the O(S/chunk) checkpoints the backward scan replays
    from.  Returns ``(y, final_state, h_in)``."""
    from repro.kernels.emit import emit_recurrent_bundle
    form = E.ssd_chk_form(b, nc, q, h, p, n)
    bundle = _sched.get_schedule(form, dtype=dtype_s,
                                 hardware=get_entry(hw_name), blocks=(q,))
    return jax.jit(emit_recurrent_bundle(bundle, out_dtype="float32",
                                         interpret=interpret))


@functools.lru_cache(maxsize=128)
def _ssd_bwd_executor(b, nc, q, h, p, n, dtype_s, hw_name, interpret):
    """The ``ssd_backward`` recurrence: streams chunks in *reverse* (the
    caller flips the chunk axis) carrying the state cotangent dh, replays
    each chunk's forward factoring from the saved entering state, and emits
    the full cotangent chain per chunk.  Operand order
    ``(C, B, dY, X, dA, Hin, dHf)``; returns ``(dX, dh0, dB, dC, ddA)``."""
    from repro.kernels.emit import emit_recurrent_bundle
    form = E.ssd_bwd_form(b, nc, q, h, p, n)
    bundle = _sched.get_schedule(form, dtype=dtype_s,
                                 hardware=get_entry(hw_name), blocks=(q,))
    return jax.jit(emit_recurrent_bundle(bundle, out_dtype="float32",
                                         interpret=interpret))


def _ssd_kernel_fwd(xdt, dA, B, C, h0, chunk, hw_name, interpret):
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    sp = s + pad
    nc = sp // chunk
    xp = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else xdt
    dp = jnp.pad(dA, ((0, 0), (0, pad), (0, 0))) if pad else dA
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0))) if pad else B
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0))) if pad else C
    fn = _ssd_chk_executor(b, nc, chunk, h, p, n, str(jnp.dtype(xdt.dtype)),
                           hw_name, interpret)
    y, final, hin = fn(Cp.reshape(b, nc, chunk, n),
                       Bp.reshape(b, nc, chunk, n),
                       xp.reshape(b, nc, chunk, h, p),
                       dp.reshape(b, nc, chunk, h), h0)
    return (y.reshape(b, sp, h, p)[:, :s], final), (xdt, dA, B, C, hin)


def _ssd_kernel_bwd(chunk, hw_name, interpret, resid, g):
    """Derived scan backward: the ``ssd_backward`` recurrence streamed over
    *time-reversed* chunks, seeded with the final-state cotangent.  Each
    step replays the chunk's forward factoring from the saved entering
    state ``h_in`` (same O(chunk) live intermediates as the old oracle
    recompute, but as a derived kernel) and chains the cotangents; the
    carried dh after the last (earliest) chunk is dh0."""
    xdt, dA, B, C, hin = resid
    gy, gfinal = g
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    sp = s + pad
    nc = sp // chunk
    xp = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else xdt
    dp = jnp.pad(dA, ((0, 0), (0, pad), (0, 0))) if pad else dA
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0))) if pad else B
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0))) if pad else C
    gyp = jnp.pad(gy, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else gy

    def rev(a):
        return jnp.flip(a, axis=1)

    fn = _ssd_bwd_executor(b, nc, chunk, h, p, n, str(jnp.dtype(xdt.dtype)),
                           hw_name, interpret)
    dX, dh0, dB, dC, ddA = fn(rev(Cp.reshape(b, nc, chunk, n)),
                              rev(Bp.reshape(b, nc, chunk, n)),
                              rev(gyp.reshape(b, nc, chunk, h, p)),
                              rev(xp.reshape(b, nc, chunk, h, p)),
                              rev(dp.reshape(b, nc, chunk, h)),
                              rev(hin), gfinal)
    dxdt = rev(dX).reshape(b, sp, h, p)[:, :s].astype(xdt.dtype)
    dBv = rev(dB).reshape(b, sp, n)[:, :s].astype(B.dtype)
    dCv = rev(dC).reshape(b, sp, n)[:, :s].astype(C.dtype)
    ddAv = rev(ddA).reshape(b, sp, h)[:, :s].astype(dA.dtype)
    return dxdt, ddAv, dBv, dCv, dh0


_ssd_kernel.defvjp(_ssd_kernel_fwd, _ssd_kernel_bwd)


def scan_ssd(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array, *,
             init_state: Optional[jax.Array] = None,
             chunk: Optional[int] = None, unroll: bool = False,
             interpret: Optional[bool] = None,
             hardware: Optional[HardwareEntry] = None
             ) -> tuple[jax.Array, jax.Array]:
    """Unified Mamba-2 SSD chunked scan — the model-facing entry.

    ``xdt (B, S, H, P)`` the dt-folded input, ``dA (B, S, H)`` the log
    decay, ``B/C (B, S, N)`` the state projections.  Returns ``(y (B, S,
    H, P) f32, final state (B, H, P, N) f32)``.

    On a Pallas backend (or under ``interpret=True``) this runs the kernel
    from the *derived* recurrent schedule (``expr.ssd_form`` — the chunk
    from ``solve_recurrence_blocks`` unless pinned), with the ops-level
    pad/slice contract: any sequence length works, padded tokens are the
    monoid's identity step.  Differentiable with a fully *derived* VJP:
    the forward checkpoints the per-chunk entering states
    (``ssd_chk_form``) and the backward streams the chunks in reverse
    through the ``ssd_backward`` recurrence kind — no oracle recompute.
    On "xla" entries the jnp oracle is the forward path (and
    differentiates through itself), so semantics are identical everywhere.
    """
    hw, interp = _resolve(hardware, interpret)
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    if chunk is None:
        chunk = default_ssd_chunk(s, h, p, n, str(jnp.dtype(xdt.dtype)), hw)
    chunk = max(1, min(int(chunk), s))
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    use_kernel = _use_kernel(hw, interp, interpret)
    if use_kernel:
        return _ssd_kernel(xdt, dA, B, C, init_state, chunk, hw.name,
                           bool(interp))
    return _ssd_oracle(xdt, dA, B, C, init_state, chunk, unroll)


@functools.lru_cache(maxsize=128)
def _gated_executor(b, nc, q, w, dtype_s, hw_name, interpret):
    """Jitted executable for one chunked gated-scan shape
    (``expr.rglru_form`` through ``emit_recurrent``): operand order
    (log_a, b, H0); returns ``(h_seq, final_state)``."""
    from repro.kernels.emit import emit_recurrent_bundle
    form = E.rglru_form(b, nc, q, w)
    bundle = _sched.get_schedule(form, dtype=dtype_s,
                                 hardware=get_entry(hw_name), blocks=(q,))
    return jax.jit(emit_recurrent_bundle(bundle, out_dtype="float32",
                                         interpret=interpret))


def _gated_oracle(log_a, b_in, h0):
    return ref.gated_scan_ref(log_a, b_in, h0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _gated_kernel(log_a, b_in, h0, chunk, hw_name, interpret):
    b, s, w = log_a.shape
    pad = (-s) % chunk
    sp = s + pad
    nc = sp // chunk
    la = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0))) if pad else log_a
    bb = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0))) if pad else b_in
    fn = _gated_executor(b, nc, chunk, w, str(jnp.dtype(log_a.dtype)),
                         hw_name, interpret)
    hs, final = fn(la.reshape(b, nc, chunk, w), bb.reshape(b, nc, chunk, w),
                   h0)
    return hs.reshape(b, sp, w)[:, :s], final


@functools.lru_cache(maxsize=128)
def _gated_bwd_executor(b, nc, q, w, hw_name, interpret):
    """The degenerate backward kind: the gated-scan cotangent recurrence
    ``dbar_t = dy_t + a_{t+1} dbar_{t+1}`` *is* a gated scan on
    time-reversed operands with the gate shifted one step — so the
    ``gated_backward`` kind reuses the forward kernel body verbatim on a
    form of its own (its own schedule-cache entry)."""
    from repro.kernels.emit import emit_recurrent_bundle
    form = E.rglru_bwd_form(b, nc, q, w)
    bundle = _sched.get_schedule(form, dtype="float32",
                                 hardware=get_entry(hw_name), blocks=(q,))
    return jax.jit(emit_recurrent_bundle(bundle, out_dtype="float32",
                                         interpret=interpret))


def _gated_kernel_fwd(log_a, b_in, h0, chunk, hw_name, interpret):
    out = _gated_kernel(log_a, b_in, h0, chunk, hw_name, interpret)
    return out, (log_a, b_in, h0, out[0])


def _gated_kernel_bwd(chunk, hw_name, interpret, resid, g):
    """Derived gated backward: run the ``gated_backward`` recurrence on the
    flipped, gate-shifted operands to get dbar, then the per-token
    cotangents are elementwise in the saved forward outputs (no oracle
    recompute — ``h_{t-1}`` comes from the saved sequence, not a replay)."""
    log_a, b_in, h0, hs = resid
    gy, gfin = g
    b, s, w = log_a.shape
    la32 = log_a.astype(jnp.float32)
    dy = gy.astype(jnp.float32).at[:, -1].add(gfin.astype(jnp.float32))
    la_shift = jnp.concatenate(
        [la32[:, 1:], jnp.zeros((b, 1, w), jnp.float32)], axis=1)
    laf = jnp.flip(la_shift, axis=1)
    dyf = jnp.flip(dy, axis=1)
    pad = (-s) % chunk
    sp = s + pad
    nc = sp // chunk
    # trailing pads sit *after* t=0 in reversed time: log_a=0 gates by 1,
    # dy=0 adds nothing, and the padded outputs are sliced away
    if pad:
        laf = jnp.pad(laf, ((0, 0), (0, pad), (0, 0)))
        dyf = jnp.pad(dyf, ((0, 0), (0, pad), (0, 0)))
    fn = _gated_bwd_executor(b, nc, chunk, w, hw_name, interpret)
    dbf, _ = fn(laf.reshape(b, nc, chunk, w), dyf.reshape(b, nc, chunk, w),
                jnp.zeros((b, w), jnp.float32))
    dbar = jnp.flip(dbf.reshape(b, sp, w)[:, :s], axis=1)
    a = jnp.exp(la32)
    h_prev = jnp.concatenate(
        [h0.astype(jnp.float32)[:, None], hs[:, :-1]], axis=1)
    dlog_a = (dbar * a * h_prev).astype(log_a.dtype)
    db = dbar.astype(b_in.dtype)
    dh0 = a[:, 0] * dbar[:, 0]
    return dlog_a, db, dh0


_gated_kernel.defvjp(_gated_kernel_fwd, _gated_kernel_bwd)


def gated_scan(log_a: jax.Array, b_in: jax.Array, *,
               init_state: Optional[jax.Array] = None,
               chunk: Optional[int] = None,
               interpret: Optional[bool] = None,
               hardware: Optional[HardwareEntry] = None
               ) -> tuple[jax.Array, jax.Array]:
    """Unified RG-LRU gated linear scan ``h_t = exp(log_a_t) h_{t-1} +
    b_t`` — the model-facing entry.  Returns ``(h (B, S, w) f32, final
    (B, w) f32)``.

    Same contract as ``scan_ssd``: the derived chunked kernel on Pallas /
    interpret entries (chunk from ``solve_recurrence_blocks``), the
    log-depth associative-scan oracle on "xla" entries only.  The VJP is
    derived too — the reversed cotangent scan is *itself* a gated scan on
    flipped, gate-shifted operands (the ``gated_backward`` kind).
    """
    hw, interp = _resolve(hardware, interpret)
    b, s, w = log_a.shape
    if init_state is None:
        init_state = jnp.zeros((b, w), jnp.float32)
    use_kernel = _use_kernel(hw, interp, interpret)
    if not use_kernel:
        return _gated_oracle(log_a, b_in, init_state)
    if chunk is None:
        chunk = default_gated_chunk(s, w, str(jnp.dtype(log_a.dtype)), hw)
    chunk = max(1, min(int(chunk), s))
    return _gated_kernel(log_a, b_in, init_state, chunk, hw.name,
                         bool(interp))


# ---------------------------------------------------------------------------
# paged decode: one query token against a paged KV cache.  The page table is
# STATIC schedule metadata (it rides RecurrentForm.key(), so the executor
# cache re-keys only when pages are allocated, never per token); the query's
# view-relative position is RUNTIME data in the POS aux operand, so one
# compiled kernel serves every token between allocations.  "xla" entries use
# the gather-pages jnp oracle — also the bit-identity reference for tests.
# ---------------------------------------------------------------------------

def default_decode_page(view_tokens: int, hkv: int, g: int, hd: int,
                        vd: int = 0, dtype="float32",
                        hardware: Optional[HardwareEntry] = None) -> int:
    """The derived KV page size: ``solve_recurrence_blocks`` over the
    streamed key axis with the O(window) carried (m, l, acc) state, the
    per-page K/V slabs as the token operands and the (g, page) score block
    as the quadratic intermediate.  The solved stream block IS the page —
    pages exist so BlockSpecs can address them, so their size is a property
    of the memory hierarchy, not a tuning knob."""
    from repro.core.blocking import solve_recurrence_blocks
    vd = vd or hd
    hw = hardware or current_hardware()
    choice = solve_recurrence_blocks(
        view_tokens,
        token_elems=hkv * (hd + vd),            # one K + one V row per key
        state_elems=g * (vd + 2),               # carried acc + (m, l)
        quad_elems=g,                           # the (g, page) score block
        lin_elems=g * hd,                       # the resident query rows
        dtype=dtype, hardware=getattr(hw, "shape", hw))
    return choice.bs


@functools.lru_cache(maxsize=512)
def _decode_executor(hkv, g, hd, vd, page, view_pages, pool_pages, table,
                     window, scale, dtype_s, hw_name, interpret):
    """Jitted executable for one paged-decode shape + page table: the
    cached derivation of ``expr.windowed_decode_form`` through
    ``emit_recurrent``.  Binds (q, k_pool, v_pool, pos); returns the
    (hkv, g, vd) f32 context.  A canonical page allocator makes tables recur
    across sequences, so this cache stays hot in steady-state serving."""
    from repro.kernels.emit import emit_recurrent_bundle
    form = E.windowed_decode_form(hkv, g, hd, vd, page=page,
                                  view_pages=view_pages,
                                  pool_pages=pool_pages, page_table=table,
                                  window=window)
    bundle = _sched.get_schedule(form, dtype=dtype_s,
                                 hardware=get_entry(hw_name),
                                 blocks=(g, page))
    return jax.jit(emit_recurrent_bundle(bundle, scale=scale, causal=True,
                                         out_dtype="float32",
                                         interpret=interpret))


def _paged_oracle(q, k_pool, v_pool, pos, table, page, scale, window):
    """Gather the view pages into a contiguous cache, then run the masked
    softmax — the reference the kernel must match bit-for-bit on integer
    inputs (both paths do the same float ops in the same order per key)."""
    idx = jnp.concatenate(
        [jnp.arange(t * page, (t + 1) * page) for t in table])
    k = k_pool[idx]                              # (sk, hkv, hd)
    v = v_pool[idx]
    s = jnp.einsum("hgc,jhc->hgj", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    j = jnp.arange(k.shape[0])[None, None, :]
    vpos = pos[0, 0]
    mask = j <= vpos
    if window:
        mask = jnp.logical_and(mask, j > vpos - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("hgj,jhd->hgd", p, v.astype(jnp.float32))


def paged_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                 pos: jax.Array, *, page_table: tuple, page: int,
                 scale: float, window: int = 0,
                 interpret: Optional[bool] = None,
                 hardware: Optional[HardwareEntry] = None) -> jax.Array:
    """One decode step of grouped-query attention against a paged KV cache.

    ``q`` is (hkv, g, hd) — one token's query heads grouped under their KV
    head; ``k_pool``/``v_pool`` are the (pool_tokens, hkv, hd) slab pools;
    ``pos`` is the (1, 2) int32 POS aux whose ``[0, 0]`` entry is the
    query's VIEW-RELATIVE position (absolute position minus the view's
    start token).  ``page_table`` maps view page -> pool slab; masking is
    entirely in view coordinates, so unallocated trailing view pages may
    point at any slab — the causal mask keeps them inert.
    """
    hw, interp = _resolve(hardware, interpret)
    table = tuple(int(t) for t in page_table)
    if not table:
        raise ValueError("paged_decode requires a non-empty page table")
    hkv, g, hd = q.shape
    vd = v_pool.shape[-1]
    if k_pool.shape[0] % page or k_pool.shape[0] != v_pool.shape[0]:
        raise ValueError(
            f"pool token extents {k_pool.shape[0]}/{v_pool.shape[0]} must "
            f"be equal and a multiple of page={page}")
    pool_pages = k_pool.shape[0] // page
    use_kernel = _use_kernel(hw, interp, interpret)
    if not use_kernel:
        return _paged_oracle(q, k_pool, v_pool, pos, table, page,
                             float(scale), int(window))
    fn = _decode_executor(hkv, g, hd, vd, int(page), len(table),
                          pool_pages, table, int(window), float(scale),
                          str(jnp.dtype(q.dtype)), hw.name, bool(interp))
    return fn(q, k_pool, v_pool, pos)


@functools.lru_cache(maxsize=512)
def _batched_decode_executor(slots, hkv, g, hd, vd, page, view_pages,
                             pool_pages, tables, window, scale, dtype_s,
                             hw_name, interpret):
    """Jitted executable for one batched-decode shape + STACKED page table:
    the cached derivation of ``expr.batched_decode_form`` through
    ``emit_recurrent``.  Binds (q, k_pool, v_pool, pos); returns the
    (slots, hkv, g, vd) f32 context.  The LRU key is the stacked-table
    tuple — the engine pads dead slots with a dead table row, so the key
    changes only when live pages move, never with the active slot count."""
    from repro.kernels.emit import emit_recurrent_bundle
    form = E.batched_decode_form(slots, hkv, g, hd, vd, page=page,
                                 view_pages=view_pages,
                                 pool_pages=pool_pages, page_tables=tables,
                                 window=window)
    bundle = _sched.get_schedule(form, dtype=dtype_s,
                                 hardware=get_entry(hw_name),
                                 blocks=(g, page))
    return jax.jit(emit_recurrent_bundle(bundle, scale=scale, causal=True,
                                         out_dtype="float32",
                                         interpret=interpret))


def _batched_oracle(q, k_pool, v_pool, pos, tables, page, scale, window):
    """Per-slot ``_paged_oracle`` stacked over the slot axis — the batched
    reference.  Dead slots (pos -1) produce garbage rows the caller masks;
    the oracle clamps their gather indices like the device would."""
    outs = [_paged_oracle(q[s], k_pool, v_pool, pos[s:s + 1], tables[s],
                          page, scale, window)
            for s in range(q.shape[0])]
    return jnp.stack(outs)


def paged_decode_batched(q: jax.Array, k_pool: jax.Array,
                         v_pool: jax.Array, pos: jax.Array, *,
                         page_tables: tuple, page: int, scale: float,
                         window: int = 0, interpret: Optional[bool] = None,
                         hardware: Optional[HardwareEntry] = None
                         ) -> jax.Array:
    """One decode step for EVERY active slot in one kernel launch.

    ``q`` is (slots, hkv, g, hd) — one query token per slot; the pools are
    the same shared (pool_tokens, hkv, hd) slab storage ``paged_decode``
    binds; ``pos`` is the (slots, 2) int32 POS aux whose ``[s, 0]`` entry
    is slot ``s``'s view-relative query position.  ``page_tables`` is the
    stacked ``[slot][k]`` view->slab map — static metadata on the executor
    cache, so the launch count per engine iteration is 1 regardless of the
    active slot count.  A dead/padded slot rides a row of dead entries
    with ``pos[s, 0] == -1``: every block-skip guard ``k*page <= -1`` is
    false, so its (m, l, acc) state never folds and the flush emits the
    0/max(l, eps) zero row.
    """
    hw, interp = _resolve(hardware, interpret)
    tables = tuple(tuple(int(t) for t in row) for row in page_tables)
    if not tables or not tables[0]:
        raise ValueError(
            "paged_decode_batched requires a non-empty stacked page table")
    slots, hkv, g, hd = q.shape
    vd = v_pool.shape[-1]
    if k_pool.shape[0] % page or k_pool.shape[0] != v_pool.shape[0]:
        raise ValueError(
            f"pool token extents {k_pool.shape[0]}/{v_pool.shape[0]} must "
            f"be equal and a multiple of page={page}")
    pool_pages = k_pool.shape[0] // page
    use_kernel = _use_kernel(hw, interp, interpret)
    if not use_kernel:
        return _batched_oracle(q, k_pool, v_pool, pos, tables, page,
                               float(scale), int(window))
    fn = _batched_decode_executor(slots, hkv, g, hd, vd, int(page),
                                  len(tables[0]), pool_pages, tables,
                                  int(window), float(scale),
                                  str(jnp.dtype(q.dtype)), hw.name,
                                  bool(interp))
    return fn(q, k_pool, v_pool, pos)


# ---------------------------------------------------------------------------
# the unified operator (paper appendix: "one algorithm/circuit (ipophp)")
# ---------------------------------------------------------------------------

def outer(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None
          ) -> jax.Array:
    """Outer product of matrices through the SAME gemm circuit: the MoA
    degenerate inner product — rav(A) (mn,1) . rav(B)^T (1,pq), reshaped.
    (Contraction extent 1: the sigma loop collapses, nothing else changes.)"""
    m, n = a.shape
    p, q = b.shape
    flat = moa_gemm(a.reshape(m * n, 1), b.reshape(1, p * q),
                    interpret=interpret)
    return flat.reshape(m, n, p, q)


def kron(a: jax.Array, b: jax.Array, *, interpret: Optional[bool] = None
         ) -> jax.Array:
    """Kronecker product = outer product + gamma re-layout (transpose/reshape):
    the paper's claim that KP shares the MM circuit, realized literally."""
    m, n = a.shape
    p, q = b.shape
    return outer(a, b, interpret=interpret).transpose(0, 2, 1, 3).reshape(m * p, n * q)


def ipophp(a: jax.Array, b: jax.Array, mode: str, *,
           interpret: Optional[bool] = None) -> jax.Array:
    """Unified inner/outer/hadamard/kron dispatcher (single blocked circuit:
    'ip' is the full schedule, 'op'/'kp' are its contraction-degenerate form,
    'hp' its pairing-degenerate form)."""
    if mode == "ip":
        return moa_gemm(a, b, interpret=interpret)
    if mode == "op":
        return outer(a, b, interpret=interpret)
    if mode == "kp":
        return kron(a, b, interpret=interpret)
    if mode == "hp":
        return hadamard(a, b, interpret=interpret)
    raise ValueError(f"unknown ipophp mode {mode!r}")


# convenience: oracle aliases so callers can switch paths uniformly
gemm_ref = ref.gemm_ref
ipophp_ref = ref.ipophp_ref
