"""Pallas TPU kernels for the paper's compute hot-spot: blocked MoA GEMM and
its unified-operator family (inner/outer/hadamard/kron), plus the MoE
expert-GEMM extension.  ``ref`` holds the pure-jnp oracles; ``ops`` the
public jit wrappers with static block solving and padding."""
from repro.kernels.ops import (  # noqa: F401
    moa_gemm, expert_gemm, hadamard, outer, kron, ipophp,
)
from repro.kernels import ref  # noqa: F401
