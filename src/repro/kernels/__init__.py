"""Pallas TPU kernels for the paper's compute hot-spot, now *derived*: every
kernel's grid, BlockSpecs and semantics come from ``derive_schedule`` over a
lifted ONF (``repro.core.schedule``) and the generic ``emit_pallas`` emitter.
``ops`` holds the public jit wrappers (schedule cache + hardware-registry
dispatch + the unified ``matmul``/``expert_matmul`` model entries); ``ref``
the pure-jnp oracles; ``moa_gemm`` the legacy hand-written kernels kept one
release as a cross-check (REPRO_LEGACY_KERNELS=1)."""
from repro.kernels.ops import (  # noqa: F401
    moa_gemm, expert_gemm, hadamard, outer, kron, ipophp,
    matmul, expert_matmul,
)
from repro.kernels.emit import emit_pallas  # noqa: F401
from repro.kernels import ref  # noqa: F401
