"""Pallas TPU kernels for the paper's compute hot-spot, now *derived*: every
kernel's grid, BlockSpecs and semantics come from ``derive_schedule`` over a
normalized, lifted MoA expression (``repro.core.expr`` ->
``repro.core.schedule``) and the generic ``emit_pallas`` emitter.  ``ops``
holds the public jit wrappers — ``apply`` for arbitrary expressions, the
schedule cache + hardware-registry dispatch, and the unified
``matmul``/``expert_matmul``/``semiring_matmul`` model entries; ``ref`` the
pure-jnp oracles (including the generic expression evaluator)."""
from repro.kernels.ops import (  # noqa: F401
    apply, moa_gemm, expert_gemm, hadamard, outer, kron, ipophp,
    matmul, expert_matmul, head_matmul, semiring_matmul, attention,
)
from repro.kernels.emit import emit_bundle, emit_pallas, emit_shard_map  # noqa: F401
from repro.kernels import ref  # noqa: F401
