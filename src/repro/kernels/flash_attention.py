"""Flash attention as a *derived* streaming schedule — no hand-written grid.

The schedule comes from the same pipeline as every GEMM in the repo:
``expr.attention_form`` composes the two chained contractions (q·kᵀ and the
online-softmax-weighted p·v) into a ``StreamingForm``; ``get_schedule``
lifts it (batch/kv-head/group fully onto "proc", the query axis blockwise
onto "proc", the key axis blockwise onto the sigma "block" resource) and
derives grid, BlockSpecs, index maps and ``(bq, bk)`` — the latter from
``solve_stream_blocks``, whose working-set model includes the carried
(acc, m, l) state; ``emit_streaming`` generalizes the sigma-accumulator
init/step/flush contract to the rescale-carrying online softmax.

The GQA q-head -> kv-head index map is *recovered*, not hand-coded: K/V
carry a zero Access coefficient on the group axis, so their derived
BlockSpecs simply omit the group grid dimension.  Derivations live in the
process-wide LRU schedule cache keyed on the streaming form.

``repro.models.chunked_attention`` remains the jnp oracle and XLA fallback;
``kernels.ops.attention`` is the model-facing wrapper (grouped layout,
differentiable).  This entry keeps the historical ``(B, H, S, hd)`` layout
and pads any sequence length to the derived block multiples (padded keys
are masked inert by the emitter's ``kpos < sk`` guard).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import expr as E
from repro.core import schedule as _sched
from repro.core.hardware import current_hardware, get_entry
from repro.kernels.emit import NEG_INF, emit_streaming_bundle  # noqa: F401


def attention_bundle(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                     vd: Optional[int] = None, *, dtype="float32",
                     hardware=None, blocks=None, window: int = 0,
                     prefix_len: int = 0) -> "_sched.ScheduleBundle":
    """The cached streaming-schedule derivation for one attention shape.
    ``window``/``prefix_len`` ride the recurrent form as streamed-axis
    masking metadata (the emitter derives block-skip from them)."""
    hw = hardware or current_hardware()
    return _sched.get_schedule(E.attention_form(b, hkv, g, sq, sk, hd, vd,
                                                window=window,
                                                prefix_len=prefix_len),
                               dtype=dtype, hardware=hw, blocks=blocks)


@functools.lru_cache(maxsize=256)
def _executor(b: int, hkv: int, g: int, sq: int, sk: int, hd: int, vd: int,
              dtype_s: str, out_dtype_s: str, hw_name: str, interpret: bool,
              causal: bool, scale: float, blocks, window: int = 0,
              prefix_len: int = 0):
    """Jitted pad/kernel/slice callable over the *stored* model layouts
    ``q (b, sq, hkv, g, hd); k (b, sk, hkv, hd); v (b, sk, hkv, vd)`` —
    the derived BlockSpecs walk these buffers in place (no relayout) —
    memoized per (shape, dtype, hardware, masking, blocks).  Returns the
    derived output layout ``(b, hkv, g, sq, vd)``."""
    bundle = attention_bundle(b, hkv, g, sq, sk, hd, vd, dtype=dtype_s,
                              hardware=get_entry(hw_name), blocks=blocks,
                              window=window, prefix_len=prefix_len)
    return jax.jit(emit_streaming_bundle(bundle, scale=scale, causal=causal,
                                         out_dtype=out_dtype_s,
                                         interpret=interpret))


@functools.lru_cache(maxsize=256)
def _stats_executor(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                    vd: int, dtype_s: str, out_dtype_s: str, hw_name: str,
                    interpret: bool, causal: bool, scale: float, blocks,
                    window: int = 0, prefix_len: int = 0):
    """Forward executor that additionally exports the carried online-softmax
    ``(m, l)`` statistics (the ``flash_attention_stats`` form) — the backward
    residuals, so the derived dQ/dK/dV kernels can reconstruct ``p`` without
    a jnp oracle recompute.  Same derivation, same ``(bq, bk)`` solve as the
    plain forward (the state kind is still ``online_softmax``).  Returns
    ``(out (b,hkv,g,sq,vd), m, l)`` with m/l f32 on the *padded* row axis."""
    bundle = _sched.get_schedule(
        E.attention_stats_form(b, hkv, g, sq, sk, hd, vd, window=window,
                               prefix_len=prefix_len),
        dtype=dtype_s, hardware=get_entry(hw_name), blocks=blocks)
    return jax.jit(emit_streaming_bundle(bundle, scale=scale, causal=causal,
                                         out_dtype=out_dtype_s,
                                         interpret=interpret))


@functools.lru_cache(maxsize=256)
def _dq_executor(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                 vd: int, dtype_s: str, hw_name: str, interpret: bool,
                 causal: bool, scale: float, blocks, window: int = 0,
                 prefix_len: int = 0):
    """Derived flash-backward dQ executor (``flash_dq`` kind): streams key
    blocks with a carried f32 dq accumulator, binding
    ``(q, k, k, do, v, m, l, delta)`` in stored layouts (k appears twice —
    once per contraction stage of the lifted form).  ``blocks`` must be the
    forward's ``(bq, bk)`` so the saved padded-row m/l line up.  Returns
    ``dq (b, hkv, g, sq, hd)`` f32."""
    bundle = _sched.get_schedule(
        E.attention_dq_form(b, hkv, g, sq, sk, hd, vd, window=window,
                            prefix_len=prefix_len),
        dtype=dtype_s, hardware=get_entry(hw_name), blocks=blocks)
    return jax.jit(emit_streaming_bundle(bundle, scale=scale, causal=causal,
                                         out_dtype="float32",
                                         interpret=interpret))


@functools.lru_cache(maxsize=256)
def _dkv_executor(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                  vd: int, dtype_s: str, hw_name: str, interpret: bool,
                  causal: bool, scale: float, blocks, window: int = 0,
                  prefix_len: int = 0):
    """Derived flash-backward dK/dV executor (``flash_dkv`` kind): the
    transposed weld — rows are *key* blocks, the streamed axis is the
    query axis — with carried dk accumulator plus an exported dv state.
    Binds ``(k, q, q, do, v, m, l, delta)``; ``blocks`` must be the
    forward's ``(bk, bq)`` (row gets the key block, stream the query
    block).  Returns ``(dk (b,hkv,g,sk,hd), dv (b,hkv,g,sk_pad,vd))``,
    dv unsliced on the padded key axis (exports pass through padded)."""
    bundle = _sched.get_schedule(
        E.attention_dkv_form(b, hkv, g, sq, sk, hd, vd, window=window,
                             prefix_len=prefix_len),
        dtype=dtype_s, hardware=get_entry(hw_name), blocks=blocks)
    return jax.jit(emit_streaming_bundle(bundle, scale=scale, causal=causal,
                                         out_dtype="float32",
                                         interpret=interpret))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: bool = False,
                    hardware=None) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd), Hq % Hkv == 0.
    Returns (B, Hq, Sq, hd).  Any Sq/Sk: operands are padded to the derived
    block multiples and the result sliced back (padded keys are masked).
    ``block_q``/``block_k`` pin the blocks (tests); by default they come
    from the solver inside the derived schedule."""
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    hw = hardware or current_hardware()
    blocks = None
    if block_q is not None or block_k is not None:
        blocks = (block_q or 512, block_k or 512)
    fn = _executor(b, hkv, g, sq, sk, hd, v.shape[-1],
                   str(jnp.dtype(q.dtype)), str(jnp.dtype(q.dtype)),
                   hw.name, bool(interpret), bool(causal), float(scale),
                   blocks)
    # this compat facade takes (B, H, S, hd); the executor binds the models'
    # stored (B, S, KV, G, hd) layouts, so relayout here (the model-facing
    # ops.attention entry has no such copies)
    out = fn(q.reshape(b, hkv, g, sq, hd).transpose(0, 3, 1, 2, 4),
             k.transpose(0, 2, 1, 3),
             v.transpose(0, 2, 1, 3))               # (b, hkv, g, sq, vd)
    return out.reshape(b, hq, sq, -1)
