"""Flash attention as a Pallas TPU kernel — the VMEM-blocked twin of
``repro.models.chunked_attention`` (which is its jnp oracle and the XLA
fallback path used by the dry-run).

Schedule = dimension lifting of both sequence axes:

    grid = (batch*q_heads, Sq/bq, Sk/bk)      k innermost ("arbitrary")
    resident per step: q (bq,hd), k (bk,hd), v (bk,hd), acc (bq,hd) f32,
    running max m and denominator l — the block solver's '3 blocks + state
    <= VMEM' constraint picks (bq, bk).

GQA handled in the BlockSpec index map (q head -> kv head, no K/V repeat).
Causal masking from absolute positions; fully-masked k-blocks are skipped
via ``pl.when`` (halves the work for causal attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.emit import compiler_params

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  nk: int, scale: float, causal: bool, bq: int, bk: int,
                  out_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip k-blocks strictly above the diagonal
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                  # (bq, hd)
        k = k_ref[0]                                  # (bk, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p.astype(v.dtype), v,
                                              (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[:, 0], 1e-30)[:, None]).astype(out_dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd), Hq % Hkv == 0.
    Returns (B, Hq, Sq, hd).  Sq/Sk must be multiples of the blocks
    (ops-level wrapper pads)."""
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nq, nk = sq // bq, sk // bk

    qf = q.reshape(b * hq, sq, hd)
    kf = k.reshape(b * hkv, sk, hd)
    vf = v.reshape(b * hkv, sk, hd)

    def kv_map(h, qi, ki):
        return ((h // hq) * hkv + (h % hq) // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, scale=scale, causal=causal,
                          bq=bq, bk=bk, out_dtype=q.dtype),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),         # running max
            pltpu.VMEM((bq, 1), jnp.float32),         # denominator
            pltpu.VMEM((bq, hd), jnp.float32),        # accumulator
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, hd)
