"""Fault-tolerant checkpointing: atomic, integrity-checked, async, keep-k,
and *reshardable on restore* (elastic scaling).

Layout per step:  <dir>/step_<N>/arrays.npz  +  manifest.json
(manifest carries step, sha256 of the npz, leaf names, and user metadata).

Guarantees:
* atomicity — written to ``.tmp-`` then os.replace'd; a crash mid-write never
  corrupts the latest valid checkpoint;
* integrity — restore verifies the digest and *falls back to the newest
  valid earlier checkpoint* if the latest is torn (node-failure recovery);
* resharding — ``restore`` takes target shardings (possibly for a different
  mesh than the save-time one) and device_puts each host array accordingly,
  so shrink/grow restarts "just work";
* async — ``save_async`` snapshots to host then writes on a worker thread,
  keeping the step loop running (``wait()`` joins before exit).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                        for p in path)
        flat[name] = np.asarray(leaf)
    return flat


def _np_safe(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """npz can't hold bf16: view as uint16 with a dtype tag."""
    if arr.dtype.name == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, arr.dtype.name


def _np_restore(arr: np.ndarray, tag: str) -> np.ndarray:
    if tag == "bfloat16":
        import ml_dtypes  # jax dependency, always present
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()
        self._save_impl(step, _flatten(tree), metadata or {})

    def save_async(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()
        host = _flatten(tree)                      # snapshot on caller thread
        self._thread = threading.Thread(
            target=self._save_impl, args=(step, host, metadata or {}),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_impl(self, step: int, flat: dict, metadata: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp-partial"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        tags = {}
        store = {}
        for k, v in flat.items():
            safe, tag = _np_safe(v)
            store[k] = safe
            tags[k] = tag
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **store)
        manifest = {"step": step, "digest": _digest(npz), "dtypes": tags,
                    "metadata": metadata}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)                     # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp-partial"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _load_step(self, step: int) -> tuple[dict, dict]:
        base = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        npz = os.path.join(base, "arrays.npz")
        if _digest(npz) != manifest["digest"]:
            raise IOError(f"checkpoint step {step} failed integrity check")
        data = np.load(npz)
        flat = {k: _np_restore(data[k], manifest["dtypes"][k]) for k in data.files}
        return flat, manifest

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; optionally device_put each
        leaf with the given shardings pytree (same structure) — this is the
        elastic re-shard path.  Falls back to older checkpoints on corruption.
        """
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s == step]
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        last_err: Exception | None = None
        for s in reversed(steps):
            try:
                flat, manifest = self._load_step(s)
                break
            except Exception as e:                 # torn checkpoint: fall back
                last_err = e
        else:
            raise IOError(f"all checkpoints corrupt; last error: {last_err}")

        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        flat_shardings = (treedef.flatten_up_to(shardings)
                          if shardings is not None else [None] * len(leaves_like))
        for (path, leaf), shard in zip(leaves_like, flat_shardings):
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                            for p in path)
            arr = flat[name]
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out)
        return tree, manifest
