"""A named-rule lint framework over traced jaxprs.

PRs 2-6 earned jaxpr-level guarantees — no transpose relayout before a
derived kernel, no oracle recompute in a train step, exactly the planned
collectives in a shard_map program — and each guarantee lived as an ad-hoc
scanner copy-pasted into a test file.  This module is the one traversal
and the one registry those pins now share:

==========================  ================================================
rule                        what it proves
==========================  ================================================
``no-transpose-copy``       no ``transpose`` primitive anywhere in the
                            traced program: transposed operands flow into
                            kernels through index maps, never a relayout
                            copy.
``no-oracle-recompute``     a differentiated trace binds derived kernels
                            (``pallas_call`` present, >= ``min_calls``);
                            combine with oracle stubs that raise to prove
                            no fallback path was traced.
``only-planned-collectives``  the collectives in the program are exactly
                            the plan's (``collective=`` names the planned
                            summary, e.g. ``"psum"`` or
                            ``"reduce_scatter+all_gather"``; or pass
                            ``allowed=`` a set of primitive names).
``no-silent-fallback``      a kernel-dispatch entry really reached
                            ``pallas_call`` (>= ``min_calls``) instead of
                            silently falling back to a jnp oracle.
==========================  ================================================

``lint(fn, *args, rules=...)`` traces ``fn`` and runs the rules;
``lint_jaxpr`` runs them on an already-traced (Closed)Jaxpr.  Both return
``Finding`` tuples (empty == clean) so test pins read
``assert not analysis.lint(fn, x, w, rules=("no-transpose-copy",))``.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from repro.analysis.verify import Finding

#: every cross-device transfer primitive jax may emit
COLLECTIVE_PRIMS = frozenset({"psum", "all_gather", "reduce_scatter",
                              "all_to_all", "ppermute", "psum_scatter"})

#: planned-collective summary (``DistributedPlan.collective``) -> the
#: primitives that summary is allowed to lower to
PLANNED_PRIMS = {"none": frozenset(),
                 "psum": frozenset({"psum"}),
                 "all_gather": frozenset({"all_gather"}),
                 "reduce_scatter": frozenset({"reduce_scatter",
                                              "psum_scatter"}),
                 # ring schedules (ring attention / pipelined sigma
                 # rotation) lower to neighbor permutes
                 "ppermute": frozenset({"ppermute"}),
                 # MoE expert dispatch/combine shuffles tokens across the
                 # expert mesh axis
                 "all_to_all": frozenset({"all_to_all"})}


class LintError(ValueError):
    """Raised by ``lint(..., strict=True)`` when findings exist."""

    def __init__(self, findings):
        self.findings = tuple(findings)
        super().__init__("jaxpr lint failed:\n  " +
                         "\n  ".join(str(f) for f in self.findings))


def jaxpr_primitives(jaxpr) -> Counter:
    """Count every primitive in a jaxpr, recursing into sub-jaxpr params —
    raw ``Jaxpr`` params (shard_map), ``ClosedJaxpr`` params (pjit,
    custom_vjp), and lists/tuples of either."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)      # unwrap ClosedJaxpr
    prims: Counter = Counter()
    todo = [jaxpr]
    while todo:
        j = todo.pop()
        for eqn in j.eqns:
            prims[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for x in (v if isinstance(v, (list, tuple)) else [v]):
                    if hasattr(x, "eqns"):
                        todo.append(x)
                    elif hasattr(x, "jaxpr"):
                        todo.append(x.jaxpr)
    return prims


# ---------------------------------------------------------------------------
# the rule registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LintRule:
    name: str
    description: str
    check: Callable  # (prims: Counter, ctx: dict) -> list[str]


_RULES: dict[str, LintRule] = {}


def register_rule(rule: LintRule) -> LintRule:
    _RULES[rule.name] = rule
    return rule


def lint_rules() -> tuple[LintRule, ...]:
    """Every registered rule, sorted by name (the README table source)."""
    return tuple(_RULES[n] for n in sorted(_RULES))


def _no_transpose(prims: Counter, ctx: dict) -> list:
    if prims.get("transpose"):
        return [f"{prims['transpose']} transpose primitive(s) in the "
                f"traced program — a relayout copy the psi-calculus "
                f"derivation is supposed to absorb into index maps"]
    return []


def _kernel_reached(prims: Counter, ctx: dict, what: str) -> list:
    want = int(ctx.get("min_calls", 1))
    got = prims.get("pallas_call", 0)
    if got < want:
        return [f"{got} pallas_call(s) traced, expected >= {want} — "
                f"{what}"]
    return []


def _no_oracle_recompute(prims: Counter, ctx: dict) -> list:
    return _kernel_reached(
        prims, ctx, "a differentiated path recomputes through a jnp "
        "oracle instead of a derived backward kernel")


def _no_silent_fallback(prims: Counter, ctx: dict) -> list:
    return _kernel_reached(
        prims, ctx, "the dispatch entry silently fell back to the jnp "
        "oracle instead of the derived kernel")


def _only_planned_collectives(prims: Counter, ctx: dict) -> list:
    if "allowed" in ctx:
        want = frozenset(ctx["allowed"])
    else:
        summary = ctx.get("collective", "none")
        want = frozenset()
        for kind in str(summary).split("+"):
            if kind not in PLANNED_PRIMS:
                return [f"unknown planned-collective summary {kind!r} "
                        f"(known: {sorted(PLANNED_PRIMS)})"]
            want |= PLANNED_PRIMS[kind]
    got = frozenset(p for p in prims if p in COLLECTIVE_PRIMS)
    out = []
    if got - want:
        out.append(f"unplanned collective(s) {sorted(got - want)} in the "
                   f"traced program (planned: {sorted(want) or 'none'})")
    if want and not got:
        out.append(f"planned collective ({sorted(want)}) never appears in "
                   f"the traced program")
    return out


register_rule(LintRule(
    "no-transpose-copy",
    "no transpose primitive anywhere — transposed operands ride index "
    "maps, not relayout copies", _no_transpose))
register_rule(LintRule(
    "no-oracle-recompute",
    "differentiated traces bind derived kernels (pallas_call), never a "
    "jnp oracle recompute", _no_oracle_recompute))
register_rule(LintRule(
    "only-planned-collectives",
    "exactly the plan's collectives appear — no unplanned resharding "
    "transfer", _only_planned_collectives))
register_rule(LintRule(
    "no-silent-fallback",
    "kernel-dispatch entries really reach pallas_call instead of silently "
    "falling back", _no_silent_fallback))


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def lint_jaxpr(jaxpr, rules: Optional[Iterable[str]] = None,
               strict: bool = False, **ctx) -> tuple[Finding, ...]:
    """Run named rules against an already-traced (Closed)Jaxpr."""
    names = tuple(rules) if rules is not None else tuple(sorted(_RULES))
    prims = jaxpr_primitives(jaxpr)
    findings = []
    for name in names:
        try:
            rule = _RULES[name]
        except KeyError:
            raise KeyError(f"unknown lint rule {name!r}; registered: "
                           f"{sorted(_RULES)}") from None
        for msg in rule.check(prims, ctx):
            findings.append(Finding(name, "error", "jaxpr", msg))
    findings = tuple(findings)
    if strict and findings:
        raise LintError(findings)
    return findings


def lint(fn: Callable, *args, rules: Optional[Iterable[str]] = None,
         strict: bool = False, **ctx) -> tuple[Finding, ...]:
    """Trace ``fn(*args)`` (abstractly — nothing executes) and run the
    named rules; ``rules=None`` runs all registered rules.  Rule context
    rides as keyword arguments (``collective=``, ``allowed=``,
    ``min_calls=``)."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    return lint_jaxpr(jaxpr, rules=rules, strict=strict, **ctx)
