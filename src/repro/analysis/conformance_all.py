"""``python -m repro.analysis.conformance_all`` — the kernel-body sweep.

The companion of ``verify_all``: where that sweep proves *schedule-level*
claims jax-free, this one emits the actual Pallas kernel for every
registered recurrence kind and generic form x hardware entry x
(dtype, acc_dtype) pair — the same registry ``verify_all`` walks — traces
it to a jaxpr, and abstractly interprets the body against the schedule
contract (``analysis.conformance``).  No kernel *executes*: tracing is
``jax.make_jaxpr`` over ``ShapeDtypeStruct`` refs.

A combination the registries refuse to derive (an illegal semiring/acc
pair, infeasible blocks, a non-float recurrent accumulator) counts as
``refused``; any error finding on a kernel that traced is a failure
(exit 1).  Causal-capable kinds are swept in both causal variants —
masked streams exercise the ``select_n`` guard lattice.

``--json out.json`` writes the machine-readable report (summary counts +
per-finding rows) CI uploads as an artifact; ``--hardware NAME`` restricts
the sweep (the tier-1 tests run the cpu slice; CI runs everything).
"""
from __future__ import annotations

import json
import sys

from repro.analysis import conformance
from repro.analysis.verify import errors
from repro.analysis.verify_all import _DTYPE_MATRIX, _forms
from repro.core import hardware as hwr
from repro.core import schedule as sched_mod

#: forms whose streamed axis only derives with pinned blocks — the paged
#: decode step pins (group rows, page size) exactly as the serving engine
#: does (``ops._decode_executor``)
BLOCK_OVERRIDES = {"windowed_decode": (4, 16),
                   "batched_decode": (4, 16)}


def _causal_variants(bundle):
    """(label_suffix, causal) variants to sweep for one derived bundle."""
    sch = bundle.schedule
    if not hasattr(sch, "state") or sch.state is None:
        return (("", None),)
    from repro.kernels import emit
    contract = emit.kind_contract(sch.state.kind)
    if contract is None or not contract.causal_mask:
        return (("", None),)
    if sch.window or sch.prefix_len:
        # masked streams *require* causal=True (honor-or-raise)
        return (("+causal", True),)
    return (("", False), ("+causal", True))


def run_sweep(hardware=None, verbose=False):
    """Sweep; returns the report dict ``--json`` serializes."""
    names = [hardware] if hardware else list(hwr.registered_hardware())
    checked = refused = 0
    failures: list = []
    rows: list = []
    for hw_name in names:
        entry = hwr.get_entry(hw_name)
        for label, form in _forms():
            for dtype, acc in _DTYPE_MATRIX:
                case = f"{hw_name}/{label}/{dtype}+{acc}"
                try:
                    bundle = sched_mod.get_schedule(
                        form, dtype=dtype, hardware=entry, acc_dtype=acc,
                        blocks=BLOCK_OVERRIDES.get(label))
                except (ValueError, AssertionError) as exc:
                    refused += 1
                    if verbose:
                        print(f"  refused {case}: {exc}")
                    continue
                for suffix, causal in _causal_variants(bundle):
                    vcase = case + suffix
                    findings = conformance.kernel_findings(
                        bundle, dtype=dtype, causal=causal)
                    checked += 1
                    errs = errors(findings)
                    if errs:
                        failures.append(vcase)
                        for f in errs:
                            rows.append({"case": vcase, "rule": f.rule,
                                         "level": f.level,
                                         "subject": f.subject,
                                         "message": f.message})
                            print(f"FAIL {vcase}: {f}")
                    elif verbose:
                        print(f"  ok {vcase}")
    return {
        "sweep": "conformance_all",
        "hardware": names,
        "checked": checked,
        "refused": refused,
        "failed": len(failures),
        "failures": failures,
        "findings": rows,
    }


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    verbose = "-v" in args
    hardware = None
    json_path = None
    if "--hardware" in args:
        hardware = args[args.index("--hardware") + 1]
    if "--json" in args:
        json_path = args[args.index("--json") + 1]
    report = run_sweep(hardware=hardware, verbose=verbose)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
    print(f"conformance_all: {report['checked']} kernel bodies checked, "
          f"{report['refused']} refused at derivation, "
          f"{report['failed']} failures across "
          f"{len(report['hardware'])} hardware entries")
    return 1 if report["failed"] else 0


if __name__ == "__main__":
    sys.exit(main())
