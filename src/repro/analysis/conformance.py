"""Kernel-body conformance: prove the emitted jaxpr obeys the schedule.

``analysis/verify.py`` proves claims about the *schedule dataclasses* and
``analysis/jaxpr_lint.py`` checks *top-level traced programs*; the emitter
between them (``kernels/emit.py`` — the generic sigma driver plus every
registered recurrence kind) was proven only by bit-identity sampling.  This
module closes that layer: it traces an emitted Pallas kernel body to its
jaxpr (``jax.make_jaxpr`` over ``ShapeDtypeStruct`` refs — jax is imported
only on this path, so the schedule-layer verifier stays jax-free) and
abstractly interprets it into a per-ref **effect summary**:

* which refs are loaded and stored, and the static slice windows touched;
* the dtype lattice of every accumulation chain (scratch dtypes + the
  ``preferred_element_type`` of every ``dot_general`` whose result flows
  into a store, with loads resetting the dataflow);
* which loads/stores are dominated by a ``@pl.when`` guard or a
  ``select_n`` mask, with each guard *classified* against the schedule:
  ``("first", d)`` / ``("last", d)`` for ``pid(d) == 0 / extent-1``,
  ``"stream"`` for comparisons against the logical streamed extent,
  ``"dynamic"`` for comparisons against the kind's declared position
  operand, ``"other"`` for everything else (causal/window masks).

The summary is checked against the ``ScheduleBundle`` contract (and, for
recurrent kinds, the ``KindContract`` the emitter declares in
``kernels.emit.KIND_CONTRACTS``) with typed ``Finding``s in four rule
classes:

* ``effect`` — an input ref is stored; an output/``state_outs`` ref is
  never stored; a store's static slice escapes the BlockSpec block shape.
* ``acc-dtype`` — a carried-state/sigma scratch ref is allocated at a
  different width than the bundle's solved ``acc_dtype`` (both the
  "folds narrower" and the "silently widens to f32 when bf16 was
  solved" defects), or a reduction ``dot_general`` reaching a store folds
  at a different ``preferred_element_type``.
* ``guard-dominance`` — the stream-bound pad guard the kind's contract
  declares (``stream-mask`` / ``dynamic-pos``) does not dominate a fold
  into carried state, so the pad-value inertness proof does not apply.
* ``state-discipline`` — carried state is read before its ``_init`` store
  on step 0, or flushed state is stored off the ``stream_grid_dim``
  final step.

``kernel_findings(bundle)`` is the entry ``verify_bundle(...,
kernel=True)`` calls; ``summarize_kernel(bundle)`` returns the raw
``KernelSummary`` for inspection (the README's worked example).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.analysis.verify import Finding
from repro.core.schedule import RecurrentSchedule, ScheduleBundle

# taint tag kinds
_PID = "pid"        # ("pid", grid_dim) — a program_id
_LOAD = "load"      # ("load", ref_index) — value read from a ref
_IOTA = "iota"      # ("iota",) — a position lattice
_DOT = "dot"        # ("dot", order, pref_dtype_str) — a contraction result


@dataclasses.dataclass(frozen=True)
class AbsVal:
    """Abstract value: taint provenance, guard tags (for booleans), mask
    tags already applied via ``select_n``, and a static scalar when one is
    known."""
    taints: frozenset = frozenset()
    guards: frozenset = frozenset()
    masked: frozenset = frozenset()
    const: object = None


_BOTTOM = AbsVal()


@dataclasses.dataclass(frozen=True)
class Access:
    """One load or store event on a ref."""
    ref: int
    order: int
    guards: frozenset          # guard tags dominating the access
    masked: frozenset = frozenset()   # mask tags on the stored value
    taints: frozenset = frozenset()   # taints of the stored value
    oob: tuple = ()            # bounds-violation messages (stores)


@dataclasses.dataclass(frozen=True)
class RefEffect:
    """Per-ref slice of the effect summary."""
    index: int
    name: str
    role: str                  # "input" | "output" | "state_out" | "scratch"
    block: tuple
    dtype: str
    loads: tuple               # tuple[Access, ...]
    stores: tuple              # tuple[Access, ...]


@dataclasses.dataclass(frozen=True)
class KernelSummary:
    """The whole-kernel effect summary the rules consume."""
    name: str
    grid: tuple
    stream_dim: Optional[int]
    logical_stream: Optional[int]
    guard_contract: Optional[str]     # KindContract.guard, if declared
    acc_dtype: str                    # the bundle's solved accumulator
    refs: tuple                       # tuple[RefEffect, ...]

    def describe(self) -> str:
        """Human-readable rendering (the README worked example)."""
        lines = [f"{self.name}: grid={self.grid} stream_dim="
                 f"{self.stream_dim} logical_stream={self.logical_stream} "
                 f"guard={self.guard_contract!r} acc={self.acc_dtype}"]
        for r in self.refs:
            lines.append(f"  [{r.index}] {r.role} {r.name} "
                         f"block={r.block} {r.dtype}: "
                         f"{len(r.loads)} loads, {len(r.stores)} stores")
            for s in r.stores:
                tags = sorted(map(str, s.guards | s.masked))
                lines.append(f"      store@{s.order} under {tags}")
        return "\n".join(lines)


def _fmt_tag(t) -> str:
    return f"{t[0]}:{t[1]}" if isinstance(t, tuple) else str(t)


class _Interp:
    """Abstract interpreter over one Pallas kernel jaxpr."""

    def __init__(self, kernel_jaxpr, grid, ref_splits, *, stream_dim,
                 logical_stream, pos_input):
        self.grid = tuple(grid)
        self.stream_dim = stream_dim
        self.logical_stream = logical_stream
        self.pos_input = pos_input
        self.order = 0
        self.loads: list = []
        self.stores: list = []
        ni, no, nscr = ref_splits
        self.ref_vars = {v: i for i, v in enumerate(kernel_jaxpr.invars)}
        self.ref_shapes = [tuple(v.aval.shape) for v in kernel_jaxpr.invars]
        self.ref_dtypes = [str(v.aval.dtype) for v in kernel_jaxpr.invars]
        self.n_inputs, self.n_outputs, self.n_scratch = ni, no, nscr
        env = {v: AbsVal(taints=frozenset({(_LOAD, i)}))
               for v, i in self.ref_vars.items()}
        self.walk(kernel_jaxpr, env, frozenset())

    # -- environment ------------------------------------------------------
    def read(self, env, atom) -> AbsVal:
        val = getattr(atom, "val", None)
        if val is not None or type(atom).__name__ == "Literal":
            try:
                c = val.item() if hasattr(val, "item") else val
            except (ValueError, TypeError):
                c = None
            return AbsVal(const=c)
        return env.get(atom, _BOTTOM)

    # -- guard classification --------------------------------------------
    def _classify_cmp(self, prim: str, lhs: AbsVal, rhs: AbsVal) -> frozenset:
        tags = set()
        union = lhs.taints | rhs.taints
        if self.pos_input is not None and (_LOAD, self.pos_input) in union:
            tags.add("dynamic")
        if prim == "eq":
            for a, b in ((lhs, rhs), (rhs, lhs)):
                pids = [t for t in a.taints if t[0] == _PID]
                if len(pids) == 1 and a.taints == frozenset(pids) \
                        and b.const is not None:
                    d = pids[0][1]
                    if b.const == 0:
                        tags.add(("first", d))
                    if d < len(self.grid) and b.const == self.grid[d] - 1:
                        tags.add(("last", d))
        else:
            if self.stream_dim is not None and \
                    (_PID, self.stream_dim) in union and \
                    self.logical_stream is not None:
                for a, b in ((lhs, rhs), (rhs, lhs)):
                    if b.const == self.logical_stream:
                        tags.add("stream")
        if not tags:
            tags.add("other")
        return frozenset(tags)

    # -- indexer bounds ---------------------------------------------------
    def _store_oob(self, eqn, refidx) -> tuple:
        import jax
        tree = eqn.params.get("tree")
        if tree is None:
            return ()
        try:
            idx = jax.tree_util.tree_unflatten(tree, list(eqn.invars[2:]))
        except Exception:
            return ()
        entries = []
        for part in (idx if isinstance(idx, tuple) else (idx,)):
            entries.extend(getattr(part, "indices", (part,)))
        shape = self.ref_shapes[refidx]
        msgs = []
        for d, ent in enumerate(entries):
            if d >= len(shape):
                break
            if hasattr(ent, "size"):                       # a Slice
                start = getattr(ent, "start", None)
                if getattr(ent, "is_dynamic_start", False) or \
                        not isinstance(start, int):
                    continue
                if start < 0 or start + ent.size > shape[d]:
                    msgs.append(
                        f"dim {d}: slice [{start}, {start + ent.size}) "
                        f"escapes the block extent {shape[d]}")
            elif isinstance(ent, int):
                if ent < 0 or ent >= shape[d]:
                    msgs.append(f"dim {d}: index {ent} escapes the block "
                                f"extent {shape[d]}")
        return tuple(msgs)

    # -- the walk ---------------------------------------------------------
    def walk(self, jaxpr, env, guards) -> None:
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            ins = [self.read(env, a) for a in eqn.invars]
            self.order += 1
            out = self._eval(eqn, prim, ins, env, guards)
            for ov in eqn.outvars:
                env[ov] = out

    def _subwalk(self, closed, operand_atoms, operand_vals,
                 guards) -> AbsVal:
        sub = {}
        inner = closed.jaxpr
        for v, atom, val in zip(inner.invars, operand_atoms, operand_vals):
            sub[v] = val
            # a ref passed into the branch keeps its identity: loads and
            # stores inside the cond attribute to the outer ref
            if type(atom).__name__ != "Literal":
                refidx = self.ref_vars.get(atom)
                if refidx is not None:
                    self.ref_vars[v] = refidx
        for v, c in zip(inner.constvars, closed.consts):
            try:
                cv = c.item() if hasattr(c, "item") and c.size == 1 else None
            except Exception:
                cv = None
            sub[v] = AbsVal(const=cv)
        self.walk(inner, sub, guards)
        outs = [sub.get(v, self.read(sub, v)) for v in inner.outvars]
        if not outs:
            return _BOTTOM
        return AbsVal(
            taints=frozenset().union(*(o.taints for o in outs)),
            masked=frozenset().union(*(o.masked for o in outs)))

    def _eval(self, eqn, prim, ins, env, guards) -> AbsVal:
        taints = frozenset().union(*(v.taints for v in ins)) \
            if ins else frozenset()
        masked = frozenset().union(*(v.masked for v in ins)) \
            if ins else frozenset()

        if prim == "program_id":
            return AbsVal(taints=frozenset({(_PID, eqn.params["axis"])}))

        if prim == "get":
            refidx = self.ref_vars.get(eqn.invars[0])
            if refidx is not None:
                self.loads.append(Access(refidx, self.order, guards))
                return AbsVal(taints=frozenset({(_LOAD, refidx)}))
            return AbsVal(taints=taints)

        if prim == "swap":
            refidx = self.ref_vars.get(eqn.invars[0])
            if refidx is not None:
                val = ins[1]
                self.stores.append(Access(
                    refidx, self.order, guards, masked=val.masked,
                    taints=val.taints,
                    oob=self._store_oob(eqn, refidx)))
                return AbsVal(taints=frozenset({(_LOAD, refidx)}))
            return AbsVal(taints=taints)

        if prim in ("eq", "ne", "lt", "le", "gt", "ge") and len(ins) == 2:
            return AbsVal(taints=taints, masked=masked,
                          guards=self._classify_cmp(prim, ins[0], ins[1]))

        if prim == "and":
            return AbsVal(taints=taints, masked=masked,
                          guards=ins[0].guards | ins[1].guards)
        if prim == "or":
            return AbsVal(taints=taints, masked=masked,
                          guards=ins[0].guards & ins[1].guards)
        if prim == "not":
            return AbsVal(taints=taints, masked=masked)

        if prim == "select_n":
            pred = ins[0]
            return AbsVal(taints=taints, masked=masked | pred.guards)

        if prim == "cond":
            branches = eqn.params["branches"]
            pred = ins[0]
            outs = []
            for bi, br in enumerate(branches):
                # the last branch is the true branch: its body is dominated
                # by the predicate's guard tags
                bg = guards | pred.guards if bi == len(branches) - 1 \
                    else guards
                outs.append(self._subwalk(br, eqn.invars[1:], ins[1:], bg))
            return AbsVal(
                taints=frozenset().union(*(o.taints for o in outs)),
                masked=frozenset().union(*(o.masked for o in outs)))

        if "jaxpr" in eqn.params:                  # pjit / closed_call
            closed = eqn.params["jaxpr"]
            if hasattr(closed, "jaxpr"):
                return self._subwalk(closed, eqn.invars, ins, guards)

        if prim == "dot_general":
            pref = eqn.params.get("preferred_element_type")
            if pref is None and eqn.outvars:
                pref = eqn.outvars[0].aval.dtype
            return AbsVal(taints=taints |
                          frozenset({(_DOT, self.order, str(pref))}),
                          masked=masked)

        if prim == "iota":
            return AbsVal(taints=frozenset({(_IOTA,)}))

        const = None
        if prim in ("broadcast_in_dim", "convert_element_type", "reshape",
                    "squeeze"):
            const = ins[0].const
            return AbsVal(taints=taints, masked=masked,
                          guards=ins[0].guards, const=const)
        if all(v.const is not None for v in ins) and ins:
            try:
                if prim == "mul":
                    const = ins[0].const * ins[1].const
                elif prim == "add":
                    const = ins[0].const + ins[1].const
                elif prim == "sub":
                    const = ins[0].const - ins[1].const
            except TypeError:
                const = None
        return AbsVal(taints=taints, masked=masked, const=const)


# ---------------------------------------------------------------------------
# tracing: emit the bundle's kernel and pull out the Pallas jaxpr
# ---------------------------------------------------------------------------

def _resolve_contract(sch):
    from repro.kernels import emit
    if isinstance(sch, RecurrentSchedule):
        kind = sch.state.kind if sch.state else "online_softmax"
        return emit.kind_contract(kind)
    return None


def _trace(bundle: ScheduleBundle, *, dtype, causal, scale, out_dtype,
           acc_dtype):
    """Emit + ``make_jaxpr`` the bundle's kernel; return
    ``(kernel_jaxpr, grid_mapping, contract)``."""
    import jax
    from repro.kernels import emit
    sch = bundle.schedule
    contract = _resolve_contract(sch)
    if isinstance(sch, RecurrentSchedule):
        if causal is None:
            causal = bool(contract and contract.causal_mask and
                          (sch.window or sch.prefix_len))
        kern = emit.emit_recurrent(
            sch, scale=scale, causal=causal,
            logical_stream=bundle.shapes[-1], out_dtype=out_dtype,
            acc_dtype=acc_dtype)
    else:
        kern = emit.emit_pallas(sch, out_dtype=out_dtype,
                                acc_dtype=acc_dtype)
    ni = len(sch.ins)
    pos = contract.pos_input % ni if contract is not None and \
        contract.pos_input is not None else None
    refs = [jax.ShapeDtypeStruct(spec.shape,
                                 "int32" if i == pos else dtype)
            for i, spec in enumerate(sch.ins)]
    traced = jax.make_jaxpr(kern)(*refs)
    pcs = [e for e in traced.jaxpr.eqns if e.primitive.name == "pallas_call"]
    if len(pcs) != 1:
        raise ValueError(
            f"{sch.name}: expected exactly one pallas_call in the emitted "
            f"program, found {len(pcs)}")
    eqn = pcs[0]
    return eqn.params["jaxpr"], eqn.params["grid_mapping"], contract, pos


# ---------------------------------------------------------------------------
# the effect summary + the four rules
# ---------------------------------------------------------------------------

def _ref_table(sch, gm):
    """(name, role) per kernel invar, in Pallas operand order."""
    ni, no = gm.num_inputs, gm.num_outputs
    nscr = gm.num_scratch_operands
    rows = []
    for spec in sch.ins:
        rows.append((spec.array, "input", spec))
    if isinstance(sch, RecurrentSchedule):
        outs = (sch.out,) + tuple(sch.state_outs)
        roles = ["output"] + ["state_out"] * len(sch.state_outs)
    else:
        outs, roles = (sch.out,), ["output"]
    for spec, role in zip(outs, roles):
        rows.append((spec.array, role, spec))
    for i in range(nscr):
        rows.append((f"scratch{i}", "scratch", None))
    if len(rows) != ni + no + nscr:
        raise ValueError(
            f"{sch.name}: schedule declares {len(rows)} refs but the "
            f"kernel binds {ni + no + nscr}")
    return rows


def _summary(bundle, interp, gm, contract, table, kernel_jaxpr):
    sch = bundle.schedule
    refs = []
    for i, (name, role, _spec) in enumerate(table):
        loads = tuple(a for a in interp.loads if a.ref == i)
        stores = tuple(a for a in interp.stores if a.ref == i)
        refs.append(RefEffect(
            index=i, name=name, role=role,
            block=interp.ref_shapes[i], dtype=interp.ref_dtypes[i],
            loads=loads, stores=stores))
    stream_dim = sch.stream_grid_dim \
        if isinstance(sch, RecurrentSchedule) else sch.reduce_grid_dim
    return KernelSummary(
        name=sch.name, grid=tuple(gm.grid), stream_dim=stream_dim,
        logical_stream=(bundle.shapes[-1]
                        if isinstance(sch, RecurrentSchedule) else None),
        guard_contract=contract.guard if contract else None,
        acc_dtype=str(bundle.acc_dtype), refs=tuple(refs))


def _is_init_store(store: Access, stream_dim) -> bool:
    return ("first", stream_dim) in store.guards or not store.guards


def _rule_effect(summary: KernelSummary, sch) -> list:
    out = []
    for r in summary.refs:
        if r.role == "input" and r.stores:
            out.append(Finding(
                "effect", "error", summary.name,
                f"input ref {r.name} is stored {len(r.stores)} time(s) — "
                f"kernels must not mutate their operands"))
        if r.role in ("output", "state_out") and not r.stores:
            out.append(Finding(
                "effect", "error", summary.name,
                f"{r.role} ref {r.name} is never stored — the kernel "
                f"cannot produce it"))
        for s in r.stores:
            for msg in s.oob:
                out.append(Finding(
                    "effect", "error", summary.name,
                    f"store to {r.name} escapes its BlockSpec block "
                    f"{r.block}: {msg}"))
    return out


def _rule_acc_dtype(summary: KernelSummary, sch) -> list:
    out = []
    acc = summary.acc_dtype
    for r in summary.refs:
        if r.role == "scratch" and r.dtype != acc:
            what = "silently widens" if r.dtype == "float32" else "folds"
            out.append(Finding(
                "acc-dtype", "error", summary.name,
                f"scratch ref {r.name} accumulates at {r.dtype} but the "
                f"solver budgeted acc_dtype={acc} — the kernel {what} "
                f"off the solved accumulation width"))
    seen = set()
    for r in summary.refs:
        for s in r.stores:
            for t in s.taints:
                if t[0] == _DOT and t[2] != acc and t not in seen:
                    seen.add(t)
                    out.append(Finding(
                        "acc-dtype", "error", summary.name,
                        f"a dot_general feeding the store to {r.name} "
                        f"folds at preferred_element_type={t[2]}, not the "
                        f"solved acc_dtype={acc}"))
    return out


def _rule_guard_dominance(summary: KernelSummary, sch, bundle) -> list:
    guard = summary.guard_contract
    if guard in (None, "identity-pad"):
        return []       # executor-side padding with the inert element
    needed = "stream" if guard == "stream-mask" else "dynamic"
    if guard == "stream-mask" and bundle.padded[-1] == bundle.shapes[-1]:
        return []       # the streamed axis does not pad — nothing to mask
    out = []
    sd = summary.stream_dim
    for r in summary.refs:
        if r.role != "scratch":
            continue
        for s in r.stores:
            # only the explicit step-0 init store is exempt: an unguarded
            # fold is exactly the defect this rule exists to catch
            if ("first", sd) in s.guards:
                continue
            if needed not in s.guards and needed not in s.masked:
                tags = sorted(_fmt_tag(t) for t in s.guards | s.masked)
                out.append(Finding(
                    "guard-dominance", "error", summary.name,
                    f"{guard} kind: fold into carried state {r.name} is "
                    f"guarded only by {tags}, not by the {needed!r} "
                    f"pad bound — padded streamed positions enter the "
                    f"monoid, voiding the inertness proof"))
    return out


def _rule_state_discipline(summary: KernelSummary, sch) -> list:
    out = []
    sd = summary.stream_dim
    # (a) carried state must be init-stored before its first read
    for r in summary.refs:
        if r.role != "scratch" or not r.loads:
            continue
        inits = [s.order for s in r.stores if _is_init_store(s, sd)]
        first_read = min(a.order for a in r.loads)
        if not inits:
            out.append(Finding(
                "state-discipline", "error", summary.name,
                f"carried state {r.name} is read but never initialized "
                f"on step 0 of grid dim {sd}"))
        elif min(inits) > first_read:
            out.append(Finding(
                "state-discipline", "error", summary.name,
                f"carried state {r.name} is read (order {first_read}) "
                f"before its step-0 init store (order {min(inits)})"))
    # (b) outputs not indexed by the streamed/reduce dim are flush-only
    if sd is None:
        return out
    table = {r.index: r for r in summary.refs}
    specs = []
    if isinstance(sch, RecurrentSchedule):
        outs = (sch.out,) + tuple(sch.state_outs)
    else:
        outs = (sch.out,)
    ni = len(sch.ins)
    for j, spec in enumerate(outs):
        r = table[ni + j]
        if sd in spec.grid_dims:
            continue        # per-step output, indexed by the stream dim
        for s in r.stores:
            if ("last", sd) not in s.guards:
                tags = sorted(_fmt_tag(t) for t in s.guards)
                out.append(Finding(
                    "state-discipline", "error", summary.name,
                    f"flushed {r.role} {r.name} revisits its block every "
                    f"streamed step but is stored under {tags}, not the "
                    f"final step of grid dim {sd}"))
    return out


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _analyze(bundle: ScheduleBundle, *, dtype="float32", causal=None,
             scale: float = 1.0, out_dtype="float32", acc_dtype=None):
    sch = bundle.schedule
    emit_acc = acc_dtype if acc_dtype is not None else bundle.acc_dtype
    kernel_jaxpr, gm, contract, pos = _trace(
        bundle, dtype=dtype, causal=causal, scale=scale,
        out_dtype=out_dtype, acc_dtype=emit_acc)
    stream_dim = sch.stream_grid_dim \
        if isinstance(sch, RecurrentSchedule) else sch.reduce_grid_dim
    interp = _Interp(
        kernel_jaxpr.jaxpr if hasattr(kernel_jaxpr, "jaxpr")
        else kernel_jaxpr,
        gm.grid,
        (gm.num_inputs, gm.num_outputs, gm.num_scratch_operands),
        stream_dim=stream_dim,
        logical_stream=(bundle.shapes[-1]
                        if isinstance(sch, RecurrentSchedule) else None),
        pos_input=pos)
    table = _ref_table(sch, gm)
    summary = _summary(bundle, interp, gm, contract, table, kernel_jaxpr)
    return summary


def summarize_kernel(bundle: ScheduleBundle, *, dtype="float32",
                     causal=None, scale: float = 1.0,
                     out_dtype="float32") -> KernelSummary:
    """Trace the bundle's emitted kernel and return its effect summary."""
    return _analyze(bundle, dtype=dtype, causal=causal, scale=scale,
                    out_dtype=out_dtype)


def kernel_findings(bundle: ScheduleBundle, *, dtype="float32", causal=None,
                    scale: float = 1.0, out_dtype="float32",
                    acc_dtype=None) -> tuple:
    """Trace + abstractly interpret the bundle's kernel body and check the
    effect summary against the schedule contract.

    ``acc_dtype`` overrides the accumulator the kernel is *emitted* with
    (the bundle's solved ``acc_dtype`` stays the contract side) — used by
    mutation tests to seed the swapped-accumulator defect; leave ``None``
    outside tests.
    """
    sch = bundle.schedule
    summary = _analyze(bundle, dtype=dtype, causal=causal, scale=scale,
                       out_dtype=out_dtype, acc_dtype=acc_dtype)
    findings = []
    findings += _rule_effect(summary, sch)
    findings += _rule_acc_dtype(summary, sch)
    findings += _rule_guard_dominance(summary, sch, bundle)
    findings += _rule_state_discipline(summary, sch)
    return tuple(findings)
