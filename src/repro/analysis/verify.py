"""Static soundness checks for derived schedules, bundles and plans.

The psi-calculus derivation (``core/schedule.py``) claims every schedule it
emits is correct by construction.  This module *proves* the claims it can
state symbolically, without executing a kernel:

* **coverage / disjointness** — every logical element of every operand and
  of the output is touched by exactly one (non-sigma) grid point: a
  grid-driven dimension's ``block * grid_extent`` must equal the padded
  extent with a zero index-map offset, a resident dimension's block must
  equal its extent, and one logical axis must present one consistent
  extent across all operands;
* **psi bounds** — a psi view's constant slab offset stays inside the
  declared leading dimension;
* **races** — a grid axis that revisits the output (or an exported-state)
  block without declared reduction/carried-state semantics is the Pallas
  write-write race; declared revisiting axes must be "arbitrary"
  (sequential), never "parallel";
* **pad guard / pad value** — when a reduce axis is padded, the fill
  element must be inert under the semiring (``combine(pad, pad)`` folds
  into the reduce identity); a recurrent bundle's masking guard must use
  the true logical streamed extent its operands record;
* **resources** — the working set recomputed at the bundle's real
  ``acc_dtype`` width (plus the materialized-combine intermediate) must
  fit the hardware table, and the solver's recorded certificate must not
  understate the formula it was solved with (an undersized scratch
  budget).

Everything here is pure Python over the schedule dataclasses — no jax —
and results are LRU-cached on the same normal-form keys as the schedule
cache, so a ``verify=False`` path pays nothing and a hot ``verify=True``
path pays one dict lookup.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable

from repro.core import expr as expr_mod
from repro.core import schedule as sched_mod
from repro.core import semiring
from repro.core.blocking import (BlockChoice, RecurrenceBlockChoice,
                                 StreamBlockChoice, gemm_working_set,
                                 _dtype_size)
from repro.core.schedule import (PSI_AXIS, OperandSpec, RecurrentSchedule,
                                 Schedule, ScheduleBundle,
                                 bundle_needs_padding, bundle_pad_value)


@dataclass(frozen=True)
class Finding:
    """One verifier result: a defect class (``rule``), a severity
    (``"error"`` — the schedule is unsound — or ``"warning"``), the
    subject (schedule/operand/plan name) and a human-readable message."""
    rule: str
    level: str
    subject: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.level} {self.subject}: {self.message}"


class VerificationError(ValueError):
    """Raised on strict verification when error findings exist."""

    def __init__(self, findings: tuple[Finding, ...]):
        self.findings = findings
        super().__init__(
            "static verification failed:\n  " +
            "\n  ".join(str(f) for f in findings if f.level == "error"))


def errors(findings) -> tuple[Finding, ...]:
    return tuple(f for f in findings if f.level == "error")


# ---------------------------------------------------------------------------
# coverage / disjointness / psi bounds / races — pure grid x BlockSpec walk
# ---------------------------------------------------------------------------

def _spec_findings(spec: OperandSpec, grid, axis_extent: dict,
                   subject: str) -> list:
    """Coverage proof for one operand: walk each array dimension against
    the grid and record the full logical extent each axis presents."""
    out = []
    offs = spec.offsets or (0,) * len(spec.axes)
    for i, (ax, s, b, gd) in enumerate(zip(spec.axes, spec.shape,
                                           spec.block, spec.grid_dims)):
        off = offs[i] if i < len(offs) else 0
        if ax == PSI_AXIS:
            if b != 1 or gd is not None:
                out.append(Finding(
                    "psi-bounds", "error", subject,
                    f"{spec.array}: psi slab dim must be block 1 and "
                    f"grid-pinned, got block {b}, grid dim {gd}"))
            if off < 0 or off + b > s:
                out.append(Finding(
                    "psi-bounds", "error", subject,
                    f"{spec.array}: psi slab offset {off} outside the "
                    f"declared {s} leading slab(s)"))
            continue
        if off != 0:
            out.append(Finding(
                "coverage", "error", subject,
                f"{spec.array} dim {i} ({ax!r}) carries a constant "
                f"block-index offset {off} on a non-psi dimension — a "
                f"shifted index map: element block 0 is never touched and "
                f"the last grid step reads past extent {s}"))
            continue
        if gd is not None:
            if gd >= len(grid):
                out.append(Finding(
                    "coverage", "error", subject,
                    f"{spec.array} dim {i} ({ax!r}) driven by grid dim "
                    f"{gd}, but the grid has {len(grid)} axes"))
                continue
            table = getattr(spec, "page_table", None)
            if table is not None and i == 0:
                # a paged psi view: dim 0's stored extent is the slab pool,
                # its logical extent is len(table) pages of ``b``.  The
                # per-page slab offsets must stay inside the pool (the
                # paged analogue of psi-bounds) and the table must name one
                # slab per streamed grid step.  A stacked [slot, k] table
                # (``page_slot_dim`` set) adds the slot dimension: one row
                # per slot grid step, every slab of every slot in-pool.
                slot_dim = getattr(spec, "page_slot_dim", None)
                if slot_dim is not None:
                    if slot_dim >= len(grid):
                        out.append(Finding(
                            "page-bounds", "error", subject,
                            f"{spec.array}: stacked page table keyed on "
                            f"grid dim {slot_dim}, but the grid has "
                            f"{len(grid)} axes"))
                    elif len(table) != grid[slot_dim].extent:
                        out.append(Finding(
                            "page-bounds", "error", subject,
                            f"{spec.array}: stacked page table has "
                            f"{len(table)} rows but the slot grid dim "
                            f"{slot_dim} runs {grid[slot_dim].extent} "
                            f"steps"))
                    rows = table
                else:
                    rows = (table,)
                n_cols = {len(row) for row in rows}
                if n_cols != {grid[gd].extent}:
                    out.append(Finding(
                        "page-bounds", "error", subject,
                        f"{spec.array}: page table names {sorted(n_cols)} "
                        f"slabs but the streamed grid dim {gd} runs "
                        f"{grid[gd].extent} steps"))
                for sno, row in enumerate(rows):
                    for pno, slab in enumerate(row):
                        if slab < 0 or (slab + 1) * b > s:
                            where = (f"slot {sno} view page {pno}"
                                     if slot_dim is not None
                                     else f"view page {pno}")
                            out.append(Finding(
                                "page-bounds", "error", subject,
                                f"{spec.array}: {where} maps to slab "
                                f"{slab}, whose block of {b} ends at "
                                f"{(slab + 1) * b} — outside the "
                                f"{s}-element pool"))
                full = len(rows[0]) * b
                prev = axis_extent.get(ax)
                if prev is None:
                    axis_extent[ax] = full
                elif prev != full:
                    out.append(Finding(
                        "coverage", "error", subject,
                        f"axis {ax!r} presents extent {full} on "
                        f"{spec.array} but {prev} elsewhere — operands "
                        f"disagree on the logical iteration space"))
                continue
            covered = b * grid[gd].extent
            if covered != s:
                out.append(Finding(
                    "coverage", "error", subject,
                    f"{spec.array} dim {i} ({ax!r}): blocks of {b} over "
                    f"{grid[gd].extent} grid steps cover {covered} of "
                    f"extent {s}"))
                continue
            full = covered
        else:
            if b != s:
                out.append(Finding(
                    "coverage", "error", subject,
                    f"{spec.array} dim {i} ({ax!r}) is grid-resident with "
                    f"block {b} != extent {s} — elements beyond the block "
                    f"are never touched"))
                continue
            full = s
        prev = axis_extent.get(ax)
        if prev is None:
            axis_extent[ax] = full
        elif prev != full:
            out.append(Finding(
                "coverage", "error", subject,
                f"axis {ax!r} presents extent {full} on {spec.array} but "
                f"{prev} elsewhere — operands disagree on the logical "
                f"iteration space"))
    return out


def _race_findings(sched, spec: OperandSpec, legal_dims: set,
                   subject: str) -> list:
    """A grid axis not driving any dimension of a *written* operand revisits
    its block every step — a write-write race unless that axis is the
    declared reduction / carried-state stream (and sequential)."""
    out = []
    written = {gd for gd in spec.grid_dims if gd is not None}
    for gi, g in enumerate(sched.grid):
        if gi in written:
            continue
        if gi in legal_dims:
            if g.semantics != "arbitrary":
                out.append(Finding(
                    "race", "error", subject,
                    f"grid axis {gi} ({g.base!r}) accumulates into "
                    f"{spec.array} but has {g.semantics!r} semantics — "
                    f"Mosaic may run its steps concurrently"))
            continue
        out.append(Finding(
            "race", "error", subject,
            f"grid axis {gi} ({g.base!r}, {g.extent} steps) revisits the "
            f"{spec.array} block with no declared reduction or "
            f"carried-state semantics — a write-write race"))
    return out


def verify_schedule(sched) -> tuple[Finding, ...]:
    """Symbolic coverage/disjointness/race proof for a ``Schedule`` or
    ``RecurrentSchedule``.  Returns findings (empty == proven sound)."""
    findings: list = []
    axis_extent: dict = {}
    subject = sched.name
    if isinstance(sched, RecurrentSchedule):
        writes = [sched.out] + list(sched.state_outs)
        legal = ({sched.stream_grid_dim} if sched.state is not None
                 else set())
        for spec in list(sched.ins) + writes:
            findings += _spec_findings(spec, sched.grid, axis_extent,
                                       subject)
        for spec in writes:
            findings += _race_findings(sched, spec, legal, subject)
    else:
        legal = ({sched.reduce_grid_dim}
                 if sched.reduce_grid_dim is not None else set())
        for spec in list(sched.ins) + [sched.out]:
            findings += _spec_findings(spec, sched.grid, axis_extent,
                                       subject)
        findings += _race_findings(sched, sched.out, legal, subject)
    return tuple(findings)


# ---------------------------------------------------------------------------
# pad guard / pad value — the semiring-inertness proof
# ---------------------------------------------------------------------------

def _pad_findings(bundle: ScheduleBundle) -> list:
    sch = bundle.schedule
    subject = sch.name
    out: list = []
    if isinstance(sch, RecurrentSchedule):
        # the emitter masks padded streamed positions with a
        # ``kpos < logical_stream`` guard built from ``bundle.shapes[-1]``;
        # that bound must equal the streamed extent the operands record,
        # else padded keys/tokens silently enter the reduction
        declared = bundle.shapes[-1]
        for spec, logical in zip(sch.ins, bundle.in_shapes):
            if sch.stream_axis in spec.axes and \
                    len(logical) == len(spec.shape):
                true_ls = logical[spec.axes.index(sch.stream_axis)]
                if true_ls != declared:
                    out.append(Finding(
                        "pad-guard", "error", subject,
                        f"the masking guard bounds the streamed axis "
                        f"{sch.stream_axis!r} at {declared}, but operand "
                        f"{spec.array} records logical extent {true_ls} — "
                        f"padded positions are not guarded"))
                break
        return out
    if not bundle_needs_padding(bundle):
        return out
    try:
        pad_val = bundle_pad_value(bundle)
    except ValueError as exc:
        out.append(Finding("pad-guard", "error", subject,
                           f"padding required but unguarded: {exc}"))
        return out
    # inertness only matters where a *reduce* axis is padded — padded
    # output rows/cols are sliced away after the kernel
    n_out = len(bundle.out_shape)
    if bundle.padded[n_out:] == bundle.shapes[n_out:]:
        return out
    cdef = semiring.combine_def(sch.combine)
    rdef = semiring.reduce_def(sch.reduce_op)
    contrib = cdef.np_fn(pad_val, pad_val) if len(sch.ins) > 1 else pad_val
    folded = rdef.np_fn(rdef.identity, contrib)
    if not (folded == rdef.identity or
            (folded != folded and rdef.identity != rdef.identity)):
        out.append(Finding(
            "pad-value", "error", subject,
            f"pad element {pad_val!r} is not inert under "
            f"({sch.combine}, {sch.reduce_op}): combine(pad, pad) folds "
            f"{rdef.identity!r} to {folded!r} — padded reduce positions "
            f"corrupt the result"))
    return out


# ---------------------------------------------------------------------------
# resource certificate — the acc-width working set vs the hardware table
# ---------------------------------------------------------------------------

def _resource_findings(bundle: ScheduleBundle, hw_shape,
                       dtype: str) -> list:
    sch = bundle.schedule
    subject = sch.name
    out: list = []
    ws = sch.working_set_bytes(dtype, bundle.acc_dtype)
    if hw_shape is not None and ws > hw_shape.vmem.capacity_bytes:
        out.append(Finding(
            "resource", "error", subject,
            f"working set {ws} B at acc_dtype={bundle.acc_dtype} exceeds "
            f"{hw_shape.name}'s {hw_shape.vmem.capacity_bytes} B VMEM"))
    blocks = bundle.blocks
    if isinstance(sch, Schedule) and isinstance(blocks, BlockChoice) \
            and blocks.vmem_bytes > 0:
        cert = gemm_working_set(
            blocks.bm, blocks.bk, blocks.bn, _dtype_size(dtype),
            _dtype_size(bundle.acc_dtype),
            materialized_combine=(sch.combine, sch.reduce_op) != ("mul",
                                                                  "add"))
        if blocks.vmem_bytes < cert:
            out.append(Finding(
                "scratch", "error", subject,
                f"solver certificate records {blocks.vmem_bytes} B but the "
                f"({blocks.bm}, {blocks.bk}, {blocks.bn}) blocks need "
                f"{cert} B at acc_dtype={bundle.acc_dtype} — an undersized "
                f"scratch budget"))
    return out


# ---------------------------------------------------------------------------
# the cached public entry points
# ---------------------------------------------------------------------------

VERIFY_CACHE_SIZE = 512
_cache: "OrderedDict[tuple, tuple[Finding, ...]]" = OrderedDict()
_lock = threading.Lock()
_stats = {"hits": 0, "misses": 0}


def verification_cache_stats() -> dict:
    with _lock:
        return dict(_stats)


def reset_verification_cache() -> None:
    with _lock:
        _cache.clear()
        for k in _stats:
            _stats[k] = 0


def _cached(key, compute: Callable[[], tuple]) -> tuple:
    if key is None:
        return compute()
    with _lock:
        hit = _cache.get(key)
        if hit is not None:
            _stats["hits"] += 1
            _cache.move_to_end(key)
            return hit
        _stats["misses"] += 1
    findings = compute()
    with _lock:
        _cache[key] = findings
        while len(_cache) > VERIFY_CACHE_SIZE:
            _cache.popitem(last=False)
    return findings


def verify_bundle(bundle: ScheduleBundle, *, hardware=None,
                  dtype: str = "float32", key=None,
                  strict: bool = False,
                  kernel: bool = False) -> tuple[Finding, ...]:
    """Run every static check on a cached derivation.

    ``hardware`` is a ``HardwareEntry`` or ``HardwareShape`` (or None to
    skip the capacity check); ``dtype`` must be the input dtype the bundle
    was derived at.  ``key`` enables the LRU result cache (pass the same
    tuple shape as the schedule cache key).  ``kernel=True`` additionally
    traces the emitted Pallas kernel body and checks its effect summary
    against the schedule contract (``analysis.conformance``) — this is the
    one verify path that imports jax, so it is opt-in and its results
    cache under a distinct key.  ``strict=True`` raises
    ``VerificationError`` when any error finding survives.
    """
    hw_shape = getattr(hardware, "shape", hardware)

    def compute():
        findings = list(verify_schedule(bundle.schedule))
        findings += _pad_findings(bundle)
        findings += _resource_findings(bundle, hw_shape, str(dtype))
        if kernel:
            from repro.analysis import conformance
            findings += conformance.kernel_findings(bundle, dtype=dtype)
        return tuple(findings)

    findings = _cached((key, "kernel") if kernel and key is not None
                       else key, compute)
    if strict and errors(findings):
        raise VerificationError(findings)
    return findings


def verify_expr(op, *, dtype: str = "float32", hardware=None, blocks=None,
                acc_dtype: str = "float32",
                strict: bool = True,
                kernel: bool = False) -> tuple[Finding, ...]:
    """Derive (via the schedule cache) and verify a normalized expression —
    the ``ops.apply(..., verify=True)`` entry.  Results cache on the same
    ``(Onf.key(), dtype, hardware, blocks, acc_dtype)`` key as schedules.
    ``kernel=True`` extends the checks to the traced Pallas kernel body."""
    if hardware is None:
        raise TypeError("verify_expr requires a hardware entry/shape")
    bundle = sched_mod.get_schedule(op, dtype=dtype, hardware=hardware,
                                    blocks=blocks, acc_dtype=acc_dtype)
    if isinstance(op, (expr_mod.NormalForm, expr_mod.RecurrentForm)):
        nf = op
    else:
        nf = expr_mod.normal_form(op, name=getattr(op, "name", None)
                                  or "expr")
    hw_shape = getattr(hardware, "shape", hardware)
    hw_name = getattr(hardware, "name", None) or hw_shape.name
    block_key = tuple(blocks) if isinstance(blocks, (list, tuple)) else blocks
    if isinstance(block_key, (BlockChoice, StreamBlockChoice,
                              RecurrenceBlockChoice)):
        block_key = block_key.as_tuple()
    key = (nf.key(), str(dtype), hw_name, block_key, str(acc_dtype))
    return verify_bundle(bundle, hardware=hardware, dtype=dtype, key=key,
                         strict=strict, kernel=kernel)


def verify_plan(plan, *, hardware=None, dtype: str = "float32", key=None,
                strict: bool = False) -> tuple[Finding, ...]:
    """Verify a ``DistributedPlan``: the per-shard bundle (at its real —
    possibly widened — ``acc_dtype``), the collective ordering, and the
    replication fallbacks surfaced as warnings naming the axis."""

    def compute():
        findings = list(verify_bundle(plan.bundle, hardware=hardware,
                                      dtype=dtype))
        mesh_size = dict(plan.mesh.axes)
        for sym, axis in plan.dropped:
            findings.append(Finding(
                "replication-fallback", "warning", plan.name,
                f"axis {sym!r} is not divisible by mesh axis {axis!r} "
                f"(size {mesh_size.get(axis)}) — operand replicated "
                f"instead of sharded"))
        # a gather replicates whatever the shard holds *now*: any
        # psum/reduce_scatter sequenced after an all_gather reads partial
        # sums another step may still be accumulating
        gathered = None
        for step in plan.collectives:
            if step.kind == "all_gather":
                gathered = step
            elif step.kind in ("psum", "reduce_scatter") and gathered:
                findings.append(Finding(
                    "collective-order", "error", plan.name,
                    f"{step.kind} over {step.mesh_axis!r} is sequenced "
                    f"after all_gather over {gathered.mesh_axis!r} — the "
                    f"gather replicates partial sums before the reduction "
                    f"completes"))
            if step.kind in ("reduce_scatter", "all_gather"):
                if step.out_dim is None or not (
                        0 <= step.out_dim < len(plan.out_shape)):
                    findings.append(Finding(
                        "collective-order", "error", plan.name,
                        f"{step.kind} over {step.mesh_axis!r} targets "
                        f"output dim {step.out_dim} of a rank-"
                        f"{len(plan.out_shape)} result"))
        return tuple(findings)

    findings = _cached(key, compute)
    if strict and errors(findings):
        raise VerificationError(findings)
    return findings


def verify_sharded(op, mesh, shard, *, hardware=None, dtype: str = "float32",
                   replicate_out: bool = False, scatter_axis=None,
                   acc_dtype: str = "float32",
                   strict: bool = True) -> tuple[Finding, ...]:
    """Derive (via the plan cache) and verify a distributed plan — the
    ``ops.apply(mesh=..., verify=True)`` entry."""
    from repro.core.mesh import from_jax_mesh
    from repro.distributed import plan as dplan
    if hardware is None:
        raise TypeError("verify_sharded requires a hardware entry/shape")
    plan = dplan.derive_plan(op, mesh, shard=shard, hardware=hardware,
                             dtype=dtype, replicate_out=replicate_out,
                             scatter_axis=scatter_axis, acc_dtype=acc_dtype)
    if isinstance(op, (expr_mod.NormalForm, expr_mod.RecurrentForm)):
        nf = op
    else:
        nf = expr_mod.normal_form(op, name=getattr(op, "name", None)
                                  or "expr")
    hw_shape = getattr(hardware, "shape", hardware)
    hw_name = getattr(hardware, "name", None) or hw_shape.name
    key = ("plan", nf.key(), from_jax_mesh(mesh).axes,
           tuple(sorted(shard.items())), bool(replicate_out), scatter_axis,
           str(dtype), hw_name, str(acc_dtype))
    return verify_plan(plan, hardware=hardware, dtype=dtype, key=key,
                       strict=strict)
