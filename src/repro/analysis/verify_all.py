"""``python -m repro.analysis.verify_all`` — the registry sweep.

Derives and statically verifies every registered form x hardware entry x
dtype x accumulation semiring, plus a distributed-plan matrix (sharded
rows/cols/sigma, reduce-scatter, replication fallbacks) on a 2-device
``MeshShape``.  Pure derivation + verification: no kernel executes, no jax
device state is touched (plans derive on bare ``MeshShape``), so the sweep
is CI-cheap and runs anywhere.

A combination the registries refuse to derive — a semiring/acc-width pair
the hardware table has no path for, or blocks that cannot fit a small
memory (the V100's 32 KiB L1 with a materialized tropical combine) — is
*correct* static behavior and counts as ``refused``, not a failure.  Any
error finding on a derivation that succeeded fails the sweep (exit 1).

``--json out.json`` writes a machine-readable report (summary counts +
per-finding rows, same schema as ``conformance_all``) that CI uploads as
an artifact and the tests pin, so silent registry shrinkage fails loudly.
"""
from __future__ import annotations

import json
import sys

from repro import analysis
from repro.core import expr as E
from repro.core import hardware as hwr
from repro.core.mesh import MeshShape


def _forms():
    """(label, form) for every registered schedule shape, at sizes that
    exercise padding on both output and reduce axes."""
    yield "matmul", E.matmul_expr(300, 200, 160)
    yield "matmul_tb", E.matmul_expr(300, 200, 160, transpose_b=True)
    yield "expert_gemm", E.expert_gemm_expr(4, 60, 96, 72)
    yield "hadamard", E.hadamard_expr(200, 300)
    yield "head_gemm", E.head_gemm_expr(4, 48, 32, 40)
    yield "head_gemm_tb", E.head_gemm_expr(4, 48, 32, 40, transpose_b=True)
    yield "max_plus", E.inner("max", "add", E.arr("A", (100, 60)),
                              E.arr("B", (60, 80)))
    yield "min_plus", E.inner("min", "add", E.arr("A", (100, 60)),
                              E.arr("B", (60, 80)))
    yield "attention", E.attention_form(1, 2, 2, 300, 300, 64)
    yield "attention_stats", E.attention_stats_form(1, 2, 2, 300, 300, 64)
    yield "attention_windowed", E.attention_form(1, 1, 1, 256, 256, 64,
                                                 window=128)
    yield "flash_dq", E.attention_dq_form(1, 1, 1, 300, 300, 64)
    yield "flash_dkv", E.attention_dkv_form(1, 1, 1, 300, 300, 64)
    yield "ssd", E.ssd_form(1, 4, 64, 2, 16, 16)
    yield "ssd_chk", E.ssd_chk_form(1, 4, 64, 2, 16, 16)
    yield "ssd_bwd", E.ssd_bwd_form(1, 4, 64, 2, 16, 16)
    yield "rglru", E.rglru_form(1, 4, 64, 32)
    yield "rglru_bwd", E.rglru_bwd_form(1, 4, 64, 32)
    # the paged decode step: a scrambled page table into a larger slab pool
    yield "windowed_decode", E.windowed_decode_form(
        2, 4, 64, page=16, view_pages=4, pool_pages=6,
        page_table=(0, 3, 1, 5), window=32)
    # batched multi-slot decode: the slot axis lifted, a stacked [slot, k]
    # table into one shared pool
    yield "batched_decode", E.batched_decode_form(
        3, 2, 4, 64, page=16, view_pages=4, pool_pages=8,
        page_tables=((0, 3, 1, 5), (2, 4, 6, 7), (1, 0, 3, 2)), window=32)


#: (input dtype, accumulation dtype) — legality is decided per hardware
#: entry by the semiring registry + hardware table at derivation time
_DTYPE_MATRIX = (("float32", "float32"),
                 ("bfloat16", "float32"),
                 ("bfloat16", "bfloat16"),
                 ("int8", "int32"))

#: forms whose streamed axis only derives with pinned blocks: batched
#: decode pins (group rows, page size) exactly as the serving engine does
#: (``ops._batched_decode_executor``) — the generic solver has no page-
#: alignment constraint, so its solved stream block may pad the view
BLOCK_OVERRIDES = {"batched_decode": (4, 16)}


def _plan_cases():
    mesh = MeshShape((("x", 2),))
    mesh2 = MeshShape((("dx", 2), ("dy", 2)))
    m, k, n = 64, 96, 32
    f = E.matmul_expr(m, k, n)
    yield "plan_row", f, mesh, {"i": "x"}, {}
    yield "plan_col", f, mesh, {"j": "x"}, {}
    yield "plan_sigma", f, mesh, {"k": "x"}, {}
    yield "plan_both", f, mesh2, {"i": "dx", "j": "dy"}, {}
    yield "plan_gather", f, mesh, {"i": "x"}, {"replicate_out": True}
    yield "plan_scatter", f, mesh, {"k": "x"}, {"scatter_axis": "i"}
    yield ("plan_fallback", E.matmul_expr(31, 96, 32), mesh, {"i": "x"}, {})
    yield ("plan_expert", E.expert_gemm_expr(4, 60, 96, 72), mesh,
           {"i": "x"}, {})
    yield ("plan_bf16_acc", f, mesh, {"k": "x"},
           {"dtype": "bfloat16", "acc_dtype": "bfloat16"})


def run_sweep(verbose=False):
    """Sweep every registry entry; returns the report dict ``--json``
    serializes (summary counts + per-error-finding rows)."""
    checked = refused = warned = 0
    failures: list[str] = []
    rows: list[dict] = []

    for hw_name in hwr.registered_hardware():
        entry = hwr.get_entry(hw_name)
        for label, form in _forms():
            for dtype, acc in _DTYPE_MATRIX:
                case = f"{hw_name}/{label}/{dtype}+{acc}"
                try:
                    findings = analysis.verify_expr(
                        form, dtype=dtype, hardware=entry, acc_dtype=acc,
                        blocks=BLOCK_OVERRIDES.get(label), strict=False)
                except (ValueError, AssertionError) as exc:
                    # the registries refusing an illegal/infeasible combo
                    # IS the derivation-time failure the certifier wants
                    refused += 1
                    if verbose:
                        print(f"  refused {case}: {exc}")
                    continue
                checked += 1
                errs = analysis.verify.errors(findings)
                warned += len(findings) - len(errs)
                if errs:
                    failures.append(case)
                    for f in errs:
                        rows.append({"case": case, "rule": f.rule,
                                     "level": f.level, "subject": f.subject,
                                     "message": f.message})
                        print(f"FAIL {case}: {f}")
                elif verbose:
                    print(f"  ok {case}")

        for label, form, mesh, shard, kw in _plan_cases():
            kw = dict(kw)
            dtype = kw.pop("dtype", "float32")
            case = f"{hw_name}/{label}/{dtype}"
            try:
                findings = analysis.verify_sharded(
                    form, mesh, shard, hardware=entry, dtype=dtype,
                    strict=False, **kw)
            except (ValueError, AssertionError) as exc:
                refused += 1
                if verbose:
                    print(f"  refused {case}: {exc}")
                continue
            checked += 1
            errs = analysis.verify.errors(findings)
            warned += len(findings) - len(errs)
            if errs:
                failures.append(case)
                for f in errs:
                    rows.append({"case": case, "rule": f.rule,
                                 "level": f.level, "subject": f.subject,
                                 "message": f.message})
                    print(f"FAIL {case}: {f}")
            elif verbose:
                print(f"  ok {case}")

    return {
        "sweep": "verify_all",
        "hardware": list(hwr.registered_hardware()),
        "checked": checked,
        "refused": refused,
        "warned": warned,
        "failed": len(failures),
        "failures": failures,
        "findings": rows,
    }


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    json_path = args[args.index("--json") + 1] if "--json" in args else None
    report = run_sweep(verbose="-v" in args)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
    print(f"verify_all: {report['checked']} combinations verified, "
          f"{report['refused']} refused at derivation, "
          f"{report['warned']} warnings, {report['failed']} failures "
          f"across {len(report['hardware'])} hardware entries")
    return 1 if report["failed"] else 0


if __name__ == "__main__":
    sys.exit(main())
