"""Static verification of derived schedules, plans and emitted jaxprs.

The paper's claim is that static information — types, shapes, the lifted
psi-calculus indexing — fully determines a correct layout.  This package
makes "derived => correct" a *checkable* property without executing any
kernel:

* ``verify_schedule`` / ``verify_bundle`` / ``verify_plan``
  (``analysis.verify``): symbolic coverage/disjointness proofs over the
  grid x BlockSpec index maps, grid write-write race detection, pad-guard
  and pad-value (semiring inertness) checks, psi offset bounds, and the
  VMEM resource certificate recomputed at the real accumulation width.
* ``lint`` / ``lint_jaxpr`` (``analysis.jaxpr_lint``): a named-rule
  registry over traced jaxprs — ``no-transpose-copy``,
  ``no-oracle-recompute``, ``only-planned-collectives``,
  ``no-silent-fallback`` — replacing the ad-hoc scanners that used to be
  copy-pasted across the test files.
* ``summarize_kernel`` / ``kernel_findings`` (``analysis.conformance``):
  trace each *emitted Pallas kernel body* to a jaxpr and abstractly
  interpret it into a per-ref effect summary, checked against the
  schedule contract — rule classes ``effect``, ``acc-dtype``,
  ``guard-dominance``, ``state-discipline``.  This is the one analysis
  path that imports jax (tracing only; nothing executes), so it loads
  lazily and ``verify_bundle(..., kernel=True)`` opts in explicitly.
* ``python -m repro.analysis.verify_all``: the registry sweep over every
  form x hardware entry x dtype x semiring (schedule layer, jax-free).
* ``python -m repro.analysis.conformance_all``: the same registry swept
  through the emitter — every kernel body traced and checked.

``kernels.ops.apply(..., verify=True)`` runs the schedule checks inline
(``verify="kernel"`` adds the body checks); results are LRU-cached on the
same normal-form keys as the schedules, so ``verify=False`` paths pay
nothing.
"""
from repro.analysis.verify import (Finding, VerificationError,
                                   reset_verification_cache, verify_bundle,
                                   verify_expr, verify_plan, verify_schedule,
                                   verify_sharded,
                                   verification_cache_stats)
from repro.analysis.jaxpr_lint import (COLLECTIVE_PRIMS, LintError,
                                       PLANNED_PRIMS, jaxpr_primitives, lint,
                                       lint_jaxpr, lint_rules)

#: conformance names resolved lazily — importing them pulls in jax, and the
#: schedule-layer verifier must stay importable without it
_CONFORMANCE_NAMES = ("KernelSummary", "kernel_findings", "summarize_kernel")


def __getattr__(name):
    if name in _CONFORMANCE_NAMES:
        from repro.analysis import conformance
        return getattr(conformance, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "COLLECTIVE_PRIMS",
    "Finding",
    "KernelSummary",
    "LintError",
    "PLANNED_PRIMS",
    "VerificationError",
    "jaxpr_primitives",
    "kernel_findings",
    "lint",
    "lint_jaxpr",
    "lint_rules",
    "reset_verification_cache",
    "summarize_kernel",
    "verification_cache_stats",
    "verify_bundle",
    "verify_expr",
    "verify_plan",
    "verify_schedule",
    "verify_sharded",
]
