"""Static verification of derived schedules, plans and emitted jaxprs.

The paper's claim is that static information — types, shapes, the lifted
psi-calculus indexing — fully determines a correct layout.  This package
makes "derived => correct" a *checkable* property without executing any
kernel:

* ``verify_schedule`` / ``verify_bundle`` / ``verify_plan``
  (``analysis.verify``): symbolic coverage/disjointness proofs over the
  grid x BlockSpec index maps, grid write-write race detection, pad-guard
  and pad-value (semiring inertness) checks, psi offset bounds, and the
  VMEM resource certificate recomputed at the real accumulation width.
* ``lint`` / ``lint_jaxpr`` (``analysis.jaxpr_lint``): a named-rule
  registry over traced jaxprs — ``no-transpose-copy``,
  ``no-oracle-recompute``, ``only-planned-collectives``,
  ``no-silent-fallback`` — replacing the ad-hoc scanners that used to be
  copy-pasted across the test files.
* ``python -m repro.analysis.verify_all``: the registry sweep over every
  form x hardware entry x dtype x semiring.

``kernels.ops.apply(..., verify=True)`` runs the schedule checks inline;
results are LRU-cached on the same normal-form keys as the schedules, so
``verify=False`` paths pay nothing.
"""
from repro.analysis.verify import (Finding, VerificationError,
                                   reset_verification_cache, verify_bundle,
                                   verify_expr, verify_plan, verify_schedule,
                                   verify_sharded,
                                   verification_cache_stats)
from repro.analysis.jaxpr_lint import (COLLECTIVE_PRIMS, LintError,
                                       PLANNED_PRIMS, jaxpr_primitives, lint,
                                       lint_jaxpr, lint_rules)

__all__ = [
    "COLLECTIVE_PRIMS",
    "Finding",
    "LintError",
    "PLANNED_PRIMS",
    "VerificationError",
    "jaxpr_primitives",
    "lint",
    "lint_jaxpr",
    "lint_rules",
    "reset_verification_cache",
    "verification_cache_stats",
    "verify_bundle",
    "verify_expr",
    "verify_plan",
    "verify_schedule",
    "verify_sharded",
]
