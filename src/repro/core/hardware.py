"""Hardware registry: one place that answers "what machine is this?".

The paper's derivation is parameterized by a hardware *shape* (the resource
hierarchy the lifted axes index).  At runtime the kernels additionally need a
*backend policy* — run compiled Pallas, run interpret-mode Pallas (the CPU
validation path), or fall back to the XLA oracle.  A ``HardwareEntry`` bundles
both, and ``detect_hardware`` probes the jax backend exactly once per process
(replacing the per-call ``jax.default_backend()`` probes the kernel wrappers
used to do), with an ``REPRO_HARDWARE`` env override for forcing an entry.

The registry is open: ``register_hardware`` adds entries for new chips, and
the schedule cache (repro.core.schedule) keys on the entry name, so two
entries never share schedules.
"""
from __future__ import annotations

import contextlib
import os
from dataclasses import dataclass
from functools import lru_cache
from typing import Iterator, Optional

from repro.core.lifting import (GPU_A100, HardwareShape, TPU_V5E,
                                TPU_V5E_2POD, V100)


@dataclass(frozen=True)
class HardwareEntry:
    """A registered machine: the array-view shape + kernel backend policy.

    ``backend``:
      * "pallas"    — compile Pallas kernels for the attached accelerator,
      * "interpret" — run the same kernels through the Pallas interpreter
                      (bit-level validation of the derived schedules on CPU),
      * "xla"       — no Pallas backend; the unified entry points
                      (``ops.matmul`` & co) use the jnp oracle instead.
    """
    name: str
    shape: HardwareShape
    backend: str
    description: str = ""

    def __post_init__(self):
        if self.backend not in ("pallas", "interpret", "xla"):
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def interpret(self) -> bool:
        """Whether Pallas kernels should run in interpret mode here."""
        return self.backend != "pallas"


_REGISTRY: dict[str, HardwareEntry] = {}


def register_hardware(entry: HardwareEntry) -> HardwareEntry:
    _REGISTRY[entry.name] = entry
    return entry


def get_entry(name: str) -> HardwareEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware entry {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_hardware() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


TPU_V5E_ENTRY = register_hardware(HardwareEntry(
    "tpu_v5e", TPU_V5E, "pallas", "TPU v5e pod slice (compiled Pallas)"))
TPU_V5E_2POD_ENTRY = register_hardware(HardwareEntry(
    "tpu_v5e_2pod", TPU_V5E_2POD, "pallas", "2-pod TPU v5e (compiled Pallas)"))
V100_ENTRY = register_hardware(HardwareEntry(
    "v100", V100, "xla", "the paper's V100 — block solver target, XLA exec"))
# The GPU (triton-Pallas) entry: derive_schedule / solve_blocks produce
# CUDA-shaped tiles from the A100 table (shared memory for VMEM, warp for
# the lane tile, tensor-core fragment for the MXU tile) under
# REPRO_HARDWARE=gpu.  CI has no GPU, so this entry is exercised by
# schedule-inspection tests only; execution on a real GPU compiles the
# same derived schedules through the Pallas triton lowering.
GPU_ENTRY = register_hardware(HardwareEntry(
    "gpu", GPU_A100, "pallas", "A100 SMs — triton-Pallas, derived CUDA tiles"))
# The CPU entry deliberately reuses the v5e hardware shape: interpret-mode
# Pallas then executes the *identical* derived schedule a v5e would compile,
# which is what makes CPU runs a bit-level validation of the TPU path.
CPU_ENTRY = register_hardware(HardwareEntry(
    "cpu", TPU_V5E, "interpret", "host CPU; v5e schedules via Pallas interpreter"))


@lru_cache(maxsize=1)
def _detected_name() -> str:
    import jax
    backend = jax.default_backend()
    if backend == "tpu":
        return "tpu_v5e"
    if backend == "gpu":
        return "v100"
    return "cpu"


_OVERRIDE: Optional[str] = None


def detect_hardware() -> HardwareEntry:
    """The active entry: explicit override > REPRO_HARDWARE env > probed."""
    if _OVERRIDE is not None:
        return get_entry(_OVERRIDE)
    env = os.environ.get("REPRO_HARDWARE")
    if env:
        return get_entry(env)
    return get_entry(_detected_name())


# ``current_hardware`` is the name the dispatch layer uses; ``detect_hardware``
# is the probing act.  They are the same callable today.
current_hardware = detect_hardware


def set_default_hardware(name: Optional[str]) -> None:
    """Force (or with None, un-force) the process-wide hardware entry."""
    global _OVERRIDE
    if name is not None:
        get_entry(name)                      # fail fast on typos
    _OVERRIDE = name


@contextlib.contextmanager
def use_hardware(name: str) -> Iterator[HardwareEntry]:
    """Scoped override, for tests and benchmarks."""
    prev = _OVERRIDE
    set_default_hardware(name)
    try:
        yield get_entry(name)
    finally:
        set_default_hardware(prev)
