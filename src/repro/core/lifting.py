"""Dimension lifting: the paper's bridge between data shapes and hardware shapes.

    "Dimension lifting is defined by systematically partitioning each shape
     component into 2, thus lifting the dimension of the problem as each
     partitioned shape is used to identify an architectural resource."
                                                        — Mullin 2023, Def 3.1

The hardware is itself an array.  ``HardwareShape`` declares the resource
hierarchy (axes with sizes, capacities, bandwidths and per-unit energies);
``lift`` splits logical axes so that each new outer axis indexes a resource
level.  A ``LiftedShape`` then *emits* the concrete artifacts each level
needs:

* mesh levels  -> ``jax.sharding.PartitionSpec`` entries (pjit/shard_map),
* vmem level   -> Pallas ``grid`` extents + ``BlockSpec`` block shapes,
* vreg level   -> alignment constraints ((8, 128) sublane×lane tiles).

This file is pure Python + dataclasses (no jax import at module top except
for types used lazily) so importing it never touches device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.moa import pi

# ---------------------------------------------------------------------------
# hardware constants — the "relevant numbers" table (paper Table 1), for TPU
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MemoryLevel:
    name: str
    capacity_bytes: int            # per unit
    bandwidth_Bps: float           # bytes/second into the level below
    energy_pJ_per_byte: float      # access energy (model; relative scale)


@dataclass(frozen=True)
class HardwareShape:
    """An array-view of the machine: hierarchy of resource axes.

    ``mesh_axes`` are the *distribution* levels (lifted axes become named mesh
    axes for pjit); ``grid_axes`` are the on-chip levels (lifted axes become
    Pallas grid dimensions); alignment is the register/MXU tile.
    """
    name: str
    mesh_axes: tuple[tuple[str, int], ...]        # e.g. (("pod",2),("data",16),("model",16))
    vmem: MemoryLevel
    hbm: MemoryLevel
    ici_Bps: float                                # per-link bandwidth
    ici_energy_pJ_per_byte: float
    peak_flops: float                             # per chip, bf16
    flop_energy_pJ: float                         # per FLOP (model)
    mxu_tile: tuple[int, int] = (128, 128)
    vreg_tile: tuple[int, int] = (8, 128)
    sa_power_W: float = 200.0                     # static+active power scale for energy model
    #: accumulation dtypes this part's matrix unit supports (names resolved
    #: through ``core.semiring.accum_def``); every entry keeps f32, and the
    #: MXU-era parts add the bf16 partial-sum and int8->int32 paths.
    acc_dtypes: tuple = ("float32", "bfloat16", "int32")

    @property
    def n_chips(self) -> int:
        return pi([s for _, s in self.mesh_axes])

    def mesh_axis_names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.mesh_axes)

    def mesh_shape(self) -> tuple[int, ...]:
        return tuple(s for _, s in self.mesh_axes)


# TPU v5e, per task statement: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
# ICI.  VMEM ~128 MiB on v5e? (v5e has 128MB? v4: 128MiB? ) -- v5e VMEM is
# 128 MiB total? Public spec: TPU v5e has 16 GiB HBM @819GBps and ~100 MiB
# on-chip VMEM is not published; we adopt 64 MiB usable VMEM budget per core
# half of which we leave for double-buffering headroom.  The *solver* takes
# the budget as a parameter so this constant is not load-bearing for
# correctness, only for default block choices.
TPU_V5E = HardwareShape(
    name="tpu_v5e",
    mesh_axes=(("data", 16), ("model", 16)),
    vmem=MemoryLevel("vmem", capacity_bytes=64 * 2**20, bandwidth_Bps=4e12,
                     energy_pJ_per_byte=0.06),
    hbm=MemoryLevel("hbm", capacity_bytes=16 * 2**30, bandwidth_Bps=819e9,
                    energy_pJ_per_byte=5.0),
    ici_Bps=50e9,
    ici_energy_pJ_per_byte=10.0,
    peak_flops=197e12,
    flop_energy_pJ=0.25,
)

TPU_V5E_2POD = dataclasses.replace(
    TPU_V5E, mesh_axes=(("pod", 2), ("data", 16), ("model", 16)))

# A GPU target for the triton-Pallas backend: VMEM's analogue is the SM's
# shared memory (A100: 164 KiB usable per SM, of which we expose the 192 KiB
# carveout's usable slice), the MXU tile's analogue is the tensor-core
# m16n16 fragment, and the (sublane, lane) register tile's analogue is a
# warp of 32 lanes.  The same a-priori solver, pointed at this table,
# produces CUDA-shaped tiles (multiples of 16/32, far smaller than the v5e's
# 512-class blocks) — see tests/test_recurrence.py.
GPU_A100 = HardwareShape(
    name="gpu_a100",
    mesh_axes=(("sm", 108),),
    vmem=MemoryLevel("smem", capacity_bytes=164 * 2**10, bandwidth_Bps=1.9e13,
                     energy_pJ_per_byte=0.09),
    hbm=MemoryLevel("hbm", capacity_bytes=40 * 2**30, bandwidth_Bps=1555e9,
                    energy_pJ_per_byte=4.0),
    ici_Bps=600e9,                # NVLink3 aggregate
    ici_energy_pJ_per_byte=8.0,
    peak_flops=312e12,            # bf16 tensor core
    flop_energy_pJ=0.4,
    mxu_tile=(16, 16),            # tensor-core m16n16k16 fragment
    vreg_tile=(1, 32),            # one warp, coalesced 32-lane accesses
)

# the paper's V100 (Table 1) for cross-validation of the block solver
V100 = HardwareShape(
    name="v100",
    mesh_axes=(("sm", 80),),
    vmem=MemoryLevel("l1", capacity_bytes=32 * 2**10, bandwidth_Bps=1.2e13,
                     energy_pJ_per_byte=0.1),
    hbm=MemoryLevel("global", capacity_bytes=16 * 2**30, bandwidth_Bps=900e9,
                    energy_pJ_per_byte=6.0),
    ici_Bps=32e9,                 # NVLink-ish
    ici_energy_pJ_per_byte=12.0,
    peak_flops=7.8e12,            # fp64
    flop_energy_pJ=6.0,
    mxu_tile=(1, 1),              # no systolic alignment for CUDA cores
    vreg_tile=(1, 8),             # warp-coalesced groups of 8 doubles
    acc_dtypes=("float32",),      # CUDA-core FMA: f32 partial sums only
)


# ---------------------------------------------------------------------------
# lifted shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LiftedAxis:
    """One logical axis after lifting: ordered (outer..inner) factors, each
    tagged with the resource it indexes.  ``None`` resource = stays a plain
    loop/data axis at that level."""
    name: str                       # logical axis name, e.g. "batch", "d_ff"
    size: int
    factors: tuple[tuple[Optional[str], int], ...]   # ((resource|None, extent), ...)

    def __post_init__(self):
        if pi([e for _, e in self.factors]) != self.size:
            raise ValueError(
                f"lifting of {self.name}: factors {self.factors} do not "
                f"multiply to {self.size}")

    def resource_extent(self, resource: str) -> int:
        for r, e in self.factors:
            if r == resource:
                return e
        return 1

    @property
    def innermost(self) -> int:
        return self.factors[-1][1]


@dataclass(frozen=True)
class LiftedShape:
    """A full lifted operand/loop-nest shape + emitters."""
    axes: tuple[LiftedAxis, ...]
    hardware: HardwareShape

    # ---- emitters -------------------------------------------------------
    def partition_spec(self):
        """PartitionSpec naming, per logical axis, the mesh resources it was
        lifted over (outer factors only; grid/loop factors are not sharded)."""
        from jax.sharding import PartitionSpec
        mesh_names = set(self.hardware.mesh_axis_names())
        entries = []
        for ax in self.axes:
            shards = tuple(r for r, _ in ax.factors if r in mesh_names)
            if not shards:
                entries.append(None)
            elif len(shards) == 1:
                entries.append(shards[0])
            else:
                entries.append(shards)
        # trim trailing Nones (canonical form)
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    def grid(self) -> tuple[int, ...]:
        """Pallas grid extents: product of every 'grid'-tagged factor per axis
        (axes with none contribute nothing)."""
        g = []
        for ax in self.axes:
            e = ax.resource_extent("grid")
            if e > 1:
                g.append(e)
        return tuple(g)

    def block_shape(self) -> tuple[int, ...]:
        """Per-axis innermost (VMEM-resident) extents."""
        return tuple(ax.innermost for ax in self.axes)

    def local_shape(self) -> tuple[int, ...]:
        """Shape of the per-chip shard (after removing mesh factors)."""
        mesh_names = set(self.hardware.mesh_axis_names())
        out = []
        for ax in self.axes:
            s = ax.size
            for r, e in ax.factors:
                if r in mesh_names:
                    s //= e
            out.append(s)
        return tuple(out)


def lift(axis_name: str, size: int, splits: Sequence[tuple[Optional[str], int]],
         ) -> LiftedAxis:
    """Lift one axis: ``splits`` lists (resource, extent) outer-to-inner for
    every factor *except* the innermost remainder, which is computed.

    lift("i", 4096, [("pod", 2), ("data", 16)]) ->
        factors (("pod",2), ("data",16), (None, 128))
    """
    rem = size
    for r, e in splits:
        if rem % e:
            raise ValueError(
                f"cannot lift axis {axis_name}={size}: factor {r}={e} does not "
                f"divide remaining extent {rem}")
        rem //= e
    return LiftedAxis(axis_name, size, tuple(splits) + ((None, rem),))


def lift_shape(hardware: HardwareShape,
               axes: Sequence[tuple[str, int, Sequence[tuple[Optional[str], int]]]]
               ) -> LiftedShape:
    return LiftedShape(tuple(lift(n, s, sp) for n, s, sp in axes), hardware)


# ---------------------------------------------------------------------------
# canonical liftings for the framework's tensors
# ---------------------------------------------------------------------------

def batch_lifting(hardware: HardwareShape, batch: int, *rest: tuple[str, int]
                  ) -> LiftedShape:
    """Lift the batch axis over all data-parallel mesh axes (pod, data);
    remaining axes unlifted.  This is the activation sharding rule."""
    dp_axes = [(n, s) for n, s in hardware.mesh_axes if n in ("pod", "data")]
    axes = [("batch", batch, [(n, s) for n, s in dp_axes])]
    axes += [(n, s, []) for n, s in rest]
    return lift_shape(hardware, axes)


def model_lifting(hardware: HardwareShape, axis_name: str, size: int,
                  *rest: tuple[str, int]) -> LiftedShape:
    """Lift a feature axis over the model mesh axis (tensor parallelism)."""
    tp = dict(hardware.mesh_axes).get("model", 1)
    axes = [(axis_name, size, [("model", tp)] if tp > 1 else [])]
    axes += [(n, s, []) for n, s in rest]
    return lift_shape(hardware, axes)
