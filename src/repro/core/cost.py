"""Roofline cost model + HLO collective-byte accounting.

Three-term roofline per (architecture x mesh), per the task spec:

    compute    = HLO_FLOPs        / (chips * peak_FLOP/s)
    memory     = HLO_bytes        / (chips * HBM_Bps)
    collective = collective_bytes / (chips * link_Bps)

``compiled.cost_analysis()`` provides FLOPs and bytes accessed;
collective bytes are NOT in cost_analysis, so ``collective_bytes_from_hlo``
parses the post-partitioning HLO text and sums operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(including their -start async forms and -done pairs counted once).

NOTE ON SPMD ACCOUNTING: jax returns the *per-device* SPMD module from
``compiled.as_text()`` (shapes are shard shapes), and ``cost_analysis``
likewise reports the per-device program.  The roofline formulas above expect
*global* quantities, so callers multiply per-device figures by ``n_chips``
(see ``Roofline.from_compiled``) — the two chip factors then cancel into
"per-chip time", which is what a roofline term is.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, asdict

from repro.core.lifting import HardwareShape, TPU_V5E

# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

# one shaped-buffer literal, e.g. bf16[16,2048]{1,0} or f32[] or pred[4]
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
# an HLO instruction definition: "%name = <type> opcode(...)"
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+|[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)",
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all shaped buffers appearing in a type string
    (handles tuples by summing members)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)     # opcode -> operand bytes
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in an HLO text dump.

    Strategy: build a symbol table name -> result bytes from every
    instruction definition; for each collective instruction, sum the sizes of
    its operands (prefer inline operand shapes when the dump includes them,
    fall back to the symbol table).  Async pairs: count ``-start`` and skip
    the matching ``-done``; skip ``-update`` forms.
    """
    stats = CollectiveStats()
    symtab: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, operands = m.groups()
        symtab[name.lstrip("%")] = _shape_bytes(type_str)
        base = opcode
        for c in _COLLECTIVE_OPS:
            if opcode == c or opcode == c + "-start":
                base = c
                break
        else:
            continue
        if opcode.endswith(("-done", "-update")):
            continue
        # operand bytes: inline shapes if present, else symbol-table lookup
        inline = _shape_bytes(operands)
        if inline == 0:
            for op_name in re.findall(r"%([\w.\-]+)", operands):
                inline += symtab.get(op_name, 0)
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + inline
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


def wire_bytes(stats: CollectiveStats, n_chips: int) -> float:
    """Bytes actually crossing links per chip, with per-algorithm multipliers
    (ring algorithms):  all-reduce 2(N-1)/N, all-gather/reduce-scatter
    (N-1)/N, all-to-all (N-1)/N, permute 1.  Used for the *modeled* term;
    the headline spec term uses the raw operand sum."""
    f = (n_chips - 1) / max(n_chips, 1)
    mult = {
        "all-reduce": 2.0 * f,
        "all-gather": f,
        "reduce-scatter": f,
        "all-to-all": f,
        "ragged-all-to-all": f,
        "collective-broadcast": f,
        "collective-permute": 1.0,
    }
    return sum(b * mult.get(op, 1.0) for op, b in stats.bytes_by_op.items())


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    """Three roofline terms (seconds) + provenance."""
    name: str
    n_chips: int
    global_flops: float
    global_hbm_bytes: float
    collective_op_bytes: float          # raw operand sum (spec headline)
    collective_wire_bytes: float        # ring-modeled per-chip wire bytes
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0            # 6*N*D (or 6*N_active*D) if provided
    collectives: dict = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) step time = max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_time_noverlap_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.global_flops if self.global_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step ran at the
        (overlapped) modeled time: useful-FLOPs MFU upper bound."""
        if self.step_time_s <= 0:
            return 0.0
        useful = self.model_flops or self.global_flops
        per_chip = useful / self.n_chips
        return per_chip / self.step_time_s / _PEAK_FLOPS_CACHE

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(dominant=self.dominant, step_time_s=self.step_time_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


_PEAK_FLOPS_CACHE = TPU_V5E.peak_flops   # set per-call in from_quantities


def from_quantities(name: str, *, n_chips: int, per_device_flops: float,
                    per_device_hbm_bytes: float, collective_stats: CollectiveStats,
                    hardware: HardwareShape = TPU_V5E,
                    model_flops: float = 0.0) -> Roofline:
    """Build roofline terms from per-device SPMD quantities (see module
    docstring for the chips-cancellation note)."""
    global _PEAK_FLOPS_CACHE
    _PEAK_FLOPS_CACHE = hardware.peak_flops
    gflops = per_device_flops * n_chips
    gbytes = per_device_hbm_bytes * n_chips
    op_bytes = collective_stats.total_bytes * n_chips      # global operand sum
    wire = wire_bytes(collective_stats, n_chips)           # per-chip wire bytes
    return Roofline(
        name=name, n_chips=n_chips,
        global_flops=gflops, global_hbm_bytes=gbytes,
        collective_op_bytes=op_bytes,
        collective_wire_bytes=wire,
        compute_s=gflops / (n_chips * hardware.peak_flops),
        memory_s=gbytes / (n_chips * hardware.hbm.bandwidth_Bps),
        # spec formula: raw operand bytes / (chips * link_bw)
        collective_s=op_bytes / (n_chips * hardware.ici_Bps),
        model_flops=model_flops,
        collectives=dict(collective_stats.bytes_by_op),
    )


def model_flops_lm(n_params: int, n_tokens: int, *, active_params: int | None = None,
                   training: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D for training (2 fwd + 4 bwd), 2*N*D for inference;
    MoE uses active params."""
    n = active_params if active_params is not None else n_params
    return (6.0 if training else 2.0) * n * n_tokens
