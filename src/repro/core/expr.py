"""A lazy MoA expression algebra: compose, then normalize (DNF -> ONF).

This is the paper's front door made literal.  Instead of dispatching kernels
on hand-written string op names, callers *compose* an expression —

    inner("add", "mul", arr("A", (m, k)), arr("B", (k, n)))          # GEMM
    inner("add", "mul", arr("A", (m, k)), transpose(arr("B", (n, k))))
                                                     # x @ w.T, no relayout
    inner("min", "add", arr("D", (n, n)), arr("D", (n, n)))
                                                     # min-plus shortest path

— and ``normalize`` psi-reduces the composed Cartesian indexing into the flat
affine ``Access`` coefficients of an ONF loop nest (paper eq. 3/4),
*generically*: transposes and psi views rewrite the index mapping, each
leaf's gamma layout (row- or column-major) turns Cartesian indices into flat
strides, and the semiring (combine/reduce names in ``core.semiring``) rides
along symbolically.  The resulting ``Onf`` is everything downstream:

* its ``execute`` is the semantic oracle,
* its ``key()`` is the schedule-cache key (``core.schedule.get_schedule``),
* dimension-lifting it (``onf.lift_loop``) derives the Pallas program.

Nodes are frozen dataclasses; the module is pure Python + numpy-free on the
hot path (no jax import), so composing and normalizing expressions never
touches device state.

The expression language is deliberately exactly as big as ONF: one combine
op, one reduce op, affine indexing.  Anything larger (softmax, data-dependent
gathers) is not an ONF and is rejected at ``normalize`` time.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

from repro.core import semiring
from repro.core.onf import Access, Loop, Onf

Shape = Tuple[int, ...]

#: index terms flowing through psi reduction: a loop symbol or a fixed int
_Sym = str
_Term = Union[_Sym, int]

#: (combine, reduce) pairs where combine distributes over reduce — the
#: semiring law that makes hoisting a nested reduction out of a combine
#: operand sound (normalize rejects hoists outside this set)
_DISTRIBUTIVE = frozenset({("mul", "add"), ("add", "max"), ("add", "min")})


class Expr:
    """Base class.  ``shape`` is defined per node; operators give sugar:
    ``a @ b`` is the (add, mul) inner product, ``a * b`` / ``a + b`` the
    pointwise combines, ``a.T`` the matrix transpose."""

    shape: Shape = ()

    def __matmul__(self, other: "Expr") -> "Expr":
        return inner("add", "mul", self, other)

    def __mul__(self, other: "Expr") -> "Expr":
        return combine("mul", self, other)

    def __add__(self, other: "Expr") -> "Expr":
        return combine("add", self, other)

    @property
    def T(self) -> "Expr":
        return transpose(self)


@dataclass(frozen=True)
class Arr(Expr):
    """A leaf: named array of a shape, stored through a gamma layout."""
    name: str
    shape: Shape
    layout: str = "row"                    # "row" (gamma_row) | "col" (gamma_col)

    def __post_init__(self):
        if self.layout not in ("row", "col"):
            raise ValueError(f"unknown layout {self.layout!r} (row|col)")
        if any(int(s) <= 0 for s in self.shape):
            raise ValueError(f"non-positive extent in shape {self.shape}")


@dataclass(frozen=True)
class Transpose(Expr):
    """Axis permutation — a pure index rewrite, never a data movement."""
    x: Expr
    perm: Tuple[int, ...]

    def __post_init__(self):
        if sorted(self.perm) != list(range(len(self.x.shape))):
            raise ValueError(
                f"perm {self.perm} is not a permutation of rank "
                f"{len(self.x.shape)}")

    @property
    def shape(self) -> Shape:                        # type: ignore[override]
        return tuple(self.x.shape[p] for p in self.perm)


@dataclass(frozen=True)
class Psi(Expr):
    """A psi view: leading Cartesian indices fixed to constants (MoA's sole
    indexing primitive).  Lowers to a constant term in the flat Access."""
    idx: Tuple[int, ...]
    x: Expr

    def __post_init__(self):
        if len(self.idx) > len(self.x.shape):
            raise IndexError(f"psi index {self.idx} longer than shape "
                             f"{self.x.shape}")
        for axis, (i, s) in enumerate(zip(self.idx, self.x.shape)):
            if not 0 <= i < s:
                raise IndexError(f"psi index {self.idx} invalid at axis "
                                 f"{axis} for shape {self.x.shape}")

    @property
    def shape(self) -> Shape:                        # type: ignore[override]
        return self.x.shape[len(self.idx):]


@dataclass(frozen=True)
class Combine(Expr):
    """Pointwise pairing of two same-shape expressions."""
    op: str
    a: Expr
    b: Expr

    def __post_init__(self):
        semiring.combine_def(self.op)                # fail fast on typos
        if self.a.shape != self.b.shape:
            raise ValueError(f"combine({self.op}) shape mismatch "
                             f"{self.a.shape} vs {self.b.shape}")

    @property
    def shape(self) -> Shape:                        # type: ignore[override]
        return self.a.shape


@dataclass(frozen=True)
class Reduce(Expr):
    """Fold one axis with a reduce op."""
    op: str
    x: Expr
    axis: int

    def __post_init__(self):
        semiring.reduce_def(self.op)
        if not 0 <= self.axis < len(self.x.shape):
            raise ValueError(f"reduce axis {self.axis} out of range for "
                             f"shape {self.x.shape}")

    @property
    def shape(self) -> Shape:                        # type: ignore[override]
        s = self.x.shape
        return s[:self.axis] + s[self.axis + 1:]


@dataclass(frozen=True)
class Inner(Expr):
    """Generalized inner product (Mullin & Raynolds, arXiv:0907.0792):
    ``reduce(plus)`` over the pairing ``times`` of a's last axis with b's
    first (after ``batch`` shared leading axes — the lifted expert axis)."""
    plus: str
    times: str
    a: Expr
    b: Expr
    batch: int = 0

    def __post_init__(self):
        semiring.reduce_def(self.plus)
        semiring.combine_def(self.times)
        sa, sb = self.a.shape, self.b.shape
        nb = self.batch
        if nb < 0 or len(sa) < nb + 1 or len(sb) < nb + 1:
            raise ValueError(f"inner: ranks {sa} x {sb} too small for "
                             f"batch={nb}")
        if sa[:nb] != sb[:nb]:
            raise ValueError(f"inner: batch axes differ {sa[:nb]} vs {sb[:nb]}")
        if sa[-1] != sb[nb]:
            raise ValueError(f"inner: contraction mismatch {sa} . {sb}")

    @property
    def shape(self) -> Shape:                        # type: ignore[override]
        sa, sb = self.a.shape, self.b.shape
        return sa[:-1] + sb[self.batch + 1:]


# ---------------------------------------------------------------------------
# public constructors (the API surface named by the redesign)
# ---------------------------------------------------------------------------

def arr(name: str, shape: Sequence[int], layout: str = "row") -> Arr:
    return Arr(name, tuple(int(s) for s in shape), layout)


def transpose(x: Expr, perm: Optional[Sequence[int]] = None) -> Transpose:
    if perm is None:
        perm = tuple(reversed(range(len(x.shape))))
    return Transpose(x, tuple(int(p) for p in perm))


def psi(idx: Sequence[int], x: Expr) -> Expr:
    idx = tuple(int(i) for i in idx)
    return x if not idx else Psi(idx, x)


def combine(op: str, a: Expr, b: Expr) -> Combine:
    return Combine(op, a, b)


def reduce(op: str, x: Expr, axis: int = 0) -> Reduce:
    return Reduce(op, x, int(axis))


def inner(plus: str, times: str, a: Expr, b: Expr, batch: int = 0) -> Inner:
    return Inner(plus, times, a, b, int(batch))


def matmul_expr(m: int, k: int, n: int, transpose_b: bool = False,
                a_name: str = "A", b_name: str = "B") -> Inner:
    """The canonical 2-D matmul expressions the kernel layer dispatches on.

    With ``transpose_b`` the second operand is the *stored* (n, k) array read
    through its transpose — normalize turns that into column-gamma
    coefficients on B, i.e. a transposed-operand schedule with no relayout
    copy."""
    b = transpose(arr(b_name, (n, k))) if transpose_b else arr(b_name, (k, n))
    return inner("add", "mul", arr(a_name, (m, k)), b)


def expert_gemm_expr(e: int, cap: int, d: int, f: int) -> Inner:
    """The capacity-padded expert GEMM: a batch-1 generalized inner product.
    The single definition shared by ``kernels.ops``, the deprecated string
    dispatch and ``onf.expert_gemm_onf`` — one source, one cache line."""
    return inner("add", "mul", arr("X", (e, cap, d)), arr("W", (e, d, f)),
                 batch=1)


def hadamard_expr(m: int, n: int) -> Combine:
    """Elementwise product — the contraction-degenerate circuit member."""
    return combine("mul", arr("A", (m, n)), arr("B", (m, n)))


def head_gemm_expr(h: int, m: int, k: int, n: int,
                   transpose_b: bool = False) -> Inner:
    """Per-head batched GEMM over a head-MIDDLE weight — the MLA decode
    contractions (``bshr,rhn->bshn`` and its transposed dual).

    Both leaves are read in *stored* layout through transposed views (pure
    index rewrites): X binds its stored ``(m, h, k)`` activation block, W
    the stored ``(k, h, n)`` table (``(n, h, k)`` when ``transpose_b``).
    normalize turns the permutations into strided-but-dense coefficients,
    so the derived schedule blocks both buffers in place.  Result shape
    ``(h, m, n)``.
    """
    x = transpose(arr("X", (m, h, k)), (1, 0, 2))
    w = transpose(arr("W", (n, h, k)), (1, 2, 0)) if transpose_b \
        else transpose(arr("W", (k, h, n)), (1, 0, 2))
    return inner("add", "mul", x, w, batch=1)


def attention_expr(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                   vd: Optional[int] = None) -> tuple[Inner, Inner]:
    """The two chained contractions of (grouped-query) attention.

    ``scores = Q · Kᵀ`` and ``context = P · V``, over the loop axes
    ``(b, h, g, i, j)`` — batch, kv-head, group, query position, key
    position.  Every leaf binds its *stored* model layout — Q
    ``(b, sq, hkv, g, hd)`` (the grouped view of the ``(b, sq, hq, hd)``
    projection, a pure reshape with ``hq = hkv * g``), K/V their
    un-repeated ``(b, sk, hkv, hd)`` — and the logical ``(b, h, g, i, ...)``
    views are transposes, i.e. pure index rewrites: the derived BlockSpecs
    walk the stored buffers in place, no relayout copy before the kernel
    (the same property as ``matmul(transpose_b=True)``).  The GQA head
    grouping is nothing but an Access coefficient pattern: K/V carry a
    *zero* coefficient on the group axis ``g``, which is exactly what lets
    ``derive_schedule`` recover the q-head -> kv-head index map instead of
    hand-coding the ``(h % hq) // g`` arithmetic.

    The middle operand ``P`` (the softmax probabilities) is never
    materialized — it is the in-VMEM intermediate a streaming schedule
    carries between the two contractions (see ``attention_form``).
    """
    vd = vd or hd
    q = transpose(arr("Q", (b, sq, hkv, g, hd)), (0, 2, 3, 1, 4))
    kt = transpose(arr("K", (b, sk, hkv, hd)), (0, 2, 3, 1))
    v = transpose(arr("V", (b, sk, hkv, vd)), (0, 2, 1, 3))
    p = arr("P", (b, hkv, g, sq, sk))
    scores = inner("add", "mul", q, kt, batch=2)
    context = inner("add", "mul", p, v, batch=2)
    return scores, context


@dataclass(frozen=True)
class StateSpec:
    """The typed carried-state monoid of a recurrence: ``kind`` names a
    registered init/step/flush body (``kernels.emit`` resolves it — the
    nonlinearity is the kind's business exactly as a semiring name resolves
    to a combine), ``carried`` declares each scratch array as (name, logical
    axes), ``rescale`` marks that every step multiplies the carried state by
    a data-dependent factor (online softmax's ``exp(m_prev - m_new)``,
    SSD's chunk decay, RG-LRU's gate product), and ``exports`` makes the
    final state a kernel output (the SSM/LRU decode caches).

    ``export_names`` restricts *which* carried arrays export (empty = all);
    ``per_step`` names carried arrays exported once **per streamed step**
    rather than once at the end — their output operands gain the streamed
    axis, block-1 and grid-indexed, so each step writes its own slab (the
    forward-pass statistics and per-chunk checkpoints the derived backward
    kernels consume)."""
    kind: str
    carried: Tuple[Tuple[str, Tuple[str, ...]], ...]
    rescale: bool = True
    exports: bool = False
    export_names: Tuple[str, ...] = ()
    per_step: Tuple[str, ...] = ()

    def key(self) -> tuple:
        return (self.kind, self.carried, self.rescale, self.exports,
                self.export_names, self.per_step)

    def exported(self) -> Tuple[Tuple[str, Tuple[str, ...]], ...]:
        """The carried entries that become kernel outputs, in carried
        order (``export_names`` filters; empty means all)."""
        if not self.exports:
            return ()
        if not self.export_names:
            return self.carried
        return tuple(c for c in self.carried if c[0] in self.export_names)


#: the online-softmax monoid: running max + denominator per output row, plus
#: the rescaled accumulator — flash attention's carried state
SOFTMAX_STATE = StateSpec("online_softmax",
                          (("m", ("row",)), ("l", ("row",)),
                           ("acc", ("row", "val"))))

#: the SSD (Mamba-2) monoid: one inter-chunk state h per (head, head_dim,
#: state_dim), stepped ``h' = chunk_decay * h + B'(decay . x)`` and exported
#: as the decode cache
SSD_STATE = StateSpec("ssd", (("h", ("h", "p", "n")),), exports=True)

#: the RG-LRU gated monoid: one state per channel, ``h' = a h + b``
GATED_STATE = StateSpec("gated", (("h", ("w",)),), exports=True)


@dataclass(frozen=True)
class RecurrentForm:
    """The composite normal form of a *carried-state recurrence*: N
    single-ONF stages welded through one streamed axis, plus the typed
    monoid the stream carries (``StateSpec``).

    Two shapes of weld, both instances of the same contract:

    * **folding** (online softmax): the streamed axis is an *output* axis of
      the first stage and the sole *reduction* of the last — each streamed
      step computes one block of the intermediate and folds it into the
      carried (m, l, acc) state.  The intermediate (the first leaf of the
      next stage) never leaves VMEM.
    * **chunked scan** (SSD, RG-LRU): the streamed axis is an *output* axis
      of every stage — the sequence axis dimension-lifted ``S -> (chunks,
      chunk_len)`` with the chunk index streamed.  Each step emits its own
      output block and steps the carried state (the inter-chunk ``h``
      recurrence); the state is optionally exported as a final output.

    ``aux`` declares extra operands consumed only by the state monoid (the
    SSD decay inputs ``dA``, the initial state) — they get derived
    BlockSpecs like any stage leaf.  ``window``/``prefix_len`` are
    streamed-axis masking metadata: the emitter derives its block-skip and
    in-block masks from them, so windowed / prefix-LM attention schedules
    are derived rather than falling back to the chunked jnp path.

    ``page_table``/``paged``/``pool_pages`` make the streamed axis a *psi
    view over paged storage*: each leaf named in ``paged`` binds one pool
    buffer of ``pool_pages`` fixed-size slabs (slab length = the streamed
    block), and streamed step ``k`` reads slab ``page_table[k]`` — the
    per-page ``Access.const`` offsets of an index-0 psi view, lowered as a
    static table lookup in the operand's BlockSpec index map instead of a
    gather-copy.  The table is static metadata (it changes only when the
    serving engine allocates a page, never per token) and rides ``key()``.

    This is the artifact ``core.schedule.get_schedule`` accepts alongside a
    plain ``NormalForm``; its ``key()`` keys the same LRU cache.
    """
    name: str
    stages: Tuple[NormalForm, ...]
    stream_axis: str
    state: StateSpec
    aux: Tuple[LeafSpec, ...] = ()
    window: int = 0
    prefix_len: int = 0
    page_table: Tuple[int, ...] = ()
    paged: Tuple[str, ...] = ()
    pool_pages: int = 0
    slot_axis: str = ""

    def __post_init__(self):
        if not self.stages:
            raise ValueError("a RecurrentForm needs at least one stage")
        ext: dict[str, int] = {}
        for nf in self.stages:
            for sym, e in nf.extent_map.items():
                if ext.setdefault(sym, e) != e:
                    raise ValueError(
                        f"axis {sym!r} disagrees between stages "
                        f"({ext[sym]} vs {e})")
        if self.stream_axis not in self.stages[0].out_axes:
            raise ValueError(
                f"stream axis {self.stream_axis!r} is not an output axis of "
                f"the first stage {self.stages[0].out_axes}")
        if self.folding:
            if len(self.stages) < 2:
                raise ValueError("a folding recurrence chains >= 2 stages")
            if self.stages[-1].reduce_axes != (self.stream_axis,):
                raise ValueError(
                    f"the last stage must reduce exactly the stream axis "
                    f"{self.stream_axis!r}, got {self.stages[-1].reduce_axes}")
        else:
            for nf in self.stages:
                if self.stream_axis not in nf.out_axes:
                    raise ValueError(
                        f"chunked-scan stream axis {self.stream_axis!r} must "
                        f"be an output axis of every stage, missing from "
                        f"{nf.out_axes}")
        for prev, nxt in zip(self.stages, self.stages[1:]):
            carrier = nxt.leaves[0]
            c_syms = tuple(t for t, _ in carrier.dims if isinstance(t, str))
            missing = [s for s in prev.out_axes if s not in c_syms]
            if missing:
                raise ValueError(
                    f"stage {nxt.name!r}'s carrier leaf {c_syms} does not "
                    f"cover the previous output axes (missing {missing}) — "
                    "not a welded chain")
            c_ext = dict((t, e) for t, e in carrier.dims
                         if isinstance(t, str))
            for s in prev.out_axes:
                if c_ext[s] != ext[s]:
                    raise ValueError(
                        f"carrier extent of {s!r} ({c_ext[s]}) disagrees "
                        f"with the stage extent ({ext[s]})")
        if (self.window or self.prefix_len) and self.window < 0:
            raise ValueError(f"negative window {self.window}")
        if self.page_table or self.paged or self.pool_pages:
            if not (self.page_table and self.paged and self.pool_pages > 0):
                raise ValueError(
                    "paged streaming needs all three of page_table / paged "
                    "leaf names / pool_pages")
            stacked = bool(self.page_table) and isinstance(
                self.page_table[0], tuple)
            if stacked != bool(self.slot_axis):
                raise ValueError(
                    "a stacked [slot, k] page table and slot_axis come "
                    "together: got "
                    f"slot_axis={self.slot_axis!r}, stacked={stacked}")
            if stacked:
                widths = {len(row) for row in self.page_table}
                if len(widths) != 1:
                    raise ValueError(
                        f"stacked page table is ragged: row lengths {widths}")
                if self.slot_axis == self.stream_axis:
                    raise ValueError(
                        f"slot axis {self.slot_axis!r} cannot be the "
                        "streamed axis")
                for nf in self.stages:
                    if self.slot_axis not in nf.out_axes:
                        raise ValueError(
                            f"slot axis {self.slot_axis!r} must be a lifted "
                            f"output axis of every stage, missing from "
                            f"{nf.out_axes}")
                if len(self.page_table) != ext.get(self.slot_axis):
                    raise ValueError(
                        f"stacked page table names {len(self.page_table)} "
                        f"slots but axis {self.slot_axis!r} has extent "
                        f"{ext.get(self.slot_axis)}")
                entries = [t for row in self.page_table for t in row]
            else:
                entries = list(self.page_table)
            bad = [t for t in entries
                   if not 0 <= int(t) < self.pool_pages]
            if bad:
                raise ValueError(
                    f"page-table entries {bad} outside the pool "
                    f"[0, {self.pool_pages})")
            leaf_names = {l.array for nf in self.stages for l in nf.leaves}
            missing = [a for a in self.paged if a not in leaf_names]
            if missing:
                raise ValueError(
                    f"paged leaves {missing} are not stage leaves")
            for nf in self.stages:
                for l in nf.leaves:
                    if l.array not in self.paged:
                        continue
                    if not l.dims or l.dims[0][0] != self.stream_axis:
                        raise ValueError(
                            f"paged leaf {l.array!r} must store the streamed "
                            f"axis {self.stream_axis!r} as its leading dim, "
                            f"got {l.dims}")
                    if self.slot_axis and any(
                            t == self.slot_axis for t, _ in l.dims):
                        raise ValueError(
                            f"paged leaf {l.array!r} must not carry the slot "
                            f"axis {self.slot_axis!r}: the pool is shared "
                            "storage, slots address it through the stacked "
                            "table")

    @property
    def folding(self) -> bool:
        """True for the online-softmax shape (stream axis folded by the last
        stage); False for the chunked-scan shape (stream axis an output)."""
        return self.stream_axis in self.stages[-1].reduce_axes

    # compat accessors for the two-stage streaming (attention) instance
    @property
    def scores(self) -> NormalForm:
        return self.stages[0]

    @property
    def context(self) -> NormalForm:
        return self.stages[-1]

    def extent_map(self) -> dict[str, int]:
        ext: dict[str, int] = {}
        for nf in self.stages:
            ext.update(nf.extent_map)
        for leaf in self.aux:
            for t, e in leaf.dims:
                if isinstance(t, str):
                    ext.setdefault(t, e)
        return ext

    def key(self) -> tuple:
        """Cache key: every stage's canonical key, the stream axis's
        structural position, the state monoid and the masking metadata."""
        return ("recurrent", tuple(nf.key() for nf in self.stages),
                self.stages[0].out_axes.index(self.stream_axis),
                self.state.key(),
                tuple((l.array, l.dims, l.layout) for l in self.aux),
                self.window, self.prefix_len,
                self.page_table, self.paged, self.pool_pages,
                self.slot_axis)


def StreamingForm(name: str, scores: NormalForm, context: NormalForm,
                  stream_axis: str) -> RecurrentForm:
    """.. deprecated:: the streaming (online-softmax) form is now the
    two-stage folding instance of ``RecurrentForm``; this factory is kept
    for one release."""
    import warnings
    warnings.warn("StreamingForm is deprecated; construct a RecurrentForm "
                  "(or use attention_form)", DeprecationWarning, stacklevel=2)
    return RecurrentForm(name, (scores, context), stream_axis, SOFTMAX_STATE)


def attention_form(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                   vd: Optional[int] = None, *, window: int = 0,
                   prefix_len: int = 0) -> RecurrentForm:
    """Normalize the attention expression pair into the online-softmax
    ``RecurrentForm`` instance.

    Axis names: ``(b, h, g, i, j)`` + the score contraction ``c`` (head_dim)
    and the context value axis ``d`` — ``j`` (key position) is the streamed
    axis, an *output* of scores and the *reduction* of context.
    ``window``/``prefix_len`` ride as streamed-axis masking metadata so the
    emitter derives the windowed / prefix-LM block-skip.
    """
    scores, context = attention_expr(b, hkv, g, sq, sk, hd, vd)
    scores_nf = normal_form(scores, name="attn_scores",
                            out_axes=("b", "h", "g", "i", "j"),
                            reduce_axes=("c",))
    context_nf = normal_form(context, name="attn_context",
                             out_axes=("b", "h", "g", "i", "d"),
                             reduce_axes=("j",))
    return RecurrentForm("flash_attention", (scores_nf, context_nf), "j",
                         SOFTMAX_STATE, window=int(window),
                         prefix_len=int(prefix_len))


def ssd_form(b: int, nc: int, q: int, h: int, p: int, n: int) -> RecurrentForm:
    """The Mamba-2 SSD chunked scan as a carried-state recurrence.

    The sequence axis arrives already dimension-lifted ``S -> (c, q)``
    (chunk index x chunk length — ``q`` comes from
    ``solve_recurrence_blocks``, the same a-priori derivation as every other
    block in the repo); the chunk index ``c`` is the streamed axis.  Two
    welded stages, both ordinary ONFs over the *stored* (B, S, ...) model
    buffers read through the chunked view (a pure reshape):

    * ``ssd_scores``:   G[b,c,i,j] = sum_n C[b,c,i,n] * B[b,c,j,n]
    * ``ssd_context``:  y[b,c,i,h,p] = sum_j P[b,c,h,i,j] * X[b,c,j,h,p]

    The intermediate P is the segsum-decay-weighted score block ``G . L`` —
    the SSD monoid's nonlinearity, exactly as softmax's ``exp`` sits between
    attention's two stages; it broadcasts the head axis (L depends on the
    per-head decay), which is why the carrier leaf carries ``h`` while the
    scores output does not.  ``aux`` declares the decay input ``dA``
    (b,c,j,h) and the initial state ``H0`` (b,h,p,n); the carried state
    ``h`` (head, head_dim, state) steps ``h' = chunk_decay * h + B'(decay
    . x)`` across chunks and is exported as the decode cache.
    """
    C = LeafSpec("C", (("b", b), ("c", nc), ("i", q), ("n", n)), "row")
    B = LeafSpec("B", (("b", b), ("c", nc), ("j", q), ("n", n)), "row")
    scores = NormalForm(
        name="ssd_scores", out_axes=("b", "c", "i", "j"), reduce_axes=("n",),
        extents=(("b", b), ("c", nc), ("i", q), ("j", q), ("n", n)),
        leaves=(C, B), combine="mul", reduce_op="add")
    P = LeafSpec("P", (("b", b), ("c", nc), ("h", h), ("i", q), ("j", q)),
                 "row")
    X = LeafSpec("X", (("b", b), ("c", nc), ("j", q), ("h", h), ("p", p)),
                 "row")
    context = NormalForm(
        name="ssd_context", out_axes=("b", "c", "i", "h", "p"),
        reduce_axes=("j",),
        extents=(("b", b), ("c", nc), ("i", q), ("h", h), ("p", p),
                 ("j", q)),
        leaves=(P, X), combine="mul", reduce_op="add")
    dA = LeafSpec("dA", (("b", b), ("c", nc), ("j", q), ("h", h)), "row")
    H0 = LeafSpec("H0", (("b", b), ("h", h), ("p", p), ("n", n)), "row")
    return RecurrentForm("ssd_scan", (scores, context), "c", SSD_STATE,
                         aux=(dA, H0))


#: the forward online-softmax monoid *with exported statistics*: identical
#: body (kind "online_softmax" — same derived blocks, same kernel math),
#: but the carried (m, l) flush as per-row kernel outputs so a derived
#: backward can reconstruct p = exp(s - lse) without re-running the stream
SOFTMAX_STATS_STATE = StateSpec("online_softmax",
                                (("m", ("i",)), ("l", ("i",)),
                                 ("acc", ("i", "d"))),
                                exports=True, export_names=("m", "l"))

#: flash backward dQ: the carried per-row gradient accumulator, streamed
#: over keys exactly as the forward (no rescale — the softmax statistics
#: are already final)
FLASH_DQ_STATE = StateSpec("flash_dq", (("dq", ("i", "c")),), rescale=False)

#: flash backward dK/dV: the transposed weld — rows are key positions, the
#: stream is query positions; dV rides as carried state exported per row
#: block (dK is the main output)
FLASH_DKV_STATE = StateSpec("flash_dkv", (("dv", ("j", "d")),),
                            rescale=False, exports=True,
                            export_names=("dv",))

#: the SSD monoid with per-chunk state checkpoints: same ``ssd`` body, but
#: each streamed step also exports the state *entering* that chunk — the
#: recomputation anchor the derived backward consumes
SSD_CHK_STATE = StateSpec("ssd", (("h", ("h", "p", "n")),
                                  ("h_in", ("h", "p", "n"))),
                          exports=True, per_step=("h_in",))

#: the SSD backward monoid: the inter-chunk state cotangent ``dh`` carried
#: across (reversed) chunks, with the per-chunk projection/decay cotangents
#: exported per streamed step
SSD_BWD_STATE = StateSpec("ssd_backward",
                          (("dh", ("h", "p", "n")), ("dB", ("j", "n")),
                           ("dC", ("i", "n")), ("ddA", ("j", "h"))),
                          rescale=False, exports=True,
                          per_step=("dB", "dC", "ddA"))

#: the gated backward monoid: the reversed recurrence ``z_k = a'_k z_{k-1}
#: + b'_k`` is *itself* a gated scan on flipped operands — degenerate case
GATED_BWD_STATE = StateSpec("gated_backward", (("h", ("w",)),),
                            exports=True)


def attention_stats_form(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                         vd: Optional[int] = None, *, window: int = 0,
                         prefix_len: int = 0) -> RecurrentForm:
    """``attention_form`` with the (m, l) statistics exported: the same two
    welded stages and the same ``online_softmax`` kind (so the solver
    derives the *same* (bq, bk) as the plain forward), but the carried
    running max and denominator flush as per-row f32 outputs — the saved
    activations the derived backward kernels reconstruct ``p`` from."""
    scores, context = attention_expr(b, hkv, g, sq, sk, hd, vd)
    scores_nf = normal_form(scores, name="attn_scores",
                            out_axes=("b", "h", "g", "i", "j"),
                            reduce_axes=("c",))
    context_nf = normal_form(context, name="attn_context",
                             out_axes=("b", "h", "g", "i", "d"),
                             reduce_axes=("j",))
    return RecurrentForm("flash_attention_stats", (scores_nf, context_nf),
                         "j", SOFTMAX_STATS_STATE, window=int(window),
                         prefix_len=int(prefix_len))


def attention_dq_form(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                      vd: Optional[int] = None, *, window: int = 0,
                      prefix_len: int = 0) -> RecurrentForm:
    """Flash backward dQ as a carried-state recurrence: the same weld shape
    as the forward (rows = query positions, stream = key positions), with
    the recomputed score block as stage 1 and the ``dS . K`` contraction as
    stage 2.  The saved statistics (M, L) and the precomputed row dot
    ``D = rowsum(dO * O)`` ride as aux operands; the monoid's body turns
    the streamed score block into ``dS = p * (dO.Vᵀ - D)`` and folds
    ``dS . K`` into the carried dq accumulator.  K binds twice (stage 1
    recompute and stage 2 contraction) — same buffer, two derived
    BlockSpecs."""
    vd = vd or hd
    Q = LeafSpec("Q", (("b", b), ("i", sq), ("h", hkv), ("g", g),
                       ("c", hd)), "row")
    K = LeafSpec("K", (("b", b), ("j", sk), ("h", hkv), ("c", hd)), "row")
    scores = NormalForm(
        name="dq_scores", out_axes=("b", "h", "g", "i", "j"),
        reduce_axes=("c",),
        extents=(("b", b), ("h", hkv), ("g", g), ("i", sq), ("j", sk),
                 ("c", hd)),
        leaves=(Q, K), combine="mul", reduce_op="add")
    dS = LeafSpec("dS", (("b", b), ("h", hkv), ("g", g), ("i", sq),
                         ("j", sk)), "row")
    out = NormalForm(
        name="dq_out", out_axes=("b", "h", "g", "i", "c"),
        reduce_axes=("j",),
        extents=(("b", b), ("h", hkv), ("g", g), ("i", sq), ("c", hd),
                 ("j", sk)),
        leaves=(dS, K), combine="mul", reduce_op="add")
    dO = LeafSpec("dO", (("b", b), ("i", sq), ("h", hkv), ("g", g),
                         ("d", vd)), "row")
    V = LeafSpec("V", (("b", b), ("j", sk), ("h", hkv), ("d", vd)), "row")
    M = LeafSpec("M", (("b", b), ("h", hkv), ("g", g), ("i", sq)), "row")
    L = LeafSpec("L", (("b", b), ("h", hkv), ("g", g), ("i", sq)), "row")
    D = LeafSpec("D", (("b", b), ("h", hkv), ("g", g), ("i", sq)), "row")
    return RecurrentForm("flash_dq", (scores, out), "j", FLASH_DQ_STATE,
                         aux=(dO, V, M, L, D), window=int(window),
                         prefix_len=int(prefix_len))


def attention_dkv_form(b: int, hkv: int, g: int, sq: int, sk: int, hd: int,
                       vd: Optional[int] = None, *, window: int = 0,
                       prefix_len: int = 0) -> RecurrentForm:
    """Flash backward dK/dV as the *transposed* weld: rows are key
    positions ``j``, the streamed axis is query positions ``i``.  Stage 1
    recomputes the transposed score block ``K . Qᵀ``; stage 2 contracts
    ``dSᵀ . Q`` into the dK output while the monoid folds ``pᵀ . dO`` into
    the carried dV, exported per row block.  Q binds twice; the per-group
    dK/dV land on a ``(b, h, g, j, ...)`` layout the ops layer sums over
    ``g`` (the GQA head-group reduction stays outside the kernel)."""
    vd = vd or hd
    K = LeafSpec("K", (("b", b), ("j", sk), ("h", hkv), ("c", hd)), "row")
    Q = LeafSpec("Q", (("b", b), ("i", sq), ("h", hkv), ("g", g),
                       ("c", hd)), "row")
    scores = NormalForm(
        name="dkv_scores", out_axes=("b", "h", "g", "j", "i"),
        reduce_axes=("c",),
        extents=(("b", b), ("h", hkv), ("g", g), ("j", sk), ("i", sq),
                 ("c", hd)),
        leaves=(K, Q), combine="mul", reduce_op="add")
    dS = LeafSpec("dS", (("b", b), ("h", hkv), ("g", g), ("j", sk),
                         ("i", sq)), "row")
    out = NormalForm(
        name="dkv_out", out_axes=("b", "h", "g", "j", "c"),
        reduce_axes=("i",),
        extents=(("b", b), ("h", hkv), ("g", g), ("j", sk), ("c", hd),
                 ("i", sq)),
        leaves=(dS, Q), combine="mul", reduce_op="add")
    dO = LeafSpec("dO", (("b", b), ("i", sq), ("h", hkv), ("g", g),
                         ("d", vd)), "row")
    V = LeafSpec("V", (("b", b), ("j", sk), ("h", hkv), ("d", vd)), "row")
    M = LeafSpec("M", (("b", b), ("h", hkv), ("g", g), ("i", sq)), "row")
    L = LeafSpec("L", (("b", b), ("h", hkv), ("g", g), ("i", sq)), "row")
    D = LeafSpec("D", (("b", b), ("h", hkv), ("g", g), ("i", sq)), "row")
    return RecurrentForm("flash_dkv", (scores, out), "i", FLASH_DKV_STATE,
                         aux=(dO, V, M, L, D), window=int(window),
                         prefix_len=int(prefix_len))


def ssd_chk_form(b: int, nc: int, q: int, h: int, p: int,
                 n: int) -> RecurrentForm:
    """``ssd_form`` with per-chunk state checkpoints: the same two welded
    stages and the same ``ssd`` kind, but each streamed step additionally
    exports the inter-chunk state *entering* that chunk (``h_in``,
    (b, nc, h, p, n)) — the recomputation anchors the derived SSD backward
    streams instead of re-scanning the whole sequence."""
    fwd = ssd_form(b, nc, q, h, p, n)
    return RecurrentForm("ssd_scan_chk", fwd.stages, fwd.stream_axis,
                         SSD_CHK_STATE, aux=fwd.aux)


def ssd_bwd_form(b: int, nc: int, q: int, h: int, p: int,
                 n: int) -> RecurrentForm:
    """The SSD backward as a carried-state recurrence over *reversed*
    chunks: stage 1 recomputes the score block ``G = C . Bᵀ``, stage 2 is
    the ``dX`` contraction ``Pᵀ . dY``; the monoid's body replays the
    forward chunk factoring from the saved per-chunk state checkpoints
    (aux ``Hin``) and chains every cotangent — ``dh`` carried across
    chunks (seeded by aux ``dHf``), ``dB``/``dC``/``ddA`` exported per
    streamed step, ``dh0`` flushed at the end."""
    C = LeafSpec("C", (("b", b), ("c", nc), ("i", q), ("n", n)), "row")
    B = LeafSpec("B", (("b", b), ("c", nc), ("j", q), ("n", n)), "row")
    scores = NormalForm(
        name="ssd_bwd_scores", out_axes=("b", "c", "i", "j"),
        reduce_axes=("n",),
        extents=(("b", b), ("c", nc), ("i", q), ("j", q), ("n", n)),
        leaves=(C, B), combine="mul", reduce_op="add")
    P = LeafSpec("P", (("b", b), ("c", nc), ("h", h), ("i", q), ("j", q)),
                 "row")
    dY = LeafSpec("dY", (("b", b), ("c", nc), ("i", q), ("h", h), ("p", p)),
                  "row")
    out = NormalForm(
        name="ssd_bwd_out", out_axes=("b", "c", "j", "h", "p"),
        reduce_axes=("i",),
        extents=(("b", b), ("c", nc), ("j", q), ("h", h), ("p", p),
                 ("i", q)),
        leaves=(P, dY), combine="mul", reduce_op="add")
    X = LeafSpec("X", (("b", b), ("c", nc), ("j", q), ("h", h), ("p", p)),
                 "row")
    dA = LeafSpec("dA", (("b", b), ("c", nc), ("j", q), ("h", h)), "row")
    Hin = LeafSpec("Hin", (("b", b), ("c", nc), ("h", h), ("p", p),
                           ("n", n)), "row")
    dHf = LeafSpec("dHf", (("b", b), ("h", h), ("p", p), ("n", n)), "row")
    return RecurrentForm("ssd_backward", (scores, out), "c", SSD_BWD_STATE,
                         aux=(X, dA, Hin, dHf))


def rglru_bwd_form(b: int, nc: int, q: int, w: int) -> RecurrentForm:
    """The RG-LRU backward recurrence: the reversed cotangent scan
    ``z_k = a'_k z_{k-1} + b'_k`` is *itself* a gated scan on flipped,
    shifted operands — the degenerate (N=1) backward kind shares the
    forward's body verbatim, only the ``StateSpec.kind`` registration
    differs (the ops layer does the flip/shift/unflip)."""
    A = LeafSpec("A", (("b", b), ("c", nc), ("i", q), ("w", w)), "row")
    Bv = LeafSpec("Bv", (("b", b), ("c", nc), ("i", q), ("w", w)), "row")
    stage = NormalForm(
        name="rglru_bwd_stage", out_axes=("b", "c", "i", "w"),
        reduce_axes=(),
        extents=(("b", b), ("c", nc), ("i", q), ("w", w)),
        leaves=(A, Bv), combine="mul", reduce_op="add")
    H0 = LeafSpec("H0", (("b", b), ("w", w)), "row")
    return RecurrentForm("rglru_backward", (stage,), "c", GATED_BWD_STATE,
                         aux=(H0,))


def rglru_form(b: int, nc: int, q: int, w: int) -> RecurrentForm:
    """The RG-LRU gated scan as the degenerate (N=1, contraction-free)
    carried-state recurrence: one elementwise stage over the chunked
    sequence view, streamed over the chunk index, with the per-channel
    state ``h' = a h + b`` carried across chunks and exported.  The stage
    pairs the gate log ``A`` (log-space for the stable in-chunk cumsum) and
    the gated input ``Bv`` — the recurrence itself is the ``gated`` monoid's
    body, exactly as softmax is not part of attention's ONF pair."""
    A = LeafSpec("A", (("b", b), ("c", nc), ("i", q), ("w", w)), "row")
    Bv = LeafSpec("Bv", (("b", b), ("c", nc), ("i", q), ("w", w)), "row")
    stage = NormalForm(
        name="rglru_stage", out_axes=("b", "c", "i", "w"), reduce_axes=(),
        extents=(("b", b), ("c", nc), ("i", q), ("w", w)),
        leaves=(A, Bv), combine="mul", reduce_op="add")
    H0 = LeafSpec("H0", (("b", b), ("w", w)), "row")
    return RecurrentForm("rglru_scan", (stage,), "c", GATED_STATE, aux=(H0,))


#: the windowed-decode monoid: the online-softmax carried state over the
#: *query-group* row axis (decode has one query token; the GQA group axis
#: is the blocked per-row axis), masked dynamically from the runtime
#: position aux instead of statically from the grid step
DECODE_STATE = StateSpec("windowed_decode",
                         (("m", ("g",)), ("l", ("g",)),
                          ("acc", ("g", "d"))))


def windowed_decode_form(hkv: int, g: int, hd: int,
                         vd: Optional[int] = None, *, page: int,
                         view_pages: int, pool_pages: int,
                         page_table: Tuple[int, ...],
                         window: int = 0) -> RecurrentForm:
    """One decode step over a *paged* KV cache as a folding recurrence.

    The single query token's GQA group axis ``g`` is the blocked row axis
    (it must be >= 2 — pure-MHA decode has no blocked per-row axis to fold
    over and the derivation refuses); key positions ``j`` stream with block
    = ``page``, so each streamed step is exactly one page and the K/V
    BlockSpec index maps read ``page_table[k]`` — the per-page psi slab
    offsets — straight from pool storage:

    * ``decode_scores``:  s[h,g,j] = sum_c Q[h,g,c] * K[j,h,c]
    * ``decode_context``: o[h,g,d] = sum_j P[h,g,j] * V[j,h,d]

    K/V carry no ``g`` dim (the GQA zero-coefficient recovery) and store
    the streamed axis leading, as the pools do.  The aux ``POS`` operand
    carries the runtime view-relative query position — masking is dynamic
    (position is data, the table is static), which is what keeps one
    executor per table instead of one per token.  ``window`` > 0 masks
    keys older than ``window`` positions; the engine then only binds the
    ceil(window/page)+1 live pages, making decode O(window) regardless of
    sequence length.
    """
    if g < 2:
        raise ValueError(
            f"windowed_decode folds over the GQA group axis; g={g} leaves "
            "no blocked per-row axis (use the dense decode path)")
    if len(page_table) != view_pages:
        raise ValueError(
            f"page table length {len(page_table)} != view_pages {view_pages}")
    vd = vd or hd
    sk = view_pages * page
    Q = LeafSpec("Q", (("h", hkv), ("g", g), ("c", hd)), "row")
    K = LeafSpec("K", (("j", sk), ("h", hkv), ("c", hd)), "row")
    scores = NormalForm(
        name="decode_scores", out_axes=("h", "g", "j"), reduce_axes=("c",),
        extents=(("h", hkv), ("g", g), ("j", sk), ("c", hd)),
        leaves=(Q, K), combine="mul", reduce_op="add")
    P = LeafSpec("P", (("h", hkv), ("g", g), ("j", sk)), "row")
    V = LeafSpec("V", (("j", sk), ("h", hkv), ("d", vd)), "row")
    context = NormalForm(
        name="decode_context", out_axes=("h", "g", "d"), reduce_axes=("j",),
        extents=(("h", hkv), ("g", g), ("d", vd), ("j", sk)),
        leaves=(P, V), combine="mul", reduce_op="add")
    POS = LeafSpec("POS", (("_pr", 1), ("_pc", 2)), "row")
    return RecurrentForm("windowed_decode", (scores, context), "j",
                         DECODE_STATE, aux=(POS,), window=int(window),
                         page_table=tuple(int(t) for t in page_table),
                         paged=("K", "V"), pool_pages=int(pool_pages))


def batched_decode_form(slots: int, hkv: int, g: int, hd: int,
                        vd: Optional[int] = None, *, page: int,
                        view_pages: int, pool_pages: int,
                        page_tables: Tuple[Tuple[int, ...], ...],
                        window: int = 0) -> RecurrentForm:
    """One decode step for *every* active serving slot as a single folding
    recurrence — ``windowed_decode`` with the slot axis dimension-lifted.

    The slot axis ``s`` is an ordinary lifted output axis on both stages
    (MoA's lifted inner product: the batched product is the same ONF with
    one more lead dimension), so the derivation, the state monoid and the
    kernel body are all ``windowed_decode``'s unchanged — each (s, h) grid
    cell folds exactly the float ops the per-slot kernel folds, which is
    what makes the batched launch bit-identical to N sequential launches.

    What *does* change is addressing: the page table stacks to 2-D
    ``[slot, k]`` static metadata, lowered in the K/V BlockSpec index maps
    as ``(s, k) -> table[s][k]`` — the select-fold now keyed on two grid
    axes.  K/V still bind the one shared pool (no slot dim: slots address
    it only through their table rows), and POS promotes to one int32 row
    per slot, so masking stays runtime data and the executor re-jits only
    when the stacked table changes, never per token.  Engine-side, a dead
    slot is just POS = -1 (every block-skip guard ``k*page <= pos`` is
    then false, so no entry its row names ever folds), which is why
    slot-count changes re-key nothing and a retirement merely reverts the
    table to a previously-seen key.
    """
    if g < 2:
        raise ValueError(
            f"windowed_decode folds over the GQA group axis; g={g} leaves "
            "no blocked per-row axis (use the dense decode path)")
    page_tables = tuple(tuple(int(t) for t in row) for row in page_tables)
    if len(page_tables) != slots:
        raise ValueError(
            f"stacked page table has {len(page_tables)} rows for "
            f"{slots} slots")
    for row in page_tables:
        if len(row) != view_pages:
            raise ValueError(
                f"page table length {len(row)} != view_pages {view_pages}")
    vd = vd or hd
    sk = view_pages * page
    Q = LeafSpec("Q", (("s", slots), ("h", hkv), ("g", g), ("c", hd)),
                 "row")
    K = LeafSpec("K", (("j", sk), ("h", hkv), ("c", hd)), "row")
    scores = NormalForm(
        name="batched_decode_scores", out_axes=("s", "h", "g", "j"),
        reduce_axes=("c",),
        extents=(("s", slots), ("h", hkv), ("g", g), ("j", sk), ("c", hd)),
        leaves=(Q, K), combine="mul", reduce_op="add")
    P = LeafSpec("P", (("s", slots), ("h", hkv), ("g", g), ("j", sk)),
                 "row")
    V = LeafSpec("V", (("j", sk), ("h", hkv), ("d", vd)), "row")
    context = NormalForm(
        name="batched_decode_context", out_axes=("s", "h", "g", "d"),
        reduce_axes=("j",),
        extents=(("s", slots), ("h", hkv), ("g", g), ("d", vd), ("j", sk)),
        leaves=(P, V), combine="mul", reduce_op="add")
    POS = LeafSpec("POS", (("s", slots), ("_pc", 2)), "row")
    return RecurrentForm("batched_decode", (scores, context), "j",
                         DECODE_STATE, aux=(POS,), window=int(window),
                         page_table=page_tables, paged=("K", "V"),
                         pool_pages=int(pool_pages), slot_axis="s")


# ---------------------------------------------------------------------------
# psi reduction: expression -> NormalForm -> Onf
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LeafSpec:
    """One leaf's resolved indexing: per *storage* dimension, the loop symbol
    (or fixed constant) indexing it, plus that dimension's logical extent and
    the leaf's gamma layout.  Enough to rebuild flat affine coefficients at
    any (padded) axis extents."""
    array: str
    dims: Tuple[Tuple[_Term, int], ...]        # ((sym | const, extent), ...)
    layout: str

    def shape(self) -> Shape:
        return tuple(e for _, e in self.dims)

    def storage_shape(self) -> Shape:
        """The physical buffer's row-major shape: a column-major array of
        logical shape s occupies the same flat buffer as a row-major array
        of shape reverse(s) — this is what executors bind operands by."""
        s = self.shape()
        return s if self.layout == "row" else tuple(reversed(s))

    def access(self, extents: dict[str, int]) -> Access:
        """Materialize the flat affine Access under (possibly padded) axis
        extents: gamma_row / gamma_col strides over the storage dims."""
        sizes = [extents.get(t, e) if isinstance(t, str) else e
                 for t, e in self.dims]
        nd = len(sizes)
        strides = []
        for d in range(nd):
            if self.layout == "row":
                s = 1
                for e in sizes[d + 1:]:
                    s *= e
            else:
                s = 1
                for e in sizes[:d]:
                    s *= e
            strides.append(s)
        coeffs: dict[str, int] = {}
        const = 0
        for (t, _), s in zip(self.dims, strides):
            if isinstance(t, str):
                coeffs[t] = coeffs.get(t, 0) + s
            else:
                const += t * s
        return Access(self.array, coeffs, const)


@dataclass(frozen=True)
class NormalForm:
    """The DNF->ONF artifact: loop axes (out + reduce), the semiring, and
    every leaf's resolved storage indexing.  ``onf()`` materializes the
    concrete loop nest — optionally under padded axis extents, which is how
    the schedule builder pads without re-walking the expression."""
    name: str
    out_axes: Tuple[str, ...]
    reduce_axes: Tuple[str, ...]
    extents: Tuple[Tuple[str, int], ...]       # logical extent per loop symbol
    leaves: Tuple[LeafSpec, ...]
    combine: str
    reduce_op: str

    @property
    def extent_map(self) -> dict[str, int]:
        return dict(self.extents)

    def out_shape(self) -> Shape:
        e = self.extent_map
        return tuple(e[s] for s in self.out_axes)

    def leaf_shapes(self) -> Tuple[Shape, ...]:
        return tuple(l.shape() for l in self.leaves)

    def leaf_storage_shapes(self) -> Tuple[Shape, ...]:
        """Physical (row-major buffer) shape per leaf — what callers bind;
        differs from ``leaf_shapes`` only for column-major leaves."""
        return tuple(l.storage_shape() for l in self.leaves)

    def loop_order(self) -> Tuple[str, ...]:
        """The MoA ONF loop order: reduce loops nest just inside the last
        output loop (paper eq. 3's (i, k, j)), so the innermost loop streams
        the output contiguously."""
        if not self.out_axes:
            return self.reduce_axes
        return (self.out_axes[:-1] + self.reduce_axes + self.out_axes[-1:])

    def onf(self, pads: Optional[dict[str, int]] = None,
            name: Optional[str] = None) -> Onf:
        ext = self.extent_map
        for sym, padded in (pads or {}).items():
            if sym not in ext:
                raise KeyError(f"pad for unknown axis {sym!r}")
            if padded < ext[sym]:
                raise ValueError(f"pad {padded} below logical extent "
                                 f"{ext[sym]} of {sym!r}")
            ext[sym] = int(padded)
        out_spec = LeafSpec("C", tuple((s, ext[s]) for s in self.out_axes),
                            "row")
        loops = tuple(Loop(s, ext[s]) for s in self.loop_order())
        return Onf(name or self.name, loops, out_spec.access(ext),
                   tuple(l.access(ext) for l in self.leaves),
                   frozenset(self.reduce_axes), self.combine, self.reduce_op)

    def key(self) -> tuple:
        """The cache key: the *logical* normal form's canonical tuple.

        Memoized on the instance (hot dispatch paths recompute it per call;
        direct ``__dict__`` write keeps the dataclass frozen)."""
        k = self.__dict__.get("_key")
        if k is None:
            k = self.onf().key()
            self.__dict__["_key"] = k
        return k


def _default_axis_names(n: int) -> Tuple[str, ...]:
    pool = ("i", "j", "l", "m", "p", "q", "r", "s")
    if n <= len(pool):
        return pool[:n]
    return tuple(f"i{d}" for d in range(n))


def normal_form(expr: Expr, *, name: str = "expr",
                out_axes: Optional[Sequence[str]] = None,
                reduce_axes: Optional[Sequence[str]] = None) -> NormalForm:
    """Psi-reduce a composed expression to its ONF normal form.

    Walks the tree once, pushing the output's Cartesian index symbols down
    through transposes (permute), psi views (prepend constants) and inner
    products (insert fresh contraction symbols) until they hit leaves, where
    the leaf's gamma layout resolves them to flat affine coefficients.

    Memoized: nodes are frozen (hashable) dataclasses, so hot dispatch paths
    that rebuild the same expression per call get the cached NormalForm (and
    its cached ``key()``) back in O(1).

    Raises ``ValueError`` if the expression mixes combine ops or reduce ops —
    an ONF has exactly one of each.
    """
    return _normal_form_cached(
        expr, name,
        tuple(out_axes) if out_axes is not None else None,
        tuple(reduce_axes) if reduce_axes is not None else None)


@functools.lru_cache(maxsize=1024)
def _normal_form_cached(expr: Expr, name: str,
                        out_axes: Optional[Tuple[str, ...]],
                        reduce_axes: Optional[Tuple[str, ...]]) -> NormalForm:
    nd = len(expr.shape)
    out_syms = tuple(out_axes) if out_axes is not None else _default_axis_names(nd)
    if len(out_syms) != nd:
        raise ValueError(f"{len(out_syms)} axis names for a rank-{nd} result")

    extents: dict[str, int] = dict(zip(out_syms, (int(s) for s in expr.shape)))
    red_names = list(reduce_axes) if reduce_axes is not None else None
    leaves: list[LeafSpec] = []
    red_syms: list[str] = []
    combine_ops: set[str] = set()
    reduce_ops: set[str] = set()
    hoisted = False                # a reduce nested under some combine's operand

    def fresh_reduce(extent: int, op: str) -> str:
        if red_names is not None:
            if len(red_syms) >= len(red_names):
                raise ValueError("fewer reduce_axes names than contractions")
            sym = red_names[len(red_syms)]
        else:
            sym = "k" if not red_syms else f"k{len(red_syms)}"
        if sym in extents:
            raise ValueError(f"duplicate axis name {sym!r}")
        extents[sym] = extent
        red_syms.append(sym)
        reduce_ops.add(op)
        return sym

    def visit(e: Expr, idx: Tuple[_Term, ...], inside: bool) -> None:
        nonlocal hoisted
        if isinstance(e, Arr):
            leaves.append(LeafSpec(
                e.name,
                tuple((t, int(s)) for t, s in zip(idx, e.shape)),
                e.layout))
        elif isinstance(e, Transpose):
            sub: list[_Term] = [0] * len(idx)
            for out_d, t in enumerate(idx):
                sub[e.perm[out_d]] = t
            visit(e.x, tuple(sub), inside)
        elif isinstance(e, Psi):
            visit(e.x, e.idx + idx, inside)
        elif isinstance(e, Combine):
            combine_ops.add(e.op)
            visit(e.a, idx, True)
            visit(e.b, idx, True)
        elif isinstance(e, Reduce):
            hoisted = hoisted or inside
            k = fresh_reduce(e.x.shape[e.axis], e.op)
            visit(e.x, idx[:e.axis] + (k,) + idx[e.axis:], inside)
        elif isinstance(e, Inner):
            hoisted = hoisted or inside
            k = fresh_reduce(e.a.shape[-1], e.plus)
            combine_ops.add(e.times)
            na = len(e.a.shape)
            visit(e.a, idx[:na - 1] + (k,), True)
            visit(e.b, idx[:e.batch] + (k,) + idx[na - 1:], True)
        else:
            raise TypeError(f"not an Expr node: {e!r}")

    visit(expr, tuple(out_syms), False)

    if len(combine_ops) > 1:
        raise ValueError(f"expression mixes combine ops {sorted(combine_ops)} "
                         "— not a single ONF")
    if len(reduce_ops) > 1:
        raise ValueError(f"expression mixes reduce ops {sorted(reduce_ops)} "
                         "— not a single ONF")
    # A reduce nested under a combine's operand gets hoisted to the single
    # loop-nest reduction — sound only when the combine distributes over the
    # reduce (the semiring law): mul over add, add over max/min.  Reject the
    # rest instead of mis-compiling (the root Inner/Reduce needs no law:
    # its reduce is already outermost in the ONF).
    if (hoisted and combine_ops
            and (next(iter(combine_ops)), next(iter(reduce_ops)))
            not in _DISTRIBUTIVE):
        raise ValueError(
            f"reduce op {sorted(reduce_ops)} is nested under combine op "
            f"{sorted(combine_ops)}, which does not distribute over it — "
            "not expressible as a single ONF")

    return NormalForm(
        name=name,
        out_axes=out_syms,
        reduce_axes=tuple(red_syms),
        extents=tuple(extents.items()),
        leaves=tuple(leaves),
        combine=next(iter(combine_ops), "mul"),
        reduce_op=next(iter(reduce_ops), "add"),
    )


def normalize(expr: Expr, *, name: str = "expr",
              out_axes: Optional[Sequence[str]] = None,
              reduce_axes: Optional[Sequence[str]] = None) -> Onf:
    """``normal_form(...).onf()`` in one call — expression to loop nest."""
    return normal_form(expr, name=name, out_axes=out_axes,
                       reduce_axes=reduce_axes).onf()
