"""A Mathematics of Arrays (MoA) — shapes, Psi indexing, gamma layouts.

This module implements the equational core of Mullin's MoA formalism
[Mullin 1988; Mullin 2023 "From array algebra to energy efficiency"]:

* an array is (shape, flat row-major data) — ``rav`` is the flattening,
* ``psi`` is the sole indexing primitive: a (partial) Cartesian index
  applied to an array yields a subarray,
* ``gamma`` is a *family* of layout functions mapping a full Cartesian
  index + shape to a flat offset (row-major, column-major, blocked);
  ``gamma_inverse`` recovers the index,
* ``iota(shape)`` enumerates all valid indices, so that
  ``psi(iota(rho(x)), x) == x`` (the fundamental MoA identity).

Everything here is small, pure, and used *symbolically* by the ONF /
dimension-lifting machinery to derive code (BlockSpecs, PartitionSpecs,
loop nests) — it is not the runtime execution path, which is XLA/Pallas.
Functions accept numpy or jax arrays; symbolic shape math is plain ints.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import reduce
from typing import Sequence, Tuple

import numpy as np

Shape = Tuple[int, ...]
Index = Tuple[int, ...]


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------

def rho(x) -> Shape:
    """The MoA shape of an array (``rho`` in the paper)."""
    return tuple(int(d) for d in np.shape(x))


def pi(shape: Sequence[int]) -> int:
    """Total component count: product of the shape vector (``pi rho x``)."""
    return int(reduce(lambda a, b: a * b, (int(s) for s in shape), 1))


def dim(x) -> int:
    """Dimensionality: length of the shape vector (``rho rho x`` first item)."""
    return len(rho(x))


def check_index(idx: Sequence[int], shape: Sequence[int]) -> None:
    """Validate a (partial) index ``0 <=* idx <* shape`` (paper eq. 2)."""
    if len(idx) > len(shape):
        raise IndexError(f"index {tuple(idx)} longer than shape {tuple(shape)}")
    for axis, (i, s) in enumerate(zip(idx, shape)):
        if not 0 <= i < s:
            raise IndexError(f"index {tuple(idx)} invalid at axis {axis} for shape {tuple(shape)}")


# ---------------------------------------------------------------------------
# gamma: layout functions (Cartesian index -> flat offset)
# ---------------------------------------------------------------------------

def gamma_row(idx: Sequence[int], shape: Sequence[int]) -> int:
    """Row-major offset: gamma_row(<i,j>; <m,n>) = i*n + j (Horner form)."""
    check_index(idx, shape)
    if len(idx) != len(shape):
        raise IndexError("gamma requires a full index")
    off = 0
    for i, s in zip(idx, shape):
        off = off * s + i
    return off


def gamma_col(idx: Sequence[int], shape: Sequence[int]) -> int:
    """Column-major offset (Fortran layout)."""
    check_index(idx, shape)
    if len(idx) != len(shape):
        raise IndexError("gamma requires a full index")
    off = 0
    for i, s in zip(reversed(tuple(idx)), reversed(tuple(shape))):
        off = off * s + i
    return off


def gamma_row_inverse(offset: int, shape: Sequence[int]) -> Index:
    """Inverse of gamma_row: flat offset -> Cartesian index."""
    n = pi(shape)
    if not 0 <= offset < max(n, 1):
        raise IndexError(f"offset {offset} out of range for shape {tuple(shape)}")
    idx = []
    for s in reversed(tuple(shape)):
        idx.append(offset % s)
        offset //= s
    return tuple(reversed(idx))


def gamma_col_inverse(offset: int, shape: Sequence[int]) -> Index:
    """Inverse of gamma_col: flat offset -> Cartesian index.

    The column-major dual of ``gamma_row_inverse`` — axis 0 varies fastest.
    Transposed-operand schedules rely on this round-trip:
    ``gamma_col(i; s) == gamma_row(reverse(i); reverse(s))``, so a stored
    row-major (n, k) array read through its transpose is exactly a
    column-major (k, n) view, and recovering Cartesian indices from flat
    offsets must invert that layout."""
    n = pi(shape)
    if not 0 <= offset < max(n, 1):
        raise IndexError(f"offset {offset} out of range for shape {tuple(shape)}")
    idx = []
    for s in tuple(shape):
        idx.append(offset % s)
        offset //= s
    return tuple(idx)


def gamma_blocked(idx: Sequence[int], shape: Sequence[int], block: Sequence[int]) -> int:
    """Blocked (tiled) layout: the offset after dimension-lifting each axis
    ``d -> (d // b, b)`` and laying out *blocks* row-major, each block
    internally row-major.  This is the layout the paper's "contiguous block"
    access pattern realizes; each axis size must be divisible by its block.
    """
    check_index(idx, shape)
    if len(idx) != len(shape) or len(block) != len(shape):
        raise IndexError("gamma_blocked requires full index and block per axis")
    for s, b in zip(shape, block):
        if s % b:
            raise ValueError(f"shape {tuple(shape)} not divisible by block {tuple(block)}")
    outer = [i // b for i, b in zip(idx, block)]
    inner = [i % b for i, b in zip(idx, block)]
    outer_shape = [s // b for s, b in zip(shape, block)]
    return gamma_row(outer, outer_shape) * pi(block) + gamma_row(inner, block)


# ---------------------------------------------------------------------------
# rav / iota / psi
# ---------------------------------------------------------------------------

def rav(x) -> np.ndarray:
    """Flatten row-major (MoA's ``rav``)."""
    return np.reshape(np.asarray(x), (-1,))


def iota(shape: Sequence[int]) -> np.ndarray:
    """All valid indices of ``shape``, in row-major order: an array of shape
    ``(*shape, len(shape))``.  ``iota(()) == empty index`` (the scalar case).
    """
    shape = tuple(int(s) for s in shape)
    if not shape:
        return np.zeros((0,), dtype=np.int64)
    grids = np.meshgrid(*[np.arange(s) for s in shape], indexing="ij")
    return np.stack(grids, axis=-1).astype(np.int64)


def psi(idx: Sequence[int], x) -> np.ndarray:
    """The Psi indexing function: a partial index selects a subarray.

    ``psi(<>, x) == x``;  ``psi(<i>, x) == x[i]``;  full index -> scalar (0-d).
    """
    x = np.asarray(x)
    idx = tuple(int(i) for i in idx)
    check_index(idx, x.shape)
    return x[idx]


def psi_flat(idx: Sequence[int], x, gamma=gamma_row) -> np.ndarray:
    """ONF form of psi: rav(psi(i, x)) == rav(x)[gamma(i; rho x) ...] —
    resolve a *full* index through the flat layout.  Used by tests to check
    DNF/ONF agreement."""
    x = np.asarray(x)
    return rav(x)[gamma(idx, x.shape)]


# ---------------------------------------------------------------------------
# the four unified operators (DNF semantics, numpy oracle level)
# ---------------------------------------------------------------------------

def hadamard(a, b) -> np.ndarray:
    """Hadamard product: psi distributes over scalar ops (loop fusion)."""
    a, b = np.asarray(a), np.asarray(b)
    if a.shape != b.shape:
        raise ValueError(f"hadamard shape mismatch {a.shape} vs {b.shape}")
    return a * b


def outer_product(a, b, op=np.multiply) -> np.ndarray:
    """MoA outer product: shape is catenation of shapes; degenerate form is
    scalar extension."""
    a, b = np.asarray(a), np.asarray(b)
    ar = a.reshape(a.shape + (1,) * b.ndim)
    return op(ar, b)


def reduce_add(x, axis: int = 0) -> np.ndarray:
    """Reduction/contraction along one axis."""
    return np.add.reduce(np.asarray(x), axis=axis)


def inner_product(a, b) -> np.ndarray:
    """MoA inner product (+ over ×): for 2-d this *is* GEMM (paper eq. 5).

    Defined the MoA way: outer product over the contraction pairing followed
    by reduction — for matrices, sum_k of (column k of A) outer (row k of B),
    i.e. the contiguous scalar×row accumulation of paper fig. 1.
    """
    a, b = np.asarray(a), np.asarray(b)
    if a.ndim == 0 or b.ndim == 0:
        return a * b
    if a.shape[-1] != b.shape[0]:
        raise ValueError(f"inner product contraction mismatch {a.shape} vs {b.shape}")
    # sum_k outer(a[..., k], b[k, ...]) — evaluated via tensordot for the oracle
    return np.tensordot(a, b, axes=(-1, 0))


def kron(a, b) -> np.ndarray:
    """Kronecker product of matrices via MoA: an outer product followed by a
    dimension-lowering interleave (the (m,p,n,q) -> (m*p, n*q) reshape)."""
    a, b = np.asarray(a), np.asarray(b)
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("kron oracle defined for matrices")
    m, n = a.shape
    p, q = b.shape
    op = outer_product(a, b)            # (m, n, p, q)
    return op.transpose(0, 2, 1, 3).reshape(m * p, n * q)


# ---------------------------------------------------------------------------
# ONF GEMM — the paper's eq. (3), executed literally over flat buffers.
# This is the *semantic reference* for the derived GEMM kernels (slow, exact).
# ---------------------------------------------------------------------------

def onf_gemm(a_flat: np.ndarray, b_flat: np.ndarray, m: int, n: int, p: int) -> np.ndarray:
    """C[(i*p)+j] := sum_k A[(i*n)+k] * B[(k*p)+j], all buffers flat row-major.

    Loop order (i, k, j): for each i, walk A's row contiguously (k), and for
    each scalar A[i,k] stream B's row k contiguously (j) into C's row i —
    every access in the inner loop is stride-1 (paper fig. 1).
    """
    a_flat = np.asarray(a_flat).reshape(-1)
    b_flat = np.asarray(b_flat).reshape(-1)
    if a_flat.size != m * n or b_flat.size != n * p:
        raise ValueError("flat buffer sizes disagree with (m, n, p)")
    c = np.zeros(m * p, dtype=np.result_type(a_flat.dtype, b_flat.dtype))
    for i in range(m):
        for k in range(n):
            aik = a_flat[i * n + k]
            c[i * p:(i + 1) * p] += aik * b_flat[k * p:(k + 1) * p]
    return c


def classical_gemm(a_flat: np.ndarray, b_flat: np.ndarray, m: int, n: int, p: int) -> np.ndarray:
    """The row(A)·column(B) formulation — strided access into B (the baseline
    the paper outperforms).  Same result, different memory-access pattern."""
    a_flat = np.asarray(a_flat).reshape(-1)
    b_flat = np.asarray(b_flat).reshape(-1)
    c = np.zeros(m * p, dtype=np.result_type(a_flat.dtype, b_flat.dtype))
    for i in range(m):
        for j in range(p):
            acc = c.dtype.type(0)
            for k in range(n):
                acc += a_flat[i * n + k] * b_flat[k * p + j]   # stride-p walk of B
            c[i * p + j] = acc
    return c


# ---------------------------------------------------------------------------
# symbolic access-pattern analysis (used by cost/energy models + benchmarks)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AccessTrace:
    """Stride summary of the innermost loop of a GEMM formulation."""
    name: str
    a_stride: int
    b_stride: int
    c_stride: int

    @property
    def contiguous(self) -> bool:
        return max(abs(self.a_stride), abs(self.b_stride), abs(self.c_stride)) <= 1


def moa_access_trace(m: int, n: int, p: int) -> AccessTrace:
    """MoA ONF inner loop (over j): A held scalar, B stride 1, C stride 1."""
    return AccessTrace("moa", 0, 1, 1)


def classical_access_trace(m: int, n: int, p: int) -> AccessTrace:
    """Classical inner loop (over k): A stride 1, B stride p, C held scalar."""
    return AccessTrace("classical", 1, p, 0)


def cacheline_traffic(trace: AccessTrace, m: int, n: int, p: int,
                      line_elems: int = 8) -> int:
    """Distinct cache-line (or DMA burst) fetches issued by the innermost
    loops over a full GEMM, for a line of ``line_elems`` elements.  This is
    the quantity the paper's contiguity argument minimizes."""
    def lines(total_iters: int, stride: int) -> int:
        if stride == 0:                 # operand held in a register all loop
            return 0
        return total_iters * min(abs(stride), line_elems) // line_elems
    inner = m * n * p
    return (lines(inner, trace.a_stride)
            + lines(inner, trace.b_stride)
            + lines(inner, trace.c_stride))


def divisors_pairs(total: int) -> list[tuple[int, int]]:
    """All (outer, inner) factorizations of ``total`` — candidate liftings."""
    out = []
    for b in range(1, int(math.isqrt(total)) + 1):
        if total % b == 0:
            out.append((total // b, b))
            if b != total // b:
                out.append((b, total // b))
    return sorted(out)
