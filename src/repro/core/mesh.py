"""Mesh shapes: the device level of the paper's dimension lifting.

The paper's Definition 3.1 partitions a shape component so that "each
partitioned shape is used to identify an architectural resource".  The
schedule subsystem already lifts onto *on-chip* resources (proc / vector /
sigma block); a ``MeshShape`` stacks one more level — named device axes — on
top of a ``HardwareShape``, so the same ``lift_loop`` rewrite can split any
logical axis ``size -> (mesh, proc, vector, block)``.

A mesh-lifted loop is tagged with the resource ``"mesh:<axis>"``.  Such a
loop has no single-chip schedule (``derive_schedule`` rejects it); instead
``distributed.plan.derive_plan`` reads the mesh-tagged Access coefficients
back out as ``PartitionSpec`` entries and a collective schedule, and derives
the per-shard schedule from the *local* (mesh-divided) extents.  This is the
BSP-style bridging model of the paper applied end to end: one normal form,
three hardware levels.

Pure Python + dataclasses — importing this module never touches jax device
state; ``from_jax_mesh`` accepts a ``jax.sharding.Mesh`` duck-typed (only
``axis_names`` and ``devices.shape`` are read).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.lifting import HardwareShape
from repro.core.moa import pi
from repro.core.onf import Onf, lift_loop

#: resource-tag prefix for mesh-lifted loops: "mesh:<axis-name>"
MESH_RESOURCE_PREFIX = "mesh:"


def mesh_resource(axis_name: str) -> str:
    return MESH_RESOURCE_PREFIX + axis_name


def is_mesh_resource(resource) -> bool:
    return isinstance(resource, str) and resource.startswith(MESH_RESOURCE_PREFIX)


def mesh_axis_of(resource: str) -> str:
    """Inverse of ``mesh_resource``: the device axis a lifted loop indexes."""
    if not is_mesh_resource(resource):
        raise ValueError(f"{resource!r} is not a mesh resource tag")
    return resource[len(MESH_RESOURCE_PREFIX):]


@dataclass(frozen=True)
class MeshShape:
    """Named device axes, outermost hardware level of the lifting hierarchy.

    ``axes`` are ordered (name, size) pairs — the same shape a
    ``jax.sharding.Mesh`` has, without the device objects, so plans can be
    derived (and tested) with no devices attached.
    """
    axes: tuple[tuple[str, int], ...]

    def __post_init__(self):
        names = [n for n, _ in self.axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mesh axis in {names}")
        for n, s in self.axes:
            if int(s) < 1:
                raise ValueError(f"mesh axis {n!r} has non-positive size {s}")

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.axes)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(s for _, s in self.axes)

    @property
    def n_devices(self) -> int:
        return pi(self.shape)

    def axis_size(self, name: str) -> int:
        for n, s in self.axes:
            if n == name:
                return s
        raise KeyError(f"unknown mesh axis {name!r}; have {self.axis_names}")

    @staticmethod
    def from_hardware(hardware: HardwareShape) -> "MeshShape":
        """The registry's hardware shapes already declare their mesh axes
        (paper Table 1's outermost rows); this instantiates them."""
        return MeshShape(tuple(hardware.mesh_axes))


def from_jax_mesh(mesh) -> MeshShape:
    """MeshShape of a ``jax.sharding.Mesh`` (duck-typed; no jax import)."""
    if isinstance(mesh, MeshShape):
        return mesh
    return MeshShape(tuple(zip(tuple(mesh.axis_names),
                               tuple(mesh.devices.shape))))


def mesh_lift(o: Onf, index: str, mesh: MeshShape, axis_name: str) -> Onf:
    """One more dimension lift: split loop ``index`` over device axis
    ``axis_name`` — ``i -> (i_o over mesh:<axis>, i_i)`` — with the same
    affine Access rewrite every other lift uses."""
    return lift_loop(o, index, mesh.axis_size(axis_name),
                     mesh_resource(axis_name))
