"""DNF -> ONF derivation: symbolic loop nests from MoA expressions.

The paper derives code in three steps:

  1. DNF (Psi reduction): compose all Cartesian indexing — minimal semantics,
     all parallelism explicit.
  2. ONF (apply a gamma layout): Cartesian indices become flat offsets —
     paper eq. (3)/(4): ``C[(i*p)+j] += A[(i*n)+k] * B[(k*p)+j]``.
  3. Dimension lifting: split loop bounds and tag each split with a resource
     (paper figs 4, 5) — the lifted ONF *is* the parallel program.

Here an ``Onf`` is a symbolic loop-nest description: loop axes (with extents
and resource tags after lifting) + flat affine access functions per operand.
Emitters turn an ``Onf`` into (a) an executable numpy interpreter (the
semantic oracle used by tests), (b) a summary of innermost strides (feeding
the cost/energy models), and (c) the C-like text of the paper's figures for
documentation/debug.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core import semiring
from repro.core.lifting import LiftedAxis, lift
from repro.core.moa import pi


@dataclass(frozen=True)
class Loop:
    """One loop of the nest.  ``resource`` tags lifted loops (paper fig 4/5:
    the np / ip split of i; jp / kp split of j; sigma blocks of k)."""
    index: str
    extent: int
    resource: Optional[str] = None      # None = sequential; "grid"/"data"/...


@dataclass(frozen=True)
class Access:
    """Flat affine access  base[ const + sum_i coeff[index_i] * index_i ].

    ``const`` carries psi views (leading indices fixed to constants)."""
    array: str
    coeffs: dict[str, int]
    const: int = 0

    def offset(self, env: dict[str, int]) -> int:
        return self.const + sum(c * env[i] for i, c in self.coeffs.items())

    def stride_in(self, index: str) -> int:
        return self.coeffs.get(index, 0)

    def render(self) -> str:
        terms = [f"({c}*{i})" if c != 1 else i
                 for i, c in self.coeffs.items() if c != 0]
        if self.const:
            terms.append(str(self.const))
        return f"{self.array}[{' + '.join(terms) if terms else '0'}]"


@dataclass(frozen=True)
class Onf:
    """out[...] (reduce)= combine(in_0[...], in_1[...]) over the loop nest.

    ``combine`` / ``reduce_op`` are names in the ``core.semiring`` registry
    ("mul"/"add" is the linear inner product; "add"/"max" is max-plus), so a
    normal form names its semiring symbolically and every emitter — the numpy
    oracle here, the Pallas emitter in ``kernels/emit.py`` — resolves it
    locally.
    """
    name: str
    loops: tuple[Loop, ...]
    out: Access
    ins: tuple[Access, ...]
    reduce_indices: frozenset[str] = frozenset()
    combine: str = "mul"
    reduce_op: str = "add"

    @property
    def identity(self) -> float:
        """The reduce op's unit — what the output accumulator starts at."""
        return semiring.reduce_def(self.reduce_op).identity

    def init_out(self, n: int, dtype=np.float32) -> np.ndarray:
        """A fresh accumulator buffer for ``execute`` (identity-filled)."""
        return np.full(n, self.identity if self.reduce_indices else 0.0,
                       dtype=dtype)

    def key(self) -> tuple:
        """Canonical hashable normal-form key: loops, accesses, semiring.

        Two expressions with the same key derive the same schedule — this is
        what the schedule cache is keyed on.  Loop index names are
        canonicalized positionally (``L0, L1, ...``) so a normal form's
        identity does not depend on how its axes were *named*, only on the
        nest's structure; ``name`` is display-only and excluded.
        """
        ren = {l.index: f"L{i}" for i, l in enumerate(self.loops)}

        def acc(a: Access) -> tuple:
            return (a.array,
                    tuple(sorted((ren[s], c) for s, c in a.coeffs.items())),
                    a.const)

        return (tuple((ren[l.index], l.extent, l.resource)
                      for l in self.loops),
                acc(self.out), tuple(acc(a) for a in self.ins),
                tuple(sorted(ren[s] for s in self.reduce_indices)),
                self.combine, self.reduce_op)

    # -- emitter (a): executable oracle ------------------------------------
    def execute(self, out_flat: np.ndarray, *in_flats: np.ndarray) -> np.ndarray:
        comb = semiring.combine_def(self.combine).np_fn
        red = semiring.reduce_def(self.reduce_op).np_fn
        out = np.array(out_flat, copy=True)
        extents = [l.extent for l in self.loops]
        names = [l.index for l in self.loops]
        for flat in np.ndindex(*extents):
            env = dict(zip(names, flat))
            vals = [f[a.offset(env)] for f, a in zip(in_flats, self.ins)]
            v = functools.reduce(comb, vals)
            o = self.out.offset(env)
            if self.reduce_indices:
                out[o] = red(out[o], v)
            else:
                out[o] = v
        return out

    # -- emitter (b): innermost stride summary ------------------------------
    def innermost_strides(self) -> dict[str, int]:
        inner = self.loops[-1].index
        d = {a.array: a.stride_in(inner) for a in self.ins}
        d[self.out.array] = self.out.stride_in(inner)
        return d

    # -- emitter (c): the paper's C-like rendering ---------------------------
    def render_c(self) -> str:
        lines = []
        indent = ""
        for l in self.loops:
            tag = f"  /* lifted: {l.resource} */" if l.resource else ""
            lines.append(f"{indent}for ({l.index}=0; {l.index}<{l.extent}; {l.index}++){tag}")
            indent += "  "
        if not self.reduce_indices:
            op = "="
        else:
            op = "+=" if self.reduce_op == "add" else f"{self.reduce_op}="
        glyph = {"mul": " * ", "add": " + "}.get(self.combine, f" {self.combine} ")
        rhs = glyph.join(a.render() for a in self.ins)
        lines.append(f"{indent}{self.out.render()} {op} {rhs};")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the paper's normal forms
# ---------------------------------------------------------------------------

def gemm_onf(m: int, n: int, p: int) -> Onf:
    """Paper eq. (3): loops (i, k, j) so the innermost loop streams B and C
    contiguously (fig 1 / ip.c of fig 3).

    .. deprecated:: now a thin wrapper over the expression algebra —
       compose ``expr.inner("add", "mul", ...)`` and ``expr.normalize``
       directly; this alias is kept for one release.
    """
    from repro.core import expr as E
    return E.normalize(E.inner("add", "mul", E.arr("A", (m, n)),
                               E.arr("B", (n, p))),
                       name="moa_gemm", out_axes=("i", "j"),
                       reduce_axes=("k",))


def gemm_classical_onf(m: int, n: int, p: int) -> Onf:
    """Row-column baseline: loops (i, j, k); innermost strides B by p.

    .. deprecated:: thin wrapper — the same normal form as ``gemm_onf``
       with the sigma loop rotated innermost (``reorder_loops``).
    """
    import dataclasses
    return reorder_loops(
        dataclasses.replace(gemm_onf(m, n, p), name="classical_gemm"),
        ("i", "j", "k"))


def lift_loop(onf: Onf, index: str, factor: int, resource: str,
              outer_first: bool = True) -> Onf:
    """Dimension-lift one loop: i -> (i_o, i_i) with i = i_o*inner + i_i,
    tagging the outer loop with the resource (paper figs 4/5).

    Access functions rewrite affinely: coeff(i) -> coeff(i)*inner for i_o and
    coeff(i) for i_i.  The lifted outer loop is hoisted to the front (it
    indexes processors — order among resource loops is free by independence).
    """
    loops, lifted_out, lifted_in = [], None, None
    for l in onf.loops:
        if l.index != index:
            loops.append(l)
            continue
        if l.extent % factor:
            raise ValueError(f"{factor} does not divide extent {l.extent} of {index}")
        inner = l.extent // factor
        lifted_out = Loop(index + "_o", factor, resource)
        lifted_in = Loop(index + "_i", inner, l.resource)
        loops.append(lifted_in)
    if lifted_out is None:
        raise KeyError(index)
    loops = ([lifted_out] + loops) if outer_first else (loops + [lifted_out])

    inner_extent = lifted_in.extent

    def rewrite(a: Access) -> Access:
        if index not in a.coeffs:
            return a
        c = dict(a.coeffs)
        k = c.pop(index)
        c[index + "_o"] = k * inner_extent
        c[index + "_i"] = k
        return Access(a.array, c, a.const)

    red = set(onf.reduce_indices)
    if index in red:
        red.discard(index)
        red |= {index + "_o", index + "_i"}
    return Onf(onf.name + f"+lift({index},{resource})", tuple(loops),
               rewrite(onf.out), tuple(rewrite(a) for a in onf.ins),
               frozenset(red), onf.combine, onf.reduce_op)


def reorder_loops(onf: Onf, order: Sequence[str]) -> Onf:
    """Permute the (sequential) loop nest — accesses are order-independent;
    only the streaming pattern (innermost strides) changes."""
    by_name = {l.index: l for l in onf.loops}
    if sorted(order) != sorted(by_name):
        raise ValueError(f"order {tuple(order)} does not permute "
                         f"{tuple(by_name)}")
    return Onf(onf.name, tuple(by_name[i] for i in order), onf.out, onf.ins,
               onf.reduce_indices, onf.combine, onf.reduce_op)


def gemm_lifted_rows(m: int, n: int, p: int, np_procs: int) -> Onf:
    """Paper fig 4 (ip_rows.c): lift i over processors."""
    return lift_loop(gemm_onf(m, n, p), "i", np_procs, "proc")


def gemm_lifted_cols(m: int, n: int, p: int, rsize: int) -> Onf:
    """Paper fig 5 (ip_cols.c): lift j into groups of ``rsize`` (vector
    registers / thread groups)."""
    assert p % rsize == 0
    return lift_loop(gemm_onf(m, n, p), "j", p // rsize, "vector")


def gemm_fully_lifted(m: int, n: int, p: int, *, procs: int, bk: int,
                      bn: int) -> Onf:
    """The paper's full schedule (fig 2): rows over processors, k into
    sigma-blocks (the extra addition loop over blocks), j into register
    groups — a 6-deep nest from the 3-deep ONF."""
    o = gemm_onf(m, n, p)
    o = lift_loop(o, "i", procs, "proc")
    o = lift_loop(o, "k", max(n // bk, 1), "block")
    o = lift_loop(o, "j", max(p // bn, 1), "vector")
    return o


def expert_gemm_onf(e: int, cap: int, d: int, f: int) -> Onf:
    """Capacity-padded MoE expert GEMM as an ONF:

        C[(ee*cap + i)*f + j] += X[(ee*cap + i)*d + k] * W[(ee*d + k)*f + j]

    The expert axis ``ee`` batches ``e`` independent MoA GEMMs over flat
    row-major (E, cap, d) / (E, d, f) / (E, cap, f) buffers.

    .. deprecated:: thin wrapper — a batched generalized inner product,
       ``expr.inner("add", "mul", X, W, batch=1)``.
    """
    from repro.core import expr as E
    return E.normalize(E.expert_gemm_expr(e, cap, d, f),
                       name="expert_gemm", out_axes=("e", "i", "j"),
                       reduce_axes=("k",))


def expert_gemm_fully_lifted(e: int, cap: int, d: int, f: int, *, bm: int,
                             bk: int, bn: int) -> Onf:
    """The expert GEMM schedule is ONE MORE dimension lift of fig 2: the
    expert axis lifts fully onto a processor resource (each grid cell an
    independent MoA GEMM), then rows/sigma-blocks/register-groups as before."""
    o = expert_gemm_onf(e, cap, d, f)
    o = lift_loop(o, "e", e, "proc")
    o = lift_loop(o, "i", max(cap // bm, 1), "proc")
    o = lift_loop(o, "k", max(d // bk, 1), "block")
    o = lift_loop(o, "j", max(f // bn, 1), "vector")
    return o


def hadamard_onf(m: int, n: int) -> Onf:
    """Elementwise product — the contraction-degenerate member of the unified
    ipophp circuit: same nest shape, empty reduce set.

    .. deprecated:: thin wrapper — ``expr.combine("mul", A, B)``.
    """
    from repro.core import expr as E
    return E.normalize(E.hadamard_expr(m, n), name="hadamard",
                       out_axes=("i", "j"))


def hadamard_lifted(m: int, n: int, *, bm: int, bn: int) -> Onf:
    """Blocked Hadamard: both axes lifted, no sigma loop."""
    o = hadamard_onf(m, n)
    o = lift_loop(o, "i", max(m // bm, 1), "proc")
    o = lift_loop(o, "j", max(n // bn, 1), "vector")
    return o
