"""The combine registry: pairing/accumulation operators for ONF loop nests.

The paper's derivation never inspects *what* the loop body computes — only
its access pattern.  The body is a semiring: a pairing ("combine") op applied
across operands and an accumulation ("reduce") op folding the contraction
axes.  ``(mul, add)`` is the linear-algebra inner product; ``(add, max)`` /
``(add, min)`` are the tropical semirings (longest / shortest path), which
route through the *same* ``normalize -> derive_schedule -> emit_pallas``
pipeline because the access pattern is identical.

This module is the registry both ends share: ``core.onf.Onf.execute`` (the
numpy oracle) resolves names through ``np_combine``/``np_reduce``, and
``kernels/emit.py`` resolves the same names to jnp callables by attribute
(kept as strings here so core stays jax-free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass(frozen=True)
class CombineDef:
    """A pairing operator: applied between operand elements."""
    name: str
    np_fn: Callable
    jnp_name: str                  # attribute of jax.numpy (binary ufunc)


@dataclass(frozen=True)
class ReduceDef:
    """An accumulation operator: folds a contraction axis.

    ``identity`` is the fold's unit (0 for add, -inf for max); ``jnp_name``
    the elementwise jnp binary, ``jnp_reducer`` the axis-reducing jnp call.
    """
    name: str
    np_fn: Callable
    identity: float
    jnp_name: str                  # elementwise: "add" / "maximum" / "minimum"
    jnp_reducer: str               # axis fold: "sum" / "max" / "min"


_COMBINES: dict[str, CombineDef] = {}
_REDUCES: dict[str, ReduceDef] = {}


def register_combine(d: CombineDef) -> CombineDef:
    _COMBINES[d.name] = d
    return d


def register_reduce(d: ReduceDef) -> ReduceDef:
    _REDUCES[d.name] = d
    return d


def combine_def(name: str) -> CombineDef:
    try:
        return _COMBINES[name]
    except KeyError:
        raise ValueError(
            f"unknown combine op {name!r}; registered: {sorted(_COMBINES)}"
        ) from None


def reduce_def(name: str) -> ReduceDef:
    try:
        return _REDUCES[name]
    except KeyError:
        raise ValueError(
            f"unknown reduce op {name!r}; registered: {sorted(_REDUCES)}"
        ) from None


register_combine(CombineDef("mul", np.multiply, "multiply"))
register_combine(CombineDef("add", np.add, "add"))

register_reduce(ReduceDef("add", np.add, 0.0, "add", "sum"))
register_reduce(ReduceDef("max", np.maximum, float("-inf"), "maximum", "max"))
register_reduce(ReduceDef("min", np.minimum, float("inf"), "minimum", "min"))


#: safe padding values per (combine, reduce): padding both operands of a
#: contraction axis with ``v`` must contribute the reduce identity, i.e.
#: combine(v, v) == identity(reduce).  (mul, add): 0*0 = 0; tropical
#: (add, max): -inf + -inf = -inf; (add, min): inf + inf = inf.
_PAD_VALUES = {
    ("mul", "add"): 0.0,
    ("add", "add"): 0.0,
    ("add", "max"): float("-inf"),
    ("add", "min"): float("inf"),
}


@dataclass(frozen=True)
class AccumDef:
    """An accumulation dtype entry: what the MXU/partial-sum register holds.

    The paper's working-set model already takes an element size for the
    accumulator; this registry pins down *which* accumulators are legal for
    which input dtypes (and on which semirings), so the solver and the
    emitter agree.  ``flops_scale`` is the throughput multiplier relative to
    f32 accumulation on the same unit (bf16 partial sums double MXU issue
    rate on v5e-class parts; int8 quadruples it).
    """
    name: str                       # jnp dtype name used as the accumulator
    itemsize: int                   # bytes per accumulator element
    inputs: tuple                   # input dtype names this accumulator serves
    flops_scale: float = 1.0        # peak-flops multiplier vs f32 accumulation


_ACCUMS: dict[str, AccumDef] = {}


def register_accum(d: AccumDef) -> AccumDef:
    _ACCUMS[d.name] = d
    return d


def accum_def(name: str) -> AccumDef:
    try:
        return _ACCUMS[name]
    except KeyError:
        raise ValueError(
            f"unknown accumulation dtype {name!r}; registered: "
            f"{sorted(_ACCUMS)}") from None


def registered_accums() -> tuple:
    return tuple(sorted(_ACCUMS))


register_accum(AccumDef("float32", 4,
                        ("float32", "bfloat16", "float16"), 1.0))
register_accum(AccumDef("bfloat16", 2, ("bfloat16",), 2.0))
register_accum(AccumDef("int32", 4, ("int8",), 4.0))


def check_accum(acc_dtype: str, in_dtype: str, combine: str,
                reduce_op: str) -> AccumDef:
    """Validate an (input dtype, accumulator, semiring) triple.

    Only the linear (mul, add) semiring has hardware accumulation paths;
    tropical semirings fold through the VPU at the input width and must use
    the f32 accumulator.
    """
    d = accum_def(acc_dtype)
    if acc_dtype != "float32" and (combine, reduce_op) != ("mul", "add"):
        raise ValueError(
            f"acc_dtype={acc_dtype!r} is only defined for the (mul, add) "
            f"semiring, not ({combine!r}, {reduce_op!r})")
    if in_dtype not in d.inputs:
        raise ValueError(
            f"acc_dtype={acc_dtype!r} does not accept {in_dtype!r} inputs "
            f"(accepts {d.inputs})")
    return d


#: finite stand-in for -inf used by masked online-softmax reductions: large
#: enough that exp(x - m) underflows to exactly 0.0 for masked entries, but
#: finite so max/subtraction arithmetic never produces NaNs.  One definition,
#: shared by the Pallas emitter and the jnp oracles — the kernel's mask
#: sentinel and its recompute-based backward must never diverge.
MASK_NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def pad_value(combine: str, reduce_op: str) -> float:
    """The element to pad contraction axes with so padded blocks are inert."""
    try:
        return _PAD_VALUES[(combine, reduce_op)]
    except KeyError:
        raise ValueError(
            f"no inert padding element known for semiring "
            f"({combine!r}, {reduce_op!r}); pad operands to block multiples "
            "by hand") from None
