"""The combine registry: pairing/accumulation operators for ONF loop nests.

The paper's derivation never inspects *what* the loop body computes — only
its access pattern.  The body is a semiring: a pairing ("combine") op applied
across operands and an accumulation ("reduce") op folding the contraction
axes.  ``(mul, add)`` is the linear-algebra inner product; ``(add, max)`` /
``(add, min)`` are the tropical semirings (longest / shortest path), which
route through the *same* ``normalize -> derive_schedule -> emit_pallas``
pipeline because the access pattern is identical.

This module is the registry both ends share: ``core.onf.Onf.execute`` (the
numpy oracle) resolves names through ``np_combine``/``np_reduce``, and
``kernels/emit.py`` resolves the same names to jnp callables by attribute
(kept as strings here so core stays jax-free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass(frozen=True)
class CombineDef:
    """A pairing operator: applied between operand elements."""
    name: str
    np_fn: Callable
    jnp_name: str                  # attribute of jax.numpy (binary ufunc)


@dataclass(frozen=True)
class ReduceDef:
    """An accumulation operator: folds a contraction axis.

    ``identity`` is the fold's unit (0 for add, -inf for max); ``jnp_name``
    the elementwise jnp binary, ``jnp_reducer`` the axis-reducing jnp call.
    """
    name: str
    np_fn: Callable
    identity: float
    jnp_name: str                  # elementwise: "add" / "maximum" / "minimum"
    jnp_reducer: str               # axis fold: "sum" / "max" / "min"


_COMBINES: dict[str, CombineDef] = {}
_REDUCES: dict[str, ReduceDef] = {}


def register_combine(d: CombineDef) -> CombineDef:
    _COMBINES[d.name] = d
    return d


def register_reduce(d: ReduceDef) -> ReduceDef:
    _REDUCES[d.name] = d
    return d


def combine_def(name: str) -> CombineDef:
    try:
        return _COMBINES[name]
    except KeyError:
        raise ValueError(
            f"unknown combine op {name!r}; registered: {sorted(_COMBINES)}"
        ) from None


def reduce_def(name: str) -> ReduceDef:
    try:
        return _REDUCES[name]
    except KeyError:
        raise ValueError(
            f"unknown reduce op {name!r}; registered: {sorted(_REDUCES)}"
        ) from None


register_combine(CombineDef("mul", np.multiply, "multiply"))
register_combine(CombineDef("add", np.add, "add"))

register_reduce(ReduceDef("add", np.add, 0.0, "add", "sum"))
register_reduce(ReduceDef("max", np.maximum, float("-inf"), "maximum", "max"))
register_reduce(ReduceDef("min", np.minimum, float("inf"), "minimum", "min"))


#: safe padding values per (combine, reduce): padding both operands of a
#: contraction axis with ``v`` must contribute the reduce identity, i.e.
#: combine(v, v) == identity(reduce).  (mul, add): 0*0 = 0; tropical
#: (add, max): -inf + -inf = -inf; (add, min): inf + inf = inf.
_PAD_VALUES = {
    ("mul", "add"): 0.0,
    ("add", "add"): 0.0,
    ("add", "max"): float("-inf"),
    ("add", "min"): float("inf"),
}


#: finite stand-in for -inf used by masked online-softmax reductions: large
#: enough that exp(x - m) underflows to exactly 0.0 for masked entries, but
#: finite so max/subtraction arithmetic never produces NaNs.  One definition,
#: shared by the Pallas emitter and the jnp oracles — the kernel's mask
#: sentinel and its recompute-based backward must never diverge.
MASK_NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def pad_value(combine: str, reduce_op: str) -> float:
    """The element to pad contraction axes with so padded blocks are inert."""
    try:
        return _PAD_VALUES[(combine, reduce_op)]
    except KeyError:
        raise ValueError(
            f"no inert padding element known for semiring "
            f"({combine!r}, {reduce_op!r}); pad operands to block multiples "
            "by hand") from None
