"""Derive Pallas schedules from lifted ONF loop nests.

This is the paper's missing executable link: "code was derived from the MoA
expression's normal form" — here literally.  ``derive_schedule`` consumes a
*lifted* ``Onf`` (the symbolic artifact of ``lift_loop`` over a normalized
expression) plus a ``HardwareShape`` and computes everything a
``pl.pallas_call`` needs:

* grid extents — the resource-tagged loops, parallel resources first,
  sigma-block (reduction) loops last;
* per-operand block shapes and index maps — recovered from the affine
  ``Access`` coefficients (each operand must be a dense view of its loop
  axes through *some* gamma — row- or column-major — which the derivation
  *verifies*, it does not assume: a transposed operand simply presents its
  axes in the other order);
* ``dimension_semantics`` — "proc"/"vector"/"grid"/"expert" resources are
  parallel, "block" (the sigma loop) is arbitrary;
* the scratch accumulator implied by a lifted reduce axis, initialized to
  the reduce op's identity (0 for add, -inf for max-plus).

``kernels/emit.py`` turns a ``Schedule`` into an executable kernel.  This
module is pure Python + dataclasses (no jax import), so deriving schedules
never touches device state, and a process-wide LRU cache keyed on the
*expression's normal form* (``Onf.key()``) makes repeated derivation (and
the brute force ``solve_blocks`` search inside it) free on hot
serving/training paths.  The old string-keyed ``get_schedule("gemm", ...)``
signature is kept for one release behind a ``DeprecationWarning``.
"""
from __future__ import annotations

import string
import threading
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Union

from repro.core import expr as expr_mod
from repro.core import onf as onf_mod
from repro.core.blocking import BlockChoice, solve_blocks, _dtype_size
from repro.core.lifting import HardwareShape
from repro.core.mesh import is_mesh_resource
from repro.core.moa import pi

#: resources whose grid loops are independent ("parallel" to Mosaic); the
#: sigma block loop ("block") carries the accumulator and stays "arbitrary".
PARALLEL_RESOURCES = frozenset({"proc", "vector", "grid", "expert"})

#: synthetic operand axis for psi views: the flat leading slab a constant
#: Access offset addresses (block extent 1, block index pinned at the slab)
PSI_AXIS = "_psi"


def _base(index: str) -> str:
    """Logical axis behind a lifted loop index: i_o / i_i -> i."""
    return index[:-2] if index.endswith(("_o", "_i")) else index


@dataclass(frozen=True)
class GridAxis:
    index: str           # lifted loop index, e.g. "i_o"
    base: str            # logical axis it partitions, e.g. "i"
    extent: int
    semantics: str       # "parallel" | "arbitrary"


@dataclass(frozen=True)
class OperandSpec:
    """One operand's BlockSpec, symbolically: which logical axis each array
    dimension walks, its full (padded) extent, the VMEM-resident block extent,
    and which grid position drives the block index (None -> pinned at 0).

    ``offsets`` are constant block-index offsets added per dimension — the
    BlockSpec lowering of a psi view's constant Access term.  A non-psi
    operand has all-zero offsets; a psi operand carries one leading
    ``PSI_AXIS`` dimension (block extent 1) whose offset pins it at the
    viewed slab."""
    array: str
    axes: tuple[str, ...]
    shape: tuple[int, ...]
    block: tuple[int, ...]
    grid_dims: tuple[Optional[int], ...]
    offsets: tuple[int, ...] = ()

    @property
    def is_psi_view(self) -> bool:
        return bool(self.axes) and self.axes[0] == PSI_AXIS


@dataclass(frozen=True)
class Schedule:
    """Everything ``pl.pallas_call`` needs, derived — not hand-written."""
    name: str
    grid: tuple[GridAxis, ...]
    ins: tuple[OperandSpec, ...]
    out: OperandSpec
    contracted: tuple[str, ...]          # logical axes reduced inside a block
    reduce_grid_dim: Optional[int]       # grid axis accumulated across steps
    combine: str = "mul"                 # semiring pairing (core.semiring)
    reduce_op: str = "add"               # semiring accumulation

    @property
    def grid_extents(self) -> tuple[int, ...]:
        return tuple(g.extent for g in self.grid)

    @property
    def dimension_semantics(self) -> tuple[str, ...]:
        return tuple(g.semantics for g in self.grid)

    @property
    def needs_scratch(self) -> bool:
        return self.reduce_grid_dim is not None

    def einsum_plan(self) -> tuple[str, tuple[tuple[int, ...], ...]]:
        """The in-block computation as an einsum over non-unit block axes.

        Returns ``(spec, kept_dims_per_input)``: each input ref is reshaped to
        its kept (block extent > 1) dims, contracted per ``spec``, and the
        result reshaped back to the output block.  Unit axes (e.g. the lifted
        expert axis, block extent 1) drop out of the contraction — summing a
        one-element axis is the identity — which keeps the emitted body
        bit-identical to a hand-written 2-D ``jnp.dot``.
        """
        letters: dict[str, str] = {}
        pool = iter(string.ascii_lowercase)
        for spec_ in (self.out,) + self.ins:
            for ax in spec_.axes:
                if ax not in letters:
                    letters[ax] = next(pool)
        in_specs, in_keep = [], []
        for opn in self.ins:
            keep = tuple(i for i, b in enumerate(opn.block) if b > 1)
            in_keep.append(keep)
            in_specs.append("".join(letters[opn.axes[i]] for i in keep))
        out_spec = "".join(letters[self.out.axes[i]]
                           for i, b in enumerate(self.out.block) if b > 1)
        return ",".join(in_specs) + "->" + out_spec, tuple(in_keep)

    def vmem_bytes(self, dtype, buffering: int = 2, acc_bytes: int = 4) -> int:
        """Modeled resident working set: double-buffered input blocks plus
        the output block and (if reducing) the f32 accumulator."""
        esize = _dtype_size(dtype)
        ws = sum(pi(opn.block) for opn in self.ins) * esize * buffering
        ws += pi(self.out.block) * esize
        if self.needs_scratch:
            ws += pi(self.out.block) * acc_bytes
        return ws


def derive_schedule(o: "onf_mod.Onf", hardware: Optional[HardwareShape] = None,
                    dtype="float32") -> Schedule:
    """Derive the full Pallas schedule from a lifted ONF.

    Raises ``ValueError`` if the nest is not lifted, if an access is not a
    dense row-major view of its loop axes, or if the derived blocks exceed
    the hardware's VMEM capacity (when ``hardware`` is given).
    """
    if any(is_mesh_resource(l.resource) for l in o.loops):
        raise ValueError(
            f"Onf {o.name!r} has mesh-lifted loops — a single-chip schedule "
            "cannot honor a device axis; derive a DistributedPlan "
            "(repro.distributed.plan.derive_plan) and schedule its per-shard "
            "normal form instead")
    grid_loops = [l for l in o.loops if l.resource is not None]
    inner_loops = [l for l in o.loops if l.resource is None]
    if not grid_loops:
        raise ValueError(
            f"Onf {o.name!r} has no resource-tagged loops — lift it first "
            "(lift_loop / gemm_fully_lifted)")
    reduce_bases = {_base(i) for i in o.reduce_indices}

    # logical extents and in-block (inner) extents per base axis
    full_extent: dict[str, int] = {}
    inner_extent: dict[str, int] = {}
    for l in o.loops:
        b = _base(l.index)
        full_extent[b] = full_extent.get(b, 1) * l.extent
        if l.resource is None:
            inner_extent[b] = inner_extent.get(b, 1) * l.extent

    # grid ordering: parallel loops first, sigma/reduce loops last, each
    # group in the order their base axes appear in the remaining inner nest
    # (order among resource loops is free by independence — paper fig 4)
    inner_order: list[str] = []
    for l in inner_loops:
        b = _base(l.index)
        if b not in inner_order:
            inner_order.append(b)

    def _position(loop) -> int:
        b = _base(loop.index)
        return inner_order.index(b) if b in inner_order else len(inner_order)

    def _semantics(loop) -> str:
        if loop.resource in PARALLEL_RESOURCES and _base(loop.index) not in reduce_bases:
            return "parallel"
        return "arbitrary"

    ordered = (sorted([l for l in grid_loops if _semantics(l) == "parallel"],
                      key=_position)
               + sorted([l for l in grid_loops if _semantics(l) == "arbitrary"],
                        key=_position))
    grid = tuple(GridAxis(l.index, _base(l.index), l.extent, _semantics(l))
                 for l in ordered)
    grid_pos: dict[str, int] = {}
    for i, g in enumerate(grid):
        if g.base in grid_pos:
            raise ValueError(f"axis {g.base!r} lifted onto two grid resources")
        grid_pos[g.base] = i

    def _operand(a: "onf_mod.Access") -> OperandSpec:
        strides: dict[str, int] = {}
        for idx, c in a.coeffs.items():
            if c == 0:
                continue
            b = _base(idx)
            strides[b] = min(strides.get(b, c), c)
            # a lifted pair must stay a single blocked axis: coeff(x_o) ==
            # coeff(x_i) * |x_i| (the lift_loop rewrite, and nothing else)
        for idx, c in a.coeffs.items():
            b = _base(idx)
            if idx.endswith("_o") and c != strides[b] * inner_extent.get(b, 1):
                raise ValueError(
                    f"{a.array}: {idx} coefficient {c} inconsistent with a "
                    f"row-major lift of {b!r}")
        axes = sorted(strides, key=lambda b: -strides[b])
        expected = 1
        for b in reversed(axes):
            if strides[b] != expected:
                raise ValueError(
                    f"{a.array} is not a dense row-major view: axis {b!r} "
                    f"stride {strides[b]}, expected {expected}")
            expected *= full_extent[b]
        axes_t = tuple(axes)
        shape = tuple(full_extent[b] for b in axes)
        block = tuple(inner_extent.get(b, 1) for b in axes)
        gdims = tuple(grid_pos.get(b) for b in axes)
        offs = (0,) * len(axes)
        if a.const:
            # a psi view: the constant offset must address whole leading
            # slabs of the dense loop-axis view; it lowers to one extra
            # leading dimension of block extent 1 whose block index is
            # pinned at the viewed slab (the index-map offset)
            if a.const % expected:
                raise ValueError(
                    f"{a.array}: constant offset {a.const} (a psi view) is "
                    f"not a multiple of the slab size {expected} — no "
                    "BlockSpec lowering; materialize the view first")
            slab = a.const // expected
            axes_t = (PSI_AXIS,) + axes_t
            shape = (slab + 1,) + shape
            block = (1,) + block
            gdims = (None,) + gdims
            offs = (slab,) + offs
        return OperandSpec(a.array, axes_t, shape, block, gdims, offs)

    out_spec = _operand(o.out)
    in_specs = tuple(_operand(a) for a in o.ins)

    in_bases = {b for s in in_specs for b in s.axes}
    contracted = tuple(b for b in inner_order
                       if b in reduce_bases and b in in_bases
                       and b not in out_spec.axes)
    reduce_dims = [i for i, g in enumerate(grid) if g.base in reduce_bases]
    if len(reduce_dims) > 1:
        raise ValueError("more than one lifted reduction axis is unsupported")
    reduce_grid_dim = reduce_dims[0] if reduce_dims else None

    sched = Schedule(o.name, grid, in_specs, out_spec, contracted,
                     reduce_grid_dim, o.combine, o.reduce_op)
    if hardware is not None:
        ws = sched.vmem_bytes(dtype)
        if ws > hardware.vmem.capacity_bytes:
            raise ValueError(
                f"derived blocks need {ws} B VMEM, over {hardware.name}'s "
                f"{hardware.vmem.capacity_bytes} B capacity")
    return sched


# ---------------------------------------------------------------------------
# block policies (the static a-priori choices of paper §3.3/3.4)
# ---------------------------------------------------------------------------

def default_gemm_blocks(m: int, k: int, n: int, dtype,
                        hardware: HardwareShape) -> BlockChoice:
    """Solver defaults tuned for kernel use: quarter-VMEM budget keeps
    double-buffering headroom; caps keep the grid >= a few cells."""
    return solve_blocks(min(m, 512), min(k, 2048), min(n, 512), dtype,
                        hardware=hardware, vmem_budget_frac=0.25)


def _pad(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# ---------------------------------------------------------------------------
# the process-wide schedule cache — keyed on expression normal forms
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScheduleBundle:
    """A cached derivation: the schedule plus the block choice and shapes the
    executor needs for pad/slice.  ``schedule.ins[i].shape`` is the padded
    *storage* shape operand ``i`` must be padded to; ``in_shapes`` are the
    logical storage shapes callers bind (a col-layout leaf's is reversed);
    ``out_shape`` the logical result shape."""
    op: str
    schedule: Schedule
    blocks: Optional[BlockChoice]
    shapes: tuple[int, ...]          # logical loop extents (out + reduce)
    padded: tuple[int, ...]          # same, padded to block multiples
    out_shape: tuple[int, ...] = ()
    in_shapes: tuple[tuple[int, ...], ...] = ()


SCHEDULE_CACHE_SIZE = 256
_cache: "OrderedDict[tuple, ScheduleBundle]" = OrderedDict()
_lock = threading.Lock()
_stats = {"hits": 0, "misses": 0, "solves": 0}


def schedule_cache_stats() -> dict[str, int]:
    """Counters for tests/monitoring: cache hits/misses and how many times
    the brute-force ``solve_blocks`` search actually ran."""
    with _lock:
        return dict(_stats)


def reset_schedule_cache() -> None:
    with _lock:
        _cache.clear()
        for k in _stats:
            _stats[k] = 0


#: alignment for the last (lane) and second-minor axes when a non-solver
#: block policy applies (elementwise nests, semiring contractions)
_LANE, _SUBLANE = 128, 8


def _build_bundle(nf: "expr_mod.NormalForm", dtype, hw_shape,
                  blocks) -> ScheduleBundle:
    """Pad, lift and derive a schedule for any normalized expression.

    The policy generalizes the paper's fig-2 lifting: leading output axes
    lift fully onto "proc" resources (each grid cell independent), the
    trailing two output axes lift blockwise onto "proc"/"vector", and the
    first contraction axis lifts onto the sigma "block" resource.  Block
    extents come from ``solve_blocks`` for the (mul, add) semiring; other
    semirings use fixed MXU-aligned tiles (their in-block combine
    materializes a (bm, bn, bk) intermediate, so tiles stay small).
    """
    ext = nf.extent_map
    out_syms, red_syms = nf.out_axes, nf.reduce_axes
    msym = out_syms[-2] if len(out_syms) >= 2 else None
    nsym = out_syms[-1] if out_syms else None
    pads: dict[str, int] = {}
    if red_syms:
        ksym = red_syms[0]
        m = ext[msym] if msym else 1
        n = ext[nsym] if nsym else 1
        k = ext[ksym]
        if blocks is None:
            _stats["solves"] += 1
            if nf.combine == "mul" and nf.reduce_op == "add":
                blocks = default_gemm_blocks(m, k, n, dtype, hw_shape)
            else:
                # general semirings materialize a (bm, bn, bk) f32 combine
                # intermediate in-block (no MXU fusion): the same solver,
                # with that array added to the working-set model, replaces
                # the old fixed 128^3 tile
                blocks = solve_blocks(min(m, 512), min(k, 2048), min(n, 512),
                                      dtype, hardware=hw_shape,
                                      vmem_budget_frac=0.25,
                                      materialized_combine=True)
        bm, bk, bn = blocks.as_tuple()
        if msym:
            pads[msym] = _pad(m, bm)
        if nsym:
            pads[nsym] = _pad(n, bn)
        pads[ksym] = _pad(k, bk)
    else:
        bm, bn = blocks if blocks is not None else (
            min(_pad(ext[msym], _SUBLANE), 256) if msym else 1,
            min(_pad(ext[nsym], _LANE), 256) if nsym else 1)
        if msym:
            pads[msym] = _pad(ext[msym], bm)
        if nsym:
            pads[nsym] = _pad(ext[nsym], bn)
        blocks = None

    lifted = nf.onf(pads)
    for s in out_syms[:-2]:
        lifted = onf_mod.lift_loop(lifted, s, ext[s], "proc")
    if msym:
        lifted = onf_mod.lift_loop(lifted, msym, pads[msym] // bm, "proc")
    if nsym:
        lifted = onf_mod.lift_loop(lifted, nsym, pads[nsym] // bn, "vector")
    if red_syms:
        lifted = onf_mod.lift_loop(lifted, red_syms[0],
                                   pads[red_syms[0]] // bk, "block")

    order = out_syms + red_syms
    logical = tuple(ext[s] for s in order)
    padded = tuple(pads.get(s, ext[s]) for s in order)
    return ScheduleBundle(nf.name, derive_schedule(lifted, hw_shape, dtype),
                          blocks, logical, padded,
                          nf.out_shape(), nf.leaf_storage_shapes())


#: the deprecated string ops, as the expressions they always were
def _expr_for_op(op: str, shapes: tuple[int, ...]) -> "expr_mod.Expr":
    if op == "gemm":
        m, k, n = shapes
        return expr_mod.matmul_expr(m, k, n)
    if op == "expert_gemm":
        return expr_mod.expert_gemm_expr(*shapes)
    if op == "hadamard":
        return expr_mod.hadamard_expr(*shapes)
    raise ValueError(f"unknown schedule op {op!r}; known: "
                     "['expert_gemm', 'gemm', 'hadamard']")


def get_schedule(op, shapes=None, dtype="float32", hardware=None,
                 blocks=None) -> ScheduleBundle:
    """LRU-cached schedule derivation keyed on the expression's normal form.

    New signature::

        get_schedule(expr, dtype=..., hardware=..., blocks=...)

    where ``expr`` is a ``core.expr.Expr``: the cache key is
    ``(normalize(expr).key(), dtype, hardware, blocks)`` — the normal form
    IS the identity of the computation, so two expressions that psi-reduce
    to the same loop nest (e.g. ``transpose(arr(..., "row"))`` and
    ``arr(..., "col")``) share one derivation.

    .. deprecated:: the string signature ``get_schedule("gemm", (m, k, n),
       dtype, hardware)`` is kept for one release; it builds the equivalent
       expression and lands on the same cache lines.

    ``hardware`` may be a ``HardwareEntry`` (preferred — its name keys the
    cache) or a bare ``HardwareShape``.
    """
    if isinstance(op, str):
        warnings.warn(
            "string-keyed get_schedule(op, shapes, ...) is deprecated; "
            "compose a repro.core.expr expression and pass it directly",
            DeprecationWarning, stacklevel=2)
        op = _expr_for_op(op, tuple(shapes))
        shapes = None
    if shapes is not None:
        raise TypeError("shapes is only valid with the deprecated string op")
    if hardware is None:
        raise TypeError("get_schedule requires a hardware entry/shape")
    nf = op if isinstance(op, expr_mod.NormalForm) else expr_mod.normal_form(
        op, name=getattr(op, "name", None) or "expr")
    hw_shape = getattr(hardware, "shape", hardware)
    hw_name = getattr(hardware, "name", None) or hw_shape.name
    dtype_key = str(dtype)
    block_key = tuple(blocks) if isinstance(blocks, (list, tuple)) else blocks
    key = (nf.key(), dtype_key, hw_name, block_key)
    with _lock:
        hit = _cache.get(key)
        if hit is not None:
            _stats["hits"] += 1
            _cache.move_to_end(key)
            return hit
        _stats["misses"] += 1
        bundle = _build_bundle(nf, dtype_key, hw_shape, blocks)
        _cache[key] = bundle
        while len(_cache) > SCHEDULE_CACHE_SIZE:
            _cache.popitem(last=False)
        return bundle
