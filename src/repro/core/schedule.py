"""Derive Pallas schedules from lifted ONF loop nests.

This is the paper's missing executable link: "code was derived from the MoA
expression's normal form" — here literally.  ``derive_schedule`` consumes a
*lifted* ``Onf`` (the symbolic artifact of ``lift_loop`` over a normalized
expression) plus a ``HardwareShape`` and computes everything a
``pl.pallas_call`` needs:

* grid extents — the resource-tagged loops, parallel resources first,
  sigma-block (reduction) loops last;
* per-operand block shapes and index maps — recovered from the affine
  ``Access`` coefficients (each operand must be a dense view of its loop
  axes through *some* gamma — row- or column-major — which the derivation
  *verifies*, it does not assume: a transposed operand simply presents its
  axes in the other order);
* ``dimension_semantics`` — "proc"/"vector"/"grid"/"expert" resources are
  parallel, "block" (the sigma loop) is arbitrary;
* the scratch accumulator implied by a lifted reduce axis, initialized to
  the reduce op's identity (0 for add, -inf for max-plus).

``kernels/emit.py`` turns a ``Schedule`` into an executable kernel.  This
module is pure Python + dataclasses (no jax import), so deriving schedules
never touches device state, and a process-wide LRU cache keyed on the
*expression's normal form* (``Onf.key()``) makes repeated derivation (and
the brute force ``solve_blocks`` search inside it) free on hot
serving/training paths.  The old string-keyed ``get_schedule("gemm", ...)``
signature is kept for one release behind a ``DeprecationWarning``.
"""
from __future__ import annotations

import string
import threading
import warnings
from collections import OrderedDict
from dataclasses import dataclass, replace as _dc_replace
from typing import Optional, Sequence, Union

from repro.core import expr as expr_mod
from repro.core import onf as onf_mod
from repro.core import semiring
from repro.core.blocking import (BlockChoice, RecurrenceBlockChoice,
                                 StreamBlockChoice, solve_blocks,
                                 solve_recurrence_blocks, solve_stream_blocks,
                                 _dtype_size)
from repro.core.lifting import HardwareShape
from repro.core.mesh import is_mesh_resource
from repro.core.moa import pi

#: resources whose grid loops are independent ("parallel" to Mosaic); the
#: sigma block loop ("block") carries the accumulator and stays "arbitrary".
PARALLEL_RESOURCES = frozenset({"proc", "vector", "grid", "expert"})

#: synthetic operand axis for psi views: the flat leading slab a constant
#: Access offset addresses (block extent 1, block index pinned at the slab)
PSI_AXIS = "_psi"


def _base(index: str) -> str:
    """Logical axis behind a lifted loop index: i_o / i_i -> i."""
    return index[:-2] if index.endswith(("_o", "_i")) else index


@dataclass(frozen=True)
class GridAxis:
    index: str           # lifted loop index, e.g. "i_o"
    base: str            # logical axis it partitions, e.g. "i"
    extent: int
    semantics: str       # "parallel" | "arbitrary"


@dataclass(frozen=True)
class OperandSpec:
    """One operand's BlockSpec, symbolically: which logical axis each array
    dimension walks, its full (padded) extent, the VMEM-resident block extent,
    and which grid position drives the block index (None -> pinned at 0).

    ``offsets`` are constant block-index offsets added per dimension — the
    BlockSpec lowering of a psi view's constant Access term.  A non-psi
    operand has all-zero offsets; a psi operand carries one leading
    ``PSI_AXIS`` dimension (block extent 1) whose offset pins it at the
    viewed slab.

    ``page_table`` generalizes the single constant offset to *one constant
    per grid step* of the leading dimension: block index ``k`` of dim 0
    reads block ``page_table[k]`` of the stored pool instead of ``k`` — the
    BlockSpec lowering of a paged psi view whose per-page slab offsets are
    ``Access.const`` terms.  ``shape[0]`` is then the pool extent
    (pool_pages * block), not the logical view extent
    (len(page_table) * block).

    With ``page_slot_dim`` set, the table is *stacked* 2-D ``[slot, k]``
    metadata (batched multi-slot decode): ``page_slot_dim`` names the grid
    axis carrying the lifted slot index ``s``, and dim 0's block index
    becomes ``page_table[s][k]`` — the same select-fold lowering keyed on
    two grid axes."""
    array: str
    axes: tuple[str, ...]
    shape: tuple[int, ...]
    block: tuple[int, ...]
    grid_dims: tuple[Optional[int], ...]
    offsets: tuple[int, ...] = ()
    page_table: Optional[tuple] = None
    page_slot_dim: Optional[int] = None

    @property
    def is_psi_view(self) -> bool:
        return bool(self.axes) and self.axes[0] == PSI_AXIS


@dataclass(frozen=True)
class Schedule:
    """Everything ``pl.pallas_call`` needs, derived — not hand-written."""
    name: str
    grid: tuple[GridAxis, ...]
    ins: tuple[OperandSpec, ...]
    out: OperandSpec
    contracted: tuple[str, ...]          # logical axes reduced inside a block
    reduce_grid_dim: Optional[int]       # grid axis accumulated across steps
    combine: str = "mul"                 # semiring pairing (core.semiring)
    reduce_op: str = "add"               # semiring accumulation

    @property
    def grid_extents(self) -> tuple[int, ...]:
        return tuple(g.extent for g in self.grid)

    @property
    def dimension_semantics(self) -> tuple[str, ...]:
        return tuple(g.semantics for g in self.grid)

    @property
    def needs_scratch(self) -> bool:
        return self.reduce_grid_dim is not None

    def einsum_plan(self) -> tuple[str, tuple[tuple[int, ...], ...]]:
        """The in-block computation as an einsum over non-unit block axes.

        Returns ``(spec, kept_dims_per_input)``: each input ref is reshaped to
        its kept (block extent > 1) dims, contracted per ``spec``, and the
        result reshaped back to the output block.  Unit axes (e.g. the lifted
        expert axis, block extent 1) drop out of the contraction — summing a
        one-element axis is the identity — which keeps the emitted body
        bit-identical to a hand-written 2-D ``jnp.dot``.
        """
        letters: dict[str, str] = {}
        pool = iter(string.ascii_lowercase)
        for spec_ in (self.out,) + self.ins:
            for ax in spec_.axes:
                if ax not in letters:
                    letters[ax] = next(pool)
        in_specs, in_keep = [], []
        for opn in self.ins:
            keep = tuple(i for i, b in enumerate(opn.block) if b > 1)
            in_keep.append(keep)
            in_specs.append("".join(letters[opn.axes[i]] for i in keep))
        out_spec = "".join(letters[self.out.axes[i]]
                           for i, b in enumerate(self.out.block) if b > 1)
        return ",".join(in_specs) + "->" + out_spec, tuple(in_keep)

    def vmem_bytes(self, dtype, buffering: int = 2, acc_bytes: int = 4) -> int:
        """Modeled resident working set: double-buffered input blocks plus
        the output block and (if reducing) the f32 accumulator."""
        esize = _dtype_size(dtype)
        ws = sum(pi(opn.block) for opn in self.ins) * esize * buffering
        ws += pi(self.out.block) * esize
        if self.needs_scratch:
            ws += pi(self.out.block) * acc_bytes
        return ws

    def working_set_bytes(self, dtype, acc_dtype: str = "float32",
                          buffering: int = 2) -> int:
        """The certified resident working set: ``vmem_bytes`` with the
        accumulator at its real ``acc_dtype`` width, plus the materialized
        in-block combine intermediate a non-(mul, add) semiring needs
        (``_general_combine`` pairs in f32 over the joint out x contracted
        block).  This is what derivation checks against the hardware table
        and what ``repro.analysis`` re-certifies."""
        ws = self.vmem_bytes(dtype, buffering,
                             acc_bytes=_dtype_size(acc_dtype))
        if (self.combine, self.reduce_op) != ("mul", "add"):
            inter = pi(self.out.block)
            for ax in self.contracted:
                for opn in self.ins:
                    if ax in opn.axes:
                        inter *= opn.block[opn.axes.index(ax)]
                        break
            ws += inter * 4
        return ws


def derive_schedule(o: "onf_mod.Onf", hardware: Optional[HardwareShape] = None,
                    dtype="float32", acc_dtype: str = "float32") -> Schedule:
    """Derive the full Pallas schedule from a lifted ONF.

    Raises ``ValueError`` if the nest is not lifted, if an access is not a
    dense row-major view of its loop axes, or if the derived blocks exceed
    the hardware's VMEM capacity (when ``hardware`` is given).
    """
    if any(is_mesh_resource(l.resource) for l in o.loops):
        raise ValueError(
            f"Onf {o.name!r} has mesh-lifted loops — a single-chip schedule "
            "cannot honor a device axis; derive a DistributedPlan "
            "(repro.distributed.plan.derive_plan) and schedule its per-shard "
            "normal form instead")
    grid_loops = [l for l in o.loops if l.resource is not None]
    inner_loops = [l for l in o.loops if l.resource is None]
    if not grid_loops:
        raise ValueError(
            f"Onf {o.name!r} has no resource-tagged loops — lift it first "
            "(lift_loop / gemm_fully_lifted)")
    reduce_bases = {_base(i) for i in o.reduce_indices}

    # logical extents and in-block (inner) extents per base axis
    full_extent: dict[str, int] = {}
    inner_extent: dict[str, int] = {}
    for l in o.loops:
        b = _base(l.index)
        full_extent[b] = full_extent.get(b, 1) * l.extent
        if l.resource is None:
            inner_extent[b] = inner_extent.get(b, 1) * l.extent

    # grid ordering: parallel loops first, sigma/reduce loops last, each
    # group in the order their base axes appear in the remaining inner nest
    # (order among resource loops is free by independence — paper fig 4)
    inner_order: list[str] = []
    for l in inner_loops:
        b = _base(l.index)
        if b not in inner_order:
            inner_order.append(b)

    def _position(loop) -> int:
        b = _base(loop.index)
        return inner_order.index(b) if b in inner_order else len(inner_order)

    def _semantics(loop) -> str:
        if loop.resource in PARALLEL_RESOURCES and _base(loop.index) not in reduce_bases:
            return "parallel"
        return "arbitrary"

    ordered = (sorted([l for l in grid_loops if _semantics(l) == "parallel"],
                      key=_position)
               + sorted([l for l in grid_loops if _semantics(l) == "arbitrary"],
                        key=_position))
    grid = tuple(GridAxis(l.index, _base(l.index), l.extent, _semantics(l))
                 for l in ordered)
    grid_pos: dict[str, int] = {}
    for i, g in enumerate(grid):
        if g.base in grid_pos:
            raise ValueError(f"axis {g.base!r} lifted onto two grid resources")
        grid_pos[g.base] = i

    def _operand(a: "onf_mod.Access") -> OperandSpec:
        strides: dict[str, int] = {}
        for idx, c in a.coeffs.items():
            if c == 0:
                continue
            b = _base(idx)
            strides[b] = min(strides.get(b, c), c)
            # a lifted pair must stay a single blocked axis: coeff(x_o) ==
            # coeff(x_i) * |x_i| (the lift_loop rewrite, and nothing else)
        for idx, c in a.coeffs.items():
            b = _base(idx)
            if idx.endswith("_o") and c != strides[b] * inner_extent.get(b, 1):
                raise ValueError(
                    f"{a.array}: {idx} coefficient {c} inconsistent with a "
                    f"row-major lift of {b!r}")
        # descending stride; stride ties (only possible when one of the tied
        # axes has extent 1 — two extent>1 axes can't share a stride in a
        # dense view) break by descending extent, so the extent-1 axis sits
        # inner where the density walk multiplies expected by 1
        axes = sorted(strides,
                      key=lambda b: (-strides[b], -full_extent[b]))
        expected = 1
        for b in reversed(axes):
            if strides[b] != expected:
                raise ValueError(
                    f"{a.array} is not a dense row-major view: axis {b!r} "
                    f"stride {strides[b]}, expected {expected}")
            expected *= full_extent[b]
        axes_t = tuple(axes)
        shape = tuple(full_extent[b] for b in axes)
        block = tuple(inner_extent.get(b, 1) for b in axes)
        gdims = tuple(grid_pos.get(b) for b in axes)
        offs = (0,) * len(axes)
        if a.const:
            # a psi view: the constant offset must address whole leading
            # slabs of the dense loop-axis view; it lowers to one extra
            # leading dimension of block extent 1 whose block index is
            # pinned at the viewed slab (the index-map offset)
            if a.const % expected:
                raise ValueError(
                    f"{a.array}: constant offset {a.const} (a psi view) is "
                    f"not a multiple of the slab size {expected} — no "
                    "BlockSpec lowering; materialize the view first")
            slab = a.const // expected
            axes_t = (PSI_AXIS,) + axes_t
            shape = (slab + 1,) + shape
            block = (1,) + block
            gdims = (None,) + gdims
            offs = (slab,) + offs
        return OperandSpec(a.array, axes_t, shape, block, gdims, offs)

    out_spec = _operand(o.out)
    in_specs = tuple(_operand(a) for a in o.ins)

    in_bases = {b for s in in_specs for b in s.axes}
    contracted = tuple(b for b in inner_order
                       if b in reduce_bases and b in in_bases
                       and b not in out_spec.axes)
    reduce_dims = [i for i, g in enumerate(grid) if g.base in reduce_bases]
    if len(reduce_dims) > 1:
        raise ValueError("more than one lifted reduction axis is unsupported")
    reduce_grid_dim = reduce_dims[0] if reduce_dims else None

    sched = Schedule(o.name, grid, in_specs, out_spec, contracted,
                     reduce_grid_dim, o.combine, o.reduce_op)
    if hardware is not None:
        ws = sched.working_set_bytes(dtype, acc_dtype)
        if ws > hardware.vmem.capacity_bytes:
            raise ValueError(
                f"derived blocks need {ws} B VMEM, over {hardware.name}'s "
                f"{hardware.vmem.capacity_bytes} B capacity")
    return sched


# ---------------------------------------------------------------------------
# recurrent schedules: carried-state recurrences (online softmax, SSD scan,
# gated scan) — the sigma accumulator generalized to a typed monoid
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StagePlan:
    """One welded stage's in-block contraction, symbolically: its operand
    blocks (including the VMEM-only carrier), output block and in-block
    contracted axes.  ``einsum_plan`` is the derived block body."""
    ins: tuple[OperandSpec, ...]
    out: OperandSpec
    contracted: tuple[str, ...]

    def einsum_plan(self) -> tuple[str, tuple[tuple[int, ...], ...]]:
        return Schedule("stage", (), self.ins, self.out, self.contracted,
                        None).einsum_plan()


@dataclass(frozen=True)
class RecurrentSchedule:
    """A derived schedule for a *carried-state recurrence*: N chained
    contractions whose shared streamed axis is lifted onto the sigma
    "block" resource with a typed monoid (``expr.StateSpec``) instead of a
    plain accumulator.

    Derived — like ``Schedule`` — entirely from lifted ONFs: the grid, the
    operand BlockSpecs (including the GQA q-head -> kv-head index map, which
    falls out of the kv operands' zero coefficient on the group axis; and
    the SSD head broadcast, which falls out the same way) and the streamed
    dimension all come from the affine Access coefficients.  The carried
    state the emitter materializes per grid cell is declared by ``state``
    (online softmax's (m, l, acc); SSD's inter-chunk (h, p, n); RG-LRU's
    channel vector) — it joins the block solvers' working-set models
    (``solve_stream_blocks`` / ``solve_recurrence_blocks``), which is where
    the blocks come from.  ``state_outs`` are the exported-final-state
    outputs (the scan decode caches); ``stages`` carry each weld's derived
    in-block einsum plan; ``window``/``prefix_len`` are the streamed-axis
    masking metadata the emitter derives block-skip from.

    The two-stage online-softmax instance is the old ``StreamingSchedule``
    (that name is a one-release alias of this class).
    """
    name: str
    grid: tuple[GridAxis, ...]
    ins: tuple[OperandSpec, ...]         # stage inputs (carriers excluded)
    out: OperandSpec                     # then the aux (state) operands
    inters: tuple[OperandSpec, ...]      # the VMEM-only intermediate blocks
    state_outs: tuple[OperandSpec, ...]  # exported final state (may be ())
    stages: tuple[StagePlan, ...]
    contracted: tuple[str, ...]          # first contraction's in-block axes
    stream_grid_dim: int                 # grid axis carrying the state
    row_axis: str                        # per-row state axis ("" if chunked)
    stream_axis: str                     # the streamed logical axis
    state: "expr_mod.StateSpec" = None   # the carried monoid declaration
    window: int = 0
    prefix_len: int = 0

    @property
    def grid_extents(self) -> tuple[int, ...]:
        return tuple(g.extent for g in self.grid)

    @property
    def dimension_semantics(self) -> tuple[str, ...]:
        return tuple(g.semantics for g in self.grid)

    @property
    def inter(self) -> OperandSpec:
        """The first VMEM-only intermediate (THE intermediate for the
        two-stage streaming instance)."""
        return self.inters[0]

    @property
    def row_block(self) -> int:
        """bq — the block extent of the per-row state axis."""
        return self.out.block[self.out.axes.index(self.row_axis)]

    @property
    def stream_block(self) -> int:
        """bk — the block extent of the streamed axis in the intermediate
        (1 for chunked scans: the chunk index streams whole steps)."""
        return self.inter.block[self.inter.axes.index(self.stream_axis)]

    @property
    def value_axes(self) -> tuple[str, ...]:
        """Output axes NOT shared with the intermediate — the second
        contraction's value dims (head_dim for attention)."""
        return tuple(ax for ax in self.out.axes if ax not in self.inter.axes)

    @property
    def acc_block(self) -> tuple[int, ...]:
        """The accumulator scratch shape: (row block, value block) — chosen
        by axis, not by dropping unit dims, so a size-1 value axis still
        yields a rank-2 accumulator the emitter can rescale per row."""
        return (self.row_block,) + tuple(
            self.out.block[self.out.axes.index(ax)]
            for ax in self.value_axes)

    def state_blocks(self) -> tuple[tuple[int, ...], ...]:
        """Per exported state array, its in-kernel scratch shape: the
        state-out block with the grid-pinned unit dims dropped (blockwise
        grid-driven dims — a blocked per-row axis — keep their extent)."""
        out = []
        for so in self.state_outs:
            blk = tuple(b for b, d in zip(so.block, so.grid_dims)
                        if d is None or b > 1)
            out.append(blk if len(blk) >= 2 else (1,) * (2 - len(blk)) + blk)
        return tuple(out)

    def vmem_bytes(self, dtype, buffering: int = 2, acc_bytes: int = 4) -> int:
        """Modeled resident working set: double-buffered input blocks, the
        output block, the carried state and the in-block f32 intermediates
        (each counted twice: pre- and post-nonlinearity)."""
        esize = _dtype_size(dtype)
        ws = sum(pi(opn.block) for opn in self.ins) * esize * buffering
        ws += pi(self.out.block) * esize
        if self.row_axis:
            ws += (pi(self.out.block) + 2 * self.row_block) * acc_bytes
        for so in self.state_outs:
            ws += pi(so.block) * acc_bytes
        for inter in self.inters:
            ws += 2 * pi(inter.block) * acc_bytes
        return ws

    def working_set_bytes(self, dtype, acc_dtype: str = "float32",
                          buffering: int = 2) -> int:
        """``vmem_bytes`` with the carried state and accumulators at their
        real ``acc_dtype`` width — the certified working set derivation
        checks and ``repro.analysis`` re-certifies."""
        return self.vmem_bytes(dtype, buffering,
                               acc_bytes=_dtype_size(acc_dtype))


#: one-release alias: the streaming (online-softmax) schedule is the
#: two-stage instance of the recurrence subsystem
StreamingSchedule = RecurrentSchedule


def _aux_operand(leaf: "expr_mod.LeafSpec", grid_pos: dict[str, int],
                 grid_block: Optional[dict[str, int]] = None) -> OperandSpec:
    """BlockSpec for a state-monoid operand (SSD's dA, the initial state,
    the saved softmax statistics a derived backward re-reads): a dense
    row-major view of its declared axes — grid-lifted axes get their grid
    dimension's block extent (1 for fully-lifted axes, the derived row/
    stream block for blockwise-lifted axes) driven by their grid position,
    the rest stay resident whole."""
    grid_block = grid_block or {}
    axes = tuple(t for t, _ in leaf.dims)
    shape = tuple(e for _, e in leaf.dims)
    block = tuple(grid_block.get(ax, 1) if ax in grid_pos else e
                  for ax, e in leaf.dims)
    gdims = tuple(grid_pos.get(ax) for ax in axes)
    return OperandSpec(leaf.array, axes, shape, block, gdims,
                       (0,) * len(axes))


def derive_recurrent_schedule(stages: Sequence["onf_mod.Onf"],
                              stream_axis: str,
                              state: "expr_mod.StateSpec",
                              aux: Sequence["expr_mod.LeafSpec"] = (),
                              window: int = 0, prefix_len: int = 0,
                              hardware: Optional[HardwareShape] = None,
                              dtype="float32",
                              acc_dtype: str = "float32") -> RecurrentSchedule:
    """Derive a ``RecurrentSchedule`` from the lifted ONFs of a recurrence
    chain (``expr.RecurrentForm`` lifted per axis).

    Every nest must lift onto the *same* grid, with the streamed axis on
    the innermost grid dimension with "arbitrary" semantics (the carried
    state is initialized at step 0 and flushed/exported at the last step —
    anything else would share state across cells mid-recurrence); each
    stage's first leaf after the first stage is the VMEM-only carrier of
    the previous output (extra broadcast axes allowed — SSD's per-head
    decay weighting).  Each stage is derived by the ordinary
    ``derive_schedule`` — this function only welds them and verifies the
    weld.
    """
    scheds = [derive_schedule(o, None, dtype) for o in stages]
    for s in scheds[1:]:
        if s.grid != scheds[0].grid:
            raise ValueError(
                f"recurrence stages derived different grids: "
                f"{scheds[0].grid} vs {s.grid}")
    grid = scheds[0].grid
    stream_dims = [i for i, g in enumerate(grid) if g.base == stream_axis]
    if not stream_dims:
        raise ValueError(f"stream axis {stream_axis!r} is not a grid axis — "
                         "lift it onto 'block' first")
    stream_dim = stream_dims[0]
    if grid[stream_dim].semantics != "arbitrary":
        raise ValueError(
            f"streamed axis {stream_axis!r} derived 'parallel' semantics — "
            "the carried state needs a sequential grid dimension")
    if stream_dim != len(grid) - 1:
        raise ValueError(
            f"streamed axis {stream_axis!r} lifted onto grid dim "
            f"{stream_dim}, but the carried state requires it innermost "
            f"(dim {len(grid) - 1})")
    grid_pos = {g.base: i for i, g in enumerate(grid)}

    inters, plans = [], []
    plans.append(StagePlan(scheds[0].ins, scheds[0].out,
                           scheds[0].contracted))
    for prev, nxt in zip(scheds, scheds[1:]):
        inter, carrier = prev.out, nxt.ins[0]
        shared = set(inter.axes)
        if not shared <= set(carrier.axes):
            raise ValueError(
                f"stage output axes {inter.axes} are not covered by the "
                f"carrier {carrier.axes} — the intermediate cannot stay in "
                "VMEM")
        for ax in inter.axes:
            ia, ca = inter.axes.index(ax), carrier.axes.index(ax)
            if (inter.shape[ia], inter.block[ia], inter.grid_dims[ia]) != \
                    (carrier.shape[ca], carrier.block[ca],
                     carrier.grid_dims[ca]):
                raise ValueError(
                    f"carrier axis {ax!r} block disagrees with the stage "
                    f"output ({carrier} vs {inter}) — the intermediate "
                    "cannot stay in VMEM")
        inters.append(carrier)
        plans.append(StagePlan((carrier,) + nxt.ins[1:], nxt.out,
                               nxt.contracted))

    last = scheds[-1]
    folding = stream_axis not in last.out.axes
    row_axis = ""
    if folding:
        if last.reduce_grid_dim != stream_dim:
            raise ValueError(
                f"the last stage's lifted reduction axis is not the stream "
                f"axis {stream_axis!r}")
        row_candidates = [ax for ax, blk in zip(last.out.axes,
                                                last.out.block)
                          if blk > 1 and ax in inters[0].axes]
        if len(row_candidates) != 1:
            raise ValueError(
                f"expected exactly one blocked per-row state axis shared by "
                f"the output and the intermediate, got {row_candidates}")
        row_axis = row_candidates[0]

    # each grid axis's per-step block extent, recovered from the stage
    # operands it drives (1 for fully-lifted axes, bq/bk for the blockwise
    # row/stream lifts)
    grid_block: dict[str, int] = {}
    for spec in tuple(plans[0].ins) + tuple(p.out for p in plans) \
            + tuple(s for p in plans[1:] for s in p.ins):
        for ax, blk, gd in zip(spec.axes, spec.block, spec.grid_dims):
            if gd is not None and blk > 1:
                grid_block[ax] = blk

    ins = tuple(plans[0].ins)
    for plan in plans[1:]:
        ins += plan.ins[1:]
    ins += tuple(_aux_operand(l, grid_pos, grid_block) for l in aux)

    state_outs: list[OperandSpec] = []
    if state.exports:
        full_extent: dict[str, int] = {}
        for spec in ins + tuple(p.out for p in plans):
            for ax, e in zip(spec.axes, spec.shape):
                full_extent.setdefault(ax, e)
        par = tuple(g.base for g in grid if g.semantics == "parallel")
        for name, axes in state.exported():
            lead = tuple(ax for ax in par if ax not in axes)
            all_axes = lead + tuple(axes)
            if name in state.per_step:
                # per-step export: the streamed axis joins the operand,
                # grid-indexed so each streamed step writes its own slab
                all_axes = lead + (stream_axis,) + tuple(axes)
            shape = tuple(full_extent[ax] for ax in all_axes)
            block, gdims = [], []
            for ax in all_axes:
                if ax in grid_pos:
                    # grid-lifted axes — the leading parallel cells, a
                    # per-step streamed slab, or a carried axis that is
                    # itself blockwise-lifted (the blocked per-row axis of
                    # a folding form's saved statistics) — are written
                    # block by block, driven by their grid position
                    block.append(grid_block.get(ax, 1))
                    gdims.append(grid_pos[ax])
                else:
                    block.append(full_extent[ax])
                    gdims.append(None)
            state_outs.append(OperandSpec(name, all_axes, shape,
                                          tuple(block), tuple(gdims),
                                          (0,) * len(all_axes)))

    sched = RecurrentSchedule(
        stages[0].name, grid, ins, last.out, tuple(inters),
        tuple(state_outs), tuple(plans), scheds[0].contracted, stream_dim,
        row_axis, stream_axis, state, int(window), int(prefix_len))
    if hardware is not None:
        ws = sched.working_set_bytes(dtype, acc_dtype)
        if ws > hardware.vmem.capacity_bytes:
            raise ValueError(
                f"derived recurrent blocks need {ws} B VMEM, over "
                f"{hardware.name}'s {hardware.vmem.capacity_bytes} B capacity")
    return sched


def derive_streaming_schedule(scores: "onf_mod.Onf", context: "onf_mod.Onf",
                              stream_axis: str,
                              hardware: Optional[HardwareShape] = None,
                              dtype="float32") -> RecurrentSchedule:
    """.. deprecated:: the two-stage online-softmax weld is now
    ``derive_recurrent_schedule`` with the ``SOFTMAX_STATE`` monoid; this
    wrapper is kept for one release."""
    return derive_recurrent_schedule((scores, context), stream_axis,
                                     expr_mod.SOFTMAX_STATE,
                                     hardware=hardware, dtype=dtype)


# ---------------------------------------------------------------------------
# block policies (the static a-priori choices of paper §3.3/3.4)
# ---------------------------------------------------------------------------

def default_gemm_blocks(m: int, k: int, n: int, dtype,
                        hardware: HardwareShape,
                        acc_dtype: str = "float32") -> BlockChoice:
    """Solver defaults tuned for kernel use: quarter-VMEM budget keeps
    double-buffering headroom; caps keep the grid >= a few cells."""
    return solve_blocks(min(m, 512), min(k, 2048), min(n, 512), dtype,
                        hardware=hardware, vmem_budget_frac=0.25,
                        acc_dtype=acc_dtype)


def default_stream_blocks(sq: int, sk: int, hd: int, vd: int, dtype,
                          hardware: HardwareShape,
                          q_extra: int = 0, k_extra: int = 0,
                          n_inter: int = 2,
                          n_row_state: int = 2) -> StreamBlockChoice:
    """Streaming (bq, bk) policy: same quarter-VMEM budget and the same
    512 grid-coverage cap as the GEMM policy — on the v5e table this lands
    on the (512, 512) tiles the hand-written flash kernel used to fix, but
    *derived* from the carried-state working-set model, so fatter head dims
    or narrower budgets shrink the blocks instead of overflowing VMEM.
    The extra terms widen the model for the backward recurrence kinds
    (saved dO/V payloads, four in-block grad intermediates, saved-stat row
    vectors); the defaults are the forward model exactly."""
    return solve_stream_blocks(min(sq, 512), min(sk, 512), hd, vd, dtype,
                               hardware=hardware, vmem_budget_frac=0.25,
                               q_extra=q_extra, k_extra=k_extra,
                               n_inter=n_inter, n_row_state=n_row_state)


def _pad(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# ---------------------------------------------------------------------------
# the process-wide schedule cache — keyed on expression normal forms
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScheduleBundle:
    """A cached derivation: the schedule plus the block choice and shapes the
    executor needs for pad/slice.  ``schedule.ins[i].shape`` is the padded
    *storage* shape operand ``i`` must be padded to; ``in_shapes`` are the
    logical storage shapes callers bind (a col-layout leaf's is reversed);
    ``out_shape`` the logical result shape."""
    op: str
    schedule: Schedule
    blocks: Optional[BlockChoice]
    shapes: tuple[int, ...]          # logical loop extents (out + reduce)
    padded: tuple[int, ...]          # same, padded to block multiples
    out_shape: tuple[int, ...] = ()
    in_shapes: tuple[tuple[int, ...], ...] = ()
    acc_dtype: str = "float32"       # accumulation dtype the emitter honors


def bundle_needs_padding(bundle: ScheduleBundle) -> bool:
    """Whether any logical operand must be padded to reach its schedule's
    (padded) storage shape — the single detection both ``emit_bundle`` and
    the static verifier apply."""
    sch = bundle.schedule
    for spec, logical in zip(sch.ins, bundle.in_shapes):
        sym_rank = len(spec.shape) - (1 if spec.is_psi_view else 0)
        tail = tuple(logical[len(logical) - sym_rank:])
        if tail != (spec.shape[1:] if spec.is_psi_view else spec.shape):
            return True
    return False


def bundle_pad_value(bundle: ScheduleBundle) -> float:
    """The inert element padding regions are filled with — the one policy
    shared by ``emit_bundle`` and ``repro.analysis.verify_bundle``: nothing
    padded -> 0.0; a single operand pads with the reduce identity (no
    pairing happens); multi-operand pads with the semiring's registered
    inert element (raises ``ValueError`` when the table has none)."""
    sch = bundle.schedule
    if not bundle_needs_padding(bundle):
        return 0.0
    if len(sch.ins) == 1:
        return semiring.reduce_def(sch.reduce_op).identity
    return semiring.pad_value(sch.combine, sch.reduce_op)


SCHEDULE_CACHE_SIZE = 256
_cache: "OrderedDict[tuple, ScheduleBundle]" = OrderedDict()
_lock = threading.Lock()
_stats = {"hits": 0, "misses": 0, "solves": 0}


def schedule_cache_stats() -> dict[str, int]:
    """Counters for tests/monitoring: cache hits/misses and how many times
    the brute-force ``solve_blocks`` search actually ran."""
    with _lock:
        return dict(_stats)


def reset_schedule_cache() -> None:
    with _lock:
        _cache.clear()
        for k in _stats:
            _stats[k] = 0


#: alignment for the last (lane) and second-minor axes when a non-solver
#: block policy applies (elementwise nests, semiring contractions)
_LANE, _SUBLANE = 128, 8


def _build_bundle(nf: "expr_mod.NormalForm", dtype, hw_shape,
                  blocks, acc_dtype: str = "float32") -> ScheduleBundle:
    """Pad, lift and derive a schedule for any normalized expression.

    The policy generalizes the paper's fig-2 lifting: leading output axes
    lift fully onto "proc" resources (each grid cell independent), the
    trailing two output axes lift blockwise onto "proc"/"vector", and the
    first contraction axis lifts onto the sigma "block" resource.  Block
    extents come from ``solve_blocks`` for the (mul, add) semiring; other
    semirings use fixed MXU-aligned tiles (their in-block combine
    materializes a (bm, bn, bk) intermediate, so tiles stay small).
    """
    ext = nf.extent_map
    out_syms, red_syms = nf.out_axes, nf.reduce_axes
    msym = out_syms[-2] if len(out_syms) >= 2 else None
    nsym = out_syms[-1] if out_syms else None
    pads: dict[str, int] = {}
    if red_syms:
        ksym = red_syms[0]
        m = ext[msym] if msym else 1
        n = ext[nsym] if nsym else 1
        k = ext[ksym]
        if blocks is None:
            _stats["solves"] += 1
            if nf.combine == "mul" and nf.reduce_op == "add":
                blocks = default_gemm_blocks(m, k, n, dtype, hw_shape,
                                             acc_dtype=acc_dtype)
            else:
                # general semirings materialize a (bm, bn, bk) f32 combine
                # intermediate in-block (no MXU fusion): the same solver,
                # with that array added to the working-set model, replaces
                # the old fixed 128^3 tile
                blocks = solve_blocks(min(m, 512), min(k, 2048), min(n, 512),
                                      dtype, hardware=hw_shape,
                                      vmem_budget_frac=0.25,
                                      materialized_combine=True)
        bm, bk, bn = blocks.as_tuple()
        if msym:
            pads[msym] = _pad(m, bm)
        if nsym:
            pads[nsym] = _pad(n, bn)
        pads[ksym] = _pad(k, bk)
    else:
        bm, bn = blocks if blocks is not None else (
            min(_pad(ext[msym], _SUBLANE), 256) if msym else 1,
            min(_pad(ext[nsym], _LANE), 256) if nsym else 1)
        if msym:
            pads[msym] = _pad(ext[msym], bm)
        if nsym:
            pads[nsym] = _pad(ext[nsym], bn)
        blocks = None

    lifted = nf.onf(pads)
    for s in out_syms[:-2]:
        lifted = onf_mod.lift_loop(lifted, s, ext[s], "proc")
    if msym:
        lifted = onf_mod.lift_loop(lifted, msym, pads[msym] // bm, "proc")
    if nsym:
        lifted = onf_mod.lift_loop(lifted, nsym, pads[nsym] // bn, "vector")
    if red_syms:
        lifted = onf_mod.lift_loop(lifted, red_syms[0],
                                   pads[red_syms[0]] // bk, "block")

    order = out_syms + red_syms
    logical = tuple(ext[s] for s in order)
    padded = tuple(pads.get(s, ext[s]) for s in order)
    return ScheduleBundle(nf.name,
                          derive_schedule(lifted, hw_shape, dtype, acc_dtype),
                          blocks, logical, padded,
                          nf.out_shape(), nf.leaf_storage_shapes(),
                          acc_dtype=acc_dtype)


def _build_recurrent_bundle(rf: "expr_mod.RecurrentForm", dtype, hw_shape,
                            blocks,
                            acc_dtype: str = "float32") -> ScheduleBundle:
    """Pad, lift and derive a ``RecurrentSchedule`` for a recurrent form.

    Two lifting policies, chosen by the weld's shape:

    * **folding** (online softmax): every scores output axis before the
      last two lifts fully onto "proc" (batch, kv-head and group cells are
      independent), the per-row axis lifts blockwise onto "proc" with
      ``bq``, and the streamed axis (last scores output == the last stage's
      reduction) lifts blockwise onto the sigma "block" resource with
      ``bk``.  ``(bq, bk)`` come from ``solve_stream_blocks`` — the carried
      state is in its working-set model — unless pinned via ``blocks``.
    * **chunked scan** (SSD, RG-LRU): the form arrives already chunk-split
      (``S -> (c, q)`` — ``q`` chosen by ``solve_recurrence_blocks`` in the
      ops layer, where the leaf shapes are known); every last-stage output
      axis before the streamed chunk axis lifts fully onto "proc", and the
      chunk axis lifts *fully* onto "block" (inner extent 1 — each streamed
      step is one whole chunk).

    All stages are lifted with the same pads and factors so they derive one
    grid; ``derive_recurrent_schedule`` welds and verifies them.
    """
    ext = rf.extent_map()
    stream_sym = rf.stream_axis

    if rf.folding:
        s_nf, c_nf = rf.stages[0], rf.stages[-1]
        row_sym = s_nf.out_axes[-2]
        if s_nf.out_axes[-1] != stream_sym:
            raise ValueError(
                f"streaming lift expects the stream axis {stream_sym!r} as "
                f"the trailing first-stage output axis, got {s_nf.out_axes}")
        sq, sk = ext[row_sym], ext[stream_sym]
        hd = ext[s_nf.reduce_axes[0]] if s_nf.reduce_axes else 1
        vd = ext[c_nf.out_axes[-1]]
        lead = s_nf.out_axes[:-2]
        if blocks is None:
            _stats["solves"] += 1
            # backward folding kinds carry wider per-cell payloads than the
            # forward: aux leaves riding the row axis (dO) widen the q-side
            # working set, leaves riding the stream (V, saved stats) the
            # k-side, and the grad chain needs four (bq, bk) intermediates
            q_extra = k_extra = 0
            n_inter, n_rows = 2, 2
            if rf.state.kind != "online_softmax":
                n_inter = 4
                for leaf in rf.aux:
                    syms = tuple(t for t, _ in leaf.dims if isinstance(t, str))
                    per = 1
                    for t, e in leaf.dims:
                        if not isinstance(t, str) or t not in (
                                (row_sym, stream_sym) + lead):
                            per *= e
                    if row_sym in syms:
                        if per > 1:
                            q_extra += per
                        else:
                            n_rows += 1
                    elif stream_sym in syms:
                        k_extra += per
            blocks = default_stream_blocks(sq, sk, hd, vd, dtype, hw_shape,
                                           q_extra=q_extra, k_extra=k_extra,
                                           n_inter=n_inter,
                                           n_row_state=n_rows)
        elif not isinstance(blocks, StreamBlockChoice):
            bq, bk = blocks
            blocks = StreamBlockChoice(min(bq, sq), min(bk, sk), 0, 0.0, 1.0)
        bq, bk = blocks.as_tuple()
        pads = {row_sym: _pad(sq, bq), stream_sym: _pad(sk, bk)}
        factors = {row_sym: (pads[row_sym] // bq, "proc"),
                   stream_sym: (pads[stream_sym] // bk, "block")}
        order = lead + (row_sym, stream_sym)
    else:
        out_axes = rf.stages[-1].out_axes
        lead = out_axes[:out_axes.index(stream_sym)]
        pads = {}
        factors = {stream_sym: (ext[stream_sym], "block")}
        if blocks is None:
            # the chunk IS the inner extent of the split sequence axes; the
            # solver already ran in the ops layer that built the chunked
            # form — record the choice for the bundle's consumers
            blocks = RecurrenceBlockChoice(
                ext.get(rf.stages[0].out_axes[-1], 1), 0, 0.0, 1.0)
        elif not isinstance(blocks, RecurrenceBlockChoice):
            blocks = RecurrenceBlockChoice(int(blocks[0]) if
                                           isinstance(blocks, (tuple, list))
                                           else int(blocks), 0, 0.0, 1.0)
        order = lead + (stream_sym,)

    def lift_stage(nf: "expr_mod.NormalForm") -> "onf_mod.Onf":
        lifted = nf.onf({s: p for s, p in pads.items()
                         if s in nf.extent_map})
        for s in lead:
            if s in nf.extent_map:
                lifted = onf_mod.lift_loop(lifted, s, ext[s], "proc")
        for s, (f, res) in factors.items():
            if s in nf.extent_map:
                lifted = onf_mod.lift_loop(lifted, s, f, res)
        return lifted

    # aux leaves bypass the per-stage onf(pads) lift — re-declare them with
    # padded extents so their derived BlockSpecs match the padded grid
    # (the saved statistics of a folding backward ride the padded row axis)
    aux = tuple(
        expr_mod.LeafSpec(
            l.array,
            tuple((t, pads.get(t, e) if isinstance(t, str) else e)
                  for t, e in l.dims),
            l.layout)
        for l in rf.aux)
    sched = derive_recurrent_schedule(
        tuple(lift_stage(nf) for nf in rf.stages), stream_sym, rf.state,
        aux, rf.window, rf.prefix_len, hw_shape, dtype, acc_dtype)
    if rf.page_table:
        sched = _page_schedule(sched, rf, ext, pads, stream_sym)
    logical = tuple(ext[s] for s in order)
    padded = tuple(pads.get(s, ext[s]) for s in order)
    in_shapes = rf.stages[0].leaf_storage_shapes()
    for nf in rf.stages[1:]:
        in_shapes += nf.leaf_storage_shapes()[1:]
    in_shapes += tuple(l.storage_shape() for l in rf.aux)
    return ScheduleBundle(rf.name, sched, blocks, logical, padded,
                          rf.stages[-1].out_shape(), in_shapes,
                          acc_dtype=acc_dtype)


def _page_schedule(sched: RecurrentSchedule, rf: "expr_mod.RecurrentForm",
                   ext: dict, pads: dict, stream_sym: str
                   ) -> RecurrentSchedule:
    """Rewrite the paged leaves' operands to read pool storage through the
    page table: the streamed leading dimension's block index becomes a
    static table lookup (block ``k`` -> pool slab ``page_table[k]``), and
    the operand's declared shape[0] becomes the *pool* extent.  Derivation
    refuses any weld the table cannot drive: a padded stream axis (the
    table would run past its last entry), a non-leading or non-streamed
    leading dim, or a block that is not exactly the page size."""
    if pads.get(stream_sym, ext[stream_sym]) != ext[stream_sym]:
        raise ValueError(
            f"paged stream axis {stream_sym!r} must not pad — the view "
            f"extent {ext[stream_sym]} is not a multiple of the derived "
            "stream block; choose page-aligned blocks")
    page = sched.stream_block
    n_steps = sched.grid[sched.stream_grid_dim].extent
    slot_dim = None
    if rf.slot_axis:
        # stacked [slot, k] table: find the grid axis carrying the lifted
        # slot index — it must exist (a lead output axis lifts block-1 onto
        # the grid) and hold exactly one table row per slot
        dims = [i for i, g in enumerate(sched.grid)
                if g.base == rf.slot_axis]
        if len(dims) != 1:
            raise ValueError(
                f"slot axis {rf.slot_axis!r} does not map to exactly one "
                f"grid axis ({dims}) — no stacked-table index map")
        slot_dim = dims[0]
        if sched.grid[slot_dim].extent != len(rf.page_table):
            raise ValueError(
                f"stacked page table has {len(rf.page_table)} rows but the "
                f"slot grid axis takes {sched.grid[slot_dim].extent} steps")
        rows_bad = [row for row in rf.page_table if len(row) != n_steps]
        if rows_bad:
            raise ValueError(
                f"stacked page-table rows {rows_bad} do not name "
                f"{n_steps} slabs (streamed block {page})")
    elif len(rf.page_table) != n_steps:
        raise ValueError(
            f"page table has {len(rf.page_table)} entries but the streamed "
            f"grid axis takes {n_steps} steps (block {page})")
    new_ins = []
    for spec in sched.ins:
        if spec.array not in rf.paged:
            new_ins.append(spec)
            continue
        if not spec.axes or spec.axes[0] != stream_sym:
            raise ValueError(
                f"paged operand {spec.array!r} does not keep the streamed "
                f"axis leading ({spec.axes}) — no table-driven index map")
        if spec.grid_dims[0] != sched.stream_grid_dim \
                or spec.block[0] != page:
            raise ValueError(
                f"paged operand {spec.array!r} dim 0 is not the streamed "
                f"page block (block {spec.block[0]}, grid dim "
                f"{spec.grid_dims[0]})")
        if spec.offsets[0]:
            raise ValueError(
                f"paged operand {spec.array!r} mixes a constant psi offset "
                "with a page table")
        pool = rf.pool_pages * page
        new_ins.append(_dc_replace(spec, shape=(pool,) + spec.shape[1:],
                                   page_table=rf.page_table,
                                   page_slot_dim=slot_dim))
    return _dc_replace(sched, ins=tuple(new_ins))


#: the deprecated string ops, as the expressions they always were
def _expr_for_op(op: str, shapes: tuple[int, ...]) -> "expr_mod.Expr":
    if op == "gemm":
        m, k, n = shapes
        return expr_mod.matmul_expr(m, k, n)
    if op == "expert_gemm":
        return expr_mod.expert_gemm_expr(*shapes)
    if op == "hadamard":
        return expr_mod.hadamard_expr(*shapes)
    raise ValueError(f"unknown schedule op {op!r}; known: "
                     "['expert_gemm', 'gemm', 'hadamard']")


def get_schedule(op, shapes=None, dtype="float32", hardware=None,
                 blocks=None, acc_dtype: str = "float32") -> ScheduleBundle:
    """LRU-cached schedule derivation keyed on the expression's normal form.

    New signature::

        get_schedule(expr, dtype=..., hardware=..., blocks=...)

    where ``expr`` is a ``core.expr.Expr``: the cache key is
    ``(normalize(expr).key(), dtype, hardware, blocks)`` — the normal form
    IS the identity of the computation, so two expressions that psi-reduce
    to the same loop nest (e.g. ``transpose(arr(..., "row"))`` and
    ``arr(..., "col")``) share one derivation.

    A ``core.expr.RecurrentForm`` (e.g. ``expr.attention_form``,
    ``expr.ssd_form``, ``expr.rglru_form``) is accepted in place of an
    expression: the bundle then carries a ``RecurrentSchedule`` (grid +
    BlockSpecs for all welded contractions, carried-state scratch and
    exported-state outputs, blocks from ``solve_stream_blocks`` /
    ``solve_recurrence_blocks``) on the same cache, keyed on the composite
    recurrent key.

    .. deprecated:: the string signature ``get_schedule("gemm", (m, k, n),
       dtype, hardware)`` is kept for one release; it builds the equivalent
       expression and lands on the same cache lines.

    ``hardware`` may be a ``HardwareEntry`` (preferred — its name keys the
    cache) or a bare ``HardwareShape``.
    """
    if isinstance(op, str):
        warnings.warn(
            "string-keyed get_schedule(op, shapes, ...) is deprecated; "
            "compose a repro.core.expr expression and pass it directly",
            DeprecationWarning, stacklevel=2)
        op = _expr_for_op(op, tuple(shapes))
        shapes = None
    if shapes is not None:
        raise TypeError("shapes is only valid with the deprecated string op")
    if hardware is None:
        raise TypeError("get_schedule requires a hardware entry/shape")
    if isinstance(op, (expr_mod.NormalForm, expr_mod.RecurrentForm)):
        nf = op
    else:
        nf = expr_mod.normal_form(op, name=getattr(op, "name", None) or "expr")
    hw_shape = getattr(hardware, "shape", hardware)
    hw_name = getattr(hardware, "name", None) or hw_shape.name
    dtype_key = str(dtype)
    acc_dtype = str(acc_dtype)
    if acc_dtype != "float32":
        # the registry is the legality oracle; the hardware table is the
        # availability oracle — a part without the bf16 partial-sum path
        # must not get bf16-accumulation schedules cached under its name
        if isinstance(nf, expr_mod.RecurrentForm):
            # recurrent monoids are exponential-reweighting folds (softmax
            # rescaling, SSD/gated decay): an integer accumulator cannot
            # represent the carried state, so refuse at derivation instead
            # of emitting a kernel that silently widens
            if "float" not in acc_dtype and \
                    acc_dtype not in ("bf16", "f16", "f32", "f64"):
                raise ValueError(
                    f"recurrent form {nf.name!r} requires a floating "
                    f"accumulator (exp-reweighted carried state), got "
                    f"acc_dtype={acc_dtype!r}")
            last = nf.stages[-1]
            semiring.check_accum(acc_dtype, dtype_key, last.combine,
                                 last.reduce_op)
        else:
            semiring.check_accum(acc_dtype, dtype_key, nf.combine,
                                 nf.reduce_op)
        if acc_dtype not in getattr(hw_shape, "acc_dtypes", ("float32",)):
            raise ValueError(
                f"hardware {hw_name!r} has no {acc_dtype!r} accumulation "
                f"path (supports {hw_shape.acc_dtypes})")
    block_key = tuple(blocks) if isinstance(blocks, (list, tuple)) else blocks
    if isinstance(block_key, (BlockChoice, StreamBlockChoice,
                              RecurrenceBlockChoice)):
        block_key = block_key.as_tuple()
    key = (nf.key(), dtype_key, hw_name, block_key, acc_dtype)
    with _lock:
        hit = _cache.get(key)
        if hit is not None:
            _stats["hits"] += 1
            _cache.move_to_end(key)
            return hit
        _stats["misses"] += 1
        if isinstance(nf, expr_mod.RecurrentForm):
            bundle = _build_recurrent_bundle(nf, dtype_key, hw_shape, blocks,
                                             acc_dtype=acc_dtype)
        else:
            bundle = _build_bundle(nf, dtype_key, hw_shape, blocks,
                                   acc_dtype=acc_dtype)
        _cache[key] = bundle
        while len(_cache) > SCHEDULE_CACHE_SIZE:
            _cache.popitem(last=False)
        return bundle
