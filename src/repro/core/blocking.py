"""Static block-size solver — the paper's §3.3/§3.4 made executable.

The paper derives block sizes *a priori* from shapes, dtypes, and the
memory-hierarchy table: on the V100 the constraint is

    3 blocks (A, B, C) x bm*bn doubles  <=  L1 per SM (32 KiB)
    => 32x32 doubles (24 KiB) best; 64x64 when shared-memory L1 (128 KiB)
       aggregation across SMs kicks in.

On TPU the analogous constraint set is:

    (bm*bk + bk*bn + bm*bn) * dtype_size * buffering  <=  VMEM budget
    bm, bn multiples of MXU tile (128);  bk multiple of sublane pack
    (256 for int8/fp8, 16 for bf16, 8 for f32 -- we use the lane-major
    second-minor packing rule)

and the objective is MXU utilization: maximize arithmetic intensity
(bm*bn*bk) / (bm*bk + bk*bn + bm*bn) subject to the grid covering (m,n,p).

``solve_blocks`` is generic over ``HardwareShape`` so the same solver,
pointed at the V100 table, reproduces the paper's 32x32 choice (tested in
tests/test_blocking.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

from repro.core.lifting import HardwareShape, TPU_V5E


_DTYPE_SIZES = {
    "bfloat16": 2, "float16": 2, "f16": 2, "bf16": 2,
    "float32": 4, "f32": 4, "float64": 8, "f64": 8,
    "int8": 1, "uint8": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
    "int32": 4, "int16": 2, "int64": 8,
}


def _dtype_size(dtype) -> int:
    name = getattr(dtype, "name", None) or str(dtype)
    if name in _DTYPE_SIZES:
        return _DTYPE_SIZES[name]
    return int(np.dtype(dtype).itemsize)


def _sublane_multiple(dtype) -> int:
    """Second-minor tiling multiple for TPU memory layout by dtype width."""
    size = _dtype_size(dtype)
    return {8: 8, 4: 8, 2: 16, 1: 32}.get(size, 8)


def _round_down(x: int, m: int) -> int:
    return max((x // m) * m, m) if x >= m else m


def _candidates(limit: int, align: int) -> Iterable[int]:
    """Aligned candidate extents up to limit (powers of two times align)."""
    c, seen = align, set()
    while c <= limit:
        seen.add(c)
        c *= 2
    # also halfway points (e.g. 384, 768) — MoA's non-square blocks
    c = align * 3
    while c <= limit:
        seen.add(c)
        c *= 2
    return sorted(seen)


@dataclass(frozen=True)
class BlockChoice:
    bm: int
    bk: int
    bn: int
    vmem_bytes: int                 # working set incl. buffering
    arithmetic_intensity: float     # flops / byte moved HBM->VMEM
    utilization: float              # fraction of MXU tile filled

    def as_tuple(self) -> tuple[int, int, int]:
        return (self.bm, self.bk, self.bn)


# ---------------------------------------------------------------------------
# working-set models — module-level so the solvers and the static resource
# certifier (repro.analysis) compute the SAME certificate from the same
# formula, not two drifting copies
# ---------------------------------------------------------------------------

def gemm_working_set(bm: int, bk: int, bn: int, esize: int, acc_size: int,
                     buffering: int = 2,
                     materialized_combine: bool = False) -> int:
    """Resident bytes of one (bm, bk, bn) GEMM grid step: double-buffered
    input blocks, the acc-width accumulator, and (non-(mul, add) semirings)
    the materialized f32 combine intermediate."""
    ws = (bm * bk + bk * bn) * esize * buffering + bm * bn * acc_size
    if materialized_combine:
        ws += bm * bn * bk * acc_size
    return ws


def stream_working_set(bq: int, bk: int, hd: int, vd: int, esize: int,
                       acc_size: int, buffering: int = 2,
                       q_extra: int = 0, k_extra: int = 0,
                       n_inter: int = 2, n_row_state: int = 2) -> int:
    """Resident bytes of one streamed (bq, bk) step: inputs, output block,
    carried accumulator + per-row state, and the in-block intermediates."""
    ws = (bq * (hd + q_extra) + bk * (hd + vd + k_extra)) * esize * buffering
    ws += bq * vd * esize                           # output block
    ws += (bq * vd + n_row_state * bq) * acc_size   # acc + row state
    ws += n_inter * bq * bk * acc_size              # scores/probs/grads
    return ws


def recurrence_working_set(bs: int, token_elems: int, state_elems: int,
                           quad_elems: int, lin_elems: int, esize: int,
                           acc_size: int, buffering: int = 2) -> int:
    """Resident bytes of one chunk step of a carried-state scan."""
    ws = token_elems * bs * esize * buffering
    ws += state_elems * acc_size
    ws += (quad_elems * bs * bs + lin_elems * bs) * acc_size
    return ws


def solve_blocks(m: int, k: int, n: int, dtype="bfloat16",
                 hardware: HardwareShape = TPU_V5E,
                 vmem_budget_frac: float = 0.5,
                 buffering: int = 2,
                 acc_dtype="float32",
                 materialized_combine: bool = False) -> BlockChoice:
    """Choose (bm, bk, bn) for C[m,n] += A[m,k] B[k,n].

    Mirrors the paper's derivation: enumerate hardware-aligned candidates,
    keep those whose *three blocks* (+double-buffered inputs, f32 accumulator
    for C) fit the VMEM budget, maximize arithmetic intensity then block
    volume.  Shapes smaller than the alignment are padded up (grid handles
    the remainder via masking in the kernel).

    ``materialized_combine``: the in-block body pairs operands by broadcast
    before folding (any semiring other than (mul, add) — no MXU fusion), so
    a full f32 ``(bm, bn, bk)`` intermediate joins the resident working set.
    The same objective then lands on much flatter tiles than the MXU GEMM.
    """
    esize = _dtype_size(dtype)
    acc_size = _dtype_size(acc_dtype)
    supported = getattr(hardware, "acc_dtypes", ("float32",))
    if str(acc_dtype) not in supported:
        raise ValueError(
            f"hardware {hardware.name!r} has no {acc_dtype!r} accumulation "
            f"path (supports {supported})")
    budget = int(hardware.vmem.capacity_bytes * vmem_budget_frac)
    lane = hardware.mxu_tile[1]                     # 128 on TPU, 1 on V100
    sub = _sublane_multiple(dtype) if hardware.mxu_tile == (128, 128) else 1
    align_mn = lane if lane > 1 else hardware.vreg_tile[1]
    align_k = sub if sub > 1 else 1

    best: BlockChoice | None = None
    cand_m = [c for c in _candidates(max(min(m, 4096), align_mn), align_mn)]
    cand_n = [c for c in _candidates(max(min(n, 4096), align_mn), align_mn)]
    cand_k = [c for c in _candidates(max(min(k, 8192), align_k * 8), align_k * 8)]
    for bm in cand_m:
        for bn in cand_n:
            for bk in cand_k:
                ws = gemm_working_set(bm, bk, bn, esize, acc_size,
                                      buffering=buffering,
                                      materialized_combine=materialized_combine)
                if ws > budget:
                    continue
                flops = 2.0 * bm * bn * bk
                moved = (bm * bk + bk * bn) * esize + bm * bn * esize
                ai = flops / moved
                util = (min(bm, m) * min(bn, n)) / float(bm * bn)
                cand = BlockChoice(bm, bk, bn, ws, ai, util)
                if best is None or _better(cand, best):
                    best = cand
    assert best is not None, "no feasible block for the given budget"
    return best


@dataclass(frozen=True)
class StreamBlockChoice:
    """Block choice for a streaming (online-softmax) reduction: the query
    block ``bq`` and the streamed key block ``bk``."""
    bq: int
    bk: int
    vmem_bytes: int                 # working set incl. buffering + state
    arithmetic_intensity: float     # flops / byte moved HBM->VMEM
    utilization: float              # fraction of the (bq, bk) tile filled

    def as_tuple(self) -> tuple[int, int]:
        return (self.bq, self.bk)


def solve_stream_blocks(sq: int, sk: int, hd: int, vd: Optional[int] = None,
                        dtype="bfloat16", hardware: HardwareShape = TPU_V5E,
                        vmem_budget_frac: float = 0.5,
                        buffering: int = 2,
                        acc_dtype="float32",
                        q_extra: int = 0, k_extra: int = 0,
                        n_inter: int = 2,
                        n_row_state: int = 2) -> StreamBlockChoice:
    """Choose ``(bq, bk)`` for a streamed two-contraction reduction
    (flash attention): per grid step the VMEM residents are the input
    blocks q ``(bq, hd)``, k ``(bk, hd)``, v ``(bk, vd)`` (double-buffered),
    the output block ``(bq, vd)``, the carried state — f32 accumulator
    ``(bq, vd)``, running max and denominator ``(bq,)`` each — and the two
    in-block f32 intermediates (scores and probabilities, ``(bq, bk)``).

    Same shape as ``solve_blocks``: enumerate hardware-aligned candidates,
    keep those whose working set (inputs + output + carried state +
    intermediates) fits the VMEM budget, maximize arithmetic intensity.
    This is the constraint set that replaces the hand-written fixed-512
    flash-attention default: at large sequence lengths on the v5e table it
    *lands on* (512, 512), and degrades gracefully when head_dim, dtype or
    the budget push the state over.

    The backward recurrence kinds reuse this model with extra terms:
    ``q_extra``/``k_extra`` widen the per-row / per-streamed-element input
    payload (e.g. the saved dO block riding the row axis, V riding the
    stream), ``n_inter`` counts the (bq, bk) f32 in-block intermediates
    (4 for flash backward: s, p, dp, ds) and ``n_row_state`` the f32
    per-row state/statistics vectors (m, l, delta, ...).  The defaults
    reproduce the forward model exactly.
    """
    vd = vd or hd
    esize = _dtype_size(dtype)
    acc_size = _dtype_size(acc_dtype)
    budget = int(hardware.vmem.capacity_bytes * vmem_budget_frac)
    lane = hardware.mxu_tile[1]
    sub = _sublane_multiple(dtype) if hardware.mxu_tile == (128, 128) else 1
    align_q = sub if sub > 1 else max(hardware.vreg_tile[0], 1)
    align_k = lane if lane > 1 else hardware.vreg_tile[1]

    best: StreamBlockChoice | None = None
    cand_q = _candidates(max(min(sq, 4096), align_q), align_q)
    cand_k = _candidates(max(min(sk, 4096), align_k), align_k)
    for bq in cand_q:
        for bk in cand_k:
            ws = stream_working_set(bq, bk, hd, vd, esize, acc_size,
                                    buffering=buffering, q_extra=q_extra,
                                    k_extra=k_extra, n_inter=n_inter,
                                    n_row_state=n_row_state)
            if ws > budget:
                continue
            flops = 2.0 * bq * bk * (hd + vd)
            moved = (bq * hd + bk * (hd + vd) + bq * vd) * esize
            ai = flops / moved
            util = (min(bq, sq) * min(bk, sk)) / float(bq * bk)
            cand = StreamBlockChoice(bq, bk, ws, ai, util)
            if best is None or _stream_better(cand, best):
                best = cand
    assert best is not None, "no feasible streaming block for the budget"
    return best


@dataclass(frozen=True)
class RecurrenceBlockChoice:
    """Block choice for a chunked carried-state scan (SSD, RG-LRU): the
    streamed-axis block ``bs`` — the chunk length the sequence axis is
    dimension-lifted by (``S -> (S/bs, bs)``)."""
    bs: int
    vmem_bytes: int                 # working set incl. buffering + state
    arithmetic_intensity: float     # flops / byte moved HBM->VMEM
    utilization: float              # fraction of the last chunk filled

    def as_tuple(self) -> tuple[int]:
        return (self.bs,)


def solve_recurrence_blocks(s: int, *, token_elems: int, state_elems: int,
                            quad_elems: int = 0, lin_elems: int = 0,
                            flops_per_step: Optional[float] = None,
                            dtype="float32",
                            hardware: HardwareShape = TPU_V5E,
                            vmem_budget_frac: float = 0.25,
                            buffering: int = 2,
                            acc_dtype="float32",
                            max_block: int = 1024) -> RecurrenceBlockChoice:
    """Choose the chunk length ``bs`` for a carried-state chunked scan.

    Per streamed step the VMEM residents are: the per-token operand and
    output blocks (``token_elems`` elements per sequence position,
    double-buffered), the carried state (``state_elems`` — SSD's (h, p, n)
    tensor, RG-LRU's channel vector; chunk-length-independent), and the
    monoid's in-chunk intermediates — ``quad_elems * bs^2`` (the segsum
    decay mask L and the score block scale quadratically with the chunk)
    plus ``lin_elems * bs`` (cumsums, per-position decays) at accumulator
    width.

    Same shape as ``solve_blocks``: enumerate hardware-aligned candidates,
    keep those whose working set fits the budget, maximize arithmetic
    intensity (monotone in ``bs`` here — quadratic intra-chunk flops over
    linear traffic — so the largest feasible chunk wins, exactly the
    paper's a-priori rule).  This replaces the hand-written
    ``default_ssd_chunk`` heuristic: the carried ``(h, ...)`` state and the
    chunk intermediates are *in the model*, so fat heads or narrow budgets
    shrink the chunk instead of overflowing VMEM.
    """
    esize = _dtype_size(dtype)
    acc_size = _dtype_size(acc_dtype)
    budget = int(hardware.vmem.capacity_bytes * vmem_budget_frac)
    lane = hardware.mxu_tile[1]
    align = lane if lane > 1 else max(hardware.vreg_tile[1], 1)

    best: RecurrenceBlockChoice | None = None
    smallest: RecurrenceBlockChoice | None = None
    for bs in _candidates(max(min(s, max_block), align), align):
        ws = recurrence_working_set(bs, token_elems, state_elems,
                                    quad_elems, lin_elems, esize, acc_size,
                                    buffering=buffering)
        flops = (flops_per_step(bs) if callable(flops_per_step)
                 else 2.0 * bs * bs * max(quad_elems, 1))
        moved = token_elems * bs * esize
        ai = flops / max(moved, 1)
        util = min(bs, s) / float(bs)
        cand = RecurrenceBlockChoice(bs, ws, ai, util)
        if smallest is None or bs < smallest.bs:
            smallest = cand
        if ws > budget:
            continue
        if best is None or _recurrence_better(cand, best):
            best = cand
    if best is None:
        # the carried state is chunk-independent, so on small memories (a
        # GPU SM's shared memory) even the minimum chunk may exceed the
        # budget fraction — degrade to the smallest aligned chunk (spilling
        # is the backend's problem) instead of failing the derivation
        best = smallest
    assert best is not None, "no candidate chunk at all"
    return best


def _recurrence_better(a: RecurrenceBlockChoice,
                       b: RecurrenceBlockChoice) -> bool:
    if abs(a.arithmetic_intensity - b.arithmetic_intensity) > 1e-9:
        return a.arithmetic_intensity > b.arithmetic_intensity
    if a.vmem_bytes != b.vmem_bytes:
        return a.vmem_bytes < b.vmem_bytes
    return a.bs < b.bs


def _stream_better(a: StreamBlockChoice, b: StreamBlockChoice) -> bool:
    if abs(a.arithmetic_intensity - b.arithmetic_intensity) > 1e-9:
        return a.arithmetic_intensity > b.arithmetic_intensity
    if a.vmem_bytes != b.vmem_bytes:
        return a.vmem_bytes < b.vmem_bytes
    return (a.bq, a.bk) < (b.bq, b.bk)


def _better(a: BlockChoice, b: BlockChoice) -> bool:
    # lexicographic: intensity, then smaller VMEM (leave headroom), then bm
    if abs(a.arithmetic_intensity - b.arithmetic_intensity) > 1e-9:
        return a.arithmetic_intensity > b.arithmetic_intensity
    if a.vmem_bytes != b.vmem_bytes:
        return a.vmem_bytes < b.vmem_bytes
    return (a.bm, a.bn, a.bk) < (b.bm, b.bn, b.bk)


def solve_blocks_square(hardware: HardwareShape, dtype="float64",
                        n_arrays: int = 3, buffering: int = 1) -> int:
    """The paper's exact derivation: largest square block b s.t.
    ``n_arrays * b^2 * dtype_size * buffering <= L1/VMEM capacity``, rounded
    down to the vector-register multiple.  With V100 + float64 this returns
    32 (3 x 32x32 doubles = 24 KiB <= 32 KiB), the paper's measured optimum;
    with shared-memory aggregation (capacity x4 = 128 KiB) it returns 64 —
    the paper's second regime.
    """
    esize = _dtype_size(dtype)
    cap = hardware.vmem.capacity_bytes
    b = int((cap / (n_arrays * esize * buffering)) ** 0.5)
    align = max(hardware.vreg_tile[1], 1)
    # the paper's observed optima are powers of two (32 -> 64): take the
    # largest power-of-two multiple of the register width that fits
    p = align
    while p * 2 <= b:
        p *= 2
    return p


def grid_for(m: int, k: int, n: int, blocks: BlockChoice) -> tuple[int, int, int]:
    """Pallas grid covering the problem (ceil-div per lifted axis)."""
    cdiv = lambda a, b: -(-a // b)
    return (cdiv(m, blocks.bm), cdiv(n, blocks.bn), cdiv(k, blocks.bk))
