"""Energy model — reproduces the paper's figs 6-11 relationships on a modeled
TPU (we cannot measure watts on CPU; the paper's own insight is that the
energy optimum is *statically predictable from shapes*, which is exactly what
this model does).

Model:  E = E_dyn + P_static * T
        E_dyn = flops * pJ_flop + hbm_bytes * pJ_hbm_byte
                + vmem_bytes * pJ_vmem_byte + ici_bytes * pJ_ici_byte
        T     = max(compute_s, memory_s, collective_s)      (overlapped)
        P     = E / T

Reproduced paper observations (validated in tests/test_energy.py and
benchmarks/bench_energy_model.py):

* Energy tracks Time across block sizes (fig 6-8): the block size that
  minimizes modeled time also minimizes modeled energy.
* Power varies ~10% while time varies orders of magnitude (fig 9-11, §3.6.3):
  P = E/T is bounded between P_static and P_static + P_dyn_max.
* For bandwidth-bound sizes, energy is linear in the *size of the matrix*
  (quadratic in N) — the abstract's headline claim; for compute-bound sizes
  it transitions to cubic, and the model exposes the crossover.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.lifting import HardwareShape, TPU_V5E
from repro.core.blocking import (BlockChoice, RecurrenceBlockChoice,
                                 StreamBlockChoice, _dtype_size)


@dataclass(frozen=True)
class EnergyReport:
    time_s: float
    energy_J: float
    power_W: float
    flops: float
    hbm_bytes: float
    vmem_bytes: float
    ici_bytes: float
    bound: str                     # "compute" | "memory" | "collective"


def gemm_traffic(m: int, k: int, n: int, blocks: BlockChoice, dtype="bfloat16",
                 acc_dtype="float32") -> tuple[float, float]:
    """HBM and VMEM traffic (bytes) for a blocked GEMM with the given block
    choice.  The blocked-contiguous schedule reads each A block n/bn times and
    each B block m/bm times (round-robin over the lifted k axis, paper fig 2);
    C is written once.  VMEM traffic counts every element touched by the MXU.
    """
    esize = _dtype_size(dtype)
    cdiv = lambda a, b: -(-a // b)
    gm, gk, gn = cdiv(m, blocks.bm), cdiv(k, blocks.bk), cdiv(n, blocks.bn)
    hbm = (gn * (m * k) + gm * (k * n)) * esize + (m * n) * esize
    vmem = 2.0 * m * k * n / min(blocks.bk, k) * esize  # operand re-touch per MXU pass
    return float(hbm), float(vmem)


def gemm_unblocked_traffic(m: int, k: int, n: int, dtype="bfloat16",
                           burst_elems: int = 128) -> float:
    """Classical (unblocked) row-of-A x column-of-B HBM traffic.

    For every (i, j) output: A's row i streams contiguously (bursts fully
    used, so useful bytes = moved bytes), but B's column j is walked with
    stride p — each access moves a full burst of which ONE element is used.
    This is the paper's strided-access penalty, the quantity MoA's
    contiguous ONF eliminates.  C is written once.
    """
    esize = _dtype_size(dtype)
    a = float(m) * n * k * esize                      # contiguous re-reads
    b = float(m) * n * k * esize * min(burst_elems, n)  # strided burst waste
    c = float(m) * n * esize
    return a + b + c


def _report(flops: float, hbm_b: float, vmem_b: float, ici_b: float,
            hardware: HardwareShape) -> EnergyReport:
    """The shared E = E_dyn + P_static * T model: one implementation for
    every op family so the modeled numbers in BENCH_schedule.json cannot
    silently desynchronize."""
    compute_s = flops / hardware.peak_flops
    memory_s = hbm_b / hardware.hbm.bandwidth_Bps
    coll_s = ici_b / hardware.ici_Bps if ici_b else 0.0
    time_s = max(compute_s, memory_s, coll_s)
    bound = {compute_s: "compute", memory_s: "memory",
             coll_s: "collective"}[time_s]
    e_dyn = (flops * hardware.flop_energy_pJ
             + hbm_b * hardware.hbm.energy_pJ_per_byte
             + vmem_b * hardware.vmem.energy_pJ_per_byte
             + ici_b * hardware.ici_energy_pJ_per_byte) * 1e-12
    energy = e_dyn + hardware.sa_power_W * time_s
    return EnergyReport(time_s, energy, energy / max(time_s, 1e-30),
                        flops, hbm_b, vmem_b, ici_b, bound)


def gemm_energy(m: int, k: int, n: int, blocks: BlockChoice,
                dtype="bfloat16", hardware: HardwareShape = TPU_V5E,
                ici_bytes: float = 0.0) -> EnergyReport:
    flops = 2.0 * m * k * n
    hbm_b, vmem_b = gemm_traffic(m, k, n, blocks, dtype)
    return _report(flops, hbm_b, vmem_b, ici_bytes, hardware)


def attention_traffic(b: int, hq: int, sq: int, sk: int, hd: int,
                      vd: int, blocks: StreamBlockChoice, dtype="bfloat16",
                      causal: bool = True) -> tuple[float, float]:
    """HBM and VMEM traffic (bytes) for the derived streaming attention
    schedule.  Q and the output move once; K and V stream once per
    (q-head, q-block) grid cell (``hq * ceil(sq / bq)`` passes in total —
    the kv-head count cancels against the group factor, so the model needs
    only ``hq``), halved by the causal block skip.  The online-softmax
    state (m, l, acc) never leaves VMEM — that is the schedule's whole
    point, and why its HBM bytes are O(S) per query block instead of the
    O(S^2) score matrix."""
    esize = _dtype_size(dtype)
    cdiv = lambda a, b_: -(-a // b_)
    nq = cdiv(sq, blocks.bq)
    frac = 0.5 if causal else 1.0           # causal skips blocks above diag
    hbm = (b * hq * sq * (hd + vd)) * esize                 # q in, out out
    # each kv head's sk*(hd+vd) data re-streams once per (group, q-block)
    # grid cell: hkv * g * nq = hq * nq passes total
    hbm += frac * nq * (b * hq * sk * (hd + vd)) * esize
    steps = frac * (b * hq) * nq * cdiv(sk, blocks.bk)
    vmem = steps * (blocks.bq * hd + blocks.bk * (hd + vd)
                    + blocks.bq * vd) * esize
    return float(hbm), float(vmem)


def attention_energy(b: int, hq: int, sq: int, sk: int, hd: int,
                     blocks: StreamBlockChoice, dtype="bfloat16",
                     vd: int = 0, causal: bool = True,
                     hardware: HardwareShape = TPU_V5E) -> EnergyReport:
    """Modeled time/energy for flash attention under the derived (bq, bk):
    the streaming analogue of ``gemm_energy`` (same E = E_dyn + P*T model)."""
    vd = vd or hd
    frac = 0.5 if causal else 1.0
    flops = frac * 2.0 * b * hq * sq * sk * (hd + vd)
    hbm_b, vmem_b = attention_traffic(b, hq, sq, sk, hd, vd, blocks,
                                      dtype, causal)
    return _report(flops, hbm_b, vmem_b, 0.0, hardware)


def scan_traffic(b: int, s: int, h: int, p: int, n: int,
                 blocks: RecurrenceBlockChoice, dtype="float32",
                 acc_dtype="float32",
                 materialized: bool = False) -> tuple[float, float]:
    """HBM and VMEM traffic (bytes) for the SSD chunked scan.

    The derived carried-state schedule streams every operand exactly once
    (x, dA, B, C in; y out; the state crosses chunks in VMEM), so its HBM
    bytes are O(S) — independent of the chunk.  With ``materialized`` the
    model instead charges the hand-rolled jnp formulation, which round-trips
    the (b, c, h, q, q) decay mask L and the per-chunk scores through HBM —
    the O(S * q * h) traffic the derived kernel's VMEM residency eliminates
    (the same story as flash attention vs materialized softmax).
    """
    esize = _dtype_size(dtype)
    acc = _dtype_size(acc_dtype)
    q = blocks.bs
    hbm = b * s * (h * p + h + 2 * n) * esize          # x, dA, B, C in
    hbm += b * s * h * p * acc                         # y out (f32)
    hbm += 2.0 * b * h * p * n * acc                   # state in + out
    if materialized:
        # L (b,c,h,q,q) + scores (b,c,q,q) written then re-read, plus the
        # per-chunk state tensors the lax.scan stages through HBM
        hbm += 2.0 * b * s * q * (h + 1) * acc
        hbm += 2.0 * b * (s / q) * h * p * n * acc
    steps = b * (s / max(q, 1))
    vmem = steps * (q * (h * p + h + 2 * n) * esize
                    + (q * q * (h + 1) + h * p * n) * acc)
    return float(hbm), float(vmem)


def scan_energy(b: int, s: int, h: int, p: int, n: int,
                blocks: RecurrenceBlockChoice, dtype="float32",
                materialized: bool = False,
                hardware: HardwareShape = TPU_V5E) -> EnergyReport:
    """Modeled time/energy for the SSD chunked scan under the derived chunk:
    the scan analogue of ``gemm_energy``/``attention_energy`` (same
    E = E_dyn + P*T model).  Intra-chunk work is quadratic in the chunk
    (the block-diagonal q x q part) plus the linear state updates."""
    q = blocks.bs
    flops = 2.0 * b * s * (q * (n + h * p) + 2.0 * h * p * n)
    hbm_b, vmem_b = scan_traffic(b, s, h, p, n, blocks, dtype,
                                 materialized=materialized)
    return _report(flops, hbm_b, vmem_b, 0.0, hardware)


def energy_vs_blocksize(n: int, block_sizes, dtype="bfloat16",
                        hardware: HardwareShape = TPU_V5E):
    """The paper's experiment: square GEMM of size n, sweep square blocks.
    Returns list of (block, EnergyReport)."""
    out = []
    for b in block_sizes:
        bc = BlockChoice(bm=b, bk=b, bn=b,
                         vmem_bytes=3 * b * b * _dtype_size(dtype),
                         arithmetic_intensity=2.0 * b / 3.0 / _dtype_size(dtype),
                         utilization=1.0)
        out.append((b, gemm_energy(n, n, n, bc, dtype, hardware)))
    return out
