"""MoA core: array algebra, ONF derivation, dimension lifting, cost/energy.

The paper's primary contribution lives here: shapes + Psi indexing (moa),
DNF->ONF loop-nest derivation (onf), dimension lifting to hardware shapes
(lifting), the static block-size solver (blocking), and the roofline/energy
cost models (cost, energy) that the solver and benchmarks share.
"""
from repro.core import moa, onf, lifting, mesh, blocking, cost, energy  # noqa: F401
