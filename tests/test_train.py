"""Training substrate: optimizer, microbatching, compression, loss descent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import PipelineConfig, SyntheticLM
from repro.distributed import compression
from repro.models import registry
from repro.optim import adamw
from repro.train import train_step as ts


def test_adamw_matches_reference_numpy():
    cfg = adamw.AdamWConfig(lr_peak=1e-2, lr_min=1e-2, warmup_steps=0,
                            decay_steps=1, weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = adamw.init(params)
    new_p, st2, m = adamw.update(cfg, g, st, params)
    # reference
    gn = np.array([0.1, 0.2, -0.3])
    mm = 0.1 * gn
    vv = 0.05 * gn * gn
    mh = mm / (1 - 0.9)
    vh = vv / (1 - 0.95)
    want = np.array([1.0, -2.0, 3.0]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(st2.step) == 1


def test_grad_clipping_bounds_update():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = adamw.init(params)
    _, _, metrics = adamw.update(cfg, g, st, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10,
                            decay_steps=100)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=1e-2)
    assert lrs[3] == pytest.approx(1e-5, rel=1e-2)


def test_microbatching_equivalent_to_full_batch():
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
    key = jax.random.PRNGKey(0)
    state1, _ = ts.init_state(cfg, key)
    state2 = jax.tree.map(lambda x: x, state1)
    data = SyntheticLM(PipelineConfig(cfg.vocab_size, 16, 4), cfg)
    batch = jax.tree.map(jnp.asarray, data.global_batch(0))
    s1, m1 = jax.jit(ts.make_train_step(cfg, microbatches=1))(state1, batch)
    s2, m2 = jax.jit(ts.make_train_step(cfg, microbatches=2))(state2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)


def test_loss_decreases_end_to_end():
    from repro.launch.train import main
    losses = main(["--arch", "stablelm-1.6b", "--reduced", "--steps", "25",
                   "--batch", "8", "--seq", "32", "--lr", "5e-3",
                   "--warmup", "5", "--log-every", "100"])
    assert losses[-1] < losses[0] - 0.3


def test_compression_quant_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    deq = compression._quant_dequant(g, 256)
    err = np.abs(np.asarray(deq - g))
    scale = np.abs(np.asarray(g)).reshape(-1, 256).max(1).repeat(256)
    assert (err <= scale / 127.0 * 0.51 + 1e-7).all()


def test_compression_error_feedback_converges():
    """SGD on a quadratic with int8-compressed grads + error feedback reaches
    the optimum (the compression residual must not accumulate)."""
    cfg = compression.CompressionConfig(enabled=True, block_size=64)
    w = jnp.full((64,), 5.0)
    err = {"w": jnp.zeros((64,))}
    target = jnp.linspace(-1, 1, 64)
    for _ in range(200):
        g = {"w": w - target}
        (g2, err) = compression.compress_grads(cfg, g, err)
        w = w - 0.1 * g2["w"]
    assert float(jnp.max(jnp.abs(w - target))) < 1e-2


def test_compressed_bytes_accounting():
    assert compression.compressed_bytes(1024, 256) == 1024 + 16
