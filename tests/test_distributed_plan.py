"""Distributed dimension-lifting: derived shard_map plans.

In-process tests cover pure plan derivation (no devices needed): partition
specs recovered from lifted Access coefficients, the derived collective
choice per sharding kind, non-divisible replication fallback, the plan
cache, and the modeled per-device byte counts.  The multi-device matrix —
sharded result == single-device oracle across mesh shapes {1, 2, 4, 8} x
{row, col, both, sigma}-sharded operands, with jaxpr pins that no unplanned
collective appears — runs in-process when 8 devices exist (the CI
multi-device job) and in a subprocess with 8 forced host devices otherwise.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import expr as E
from repro.core import hardware as hw
from repro.core import mesh as mesh_mod
from repro.core import onf as onf_mod
from repro.core import schedule as sched
from repro.core.mesh import MeshShape
from repro.distributed import plan as dplan

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CPU = hw.get_entry("cpu")
MS8 = MeshShape((("x", 8),))


# ---------------------------------------------------------------------------
# the mesh level of the lifting hierarchy
# ---------------------------------------------------------------------------

def test_mesh_shape_validation_and_lookup():
    ms = MeshShape((("data", 4), ("model", 2)))
    assert ms.axis_names == ("data", "model")
    assert ms.shape == (4, 2) and ms.n_devices == 8
    assert ms.axis_size("model") == 2
    with pytest.raises(KeyError):
        ms.axis_size("pod")
    with pytest.raises(ValueError, match="duplicate"):
        MeshShape((("x", 2), ("x", 4)))
    with pytest.raises(ValueError, match="non-positive"):
        MeshShape((("x", 0),))
    # the registry's hardware shapes already declare their mesh axes
    from repro.core.lifting import TPU_V5E
    assert MeshShape.from_hardware(TPU_V5E).axes == (("data", 16),
                                                     ("model", 16))


def test_mesh_lift_tags_loops_and_single_chip_schedule_rejects_them():
    """A mesh-lifted loop is one more dimension lift (same affine rewrite),
    and has no single-chip schedule — derive_schedule must reject it with a
    pointer to the plan subsystem, not silently grid it."""
    o = E.normalize(E.matmul_expr(8, 8, 8))
    lifted = mesh_mod.mesh_lift(o, "i", MeshShape((("x", 2),)), "x")
    (outer,) = [l for l in lifted.loops if l.resource == "mesh:x"]
    assert outer.index == "i_o" and outer.extent == 2
    assert lifted.ins[0].coeffs["i_o"] == 4 * 8     # i -> i_o*4 + i_i
    with pytest.raises(ValueError, match="mesh"):
        sched.derive_schedule(onf_mod.lift_loop(lifted, "j", 1, "proc"))


# ---------------------------------------------------------------------------
# plan derivation: specs and collectives, asserted from the plan itself
# ---------------------------------------------------------------------------

def test_plan_specs_and_collectives_per_sharding_kind():
    cases = [
        ("row", {"m": "x"}, {}, "none",
         ((("x", None)), (None, None)), ("x", None)),
        ("col", {"n": "x"}, {}, "none",
         (((None, None)), (None, "x")), (None, "x")),
        ("sigma", {"k": "x"}, {}, "psum",
         (((None, "x")), ("x", None)), (None, None)),
        ("gather", {"m": "x"}, {"replicate_out": True}, "all_gather",
         ((("x", None)), (None, None)), (None, None)),
        ("scatter", {"k": "x"}, {"scatter_axis": "m"}, "reduce_scatter",
         (((None, "x")), ("x", None)), ("x", None)),
    ]
    for name, shard, kw, coll, in_entries, out_entries in cases:
        plan = dplan.matmul_plan(64, 48, 32, MS8, shard=shard, hardware=CPU,
                                 **kw)
        assert plan.collective == coll, name
        assert plan.in_entries == in_entries, name
        assert plan.out_entries == out_entries, name
        assert plan.dropped == (), name


def test_plan_both_sharded_needs_no_collective():
    ms = MeshShape((("dx", 4), ("dy", 2)))
    plan = dplan.matmul_plan(64, 48, 32, ms, shard={"m": "dx", "n": "dy"},
                             hardware=CPU)
    assert plan.collective == "none"
    assert plan.in_entries == (("dx", None), (None, "dy"))
    assert plan.out_entries == ("dx", "dy")
    # mixed row+sigma across two axes: psum over the sigma axis only
    plan2 = dplan.matmul_plan(64, 48, 32, ms, shard={"m": "dx", "k": "dy"},
                              hardware=CPU)
    assert plan2.collective == "psum"
    assert plan2.collectives[0].mesh_axis == "dy"
    assert plan2.out_entries == ("dx", None)


def test_plan_transposed_operand_spec_lands_on_stored_dim():
    """The acceptance property at the mesh level: specs are recovered from
    the lifted coefficients, so sharding the output columns of x @ w.T
    shards dim 0 of the STORED (n, k) table — no special casing."""
    plan = dplan.matmul_plan(64, 32, 48, MS8, shard={"n": "x"},
                             transpose_b=True, hardware=CPU)
    assert plan.in_entries[1] == ("x", None)        # stored (n, k)
    assert plan.out_entries == (None, "x")
    assert plan.collective == "none"


def test_plan_per_shard_schedule_uses_local_extents():
    plan = dplan.matmul_plan(64, 48, 32, MS8, shard={"m": "x"}, hardware=CPU)
    assert plan.local_extent("i") == 8              # 64 / 8 devices
    assert plan.local_extent("k") == 48 and plan.local_extent("j") == 32
    # the per-shard bundle is a real derived schedule over local shapes
    assert plan.bundle.out_shape == (8, 32)
    assert plan.bundle.in_shapes == ((8, 48), (48, 32))


def test_plan_non_divisible_falls_back_to_replication():
    plan = dplan.matmul_plan(30, 48, 32, MeshShape((("x", 4),)),
                             shard={"m": "x"}, hardware=CPU)
    assert plan.applied == () and plan.dropped == (("i", "x"),)
    assert plan.in_entries == ((None, None), (None, None))
    assert plan.collective == "none"
    assert plan.local_extent("i") == 30             # nothing was split


def test_apply_rejects_blocks_on_sharded_path():
    """apply(mesh=...) derives per-shard blocks from the plan; a pinned
    blocks= used to be silently dropped — now it raises."""
    from repro.kernels import ops
    mesh1 = jax.make_mesh((1,), ("x",), devices=jax.devices()[:1])
    a = jnp.ones((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="blocks"):
        ops.apply(E.matmul_expr(8, 8, 8), a, a, mesh=mesh1,
                  shard={"i": "x"}, blocks=(64, 64, 64))


def test_plan_rejects_noncommutative_sigma_shard():
    """psum ADDS per-device partials; mesh-lifting the sigma axis of a
    tropical (max/min) semiring must raise, not silently sum partial maxes."""
    maxplus = E.inner("max", "add", E.arr("A", (32, 32)),
                      E.arr("B", (32, 32)))
    with pytest.raises(ValueError, match="reduce"):
        dplan.derive_plan(maxplus, MeshShape((("x", 2),)), shard={"k": "x"},
                          hardware=CPU)
    # output-axis sharding of the same semiring needs no cross-device
    # reduction and stays derivable
    plan = dplan.derive_plan(maxplus, MeshShape((("x", 2),)),
                             shard={"i": "x"}, hardware=CPU)
    assert plan.collective == "none"


def test_plan_rejects_bad_requests():
    with pytest.raises(KeyError, match="unknown axis"):
        dplan.derive_plan(E.matmul_expr(8, 8, 8), MS8, shard={"z": "x"},
                          hardware=CPU)
    with pytest.raises(KeyError):
        dplan.matmul_plan(8, 8, 8, MS8, shard={"m": "nope"}, hardware=CPU)
    with pytest.raises(ValueError, match="two axes"):
        dplan.matmul_plan(64, 64, 64, MS8, shard={"m": "x", "n": "x"},
                          hardware=CPU)
    with pytest.raises(KeyError, match="role"):
        dplan.matmul_plan(8, 8, 8, MS8, shard={"rows": "x"}, hardware=CPU)
    # scatter_axis without a mesh-lifted sigma axis must fail loudly, not
    # silently return a collective-free plan
    with pytest.raises(ValueError, match="reduction axis"):
        dplan.matmul_plan(64, 48, 32, MS8, shard={"m": "x"},
                          scatter_axis="m", hardware=CPU)
    with pytest.raises(ValueError, match="output axis"):
        dplan.matmul_plan(64, 48, 32, MS8, shard={"m": "x"},
                          scatter_axis="k", hardware=CPU)


def test_tp_shard_helper_rejects_unknown_axis_names():
    """Silent empty shards would mean every device redundantly computes the
    full GEMM while the caller believes TP is active."""
    assert dplan.tp_matmul_shard(MeshShape((("data", 4), ("model", 2))),
                                 "sigma") == {"m": "data", "k": "model"}
    with pytest.raises(ValueError, match="data"):
        dplan.tp_matmul_shard(MS8, "col")       # axes named ("x",)
    with pytest.raises(ValueError, match="row|col|sigma"):
        dplan.tp_matmul_shard(MeshShape((("model", 2),)), "diag")


def test_expert_plan_shards_the_expert_axis():
    plan = dplan.expert_plan(8, 16, 12, 10, MS8, shard={"e": "x"},
                             hardware=CPU)
    assert plan.collective == "none"
    assert plan.in_entries == (("x", None, None), ("x", None, None))
    assert plan.out_entries == ("x", None, None)
    assert plan.local_extent("i") == 1              # one expert per device


def test_plan_cache_hits_and_stats():
    dplan.reset_plan_cache()
    p0 = dplan.matmul_plan(300, 200, 100, MS8, shard={"m": "x"}, hardware=CPU)
    assert dplan.plan_cache_stats() == {"hits": 0, "misses": 1}
    p1 = dplan.matmul_plan(300, 200, 100, MS8, shard={"m": "x"}, hardware=CPU)
    assert p1 is p0
    assert dplan.plan_cache_stats() == {"hits": 1, "misses": 1}
    # a different sharding of the same normal form is a different plan line
    dplan.matmul_plan(300, 200, 100, MS8, shard={"k": "x"}, hardware=CPU)
    assert dplan.plan_cache_stats()["misses"] == 2


def test_plan_byte_model():
    """Modeled per-device HBM and interconnect traffic: sharding shrinks the
    resident bytes; only collective-bearing plans move ICI bytes."""
    esize = 4
    none_plan = dplan.matmul_plan(64, 48, 32, MS8, shard={"m": "x"},
                                  hardware=CPU)
    assert none_plan.ici_bytes_per_device() == 0
    assert none_plan.hbm_bytes_per_device() == \
        (8 * 48 + 48 * 32 + 8 * 32) * esize
    psum_plan = dplan.matmul_plan(64, 48, 32, MS8, shard={"k": "x"},
                                  hardware=CPU)
    out_bytes = 64 * 32 * 4
    assert psum_plan.ici_bytes_per_device() == int(2 * 7 / 8 * out_bytes)
    ag_plan = dplan.matmul_plan(64, 48, 32, MS8, shard={"m": "x"},
                                replicate_out=True, hardware=CPU)
    assert ag_plan.ici_bytes_per_device() == int(7 / 8 * out_bytes)
    # the gathered result is FULL-size resident on every device
    assert ag_plan.local_out_shape() == (64, 32)
    assert ag_plan.hbm_bytes_per_device() == \
        (8 * 48 + 48 * 32 + 64 * 32) * esize


def test_plan_psi_view_nonzero_offset_lowered_to_index_map():
    """A psi view with a non-zero slab offset plans like any other leaf:
    the fixed slab dim is replicated, the sharded axis lands on the right
    stored dim, and the per-shard bundle re-derives the constant Access
    term at local extents as a BlockSpec index-map offset
    (``OperandSpec.offsets``) — no materializing copy."""
    e = E.inner("add", "mul", E.psi((1,), E.arr("X", (2, 16, 16))),
                E.arr("B", (16, 8)))
    plan = dplan.derive_plan(e, MS8, shard={"i": "x"}, hardware=CPU)
    assert plan.in_entries[0] == (None, "x", None)
    assert plan.in_entries[1] == (None, None)
    assert plan.out_entries == ("x", None)
    assert plan.collective == "none"
    assert plan.local_extent("i") == 2
    x_spec = plan.bundle.schedule.ins[0]
    assert x_spec.is_psi_view
    assert x_spec.offsets[0] == 1 and x_spec.block[0] == 1
    # sigma sharding through the viewed contraction still derives the psum
    psum = dplan.derive_plan(e, MS8, shard={"k": "x"}, hardware=CPU)
    assert psum.collective == "psum"
    assert psum.in_entries[0] == (None, None, "x")


def test_plan_psi_view_at_index_zero_places_specs_structurally():
    """Regression: _spec_entries used to key psi-view detection on
    Access.const *truthiness*, so a view at index 0 (const == 0) mis-placed
    its PartitionSpec entries on the leading slab dim.  Fixed leading dims
    are now detected structurally (storage rank vs entry count): the slab
    dim is replicated and the sharded axis lands on the right stored dim."""
    e = E.inner("add", "mul", E.psi((0,), E.arr("X", (2, 8, 8))),
                E.arr("B", (8, 8)))
    plan = dplan.derive_plan(e, MS8, shard={"i": "x"}, hardware=CPU)
    # X binds its FULL (2, 8, 8) storage: slab dim replicated, rows sharded
    assert plan.in_entries[0] == (None, "x", None)
    assert plan.in_entries[1] == (None, None)
    assert plan.out_entries == ("x", None)
    assert plan.collective == "none"
    # and the plan executes: sharded == single-device oracle
    devs = jax.devices()
    if len(devs) >= 8:
        from jax.sharding import Mesh
        from repro.kernels.emit import emit_shard_map
        x = jnp.arange(2 * 8 * 8, dtype=jnp.float32).reshape(2, 8, 8)
        b = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        with Mesh(np.array(devs[:8]), ("x",)) as m:
            got = emit_shard_map(plan, m, use_kernel=False)(x, b)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(x[0] @ b), atol=1e-4)


# ---------------------------------------------------------------------------
# multi-device matrix: sharded result == single-device oracle, and the
# jaxpr contains exactly the planned collectives
# ---------------------------------------------------------------------------

def _assert_planned_collectives_only(fn, args, collective):
    """The jaxpr pin: exactly the plan's collectives appear — no unplanned
    resharding transfer anywhere in the traced program."""
    assert not analysis.lint(fn, *args, rules=("only-planned-collectives",),
                             collective=collective), collective


def _run_matrix():
    """The acceptance matrix; callable in-process (8 devices) or from the
    subprocess runner below."""
    from repro.kernels import ops
    from repro.kernels.emit import emit_shard_map

    assert jax.device_count() >= 8, jax.device_count()
    m, k, n = 32, 48, 16
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    A = jax.random.randint(k1, (m, k), -4, 5).astype(jnp.float32)
    B = jax.random.randint(k2, (k, n), -4, 5).astype(jnp.float32)
    # integer-valued f32 inputs: every summation order yields the same exact
    # floats, so sharded == single-device is assert_array_equal, not allclose
    want = np.asarray(ops.matmul(A, B, out_dtype=jnp.float32))
    shards = {"row": {"m": "x"}, "col": {"n": "x"}, "sigma": {"k": "x"}}
    both_factors = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2)}

    for p in (1, 2, 4, 8):
        for kind in ("row", "col", "both", "sigma"):
            if kind == "both":
                a, b = both_factors[p]
                mesh = jax.make_mesh((a, b), ("dx", "dy"),
                                     devices=jax.devices()[:p])
                shard = {"m": "dx", "n": "dy"}
            else:
                mesh = jax.make_mesh((p,), ("x",), devices=jax.devices()[:p])
                shard = shards[kind]
            plan = dplan.matmul_plan(m, k, n, mesh, shard=shard)
            expect = "psum" if kind == "sigma" else "none"
            assert plan.collective == expect, (p, kind, plan.collective)

            fn = lambda x, w: ops.matmul(x, w, mesh=mesh, shard=shard,
                                         out_dtype=jnp.float32)
            got = fn(A, B)
            np.testing.assert_array_equal(np.asarray(got), want,
                                          err_msg=f"{p}x{kind}")
            _assert_planned_collectives_only(fn, (A, B), plan.collective)

    mesh8 = jax.make_mesh((8,), ("x",))
    # all-gather: row-sharded input, replicated output
    plan = dplan.matmul_plan(m, k, n, mesh8, shard={"m": "x"},
                             replicate_out=True)
    assert plan.collective == "all_gather"
    fn = lambda x, w: ops.matmul(x, w, mesh=mesh8, shard={"m": "x"},
                                 replicate_out=True, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(fn(A, B)), want)
    _assert_planned_collectives_only(fn, (A, B), "all_gather")

    # reduce-scatter: sigma-sharded with the output scattered over rows
    plan = dplan.matmul_plan(m, k, n, mesh8, shard={"k": "x"},
                             scatter_axis="m")
    assert plan.collective == "reduce_scatter"
    fn = emit_shard_map(plan, mesh8, out_dtype=jnp.float32, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(fn(A, B)), want)
    _assert_planned_collectives_only(fn, (A, B), "reduce_scatter")

    # non-divisible fallback: replicated, still exact
    mesh4 = jax.make_mesh((4,), ("x",), devices=jax.devices()[:4])
    got = ops.matmul(A[:30], B, mesh=mesh4, shard={"m": "x"},
                     out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), want[:30])

    # the derived interpret-mode Pallas kernel inside shard_map agrees too
    got = ops.apply(E.matmul_expr(m, k, n), A, B, interpret=True,
                    mesh=mesh8, shard={"i": "x"}, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), want)

    # expert parallelism through the same planning path
    X = jax.random.randint(k1, (8, 6, 12), -3, 4).astype(jnp.float32)
    W = jax.random.randint(k2, (8, 12, 10), -3, 4).astype(jnp.float32)
    wantE = np.asarray(ops.expert_matmul(X, W, out_dtype=jnp.float32))
    gotE = ops.expert_matmul(X, W, mesh=mesh8, shard={"e": "x"},
                             out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(gotE), wantE)

    # planned-mesh model routing: apply_mlp + the tied vocab head produce
    # exactly the single-device numbers (integer-valued params)
    from repro.models import layers
    from repro.models.common import ArchConfig
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                     tie_embeddings=True)
    meshdm = jax.make_mesh((4, 2), ("data", "model"))
    kp = jax.random.PRNGKey(7)
    p = {"wi": jax.random.randint(kp, (16, 64), -2, 3).astype(jnp.float32),
         "wo": jax.random.randint(kp, (32, 16), -2, 3).astype(jnp.float32)}
    x = jax.random.randint(kp, (8, 4, 16), -2, 3).astype(jnp.float32)
    base = np.asarray(layers.apply_mlp(p, x, cfg))
    with dplan.planned_mesh(meshdm):
        planned = np.asarray(layers.apply_mlp(p, x, cfg))
    # silu makes the hidden non-integer, so the derived TP psum's summation
    # order costs a few ulps — allclose here, exact for the linear head below
    np.testing.assert_allclose(planned, base, rtol=1e-4, atol=1e-3)
    params = {"embed": {"table":
                        jax.random.randint(kp, (64, 16), -2, 3)
                        .astype(jnp.float32)}}
    base_l = np.asarray(layers.logits_from_hidden(params, x, cfg))
    with dplan.planned_mesh(meshdm):
        planned_l = np.asarray(layers.logits_from_hidden(params, x, cfg))
    np.testing.assert_array_equal(planned_l, base_l)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI multi-device job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_sharded_matmul_matrix_in_process():
    _run_matrix()


@pytest.mark.slow
def test_sharded_matmul_matrix_subprocess():
    """The same matrix under 8 forced host devices, so the single-device
    tier-1 run still covers it end to end."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process matrix test")
    prog = ("import sys; sys.path.insert(0, r'%s'); "
            "from test_distributed_plan import _run_matrix; _run_matrix(); "
            "print('SUBPROCESS_OK')" % os.path.join(ROOT, "tests"))
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr


def _run_psi_offset_matrix():
    """Psi views with non-zero slab offsets through ``emit_shard_map``:
    every sharding kind, both the per-shard oracle and the derived
    interpret-mode kernel, exact against the sliced single-device matmul."""
    from repro.kernels import ops
    from repro.kernels.emit import emit_shard_map

    assert jax.device_count() >= 8, jax.device_count()
    s, m, k, n = 3, 16, 16, 8
    X = jax.random.randint(jax.random.PRNGKey(0), (s, m, k), -3, 4) \
        .astype(jnp.float32)
    B = jax.random.randint(jax.random.PRNGKey(1), (k, n), -3, 4) \
        .astype(jnp.float32)
    e = E.inner("add", "mul", E.psi((2,), E.arr("X", (s, m, k))),
                E.arr("B", (k, n)))
    want = np.asarray(X[2] @ B)
    mesh8 = jax.make_mesh((8,), ("x",))
    for shard, coll in [({"i": "x"}, "none"), ({"j": "x"}, "none"),
                        ({"k": "x"}, "psum")]:
        plan = dplan.derive_plan(e, mesh8, shard=shard)
        assert plan.collective == coll, (shard, plan.collective)
        oracle = emit_shard_map(plan, mesh8, use_kernel=False,
                                out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(oracle(X, B)), want,
                                      err_msg=f"oracle {shard}")
        _assert_planned_collectives_only(oracle, (X, B), coll)
        got = ops.apply(e, X, B, interpret=True, mesh=mesh8, shard=shard,
                        out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"kernel {shard}")
    plan = dplan.derive_plan(e, mesh8, shard={"i": "x"}, replicate_out=True)
    assert plan.collective == "all_gather"
    fn = emit_shard_map(plan, mesh8, use_kernel=False, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(fn(X, B)), want)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI multi-device job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_psi_offset_matrix_in_process():
    _run_psi_offset_matrix()


@pytest.mark.slow
def test_psi_offset_matrix_subprocess():
    """The psi-offset matrix under 8 forced host devices, so the
    single-device tier-1 run covers it end to end."""
    if jax.device_count() >= 8:
        pytest.skip("covered by the in-process psi-offset matrix test")
    prog = ("import sys; sys.path.insert(0, r'%s'); "
            "from test_distributed_plan import _run_psi_offset_matrix; "
            "_run_psi_offset_matrix(); "
            "print('SUBPROCESS_OK')" % os.path.join(ROOT, "tests"))
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_planned_mesh_train_step_matches_unplanned():
    """make_train_step(planned_mesh=...) — the model's matmuls running
    through derived shard_map plans — reproduces the unplanned loss."""
    prog = """
import os
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data import PipelineConfig, SyntheticLM
from repro.train import train_step as ts

cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
key = jax.random.PRNGKey(0)
data = SyntheticLM(PipelineConfig(cfg.vocab_size, 16, 8), cfg)
batch = jax.tree.map(jnp.asarray, data.global_batch(0))
state, _ = ts.init_state(cfg, key)
mesh = jax.make_mesh((4, 2), ("data", "model"))
_, m0 = jax.jit(ts.make_train_step(cfg))(state, batch)
_, m1 = jax.jit(ts.make_train_step(cfg, planned_mesh=mesh))(state, batch)
a, b = float(m0["loss"]), float(m1["loss"])
assert abs(a - b) < 5e-3, (a, b)
print("SUBPROCESS_OK", a, b)
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr
