"""The expression algebra: compose -> normalize (DNF->ONF) -> schedule -> emit.

Property tests (via the hypothesis shim) that every expression's emitted
kernel matches the ``Onf.execute`` oracle and the jnp oracles
(``jnp.dot``/``jnp.einsum``/tropical folds), including non-divisible shapes,
``transpose_b`` and max-plus — plus the acceptance checks of the API
redesign: the transposed-operand schedule's column-gamma coefficients, the
no-relayout jaxpr, and the tied-embeddings head joining ``ops.matmul``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import analysis
from repro.core import expr as E
from repro.core import hardware as hw
from repro.core import onf as onf_mod
from repro.core import schedule as sched
from repro.kernels import ops, ref
from repro.kernels.emit import emit_pallas


def _err(got, want):
    return float(np.max(np.abs(np.asarray(got, np.float32)
                               - np.asarray(want, np.float32))))


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# normalize: structure
# ---------------------------------------------------------------------------

def test_normalize_gemm_reproduces_paper_onf():
    o = E.normalize(E.matmul_expr(4, 6, 5), out_axes=("i", "j"),
                    reduce_axes=("k",))
    assert [(l.index, l.extent) for l in o.loops] == [("i", 4), ("k", 6), ("j", 5)]
    assert o.out.coeffs == {"i": 5, "j": 1}
    assert o.ins[0].coeffs == {"i": 6, "k": 1}
    assert o.ins[1].coeffs == {"k": 5, "j": 1}
    assert o.reduce_indices == {"k"} and (o.combine, o.reduce_op) == ("mul", "add")


def test_normalize_transposed_leaf_gives_column_gamma_coeffs():
    """The acceptance property: B read through its transpose has the
    column-gamma coefficient pattern — stride 1 on the contraction axis,
    stride k on the output axis — with no data movement implied."""
    m, k, n = 4, 6, 5
    o = E.normalize(E.matmul_expr(m, k, n, transpose_b=True),
                    out_axes=("i", "j"), reduce_axes=("k",))
    assert o.ins[1].coeffs == {"j": k, "k": 1}
    # identical to declaring the leaf column-major at the transposed shape
    o2 = E.normalize(E.inner("add", "mul", E.arr("A", (m, k)),
                             E.arr("B", (k, n), layout="col")),
                     out_axes=("i", "j"), reduce_axes=("k",))
    assert o.key() == o2.key()


def test_normalize_operator_sugar():
    a, b = E.arr("A", (3, 4)), E.arr("B", (4, 5))
    assert E.normalize(a @ b).key() == E.normalize(
        E.inner("add", "mul", a, b)).key()
    c = E.arr("C", (3, 4))
    assert E.normalize(a * c).combine == "mul"
    assert E.normalize(a + c).combine == "add"
    assert E.normalize(a @ E.arr("B2", (5, 4)).T).ins[1].coeffs == \
        {"j": 4, "k": 1}


def test_normalize_rejects_non_distributive_hoist():
    """A reduce nested under a combine operand is hoisted to the single
    loop-nest reduction — sound only under the semiring law.  add does not
    distribute over add, so this must be rejected, not mis-compiled."""
    bad = E.combine("add", E.reduce("add", E.arr("A", (3, 4)), axis=1),
                    E.arr("B", (3,)))
    with pytest.raises(ValueError, match="distribute"):
        E.normalize(bad)
    # mul DOES distribute over add: scaling a row-sum is a valid ONF and
    # matches both oracles through the kernel path
    ok = E.combine("mul", E.reduce("add", E.arr("A", (3, 4)), axis=1),
                   E.arr("B", (3,)))
    a = _rand(jax.random.PRNGKey(20), (3, 4))
    b = _rand(jax.random.PRNGKey(21), (3,))
    got = ops.apply(ok, a, b, interpret=True, out_dtype=jnp.float32)
    assert _err(got, jnp.sum(a, axis=1) * b) < 1e-5
    assert _err(got, ref.eval_expr(ok, a, b)) < 1e-5
    # chained inner products hoist through mul/add (distributive) too
    chain = E.arr("A", (3, 4)) @ E.arr("B", (4, 5)) @ E.arr("C", (5, 2))
    aa, bb, cc = (_rand(jax.random.PRNGKey(22 + i), s)
                  for i, s in enumerate([(3, 4), (4, 5), (5, 2)]))
    got = ops.apply(chain, aa, bb, cc, interpret=True, out_dtype=jnp.float32)
    assert _err(got, aa @ bb @ cc) < 1e-4


def test_unregistered_pad_semiring_runs_at_aligned_shapes():
    """(mul, max) has no inert pad element, but at block-aligned shapes no
    padding is ever applied — the pair must run, not raise eagerly."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(24))
    a, b = _rand(k1, (128, 128)), _rand(k2, (128, 128))
    got = ops.apply(E.inner("max", "mul", E.arr("A", (128, 128)),
                            E.arr("B", (128, 128))),
                    a, b, interpret=True, out_dtype=jnp.float32)
    want = jnp.max(a[:, :, None] * b[None, :, :], axis=1)
    assert _err(got, want) < 1e-5
    # at non-aligned shapes the missing pad element is still a clear error
    with pytest.raises(ValueError, match="pad"):
        ops.apply(E.inner("max", "mul", E.arr("A", (100, 70)),
                          E.arr("B", (70, 30))),
                  _rand(k1, (100, 70)), _rand(k2, (70, 30)), interpret=True)


def test_root_inner_needs_no_distributive_law():
    """inner('add', 'add', ...) keeps its reduce outermost in the ONF —
    legal for any op pair, and the kernel matches the broadcast oracle."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(23))
    a, b = _rand(k1, (5, 7)), _rand(k2, (7, 6))
    got = ops.apply(E.inner("add", "add", E.arr("A", (5, 7)),
                            E.arr("B", (7, 6))),
                    a, b, interpret=True, out_dtype=jnp.float32)
    want = jnp.sum(a[:, :, None] + b[None, :, :], axis=1)
    assert _err(got, want) < 1e-4


def test_normalize_rejects_mixed_ops_and_bad_shapes():
    a, b = E.arr("A", (3, 4)), E.arr("B", (3, 4))
    with pytest.raises(ValueError, match="mixes combine"):
        E.normalize(E.combine("add", E.combine("mul", a, b), a))
    with pytest.raises(ValueError, match="shape mismatch"):
        E.combine("mul", a, E.arr("B", (4, 3)))
    with pytest.raises(ValueError, match="contraction mismatch"):
        E.inner("add", "mul", a, E.arr("B", (5, 2)))
    with pytest.raises(ValueError, match="unknown combine"):
        E.combine("xor", a, b)


def test_psi_views_normalize_to_constant_offsets_and_execute():
    x = np.arange(24, dtype=np.float32)
    o = E.normalize(E.psi((1,), E.arr("A", (4, 6))))
    assert o.ins[0].const == 6
    np.testing.assert_array_equal(o.execute(o.init_out(6), x), x[6:12])
    # the constant offset lowers into the BlockSpec index map: one leading
    # slab dim of block 1, pinned (not grid-driven) at the viewed slab
    lifted = onf_mod.lift_loop(o, "i", 1, "proc")
    spec = sched.derive_schedule(lifted).ins[0]
    assert spec.is_psi_view
    assert spec.offsets[0] == 1 and spec.block[0] == 1
    assert spec.grid_dims[0] is None and spec.shape[0] == 2
    # a view that does not address whole slabs (col-major leaf with a fixed
    # leading Cartesian index -> the loop axis is strided) still has no
    # lowering: the dense-view check rejects it before the slab rule
    oc = E.normalize(E.psi((1,), E.arr("A", (4, 6), layout="col")))
    with pytest.raises(ValueError, match="dense row-major"):
        sched.derive_schedule(onf_mod.lift_loop(oc, "i", 1, "proc"))


def test_psi_sliced_operands_run_derived_kernels():
    """Sliced operands get derived kernels (no normalize- or schedule-time
    rejection): psi-viewed matmul operands match the jnp oracle through the
    interpret-mode kernel, including non-divisible (padded) shapes and a
    multi-index view."""
    key = jax.random.PRNGKey(30)
    x = _rand(key, (3, 10, 7))
    b = _rand(jax.random.PRNGKey(31), (7, 9))
    e = E.inner("add", "mul", E.psi((2,), E.arr("X", (3, 10, 7))),
                E.arr("B", (7, 9)))
    got = ops.apply(e, x, b, interpret=True, out_dtype=jnp.float32)
    assert _err(got, x[2] @ b) < 1e-4
    # view on the SECOND operand, two fixed leading indices
    w = _rand(jax.random.PRNGKey(32), (2, 3, 7, 9))
    e2 = E.inner("add", "mul", E.arr("A", (10, 7)),
                 E.psi((1, 2), E.arr("W", (2, 3, 7, 9))))
    a = _rand(jax.random.PRNGKey(33), (10, 7))
    got2 = ops.apply(e2, a, w, interpret=True, out_dtype=jnp.float32)
    assert _err(got2, a @ w[1, 2]) < 1e-4
    # and the XLA-oracle dispatch agrees
    with hw.use_hardware("v100"):
        assert _err(ops.apply(e2, a, w, out_dtype=jnp.float32),
                    a @ w[1, 2]) < 1e-4


def test_head_matmul_matches_einsum_both_layouts():
    """The MLA decode contractions: per-head batched GEMM over head-middle
    weights in stored layout, both plain (bshk,khn->bshn) and transposed
    (bshk,nhk->bshn) — no einsum fallback, no weight relayout."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(34))
    b, s, h, kk, n = 2, 3, 4, 8, 5
    x = _rand(k1, (b, s, h, kk))
    w = _rand(k2, (kk, h, n))
    got = ops.head_matmul(x, w, interpret=True, out_dtype=jnp.float32)
    assert _err(got, jnp.einsum("bshk,khn->bshn", x, w)) < 1e-4
    wt = _rand(k2, (n, h, kk))
    got_t = ops.head_matmul(x, wt, transpose_b=True, interpret=True,
                            out_dtype=jnp.float32)
    assert _err(got_t, jnp.einsum("bshk,nhk->bshn", x, wt)) < 1e-4
    # the XLA-oracle dispatch path agrees
    with hw.use_hardware("v100"):
        assert _err(ops.head_matmul(x, wt, transpose_b=True,
                                    out_dtype=jnp.float32),
                    jnp.einsum("bshk,nhk->bshn", x, wt)) < 1e-4


def test_reduce_node_normalizes_single_operand_fold():
    x = np.arange(12, dtype=np.float32)
    o = E.normalize(E.reduce("max", E.arr("A", (3, 4)), axis=1))
    got = o.execute(o.init_out(3), x)
    np.testing.assert_array_equal(got, x.reshape(3, 4).max(axis=1))


def test_apply_runs_single_operand_reduce_kernel():
    """A lone reduce has no pairing op: padding must fall back to the
    reduce identity, and the emitted kernel must match the jnp fold —
    non-divisible shape included."""
    x = _rand(jax.random.PRNGKey(13), (5, 37))
    got = ops.apply(E.reduce("max", E.arr("A", (5, 37)), axis=1), x,
                    interpret=True, out_dtype=jnp.float32)
    assert _err(got, jnp.max(x, axis=1)) < 1e-6
    got_min = ops.apply(E.reduce("min", E.arr("A", (5, 37)), axis=0), x,
                        interpret=True, out_dtype=jnp.float32)
    assert _err(got_min, jnp.min(x, axis=0)) < 1e-6


# ---------------------------------------------------------------------------
# keystone: emitted kernel == Onf.execute == jnp, over expression families
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40),
       st.sampled_from([False, True]), st.integers(0, 2 ** 31))
def test_property_matmul_kernel_matches_oracles(m, k, n, transpose_b, seed):
    """Every (possibly transposed, possibly non-divisible) matmul
    expression: emitted kernel == Onf.execute == jnp.dot."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (m, k))
    b = _rand(k2, (n, k) if transpose_b else (k, n))
    expr = E.matmul_expr(m, k, n, transpose_b=transpose_b)
    got = ops.apply(expr, a, b, interpret=True, out_dtype=jnp.float32)
    want_jnp = a @ (b.T if transpose_b else b)
    assert got.shape == (m, n)
    assert _err(got, want_jnp) < 5e-5 * max(k, 1)
    o = E.normalize(expr)
    want_onf = o.execute(o.init_out(m * n), np.asarray(a).ravel(),
                         np.asarray(b).ravel()).reshape(m, n)
    assert _err(got, want_onf) < 5e-5 * max(k, 1)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 24), st.integers(1, 24), st.integers(1, 24),
       st.sampled_from(["max", "min"]), st.integers(0, 2 ** 31))
def test_property_tropical_kernel_matches_oracles(m, k, n, plus, seed):
    """Max-plus / min-plus through the SAME pipeline: kernel == Onf.execute
    == the jnp broadcast/fold oracle, non-divisible shapes included (padding
    uses the semiring's inert element, not zero)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (m, k)), _rand(k2, (k, n))
    got = ops.semiring_matmul(a, b, plus=plus, times="add", interpret=True)
    fold = jnp.max if plus == "max" else jnp.min
    want = fold(a[:, :, None] + b[None, :, :], axis=1)
    assert _err(got, want) < 1e-5
    o = E.normalize(E.inner(plus, "add", E.arr("A", (m, k)),
                            E.arr("B", (k, n))))
    want_onf = o.execute(o.init_out(m * n), np.asarray(a).ravel(),
                         np.asarray(b).ravel()).reshape(m, n)
    assert _err(got, want_onf) < 1e-5


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 30), st.integers(1, 30),
       st.sampled_from(["mul", "add"]), st.integers(0, 2 ** 31))
def test_property_pointwise_kernel_matches_oracles(m, n, op, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (m, n)), _rand(k2, (m, n))
    expr = E.combine(op, E.arr("A", (m, n)), E.arr("B", (m, n)))
    got = ops.apply(expr, a, b, interpret=True, out_dtype=jnp.float32)
    want = a * b if op == "mul" else a + b
    assert _err(got, want) < 1e-6
    o = E.normalize(expr)
    want_onf = o.execute(o.init_out(m * n), np.asarray(a).ravel(),
                         np.asarray(b).ravel()).reshape(m, n)
    assert _err(got, want_onf) < 1e-6


@settings(max_examples=4, deadline=None)
@given(st.integers(1, 4), st.integers(1, 12), st.integers(1, 12),
       st.integers(1, 12), st.integers(0, 2 ** 31))
def test_property_batched_inner_matches_einsum(e, cap, d, f, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, w = _rand(k1, (e, cap, d)), _rand(k2, (e, d, f))
    expr = E.inner("add", "mul", E.arr("X", (e, cap, d)),
                   E.arr("W", (e, d, f)), batch=1)
    got = ops.apply(expr, x, w, interpret=True, out_dtype=jnp.float32)
    assert _err(got, jnp.einsum("ecd,edf->ecf", x, w)) < 5e-5 * d


# ---------------------------------------------------------------------------
# acceptance: transposed-operand schedule — no relayout copy
# ---------------------------------------------------------------------------

def test_transpose_b_schedule_blocks_stored_layout():
    """The derived schedule reads B in its STORED (n, k) shape: the operand
    spec's storage shape/axes come straight from the column-gamma
    coefficients, and both axes are driven by grid dims (j, k)."""
    entry = hw.get_entry("cpu")
    bundle = sched.get_schedule(E.matmul_expr(256, 192, 128, transpose_b=True),
                                dtype="float32", hardware=entry)
    b_spec = bundle.schedule.ins[1]
    bm, bk, bn = bundle.blocks.as_tuple()
    assert b_spec.axes == ("j", "k")               # storage order of (n, k)
    assert b_spec.shape == (bundle.padded[1], bundle.padded[2])
    assert b_spec.block == (bn, bk)
    grid_bases = [g.base for g in bundle.schedule.grid]
    assert b_spec.grid_dims == (grid_bases.index("j"), grid_bases.index("k"))


def test_transpose_b_jaxpr_has_no_relayout():
    """No transpose primitive anywhere in the jitted kernel path: the
    stored (n, k) operand flows into pallas_call via pad/slice only."""
    m, k, n = 64, 32, 48
    fn = ops._expr_callable(E.matmul_expr(m, k, n, transpose_b=True),
                            "float32", "float32", "cpu", True)
    x = jnp.zeros((m, k), jnp.float32)
    w = jnp.zeros((n, k), jnp.float32)
    assert not analysis.lint(fn, x, w, rules=("no-transpose-copy",
                                              "no-silent-fallback"))


def test_matmul_transpose_b_matches_xT_and_collapses_dims():
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = _rand(k1, (2, 5, 16))
    w = _rand(k2, (11, 16))
    got = ops.matmul(x, w, transpose_b=True, interpret=True,
                     out_dtype=jnp.float32)
    want = jnp.einsum("bsd,vd->bsv", x, w)
    assert got.shape == (2, 5, 11)
    assert _err(got, want) < 1e-4
    # XLA-oracle dispatch agrees (and also avoids a transpose: dot_general)
    with hw.use_hardware("v100"):
        assert _err(ops.matmul(x, w, transpose_b=True,
                               out_dtype=jnp.float32), want) < 1e-4


def test_matmul_backward_has_no_relayout_either():
    """Both VJP gradients are derived transposed-operand GEMMs: no
    transpose primitive in the whole grad jaxpr, forward or backward,
    for either transpose_b setting."""
    for tb in (False, True):
        def loss(x, w):
            return ops.matmul(x, w, transpose_b=tb, interpret=True).sum()

        x = jnp.zeros((8, 16), jnp.float32)
        w = jnp.zeros((4, 16) if tb else (16, 4), jnp.float32)
        assert not analysis.lint(jax.grad(loss, argnums=(0, 1)), x, w,
                                 rules=("no-transpose-copy",)), tb


def test_onf_key_is_axis_name_independent():
    """The cache key canonicalizes loop names positionally: how axes were
    *named* at normalize time cannot split cache lines."""
    o1 = E.normalize(E.matmul_expr(4, 6, 5))
    o2 = E.normalize(E.matmul_expr(4, 6, 5), out_axes=("r", "c"),
                     reduce_axes=("t",))
    assert o1.key() == o2.key()
    # ...but different structure still differs
    assert o1.key() != E.normalize(E.matmul_expr(4, 6, 5,
                                                 transpose_b=True)).key()


def test_matmul_transpose_b_is_differentiable():
    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    x = _rand(k1, (6, 8))
    w = _rand(k2, (4, 8))

    def loss(xx, ww):
        return (ops.matmul(xx, ww, transpose_b=True, interpret=True) ** 2).sum()

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx),
                               np.asarray(2 * (x @ w.T) @ w),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw),
                               np.asarray(2 * (x @ w.T).T @ x),
                               rtol=1e-4, atol=1e-4)


def test_tied_embeddings_head_uses_unified_matmul():
    """models.layers.logits_from_hidden contracts the stored (vocab, d)
    table through ops.matmul(transpose_b=True) and matches the einsum it
    replaced."""
    from repro.models import layers
    from repro.models.common import ArchConfig
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                     tie_embeddings=True)
    key = jax.random.PRNGKey(9)
    params = {"embed": {"table": _rand(key, (cfg.vocab_size, cfg.d_model))}}
    x = _rand(jax.random.PRNGKey(10), (2, 3, cfg.d_model))
    got = layers.logits_from_hidden(params, x, cfg)
    want = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"],
                      preferred_element_type=jnp.float32)
    assert _err(got, want) < 1e-4


# ---------------------------------------------------------------------------
# apply(): the public expression entry
# ---------------------------------------------------------------------------

def test_apply_col_layout_binds_storage_buffer():
    """A col-layout leaf and its transpose() twin share one normal form, so
    apply binds the SAME physical (n, k) array to both — and both match
    a @ b, on the kernel path and the XLA oracle alike."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(14))
    a = _rand(k1, (4, 6))
    b = _rand(k2, (6, 8))
    via_col = E.inner("add", "mul", E.arr("A", (4, 6)),
                      E.arr("B", (6, 8), layout="col"))
    via_t = E.inner("add", "mul", E.arr("A", (4, 6)),
                    E.transpose(E.arr("B", (8, 6))))
    storage_b = b.T                                     # the (8, 6) buffer
    got_col = ops.apply(via_col, a, storage_b, interpret=True,
                        out_dtype=jnp.float32)
    got_t = ops.apply(via_t, a, storage_b, interpret=True,
                      out_dtype=jnp.float32)
    assert _err(got_col, a @ b) < 1e-5
    np.testing.assert_array_equal(np.asarray(got_col), np.asarray(got_t))
    with hw.use_hardware("v100"):                       # eval_expr oracle
        assert _err(ops.apply(via_col, a, storage_b,
                              out_dtype=jnp.float32), a @ b) < 1e-5
    # binding the logical (k, n) array is a shape error, not silent garbage
    with pytest.raises(ValueError, match="storage shape"):
        ops.apply(via_col, a, b, interpret=True)


def test_apply_validates_leaf_arity_and_shapes():
    expr = E.matmul_expr(4, 6, 5)
    a = jnp.zeros((4, 6))
    with pytest.raises(ValueError, match="leaves"):
        ops.apply(expr, a)
    with pytest.raises(ValueError, match="shape"):
        ops.apply(expr, a, jnp.zeros((5, 6)))


def test_apply_xla_fallback_matches_kernel_path():
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    a, b = _rand(k1, (9, 7)), _rand(k2, (7, 13))
    expr = E.inner("max", "add", E.arr("A", (9, 7)), E.arr("B", (7, 13)))
    kern = ops.apply(expr, a, b, interpret=True, out_dtype=jnp.float32)
    with hw.use_hardware("v100"):                  # backend "xla"
        oracle = ops.apply(expr, a, b, out_dtype=jnp.float32)
    assert _err(kern, oracle) < 1e-5


def test_eval_expr_handles_transpose_psi_and_reduce():
    k1 = jax.random.PRNGKey(12)
    x = _rand(k1, (3, 4))
    np.testing.assert_allclose(
        np.asarray(ref.eval_expr(E.transpose(E.arr("A", (3, 4))), x)),
        np.asarray(x).T)
    np.testing.assert_allclose(
        np.asarray(ref.eval_expr(E.psi((2,), E.arr("A", (3, 4))), x)),
        np.asarray(x)[2])
    np.testing.assert_allclose(
        np.asarray(ref.eval_expr(E.reduce("min", E.arr("A", (3, 4)), 1), x)),
        np.asarray(x).min(axis=1), rtol=1e-6)


# ---------------------------------------------------------------------------
# the schedule cache under expression keys
# ---------------------------------------------------------------------------

def test_semirings_are_distinct_cache_lines():
    sched.reset_schedule_cache()
    entry = hw.get_entry("cpu")
    a, b = E.arr("A", (32, 16)), E.arr("B", (16, 24))
    sched.get_schedule(E.inner("add", "mul", a, b), dtype="float32",
                       hardware=entry)
    sched.get_schedule(E.inner("max", "add", a, b), dtype="float32",
                       hardware=entry)
    sched.get_schedule(E.inner("min", "add", a, b), dtype="float32",
                       hardware=entry)
    stats = sched.schedule_cache_stats()
    assert stats["misses"] == 3 and stats["hits"] == 0
    # every line ran the block solver once — the tropical lines with the
    # materialized (bm, bn, bk) combine intermediate in the working set
    assert stats["solves"] == 3


def test_tropical_schedule_semantics_and_scratch():
    entry = hw.get_entry("cpu")
    bundle = sched.get_schedule(
        E.inner("min", "add", E.arr("D", (200, 200)), E.arr("D2", (200, 200))),
        dtype="float32", hardware=entry)
    s = bundle.schedule
    assert (s.combine, s.reduce_op) == ("add", "min")
    assert s.needs_scratch
    fn = emit_pallas(s, out_dtype=jnp.float32, interpret=True)
    assert fn is not None
