"""The carried-state recurrence subsystem: SSD / RG-LRU scans derived from
lifted recurrent forms, windowed/prefix attention masking metadata, and the
GPU hardware entry's CUDA-shaped tiles.

Covers the derivation itself (the RecurrentSchedule object: one grid from
all welded stages, aux/state BlockSpecs, the solved chunk — the model files
hand-write nothing), kernel-vs-oracle parity (bit-identity for SSD on the
same chunking, tolerance for the re-associated gated scan), gradients
through the oracle VJP, the Mamba-2 decode/prefill cache round-trip, and
the source-scan pins that no hand-written chunk/scan loop survives in
models/ssm.py or models/rglru.py.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expr as E
from repro.core import hardware as hw
from repro.core import schedule as sched
from repro.core.blocking import solve_recurrence_blocks
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _ssd_inputs(b=2, s=24, h=3, p=4, n=5, seed=0, integer=False):
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(seed), 5)
    if integer:
        xdt = jax.random.randint(k1, (b, s, h, p), -3, 4).astype(jnp.float32)
        dA = -jax.random.randint(k2, (b, s, h), 0, 3).astype(jnp.float32)
        B = jax.random.randint(k3, (b, s, n), -2, 3).astype(jnp.float32)
        C = jax.random.randint(k4, (b, s, n), -2, 3).astype(jnp.float32)
        h0 = jax.random.randint(k5, (b, h, p, n), -2, 3).astype(jnp.float32)
    else:
        xdt = jax.random.normal(k1, (b, s, h, p), jnp.float32)
        dA = -jnp.abs(jax.random.normal(k2, (b, s, h), jnp.float32)) * 0.3
        B = jax.random.normal(k3, (b, s, n), jnp.float32)
        C = jax.random.normal(k4, (b, s, n), jnp.float32)
        h0 = jax.random.normal(k5, (b, h, p, n), jnp.float32) * 0.1
    return xdt, dA, B, C, h0


# ---------------------------------------------------------------------------
# the derivation: the RecurrentSchedule object IS the scan's layout
# ---------------------------------------------------------------------------

def test_ssd_schedule_is_derived_recurrence():
    """Inspect the RecurrentSchedule for the SSD form: one grid from both
    welded stages (batch parallel, chunk index streamed sequentially), the
    chunked BlockSpecs walking the stored (B, S, ...) buffers in place, the
    aux (dA, H0) operands, the exported final-state output, and the derived
    in-block einsum plans."""
    b, nc, q, h, p, n = 2, 4, 8, 3, 4, 5
    form = E.ssd_form(b, nc, q, h, p, n)
    bundle = sched.get_schedule(form, dtype="float32",
                                hardware=hw.get_entry("cpu"), blocks=(q,))
    rs = bundle.schedule
    assert rs.grid_extents == (b, nc)
    assert rs.dimension_semantics == ("parallel", "arbitrary")
    assert rs.stream_grid_dim == 1 and rs.stream_axis == "c"
    assert rs.state.kind == "ssd" and rs.state.exports
    Cs, Bs, Xs, dAs, H0s = rs.ins
    assert (Cs.array, Cs.block) == ("C", (1, 1, q, n))
    assert (Bs.array, Bs.block) == ("B", (1, 1, q, n))
    assert (Xs.array, Xs.block) == ("X", (1, 1, q, h, p))
    assert (dAs.array, dAs.block) == ("dA", (1, 1, q, h))
    # the initial state has no chunk dim at all: pinned per batch cell
    assert (H0s.array, H0s.shape, H0s.grid_dims) == \
        ("H0", (b, h, p, n), (0, None, None, None))
    # the intermediate carries the head broadcast (the decay weighting's
    # axis) the scores output does not — the SSD analogue of GQA's zero
    # group coefficient, recovered not hand-coded
    assert rs.inter.block == (1, 1, h, q, q)
    assert rs.stages[0].out.block == (1, 1, q, q)
    # the exported state output: (b, h, p, n), one block per batch cell
    (st,) = rs.state_outs
    assert (st.shape, st.block, st.grid_dims) == \
        ((b, h, p, n), (1, h, p, n), (0, None, None, None))
    # both in-block contractions are derived einsum plans
    s_plan, _ = rs.stages[0].einsum_plan()
    c_plan, _ = rs.stages[1].einsum_plan()
    assert s_plan.count(",") == 1 and c_plan.count(",") == 1


def test_rglru_schedule_is_degenerate_recurrence():
    """The gated scan is the N=1 contraction-free instance: one stage, no
    intermediates, per-channel exported state."""
    b, nc, q, w = 2, 3, 8, 6
    bundle = sched.get_schedule(E.rglru_form(b, nc, q, w), dtype="float32",
                                hardware=hw.get_entry("cpu"), blocks=(q,))
    rs = bundle.schedule
    assert rs.grid_extents == (b, nc)
    assert rs.inters == () and rs.state.kind == "gated"
    assert [i.array for i in rs.ins] == ["A", "Bv", "H0"]
    assert rs.state_outs[0].shape == (b, w)
    assert rs.state_blocks() == ((1, w),)


def test_recurrent_form_masking_metadata_keys_cache():
    """window/prefix_len are part of the form's identity: windowed and
    full-causal attention land on different cache lines (their emitted
    block-skip differs), same-window calls share one."""
    sched.reset_schedule_cache()
    entry = hw.get_entry("cpu")
    a = sched.get_schedule(E.attention_form(1, 1, 1, 64, 64, 8),
                           dtype="float32", hardware=entry, blocks=(16, 16))
    b = sched.get_schedule(E.attention_form(1, 1, 1, 64, 64, 8, window=8),
                           dtype="float32", hardware=entry, blocks=(16, 16))
    c = sched.get_schedule(E.attention_form(1, 1, 1, 64, 64, 8, window=8),
                           dtype="float32", hardware=entry, blocks=(16, 16))
    assert a is not b and b is c
    assert b.schedule.window == 8 and a.schedule.window == 0


def test_streaming_form_alias_one_release():
    """The deprecated StreamingForm factory still constructs the softmax
    instance (aliased rename, one release)."""
    form = E.attention_form(1, 1, 1, 32, 32, 8)
    with pytest.warns(DeprecationWarning):
        alias = E.StreamingForm("flash_attention",
                                form.stages[0], form.stages[1], "j")
    assert isinstance(alias, E.RecurrentForm)
    assert alias.key() == form.key()


def test_recurrence_chunk_is_solved_not_fixed():
    """The chunk comes from the working-set model, not a constant: fat
    heads/state shrink it below the default rather than overflow VMEM."""
    v5e = hw.get_entry("cpu").shape
    small = solve_recurrence_blocks(
        4096, token_elems=2 * 16 + 4 * 65, state_elems=2 * 4 * 64 * 16,
        quad_elems=5, lin_elems=16, hardware=v5e)
    fat = solve_recurrence_blocks(
        4096, token_elems=2 * 256 + 64 * 129, state_elems=2 * 64 * 128 * 256,
        quad_elems=65, lin_elems=256, hardware=v5e)
    assert small.bs % 128 == 0
    assert fat.bs < small.bs
    assert fat.vmem_bytes <= v5e.vmem.capacity_bytes
    # the ops-layer front lands in a sane MXU-aligned range
    q = ops.default_ssd_chunk(4096, 24, 64, 128)
    assert q % 128 == 0 and 128 <= q <= 1024


# ---------------------------------------------------------------------------
# kernel vs oracle: bit-identity (SSD) and parity (gated), incl. gradients
# ---------------------------------------------------------------------------

def test_ssd_kernel_bit_identical_to_oracle_on_integers():
    """Acceptance pin: the derived SSD kernel is bit-identical to the
    chunked-jnp oracle on integer inputs in interpret mode (same chunking,
    same factored per-chunk ops, same f32 accumulation order)."""
    xdt, dA, B, C, h0 = _ssd_inputs(integer=True)
    y_ref, f_ref = ops._ssd_oracle(xdt, dA, B, C, h0, 8)
    y_k, f_k = ops.scan_ssd(xdt, dA, B, C, init_state=h0, chunk=8,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_k))
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_k))


@pytest.mark.parametrize("s,chunk", [(24, 8), (21, 8), (5, 8), (16, 16)])
def test_ssd_kernel_matches_oracle_any_length(s, chunk):
    """The pad/slice contract: any sequence length runs the kernel; padded
    tokens are the monoid's identity step (zero input, unit decay), so the
    final state is unaffected by padding."""
    xdt, dA, B, C, h0 = _ssd_inputs(s=s)
    y_ref, f_ref = ops._ssd_oracle(xdt, dA, B, C, h0, min(chunk, s))
    y_k, f_k = ops.scan_ssd(xdt, dA, B, C, init_state=h0, chunk=chunk,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_ref), np.asarray(f_k), atol=1e-5)


def test_ssd_chunk_invariance():
    """Different chunkings (liftings) of the same scan agree — chunking is
    a schedule choice, not a semantics choice."""
    xdt, dA, B, C, h0 = _ssd_inputs(s=24)
    y1, f1 = ops.scan_ssd(xdt, dA, B, C, init_state=h0, chunk=4,
                          interpret=True)
    y2, f2 = ops.scan_ssd(xdt, dA, B, C, init_state=h0, chunk=12,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4)


def test_ssd_gradients_match_oracle():
    """The kernel path is differentiable via the chunked-jnp oracle VJP."""
    xdt, dA, B, C, h0 = _ssd_inputs(b=1, s=12, h=2, p=3, n=4)

    def loss_k(*a):
        y, f = ops.scan_ssd(*a, init_state=h0, chunk=4, interpret=True)
        return (y ** 2).sum() + (f ** 2).sum()

    def loss_o(*a):
        y, f = ops._ssd_oracle(*a, h0, 4)
        return (y ** 2).sum() + (f ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(xdt, dA, B, C)
    go = jax.grad(loss_o, argnums=(0, 1, 2, 3))(xdt, dA, B, C)
    for a, b in zip(gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_ssd_xla_entry_dispatches_oracle(monkeypatch):
    """"xla" entries run the chunked-jnp oracle (no kernel executor);
    "interpret" entries run the derived kernel — the documented backend
    split, pinned on dispatch not values."""
    calls = []
    orig = ops._ssd_executor
    monkeypatch.setattr(ops, "_ssd_executor",
                        lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1])
    xdt, dA, B, C, h0 = _ssd_inputs(b=1, s=8, h=2, p=3, n=4)
    with hw.use_hardware("v100"):
        y_x, f_x = ops.scan_ssd(xdt, dA, B, C, init_state=h0, chunk=4)
    assert not calls
    with hw.use_hardware("cpu"):
        y_i, f_i = ops.scan_ssd(xdt, dA, B, C, init_state=h0, chunk=4)
    assert calls
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_i), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_x), np.asarray(f_i), atol=1e-5)


@pytest.mark.parametrize("s,chunk", [(24, 8), (21, 8)])
def test_gated_scan_kernel_matches_oracle(s, chunk):
    b, w = 2, 6
    k1, k2, k3 = jax.random.split(KEY, 3)
    log_a = -jnp.abs(jax.random.normal(k1, (b, s, w), jnp.float32)) * 0.5
    b_in = jax.random.normal(k2, (b, s, w), jnp.float32)
    h0 = jax.random.normal(k3, (b, w), jnp.float32) * 0.1
    h_ref, f_ref = ref.gated_scan_ref(log_a, b_in, h0)
    h_k, f_k = ops.gated_scan(log_a, b_in, init_state=h0, chunk=chunk,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_k), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_ref), np.asarray(f_k), atol=1e-5)


def test_gated_scan_gradients_match_oracle():
    b, s, w = 1, 12, 4
    k1, k2 = jax.random.split(KEY)
    log_a = -jnp.abs(jax.random.normal(k1, (b, s, w), jnp.float32)) * 0.5
    b_in = jax.random.normal(k2, (b, s, w), jnp.float32)

    def loss_k(la, bb):
        h, f = ops.gated_scan(la, bb, chunk=4, interpret=True)
        return (h ** 2).sum() + (f ** 2).sum()

    def loss_o(la, bb):
        h, f = ref.gated_scan_ref(la, bb)
        return (h ** 2).sum() + (f ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1))(log_a, b_in)
    go = jax.grad(loss_o, argnums=(0, 1))(log_a, b_in)
    for a, b_ in zip(gk, go):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# source-scan pins: no hand-written chunk/scan loop survives in the models
# ---------------------------------------------------------------------------

def test_ssm_source_has_no_handwritten_scan():
    """Acceptance pin: models/ssm.py contains no hand-rolled chunk loop or
    scan — the chunked SSD schedule is derived (ops.scan_ssd), exactly as
    kernels/flash_attention.py hand-writes no grid."""
    import repro.models.ssm as ssm
    src = inspect.getsource(ssm)
    assert "lax.scan" not in src
    assert "associative_scan" not in src
    assert "_segsum" not in src
    assert "cumsum" not in src
    assert "pallas_call" not in src


def test_rglru_source_has_no_handwritten_scan():
    import repro.models.rglru as rglru
    src = inspect.getsource(rglru)
    assert "lax.scan" not in src
    assert "associative_scan" not in src
    assert "pallas_call" not in src


# ---------------------------------------------------------------------------
# Mamba-2 decode parity: step-by-step decode vs chunked prefill (the cache
# round-trip), on the module level
# ---------------------------------------------------------------------------

def test_mamba2_decode_matches_prefill():
    from repro.configs import get_config
    from repro.models import ssm as ssm_mod
    from repro.models.common import Collector
    cfg = get_config("mamba2-780m", reduced=True).with_(remat=False)
    col = Collector(jax.random.PRNGKey(5), dtype=jnp.float32)
    ssm_mod.init_mamba2(col, "m", cfg)
    p = col.params["m"]
    b, s = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(6), (b, s, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, cache_full = ssm_mod.apply_mamba2(p, x, cfg)
    cache = ssm_mod.init_ssm_cache(cfg, b, dtype=jnp.float32)
    outs = []
    for t in range(s):
        y_t, cache = ssm_mod.decode_mamba2(p, x[:, t:t + 1], cache, cfg)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
    # the cache round-trip: the prefill's exported final state equals the
    # state reached by stepping the dual recurrence token by token
    np.testing.assert_allclose(np.asarray(cache.state),
                               np.asarray(cache_full.state),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache.conv),
                               np.asarray(cache_full.conv),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# windowed / prefix-LM attention: derived schedules, no jnp fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,prefix_len", [(7, 0), (16, 0), (0, 5),
                                               (9, 6), (0, 32), (9, 24)])
def test_windowed_attention_kernel_matches_oracle(window, prefix_len):
    """Includes prefix_len > the key block (32, 24 > bk=16): prefix blocks
    ABOVE the causal diagonal must be re-admitted by the block-skip
    (regression — they used to be skipped, zeroing prefix attention)."""
    from repro.models.chunked_attention import chunked_attention
    b, kv, g, s, hd = 1, 2, 2, 45, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (b, s, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, s, kv, hd), jnp.float32)
    got = ops.attention(q, k, v, scale=0.3, causal=True, window=window,
                        prefix_len=prefix_len, interpret=True,
                        blocks=(16, 16))
    want = chunked_attention(q, k, v, scale=0.3, causal=True, window=window,
                             prefix_len=prefix_len, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_windowed_attention_no_longer_dispatches_jnp(monkeypatch):
    """Regression: attn_impl="pallas" with a causal window used to fall
    back to the chunked jnp path; the masking metadata now rides the form
    and the kernel executor runs."""
    import repro.kernels.flash_attention as fa
    import repro.models.attention as attn_mod
    from repro.configs import get_config
    from repro.models.common import Collector

    calls = []
    orig = fa._executor
    monkeypatch.setattr(fa, "_executor",
                        lambda *a, **kw: (calls.append(a), orig(*a, **kw))[1])
    cfg = get_config("stablelm-1.6b", reduced=True).with_(
        remat=False, attn_impl="pallas")
    col = Collector(jax.random.PRNGKey(3), dtype=jnp.float32)
    attn_mod.init_attention(col, "a", cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 40, cfg.d_model),
                          jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(40)[None], (1, 40))
    out_k, _ = attn_mod.attention_fwd(col.params["a"], x, cfg,
                                      positions=positions, window=16)
    assert calls                          # kernel engaged, not jnp fallback
    assert calls[-1][-2:] == (16, 0)      # window metadata reached the form
    out_x, _ = attn_mod.attention_fwd(col.params["a"], x,
                                      cfg.with_(attn_impl="xla"),
                                      positions=positions, window=16)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               atol=5e-3)


def test_window_block_skip_inert_beyond_window():
    """Keys entirely behind the window cannot influence the output (the
    derived block-skip + in-block mask): perturbing them changes nothing."""
    b, kv, g = 1, 1, 1
    s, hd, win = 64, 8, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (b, s, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, s, kv, hd), jnp.float32)
    base = ops.attention(q, k, v, scale=0.3, causal=True, window=win,
                         interpret=True, blocks=(16, 16))
    k2_ = k.at[:, :16].set(99.0)          # far behind the last rows' window
    v2_ = v.at[:, :16].set(-99.0)
    pert = ops.attention(q, k2_, v2_, scale=0.3, causal=True, window=win,
                         interpret=True, blocks=(16, 16))
    np.testing.assert_array_equal(np.asarray(base[:, -16:]),
                                  np.asarray(pert[:, -16:]))


# ---------------------------------------------------------------------------
# modeled traffic/energy: the derived scan's O(S) HBM story
# ---------------------------------------------------------------------------

def test_scan_traffic_derived_beats_materialized():
    """The derived carried-state schedule keeps the decay mask L and the
    chunk scores in VMEM; the hand-rolled jnp formulation round-trips them
    through HBM — the modeled bytes and energy must order accordingly, and
    the derived HBM bytes must be chunk-independent (O(S))."""
    from repro.core.blocking import RecurrenceBlockChoice
    from repro.core.energy import scan_energy, scan_traffic
    b, s, h, p, n = 1, 4096, 8, 64, 64
    blocks = RecurrenceBlockChoice(256, 0, 0.0, 1.0)
    hbm_d, vmem_d = scan_traffic(b, s, h, p, n, blocks)
    hbm_m, _ = scan_traffic(b, s, h, p, n, blocks, materialized=True)
    assert hbm_m > 2 * hbm_d
    hbm_d2, _ = scan_traffic(b, s, h, p, n,
                             RecurrenceBlockChoice(512, 0, 0.0, 1.0))
    assert hbm_d2 == hbm_d                    # O(S), chunk-independent
    rep_d = scan_energy(b, s, h, p, n, blocks)
    rep_m = scan_energy(b, s, h, p, n, blocks, materialized=True)
    assert rep_m.energy_J > rep_d.energy_J
    assert rep_d.time_s > 0 and rep_d.bound in ("compute", "memory")


# ---------------------------------------------------------------------------
# the GPU (triton-Pallas) hardware entry: CUDA-shaped tiles, derived
# ---------------------------------------------------------------------------

def test_gpu_entry_registered_and_env_addressable():
    entry = hw.get_entry("gpu")
    assert entry.backend == "pallas"
    assert entry.shape.mxu_tile == (16, 16)
    assert entry.shape.vreg_tile[1] == 32
    with hw.use_hardware("gpu"):
        assert hw.current_hardware().name == "gpu"


def test_gpu_gemm_tiles_are_cuda_shaped():
    """The same a-priori solver, pointed at the A100 table, derives
    tensor-core-aligned tiles bounded by shared memory — much smaller than
    the v5e's VMEM-sized blocks."""
    entry = hw.get_entry("gpu")
    bundle = sched.get_schedule(E.matmul_expr(1024, 1024, 1024),
                                dtype="float32", hardware=entry)
    bm, bk, bn = bundle.blocks.as_tuple()
    assert bm % 16 == 0 and bn % 16 == 0
    assert bundle.blocks.vmem_bytes <= entry.shape.vmem.capacity_bytes
    v5e = sched.get_schedule(E.matmul_expr(1024, 1024, 1024),
                             dtype="float32", hardware=hw.get_entry("cpu"))
    assert bm * bn < v5e.blocks.bm * v5e.blocks.bn
    # the derived schedule itself carries the GPU grid
    assert all(g.extent >= 1 for g in bundle.schedule.grid)


def test_gpu_streaming_and_recurrence_blocks_fit_smem():
    entry = hw.get_entry("gpu")
    att = sched.get_schedule(E.attention_form(1, 2, 2, 2048, 2048, 64),
                             dtype="float32", hardware=entry)
    bq, bk = att.blocks.as_tuple()
    assert att.blocks.vmem_bytes <= entry.shape.vmem.capacity_bytes
    v5e = sched.get_schedule(E.attention_form(1, 2, 2, 2048, 2048, 64),
                             dtype="float32", hardware=hw.get_entry("cpu"))
    assert bq * bk < v5e.blocks.bq * v5e.blocks.bk
    q_gpu = ops.default_ssd_chunk(4096, 24, 64, 128, hardware=entry)
    q_tpu = ops.default_ssd_chunk(4096, 24, 64, 128,
                                  hardware=hw.get_entry("cpu"))
    assert q_gpu <= q_tpu and q_gpu % 16 == 0
