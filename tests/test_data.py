"""Data pipeline: determinism, shard consistency, learnability, resume."""
import numpy as np

from repro.data import PipelineConfig, SyntheticLM


def test_deterministic_across_instances():
    a = SyntheticLM(PipelineConfig(1000, 16, 8, seed=3))
    b = SyntheticLM(PipelineConfig(1000, 16, 8, seed=3))
    for step in [0, 1, 17]:
        np.testing.assert_array_equal(a.global_batch(step)["tokens"],
                                      b.global_batch(step)["tokens"])


def test_different_steps_differ():
    p = SyntheticLM(PipelineConfig(1000, 16, 8))
    assert not np.array_equal(p.global_batch(0)["tokens"],
                              p.global_batch(1)["tokens"])


def test_host_shards_tile_the_global_batch():
    """Elastic invariant: any sharding reproduces the same global batch."""
    p = SyntheticLM(PipelineConfig(997, 12, 8, seed=1))
    g = p.global_batch(5)["tokens"]
    for n_shards in [1, 2, 4, 8]:
        parts = [p.host_shard(5, i, n_shards)["tokens"] for i in range(n_shards)]
        np.testing.assert_array_equal(np.concatenate(parts, 0), g)


def test_targets_are_shifted_tokens():
    p = SyntheticLM(PipelineConfig(50, 10, 4, noise=0.0))
    b = p.global_batch(0)
    # affine recurrence: next token = (31*t + off) % 50 -> targets follow
    t, y = b["tokens"], b["targets"]
    np.testing.assert_array_equal(t[:, 1:], y[:, :-1])


def test_learnable_structure():
    """Without noise the stream is a deterministic affine map — a model that
    learned it would reach ~0 loss; verify conditional entropy is low by
    checking the recurrence holds."""
    p = SyntheticLM(PipelineConfig(101, 32, 4, noise=0.0))
    b = p.global_batch(0)
    t = b["tokens"]
    # token[t+1] - 31*token[t] must be constant per row (the offset)
    diff = (t[:, 1:] - 31 * t[:, :-1]) % 101
    assert (diff == diff[:, :1]).all()


def test_vocab_bounds():
    p = SyntheticLM(PipelineConfig(64, 16, 8))
    b = p.global_batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 64
    assert b["tokens"].dtype == np.int32
