"""The static verifier: soundness proofs on known-good schedules, mutation
tests seeding one defect per class, the jaxpr lint rules, and the
verification cache contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import expr as E
from repro.core import hardware as hw
from repro.core import schedule as sched
from repro.core import semiring
from repro.distributed import plan as dplan
from repro.kernels import ops

HW = hw.get_entry("cpu")


def _rules(findings):
    return sorted({f.rule for f in findings if f.level == "error"})


def _gemm_bundle():
    # 300/200/160 are off every block multiple: padding on m, n AND k
    return sched.get_schedule(E.matmul_expr(300, 200, 160),
                              dtype="float32", hardware=HW)


# ---------------------------------------------------------------------------
# known-good derivations verify clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("form", [
    E.matmul_expr(300, 200, 160),
    E.matmul_expr(300, 200, 160, transpose_b=True),
    E.expert_gemm_expr(4, 60, 96, 72),
    E.hadamard_expr(200, 300),
    E.head_gemm_expr(4, 48, 32, 40),
    E.inner("max", "add", E.arr("A", (100, 60)), E.arr("B", (60, 80))),
    E.inner("min", "add", E.arr("A", (100, 60)), E.arr("B", (60, 80))),
    E.attention_form(1, 2, 2, 300, 300, 64),
    E.attention_stats_form(1, 1, 1, 300, 300, 64),
    E.attention_dq_form(1, 1, 1, 300, 300, 64),
    E.attention_dkv_form(1, 1, 1, 300, 300, 64),
    E.ssd_form(1, 4, 64, 2, 16, 16),
    E.ssd_bwd_form(1, 4, 64, 2, 16, 16),
    E.rglru_form(1, 4, 64, 32),
], ids=lambda f: getattr(f, "name", type(f).__name__))
def test_known_good_forms_verify_clean(form):
    bundle = sched.get_schedule(form, dtype="float32", hardware=HW)
    findings = analysis.verify_bundle(bundle, hardware=HW)
    assert not analysis.verify.errors(findings), [str(f) for f in findings]


def test_verify_expr_strict_passes_and_caches():
    analysis.reset_verification_cache()
    expr = E.matmul_expr(300, 200, 160)
    assert not analysis.verify_expr(expr, dtype="float32", hardware=HW)
    s1 = analysis.verification_cache_stats()
    assert not analysis.verify_expr(expr, dtype="float32", hardware=HW)
    s2 = analysis.verification_cache_stats()
    assert s2["hits"] == s1["hits"] + 1
    assert s2["misses"] == s1["misses"]


# ---------------------------------------------------------------------------
# mutation tests: one seeded defect, exactly that defect class flagged
# ---------------------------------------------------------------------------

def test_mutation_shifted_index_map_is_coverage_defect():
    b = _gemm_bundle()
    a0 = b.schedule.ins[0]                      # A's m dim is grid-driven
    mut = dataclasses.replace(a0, offsets=(1,) + a0.offsets[1:])
    s = dataclasses.replace(b.schedule, ins=(mut,) + b.schedule.ins[1:])
    findings = analysis.verify_bundle(dataclasses.replace(b, schedule=s),
                                      hardware=HW)
    assert _rules(findings) == ["coverage"]


def test_mutation_revisiting_grid_axis_is_race_defect():
    b = _gemm_bundle()
    # drop the declared reduction: the k grid axis still revisits the
    # output block every step — the Pallas write-write race
    s = dataclasses.replace(b.schedule, reduce_grid_dim=None)
    findings = analysis.verify_bundle(dataclasses.replace(b, schedule=s),
                                      hardware=HW)
    assert _rules(findings) == ["race"]


def test_mutation_parallel_reduce_axis_is_race_defect():
    b = _gemm_bundle()
    kd = b.schedule.reduce_grid_dim
    grid = tuple(dataclasses.replace(g, semantics="parallel")
                 if i == kd else g
                 for i, g in enumerate(b.schedule.grid))
    s = dataclasses.replace(b.schedule, grid=grid)
    findings = analysis.verify_bundle(dataclasses.replace(b, schedule=s),
                                      hardware=HW)
    assert _rules(findings) == ["race"]


def test_mutation_undersized_scratch_is_scratch_defect():
    b = _gemm_bundle()
    blk = dataclasses.replace(b.blocks, vmem_bytes=64)
    findings = analysis.verify_bundle(dataclasses.replace(b, blocks=blk),
                                      hardware=HW)
    assert _rules(findings) == ["scratch"]


def test_mutation_wrong_min_plus_pad_value_is_pad_value_defect(monkeypatch):
    bundle = sched.get_schedule(
        E.inner("min", "add", E.arr("A", (100, 60)), E.arr("B", (60, 80))),
        dtype="float32", hardware=HW)
    assert bundle.padded != bundle.shapes       # k=60 really is padded
    assert not analysis.verify.errors(
        analysis.verify_bundle(bundle, hardware=HW))
    # min-plus pads must be +inf; 0.0 contributes 0+0=0 to a min-reduce
    monkeypatch.setitem(semiring._PAD_VALUES, ("add", "min"), 0.0)
    findings = analysis.verify_bundle(bundle, hardware=HW)
    assert _rules(findings) == ["pad-value"]


def test_mutation_unregistered_pad_is_pad_guard_defect(monkeypatch):
    bundle = sched.get_schedule(
        E.inner("max", "add", E.arr("A", (100, 60)), E.arr("B", (60, 80))),
        dtype="float32", hardware=HW)
    monkeypatch.delitem(semiring._PAD_VALUES, ("add", "max"))
    findings = analysis.verify_bundle(bundle, hardware=HW)
    assert _rules(findings) == ["pad-guard"]


def test_mutation_dropped_stream_pad_guard_is_pad_guard_defect():
    b = sched.get_schedule(E.attention_form(1, 1, 1, 300, 300, 64),
                           dtype="float32", hardware=HW)
    assert b.padded[-1] != b.shapes[-1]         # sk=300 padded to the block
    # the emitter masks padded keys with a ``kpos < shapes[-1]`` guard;
    # recording the padded extent there drops the guard entirely
    mut = dataclasses.replace(b, shapes=b.shapes[:-1] + (b.padded[-1],))
    findings = analysis.verify_bundle(mut, hardware=HW)
    assert _rules(findings) == ["pad-guard"]


def test_mutation_oversized_working_set_is_resource_defect():
    b = _gemm_bundle()
    out = b.schedule.out
    fat = dataclasses.replace(
        out, block=(out.block[0] * 1024, out.block[1] * 1024),
        shape=(out.shape[0] * 1024, out.shape[1] * 1024))
    s = dataclasses.replace(b.schedule, out=fat)
    findings = analysis.verify_bundle(dataclasses.replace(b, schedule=s),
                                      hardware=HW)
    assert "resource" in _rules(findings)


# ---------------------------------------------------------------------------
# distributed plans: fallback warnings, widened shard accumulators,
# collective ordering
# ---------------------------------------------------------------------------

def test_plan_replication_fallback_warns_and_is_reported():
    from repro.core.mesh import MeshShape
    dplan.reset_plan_cache()
    with pytest.warns(dplan.ReplicationFallbackWarning, match="'i'"):
        plan = dplan.derive_plan(E.matmul_expr(31, 96, 32),
                                 MeshShape((("x", 2),)),
                                 shard={"i": "x"}, hardware=HW)
    assert plan.dropped == (("i", "x"),)
    findings = analysis.verify_plan(plan, hardware=HW)
    warns = [f for f in findings if f.rule == "replication-fallback"]
    assert len(warns) == 1 and warns[0].level == "warning"
    assert "'i'" in warns[0].message and "'x'" in warns[0].message
    assert not analysis.verify.errors(findings)


def test_plan_collective_order_mutation_is_flagged():
    dplan.reset_plan_cache()
    from repro.core.mesh import MeshShape
    plan = dplan.derive_plan(E.matmul_expr(64, 96, 32), MeshShape((("x", 2),)),
                             shard={"k": "x"}, hardware=HW)
    assert plan.collective == "psum"
    assert not analysis.verify.errors(analysis.verify_plan(plan, hardware=HW))
    # sequence a gather BEFORE the reduction: the gather replicates
    # partial sums — the ordering hazard the analyzer must flag
    bad = (dplan.CollectiveStep("all_gather", "x", 0),) + plan.collectives
    mut = dataclasses.replace(plan, collectives=bad)
    findings = analysis.verify_plan(mut, hardware=HW)
    assert "collective-order" in _rules(findings)


def test_plan_bundle_carries_widened_accumulator():
    dplan.reset_plan_cache()
    from repro.core.mesh import MeshShape
    plan = dplan.derive_plan(E.matmul_expr(64, 96, 32), MeshShape((("x", 2),)),
                             shard={"k": "x"}, hardware=HW,
                             dtype="bfloat16", acc_dtype="bfloat16")
    assert plan.bundle.acc_dtype == "bfloat16"
    findings = analysis.verify_plan(plan, hardware=HW, dtype="bfloat16")
    assert not analysis.verify.errors(findings)


def test_apply_mesh_accepts_acc_dtype():
    """Satellite: the PR-6 f32-only rejection on the sharded path is gone —
    bf16 accumulation threads through derive_plan's per-shard bundle and
    matches the single-chip result exactly."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (64, 96), jnp.bfloat16)
    w = jax.random.normal(k2, (96, 32), jnp.bfloat16)
    expr = E.matmul_expr(64, 96, 32)
    got = ops.apply(expr, x, w, mesh=mesh, shard={"k": "x"},
                    acc_dtype="bfloat16", interpret=True,
                    out_dtype=jnp.float32, verify=True)
    want = ops.apply(expr, x, w, acc_dtype="bfloat16", interpret=True,
                     out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_apply_verify_true_matches_and_caches():
    analysis.reset_verification_cache()
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (30, 20), jnp.float32)
    w = jax.random.normal(k2, (20, 40), jnp.float32)
    expr = E.matmul_expr(30, 20, 40)
    got = ops.apply(expr, x, w, interpret=True, verify=True)
    want = ops.apply(expr, x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    before = analysis.verification_cache_stats()
    ops.apply(expr, x, w, interpret=True, verify=True)
    after = analysis.verification_cache_stats()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]


# ---------------------------------------------------------------------------
# the jaxpr lint rules
# ---------------------------------------------------------------------------

def test_lint_no_transpose_copy_clean_on_derived_kernel():
    fn = ops._expr_callable(E.matmul_expr(64, 32, 48, transpose_b=True),
                            "float32", "float32", "cpu", True)
    x = jnp.zeros((64, 32), jnp.float32)
    w = jnp.zeros((48, 32), jnp.float32)
    assert not analysis.lint(fn, x, w, rules=("no-transpose-copy",
                                              "no-silent-fallback"))


def test_lint_no_transpose_copy_flags_relayout():
    def relayout(x, w):
        return jnp.transpose(x) @ w

    x = jnp.zeros((8, 4), jnp.float32)
    w = jnp.zeros((8, 5), jnp.float32)
    findings = analysis.lint(relayout, x, w, rules=("no-transpose-copy",))
    assert _rules(findings) == ["no-transpose-copy"]


def test_lint_no_silent_fallback_flags_oracle_dispatch():
    def oracle(x, w):
        return x @ w

    x = jnp.zeros((8, 4), jnp.float32)
    findings = analysis.lint(oracle, x, jnp.zeros((4, 5), jnp.float32),
                             rules=("no-silent-fallback",))
    assert _rules(findings) == ["no-silent-fallback"]


def test_lint_only_planned_collectives():
    def plain(x):
        return x * 2.0

    x = jnp.zeros((4,), jnp.float32)
    assert not analysis.lint(plain, x, rules=("only-planned-collectives",),
                             collective="none")
    # a planned psum that never appears is as wrong as an unplanned one
    findings = analysis.lint(plain, x, rules=("only-planned-collectives",),
                             collective="psum")
    assert _rules(findings) == ["only-planned-collectives"]
    assert not analysis.lint(plain, x, rules=("only-planned-collectives",),
                             allowed=())


def test_lint_jaxpr_entry_and_strict_mode():
    def relayout(x):
        return jnp.transpose(x)

    jaxpr = jax.make_jaxpr(relayout)(jnp.zeros((3, 4), jnp.float32))
    findings = analysis.lint_jaxpr(jaxpr, rules=("no-transpose-copy",))
    assert findings
    with pytest.raises(analysis.LintError):
        analysis.lint_jaxpr(jaxpr, rules=("no-transpose-copy",), strict=True)
    with pytest.raises(KeyError, match="no-such-rule"):
        analysis.lint_jaxpr(jaxpr, rules=("no-such-rule",))


def test_lint_rule_registry_lists_all_four():
    names = [r.name for r in analysis.jaxpr_lint.lint_rules()]
    assert names == sorted(names)
    assert set(names) >= {"no-transpose-copy", "no-oracle-recompute",
                          "only-planned-collectives", "no-silent-fallback"}


def test_planned_prims_cover_ring_and_moe_collectives():
    """Satellite: the ROADMAP's ring-attention and MoE all-to-all plans are
    expressible as planned-collective summaries."""
    assert analysis.PLANNED_PRIMS["ppermute"] == frozenset({"ppermute"})
    assert analysis.PLANNED_PRIMS["all_to_all"] == frozenset({"all_to_all"})

    def plain(x):
        return x + 1.0

    x = jnp.zeros((4,), jnp.float32)
    # a planned ppermute/all_to_all that never appears is now a *known*
    # summary (one finding), not an unknown-summary parse error
    for summary in ("ppermute", "all_to_all"):
        findings = analysis.lint(plain, x,
                                 rules=("only-planned-collectives",),
                                 collective=summary)
        assert _rules(findings) == ["only-planned-collectives"]
        assert "never appears" in findings[0].message


def test_planned_collective_combined_summary_parsing():
    """``"a+b"`` summaries union their allowed prims; an unknown component
    anywhere in the chain is named in the finding."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    from jax.experimental.shard_map import shard_map as jshard_map

    def ring(x):
        return jax.lax.ppermute(x, "x", [(0, 0)])

    fn = jshard_map(ring, mesh=mesh,
                    in_specs=jax.sharding.PartitionSpec("x"),
                    out_specs=jax.sharding.PartitionSpec("x"))
    x = jnp.zeros((4,), jnp.float32)
    # traced ppermute against its own plan: clean; against a combined
    # summary that does not include it: unplanned
    assert not analysis.lint(fn, x, rules=("only-planned-collectives",),
                             collective="ppermute")
    findings = analysis.lint(fn, x, rules=("only-planned-collectives",),
                             collective="reduce_scatter+all_gather")
    assert _rules(findings) == ["only-planned-collectives"]
    assert "ppermute" in findings[0].message

    def plain(x):
        return x * 2.0

    findings = analysis.lint(plain, x,
                             rules=("only-planned-collectives",),
                             collective="reduce_scatter+ring_exchange")
    assert _rules(findings) == ["only-planned-collectives"]
    assert "ring_exchange" in findings[0].message


# ---------------------------------------------------------------------------
# the registry sweep is importable and passes in-process
# ---------------------------------------------------------------------------

def test_verify_all_sweep_passes_and_pins_json_report(tmp_path):
    from repro.analysis import verify_all
    out = tmp_path / "verify_all.json"
    assert verify_all.main(["--json", str(out)]) == 0
    import json
    report = json.loads(out.read_text())
    assert report["sweep"] == "verify_all"
    assert report["failed"] == 0 and report["findings"] == []
    # pin the summary counts: silent registry shrinkage (a form, hardware
    # entry, or dtype pair dropping out of the sweep) fails loudly here
    assert len(report["hardware"]) == 5
    assert report["checked"] == 305
    assert report["refused"] == 140


def test_strict_verification_raises_with_findings():
    b = _gemm_bundle()
    s = dataclasses.replace(b.schedule, reduce_grid_dim=None)
    with pytest.raises(analysis.VerificationError, match="race"):
        analysis.verify_bundle(dataclasses.replace(b, schedule=s),
                               hardware=HW, strict=True)
