"""MoE dispatch: global (pjit) path properties + shard-local (shard_map)
equivalence on 8 devices (subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models.common import Collector

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(seed=0):
    cfg = get_config("deepseek-moe-16b", reduced=True)
    col = Collector(jax.random.PRNGKey(seed), dtype=jnp.float32)
    moe_mod.init_moe(col, "moe", cfg)
    params, _ = col.done()
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 8, cfg.d_model),
                          jnp.float32)
    return cfg, params["moe"], x


def test_global_dispatch_conserves_tokens():
    cfg, p, x = _setup()
    y, stats = moe_mod._apply_moe_global(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0.0 <= float(stats.dropped_frac) < 0.3
    assert float(stats.aux_loss) > 0.9          # ~1 when balanced


def test_tiny_capacity_factor_drops_most_tokens():
    cfg, p, x = _setup()
    x = jnp.tile(x, (1, 8, 1))                  # 256 tokens -> load 64/expert
    cfg0 = cfg.with_(capacity_factor=1e-9)      # cap rounds up to 8 slots
    y, stats = moe_mod._apply_moe_global(p, x, cfg0)
    assert float(stats.dropped_frac) > 0.5


def test_router_gradients_flow():
    cfg, p, x = _setup()

    def loss(p):
        y, _ = moe_mod._apply_moe_global(p, x, cfg)
        return (y ** 2).sum()

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["wi"]).sum()) > 0


@pytest.mark.slow
def test_shardmap_equals_global_8dev():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import moe as moe_mod
        from repro.models.common import Collector
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("deepseek-moe-16b", reduced=True)
        col = Collector(jax.random.PRNGKey(0), dtype=jnp.float32)
        moe_mod.init_moe(col, "moe", cfg)
        params, _ = col.done()
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
        y_ref, st_ref = moe_mod._apply_moe_global(params["moe"], x, cfg)
        for dp, tp in [(2, 4), (1, 8), (4, 2)]:
            mesh = make_host_mesh(dp=dp, tp=tp)
            with mesh:
                y, st = jax.jit(lambda p, xx: moe_mod._apply_moe_shardmap(
                    p, xx, cfg, mesh))(params["moe"], x)
            err = float(jnp.max(jnp.abs(y_ref - y)))
            assert err < 5e-4, (dp, tp, err)
            assert float(st.dropped_frac) < 0.05
        print("SUBPROCESS_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
