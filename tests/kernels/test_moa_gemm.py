"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.blocking import BlockChoice
from repro.kernels import ops, ref


def _err(got, want):
    return float(np.max(np.abs(np.asarray(got, np.float32)
                               - np.asarray(want, np.float32))))


def _tol(dtype, k):
    return 5e-5 * max(k, 1) if dtype == jnp.float32 else 2e-2 * max(k, 1) ** 0.5


SHAPES = [(128, 128, 128), (256, 512, 384), (100, 70, 130), (8, 1024, 8),
          (1, 1, 1), (129, 257, 127), (512, 16, 512)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_moa_gemm_matches_oracle(m, k, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (m, k), dtype)
    b = jax.random.normal(k2, (k, n), dtype)
    got = ops.moa_gemm(a, b, interpret=True)
    want = ref.gemm_ref(a, b)
    assert got.shape == want.shape and got.dtype == want.dtype
    assert _err(got, want) < _tol(dtype, k), (m, k, n, dtype)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(1, 200), st.integers(1, 200),
       st.integers(0, 2 ** 31))
def test_moa_gemm_hypothesis_shapes(m, k, n, seed):
    kk = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(kk)
    a = jax.random.normal(k1, (m, k), jnp.float32)
    b = jax.random.normal(k2, (k, n), jnp.float32)
    got = ops.moa_gemm(a, b, interpret=True)
    assert _err(got, ref.gemm_ref(a, b)) < _tol(jnp.float32, k)


def test_explicit_solver_blocks():
    bc = BlockChoice(bm=128, bk=128, bn=128, vmem_bytes=0,
                     arithmetic_intensity=0, utilization=1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(k1, (384, 256), jnp.float32)
    b = jax.random.normal(k2, (256, 384), jnp.float32)
    got = ops.moa_gemm(a, b, blocks=bc, interpret=True)
    assert _err(got, ref.gemm_ref(a, b)) < 1e-3


def test_out_dtype_override():
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = jax.random.normal(k1, (64, 64), jnp.bfloat16)
    b = jax.random.normal(k2, (64, 64), jnp.bfloat16)
    got = ops.moa_gemm(a, b, out_dtype=jnp.float32, interpret=True)
    assert got.dtype == jnp.float32


@pytest.mark.parametrize("e,cap,d,f", [(4, 64, 96, 48), (1, 8, 8, 8),
                                       (8, 100, 130, 70)])
def test_expert_gemm_matches_oracle(e, cap, d, f):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (e, cap, d), jnp.float32)
    w = jax.random.normal(k2, (e, d, f), jnp.float32)
    got = ops.expert_gemm(x, w, interpret=True)
    want = ref.expert_gemm_ref(x, w)
    assert _err(got, want) < _tol(jnp.float32, d)


def test_gemm_under_jit_and_vmap_composes():
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    a = jax.random.normal(k1, (3, 64, 32), jnp.float32)
    b = jax.random.normal(k2, (3, 32, 48), jnp.float32)
    got = jax.jit(jax.vmap(lambda x, y: ops.moa_gemm(x, y, interpret=True)))(a, b)
    want = jnp.einsum("bmk,bkn->bmn", a, b)
    assert _err(got, want) < 1e-3
