"""The unified inner/outer/hadamard/kron operator (paper appendix)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _err(got, want):
    return float(np.max(np.abs(np.asarray(got, np.float32)
                               - np.asarray(want, np.float32))))


@pytest.mark.parametrize("mode", ["ip", "op", "hp", "kp"])
def test_modes_match_oracles(mode):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (12, 20), jnp.float32)
    b = (jax.random.normal(k2, (20, 24), jnp.float32) if mode == "ip" else
         a if mode == "hp" else jax.random.normal(k2, (8, 16), jnp.float32))
    got = ops.ipophp(a, b, mode, interpret=True)
    want = ref.ipophp_ref(a, b, mode)
    assert got.shape == want.shape
    assert _err(got, want) < 1e-3


def test_kron_matches_numpy():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(k1, (5, 7), jnp.float32)
    b = jax.random.normal(k2, (3, 4), jnp.float32)
    got = ops.kron(a, b, interpret=True)
    want = np.kron(np.asarray(a), np.asarray(b))
    assert _err(got, want) < 1e-4


def test_kron_identity_blocks():
    """kron(I, A) is block-diagonal A — the MoA gamma-relayout property."""
    a = jax.random.normal(jax.random.PRNGKey(2), (4, 4), jnp.float32)
    got = np.asarray(ops.kron(jnp.eye(3, dtype=jnp.float32), a, interpret=True))
    for i in range(3):
        np.testing.assert_allclose(got[4 * i:4 * i + 4, 4 * i:4 * i + 4],
                                   np.asarray(a), rtol=1e-5)
    mask = np.kron(np.eye(3), np.ones((4, 4)))
    np.testing.assert_allclose(got * (1 - mask), 0, atol=1e-6)


def test_kron_mixed_product_property():
    """(A kron B)(C kron D) == (AC) kron (BD) — exercises ip+kp together."""
    key = jax.random.PRNGKey(3)
    ka, kb, kc, kd = jax.random.split(key, 4)
    A = jax.random.normal(ka, (3, 4), jnp.float32)
    B = jax.random.normal(kb, (2, 5), jnp.float32)
    C = jax.random.normal(kc, (4, 3), jnp.float32)
    D = jax.random.normal(kd, (5, 2), jnp.float32)
    lhs = ops.ipophp(ops.kron(A, B, interpret=True),
                     ops.kron(C, D, interpret=True), "ip", interpret=True)
    rhs = ops.kron(ops.ipophp(A, C, "ip", interpret=True),
                   ops.ipophp(B, D, "ip", interpret=True), interpret=True)
    assert _err(lhs, rhs) < 1e-2


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 16), st.integers(1, 16), st.integers(0, 999))
def test_hadamard_random(m, n, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (m, n), jnp.float32)
    got = ops.hadamard(a, a, interpret=True)
    assert _err(got, a * a) < 1e-5


@pytest.mark.parametrize("mode,shape_b", [("ip", (20, 24)), ("op", (8, 16)),
                                          ("hp", (12, 20)), ("kp", (8, 16))])
def test_ipophp_smoke_no_hypothesis(mode, shape_b):
    """Plain-pytest smoke for every ipophp mode, so the unified-circuit path
    runs even where hypothesis is unavailable (never silently skipped)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a = jax.random.normal(k1, (12, 20), jnp.float32)
    b = a if mode == "hp" else jax.random.normal(k2, shape_b, jnp.float32)
    got = ops.ipophp(a, b, mode, interpret=True)
    want = ref.ipophp_ref(a, b, mode)
    assert got.shape == want.shape
    assert _err(got, want) < 1e-3


def test_hadamard_smoke_no_hypothesis():
    a = jax.random.normal(jax.random.PRNGKey(11), (9, 33), jnp.float32)
    got = ops.hadamard(a, a, interpret=True)
    assert _err(got, a * a) < 1e-5


def test_outer_degenerate_contraction():
    """op == ip on rav(A) (mn,1) x rav(B)^T (1,pq): the paper's one-circuit
    claim — verified against einsum."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    a = jax.random.normal(k1, (6, 3), jnp.float32)
    b = jax.random.normal(k2, (4, 5), jnp.float32)
    got = ops.outer(a, b, interpret=True)
    want = jnp.einsum("mn,pq->mnpq", a, b)
    assert _err(got, want) < 1e-5
