"""Flash-attention Pallas kernel vs the chunked-attention oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.chunked_attention import chunked_attention_ref


def _ref(q, k, v, scale, causal):
    """Adapt (B,H,S,hd) layout to the grouped oracle layout."""
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    qg = q.transpose(0, 2, 1, 3).reshape(b, sq, hkv, g, hd)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    out = chunked_attention_ref(qg, kk, vv, scale=scale, causal=causal)
    return out.reshape(b, sq, hq, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,hq,hkv,sq,sk,hd,causal,bq,bk", [
    (1, 2, 2, 128, 128, 32, True, 64, 64),
    (2, 4, 1, 64, 64, 16, True, 32, 32),       # MQA
    (1, 6, 2, 96, 96, 32, True, 32, 32),       # GQA groups of 3
    (1, 2, 2, 64, 128, 16, False, 32, 64),     # cross/bidir
    (2, 2, 2, 256, 256, 64, True, 128, 128),
])
def test_flash_matches_oracle(b, hq, hkv, sq, sk, hd, causal, bq, bk):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, hq, sq, hd), jnp.float32)
    k = jax.random.normal(k2, (b, hkv, sk, hd), jnp.float32)
    v = jax.random.normal(k3, (b, hkv, sk, hd), jnp.float32)
    got = flash_attention(q, k, v, scale=hd ** -0.5, causal=causal,
                          block_q=bq, block_k=bk, interpret=True)
    want = _ref(q, k, v, hd ** -0.5, causal)
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    assert err < 2e-5, err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 2, 128, 32), dtype)
    k = jax.random.normal(k2, (1, 2, 128, 32), dtype)
    v = jax.random.normal(k3, (1, 2, 128, 32), dtype)
    got = flash_attention(q, k, v, scale=32 ** -0.5, block_q=64, block_k=64,
                          interpret=True)
    want = _ref(q, k, v, 32 ** -0.5, True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    err = float(np.max(np.abs(np.asarray(got, np.float32)
                              - np.asarray(want, np.float32))))
    assert got.dtype == dtype
    assert err < tol, err


def test_flash_block_shape_invariance():
    """Different liftings (block shapes) must give identical results."""
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 2, 128, 16), jnp.float32)
    k = jax.random.normal(k2, (1, 2, 128, 16), jnp.float32)
    v = jax.random.normal(k3, (1, 2, 128, 16), jnp.float32)
    a = flash_attention(q, k, v, scale=0.25, block_q=32, block_k=32,
                        interpret=True)
    b = flash_attention(q, k, v, scale=0.25, block_q=128, block_k=64,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_model_level_pallas_path_matches_xla():
    """attn_impl="pallas" routes the model's attention through the Pallas
    flash kernel (interpret on CPU) and must match the XLA path."""
    import jax
    from repro.configs import get_config
    from repro.models import registry, transformer
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False,
                                                          head_dim=32)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 512), 0,
                              cfg.vocab_size)
    h_x, _, _ = transformer.forward(params, cfg.with_(attn_impl="xla"), toks)
    h_p, _, _ = transformer.forward(params, cfg.with_(attn_impl="pallas"), toks)
    err = float(jnp.max(jnp.abs(h_x - h_p)))
    assert err < 5e-3, err
