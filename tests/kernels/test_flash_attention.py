"""Flash attention: the *derived* streaming schedule vs the chunked oracle.

Covers the derivation itself (the StreamingSchedule object: grid, recovered
GQA index maps, solver blocks, cache residency — the kernel file hand-writes
nothing), the kernel vs the jnp oracles across GQA groupings / odd
non-512-multiple lengths / gradients, and the ops-level pad/slice wrapper.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import expr as E
from repro.core import hardware as hw
from repro.core import schedule as sched
from repro.kernels import ops
from repro.kernels.flash_attention import attention_bundle, flash_attention
from repro.models.chunked_attention import (chunked_attention,
                                            chunked_attention_ref)


def _ref(q, k, v, scale, causal):
    """Adapt (B,H,S,hd) layout to the grouped oracle layout."""
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    qg = q.transpose(0, 2, 1, 3).reshape(b, sq, hkv, g, hd)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    out = chunked_attention_ref(qg, kk, vv, scale=scale, causal=causal)
    return out.reshape(b, sq, hq, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,hq,hkv,sq,sk,hd,causal,bq,bk", [
    (1, 2, 2, 128, 128, 32, True, 64, 64),
    (2, 4, 1, 64, 64, 16, True, 32, 32),       # MQA
    (1, 6, 2, 96, 96, 32, True, 32, 32),       # GQA groups of 3
    (1, 2, 2, 64, 128, 16, False, 32, 64),     # cross/bidir
    (2, 2, 2, 256, 256, 64, True, 128, 128),
])
def test_flash_matches_oracle(b, hq, hkv, sq, sk, hd, causal, bq, bk):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, hq, sq, hd), jnp.float32)
    k = jax.random.normal(k2, (b, hkv, sk, hd), jnp.float32)
    v = jax.random.normal(k3, (b, hkv, sk, hd), jnp.float32)
    got = flash_attention(q, k, v, scale=hd ** -0.5, causal=causal,
                          block_q=bq, block_k=bk, interpret=True)
    want = _ref(q, k, v, hd ** -0.5, causal)
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    assert err < 2e-5, err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 2, 128, 32), dtype)
    k = jax.random.normal(k2, (1, 2, 128, 32), dtype)
    v = jax.random.normal(k3, (1, 2, 128, 32), dtype)
    got = flash_attention(q, k, v, scale=32 ** -0.5, block_q=64, block_k=64,
                          interpret=True)
    want = _ref(q, k, v, 32 ** -0.5, True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    err = float(np.max(np.abs(np.asarray(got, np.float32)
                              - np.asarray(want, np.float32))))
    assert got.dtype == dtype
    assert err < tol, err


def test_flash_block_shape_invariance():
    """Different liftings (block shapes) must give identical results."""
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 2, 128, 16), jnp.float32)
    k = jax.random.normal(k2, (1, 2, 128, 16), jnp.float32)
    v = jax.random.normal(k3, (1, 2, 128, 16), jnp.float32)
    a = flash_attention(q, k, v, scale=0.25, block_q=32, block_k=32,
                        interpret=True)
    b = flash_attention(q, k, v, scale=0.25, block_q=128, block_k=64,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# the derivation: the schedule object IS the kernel's layout — nothing is
# hand-written in kernels/flash_attention.py
# ---------------------------------------------------------------------------

def test_attention_schedule_is_derived_streaming():
    """Inspect the StreamingSchedule: grid from the lifted axes, the GQA
    kv index map recovered from the zero group coefficient, (bq, bk) from
    the carried-state block solver, the sigma axis streamed."""
    b, hkv, g, sq, sk, hd = 2, 3, 2, 1024, 2048, 64
    bundle = attention_bundle(b, hkv, g, sq, sk, hd,
                              hardware=hw.get_entry("cpu"))
    ss = bundle.schedule
    bq, bk = bundle.blocks.as_tuple()
    assert (bq, bk) == (512, 512)            # the solver's v5e choice
    assert ss.grid_extents == (b, hkv, g, sq // bq, sk // bk)
    assert ss.dimension_semantics == ("parallel",) * 4 + ("arbitrary",)
    q_spec, k_spec, v_spec = ss.ins
    # q's BlockSpec walks the STORED (b, sq, kv, g, hd) projection buffer —
    # the grouped view is a transposed leaf, a pure index rewrite, so the
    # wrapper feeds the kernel with no relayout copy
    assert q_spec.axes == ("b", "i", "h", "g", "c")
    assert q_spec.shape == (b, sq, hkv, g, hd)
    assert q_spec.grid_dims == (0, 3, 1, 2, None)
    assert q_spec.block == (1, bq, 1, 1, hd)
    # K/V: no group dimension AT ALL — the Access coefficient on the group
    # axis is zero, so the q-head -> kv-head map is recovered, not coded
    for spec in (k_spec, v_spec):
        assert spec.axes in (("b", "j", "h", "c"), ("b", "j", "h", "d"))
        assert spec.shape == (b, sk, hkv, hd)       # stored, un-repeated
        assert spec.grid_dims == (0, 4, 1, None)    # group grid dim absent
        assert spec.block == (1, bk, 1, hd)
    assert ss.stream_grid_dim == 4           # the streamed (sigma) axis
    assert ss.contracted == ("c",)           # q·kᵀ folds head_dim in-block
    assert ss.inter.block == (1, 1, 1, bq, bk)   # VMEM-only scores block
    assert ss.acc_block == (bq, hd)          # carried accumulator
    assert ss.row_block == bq and ss.stream_block == bk


def test_derived_matches_handwritten_512_defaults():
    """The derived grid and index maps reproduce the hand-written kernel's
    layout at its old 512 defaults: grid (b*hq, Sq/512, Sk/512) with
    kv_map(h, qi, ki) = ((h // hq) * hkv + (h % hq) // g, ki, 0)."""
    b, hkv, g, s, hd = 2, 2, 3, 1024, 64
    hq = hkv * g
    bundle = attention_bundle(b, hkv, g, s, s, hd,
                              hardware=hw.get_entry("cpu"))
    ss = bundle.schedule
    bq, bk = bundle.blocks.as_tuple()
    assert (bq, bk) == (512, 512)
    nq, nk = s // bq, s // bk
    # the three leading parallel axes are the factorization of the old
    # fused b*hq grid axis; the trailing two are (Sq/bq, Sk/bk)
    assert ss.grid_extents == (b, hkv, g, nq, nk)
    assert b * hkv * g == b * hq

    def handwritten_kv_map(h, qi, ki):      # the deleted kernel's map
        return ((h // hq) * hkv + (h % hq) // g, ki, 0)

    k_spec = ss.ins[1]
    # per storage dim of the stored (b, sk, kv, hd) buffer, which grid
    # position drives its block index
    by_axis = dict(zip(k_spec.axes, k_spec.grid_dims))
    for bb in range(b):
        for kh in range(hkv):
            for gi in range(g):
                h = bb * hq + kh * g + gi   # fused grid position
                for qi in range(nq):
                    for ki in range(nk):
                        want = handwritten_kv_map(h, qi, ki)
                        gids = (bb, kh, gi, qi, ki)

                        def drive(ax):
                            d = by_axis[ax]
                            return gids[d] if d is not None else 0
                        # derived (batch, kv-head) block pair == the fused
                        # kv row index of the old hand-written map
                        assert drive("b") * hkv + drive("h") == want[0]
                        assert (drive("j"), drive("c")) == want[1:]


def test_flash_source_has_no_handwritten_layout():
    """Acceptance pin: kernels/flash_attention.py contains no hand-written
    grid or BlockSpec — everything comes from the derived schedule."""
    import inspect
    import repro.kernels.flash_attention as fa
    src = inspect.getsource(fa)
    assert "pl.BlockSpec(" not in src
    assert "grid=(" not in src
    assert "pallas_call(" not in src
    assert "scratch_shapes" not in src


def test_attention_schedule_is_cache_resident():
    sched.reset_schedule_cache()
    entry = hw.get_entry("cpu")
    form = E.attention_form(1, 2, 2, 256, 256, 32)
    b0 = sched.get_schedule(form, dtype="float32", hardware=entry)
    stats = sched.schedule_cache_stats()
    assert stats["misses"] == 1 and stats["solves"] == 1
    b1 = sched.get_schedule(E.attention_form(1, 2, 2, 256, 256, 32),
                            dtype="float32", hardware=entry)
    assert b1 is b0                          # same normal form, same line
    stats = sched.schedule_cache_stats()
    assert stats["hits"] == 1 and stats["solves"] == 1


def test_streaming_blocks_shrink_with_fat_heads():
    """(bq, bk) come from the working-set model, not a constant: a fat
    head_dim (more carried state per row) must shrink the blocks below
    the 512 default rather than overflow the budget."""
    wide = attention_bundle(1, 1, 1, 4096, 4096, 2048, dtype="bfloat16",
                            hardware=hw.get_entry("cpu"))
    assert wide.blocks.as_tuple() != (512, 512)
    assert min(wide.blocks.as_tuple()) < 512
    assert wide.schedule.vmem_bytes("bfloat16") <= \
        hw.get_entry("cpu").shape.vmem.capacity_bytes


# ---------------------------------------------------------------------------
# property tests: kernel == chunked == materialized oracle, incl. gradients
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,kv,g,sq,sk,hd", [
    (1, 2, 1, 100, 100, 16),      # odd, below one block
    (1, 1, 3, 300, 200, 32),      # non-512-multiple, GQA groups of 3
    (2, 2, 2, 513, 257, 16),      # just over block boundaries
])
def test_flash_padded_shapes_match_both_oracles(b, kv, g, sq, sk, hd):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(k1, (b, sq, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, sk, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, sk, kv, hd), jnp.float32)
    got = ops.attention(q, k, v, scale=hd ** -0.5, causal=True,
                        interpret=True, blocks=(64, 64))
    chunked = chunked_attention(q, k, v, scale=hd ** -0.5, causal=True,
                                q_chunk=64, k_chunk=64)
    ref = chunked_attention_ref(q, k, v, scale=hd ** -0.5, causal=True)
    assert got.shape == (b, sq, kv * g, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(chunked),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.integers(1, 2), st.integers(1, 3),
       st.integers(2, 70), st.integers(2, 70), st.integers(0, 999))
def test_hypothesis_flash_vs_chunked(b, kv, g, sq, sk, seed):
    hd = 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, sq, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, sk, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, sk, kv, hd), jnp.float32)
    got = ops.attention(q, k, v, scale=0.3, causal=True, interpret=True,
                        blocks=(16, 16))
    want = chunked_attention(q, k, v, scale=0.3, causal=True,
                             q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_gradients_match_chunked(causal):
    b, kv, g, sq, sk, hd = 1, 2, 2, 48, 40, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (b, sq, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, sk, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, sk, kv, hd), jnp.float32)

    def loss_flash(q, k, v):
        return (ops.attention(q, k, v, scale=0.3, causal=causal,
                              interpret=True, blocks=(16, 16)) ** 2).sum()

    def loss_ref(q, k, v):
        return (chunked_attention(q, k, v, scale=0.3, causal=causal) ** 2).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_key_padding_mask_regression():
    """Keys the pad added must be inert (the kernel's kpos < sk guard):
    identical inputs, different pad amounts, identical results."""
    b, kv, g, hd = 1, 1, 2, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(k1, (b, 40, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, 33, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, 33, kv, hd), jnp.float32)
    a = ops.attention(q, k, v, scale=0.3, causal=True, interpret=True,
                      blocks=(16, 16))     # pads sk 33 -> 48
    c = ops.attention(q, k, v, scale=0.3, causal=True, interpret=True,
                      blocks=(16, 32))     # pads sk 33 -> 64
    want = chunked_attention_ref(q, k, v, scale=0.3, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


def test_attention_inputs_bind_stored_layout_no_relayout():
    """The schedule is derived on the models' STORED q/k/v layouts (the
    grouped views are transposed leaves — index rewrites), so the forward
    jaxpr contains exactly ONE transpose: the output relayout.  No input
    copy feeds the kernel — the attention analogue of the PR-2
    no-transpose-in-jaxpr pin for matmul(transpose_b=True)."""
    q = jnp.ones((1, 128, 2, 2, 16), jnp.float32)
    k = jnp.ones((1, 128, 2, 16), jnp.float32)
    v = jnp.ones((1, 128, 2, 16), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda q, k, v: ops.attention(
        q, k, v, scale=0.25, causal=True, interpret=True,
        blocks=(64, 64)))(q, k, v)

    def count(j):
        n = 0
        for e in j.eqns:
            n += e.primitive.name == "transpose"
            for p in e.params.values():
                if hasattr(p, "jaxpr"):
                    n += count(p.jaxpr)
        return n

    assert count(jaxpr.jaxpr) == 1


def test_attention_dispatch_per_backend(monkeypatch):
    """"xla" entries run the jnp oracle (no kernel executor), "interpret"
    entries run the kernel through the Pallas interpreter — the documented
    backend-policy split (the kernel is numerically identical, so this pins
    the dispatch itself, not the values)."""
    import repro.kernels.flash_attention as fa
    calls = []
    orig = fa._executor
    monkeypatch.setattr(fa, "_executor",
                        lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1])
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 24, 1, 2, 8), jnp.float32)
    k = jax.random.normal(k2, (1, 24, 1, 8), jnp.float32)
    v = jax.random.normal(k3, (1, 24, 1, 8), jnp.float32)
    want = chunked_attention(q, k, v, scale=0.3, causal=True)
    with hw.use_hardware("v100"):
        got = ops.attention(q, k, v, scale=0.3, causal=True)
    assert not calls                          # oracle path, kernel untouched
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    with hw.use_hardware("cpu"):
        got = ops.attention(q, k, v, scale=0.3, causal=True)
    assert calls                              # interpret entry runs the kernel
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_model_level_pallas_path_matches_xla():
    """attn_impl="pallas" routes the model's attention through the Pallas
    flash kernel (interpret on CPU) and must match the XLA path."""
    import jax
    from repro.configs import get_config
    from repro.models import registry, transformer
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False,
                                                          head_dim=32)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 512), 0,
                              cfg.vocab_size)
    h_x, _, _ = transformer.forward(params, cfg.with_(attn_impl="xla"), toks)
    h_p, _, _ = transformer.forward(params, cfg.with_(attn_impl="pallas"), toks)
    err = float(jnp.max(jnp.abs(h_x - h_p)))
    assert err < 5e-3, err
