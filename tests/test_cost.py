"""Roofline cost model + HLO collective parsing."""
import numpy as np

from repro.core import cost
from repro.core.lifting import TPU_V5E

HLO = """
HloModule jit_step

%add { ... }

ENTRY %main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %p1 = f32[4,4]{1,0} parameter(1)
  %ag = bf16[256,128]{1,0} all-gather(bf16[16,128]{1,0} %p0), dimensions={0}
  %ar = f32[4,4]{1,0} all-reduce(f32[4,4]{1,0} %p1), to_apply=%add
  %rs = f32[1,4]{1,0} reduce-scatter(f32[4,4]{1,0} %ar), dimensions={0}
  %cp = bf16[16,128]{1,0} collective-permute(bf16[16,128]{1,0} %p0), source_target_pairs={{0,1}}
  %ata = f32[4,4]{1,0} all-to-all(f32[4,4]{1,0} %p1), dimensions={0}
  %ags = (bf16[16,128]{1,0}, bf16[256,128]{1,0}) all-gather-start(bf16[16,128]{1,0} %p0), dimensions={0}
  %agd = bf16[256,128]{1,0} all-gather-done((bf16[16,128], bf16[256,128]) %ags)
  ROOT %t = (bf16[256,128]{1,0}) tuple(%ag)
}
"""


def test_collective_parsing_counts_and_bytes():
    st = cost.collective_bytes_from_hlo(HLO)
    assert st.count_by_op["all-gather"] == 2          # incl. -start, not -done
    assert st.bytes_by_op["all-gather"] == 2 * 16 * 128 * 2
    assert st.bytes_by_op["all-reduce"] == 4 * 4 * 4
    assert st.bytes_by_op["reduce-scatter"] == 4 * 4 * 4
    assert st.bytes_by_op["collective-permute"] == 16 * 128 * 2
    assert st.bytes_by_op["all-to-all"] == 4 * 4 * 4


def test_shape_bytes_handles_tuples_and_scalars():
    assert cost._shape_bytes("f32[]") == 4
    assert cost._shape_bytes("(bf16[2,2]{1,0}, s32[3]{0})") == 8 + 12
    assert cost._shape_bytes("token[]") == 0


def test_roofline_terms_and_dominance():
    st = cost.CollectiveStats(bytes_by_op={"all-reduce": 10 * 2**20})
    rl = cost.from_quantities("x", n_chips=256, per_device_flops=1e12,
                              per_device_hbm_bytes=1e9, collective_stats=st,
                              hardware=TPU_V5E, model_flops=2e14)
    np.testing.assert_allclose(rl.compute_s, 1e12 / TPU_V5E.peak_flops)
    np.testing.assert_allclose(rl.memory_s, 1e9 / TPU_V5E.hbm.bandwidth_Bps)
    assert rl.dominant == "compute"
    assert 0 < rl.useful_flops_ratio < 1
    assert rl.step_time_s == max(rl.compute_s, rl.memory_s, rl.collective_s)


def test_wire_bytes_ring_multipliers():
    st = cost.CollectiveStats(bytes_by_op={"all-reduce": 1000,
                                           "all-gather": 1000,
                                           "collective-permute": 1000})
    wb = cost.wire_bytes(st, n_chips=4)
    np.testing.assert_allclose(wb, 1000 * 2 * 0.75 + 1000 * 0.75 + 1000)


def test_model_flops():
    assert cost.model_flops_lm(1e9, 1e6) == 6e15
    assert cost.model_flops_lm(1e9, 1e6, active_params=1e8) == 6e14
    assert cost.model_flops_lm(1e9, 1e6, training=False) == 2e15
