"""Chunked (online-softmax) attention vs the materialized oracle —
property-tested across masking modes, chunk shapes, and GQA groupings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.chunked_attention import (chunked_attention,
                                            chunked_attention_ref)


def _mk(b, sq, sk, kv, g, hd, vd=None, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, sq, kv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, sk, kv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, sk, kv, vd or hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("qc,kc", [(8, 8), (16, 32), (64, 16), (1000, 16)])
def test_chunk_shape_invariance(qc, kc):
    q, k, v = _mk(2, 48, 48, 2, 2, 16)
    got = chunked_attention(q, k, v, scale=0.25, q_chunk=qc, k_chunk=kc)
    want = chunked_attention_ref(q, k, v, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(2, 40), st.integers(1, 3),
       st.integers(1, 3), st.integers(0, 30), st.integers(0, 999))
def test_hypothesis_causal_window(b, s, kv, g, window, seed):
    q, k, v = _mk(b, s, s, kv, g, 8, seed=seed)
    got = chunked_attention(q, k, v, scale=0.3, window=window,
                            q_chunk=8, k_chunk=8)
    want = chunked_attention_ref(q, k, v, scale=0.3, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_prefix_lm_mode():
    q, k, v = _mk(1, 32, 32, 1, 2, 8)
    got = chunked_attention(q, k, v, scale=0.3, prefix_len=10,
                            q_chunk=8, k_chunk=8)
    want = chunked_attention_ref(q, k, v, scale=0.3, prefix_len=10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_mla_style_vd_neq_hd():
    """Latent values (vd != hd) — the absorbed-MLA prefill path."""
    q, k, v = _mk(1, 24, 24, 1, 4, 48, vd=16)
    got = chunked_attention(q, k, v, scale=48 ** -0.5, q_chunk=8, k_chunk=8)
    want = chunked_attention_ref(q, k, v, scale=48 ** -0.5)
    assert got.shape == (1, 24, 4, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_gradients_match_oracle():
    """Online-softmax backward (incl. the remat'd k-step) == oracle grad."""
    q, k, v = _mk(1, 16, 16, 2, 2, 8)

    def f_chunk(q, k, v):
        return (chunked_attention(q, k, v, scale=0.35, q_chunk=4,
                                  k_chunk=4) ** 2).sum()

    def f_ref(q, k, v):
        return (chunked_attention_ref(q, k, v, scale=0.35) ** 2).sum()

    g1 = jax.grad(f_chunk, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# non-causal window/prefix regression: honor-or-raise, never silently ignore
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn", [chunked_attention, chunked_attention_ref])
def test_noncausal_window_raises(fn):
    """window > 0 with causal=False used to silently become FULL attention;
    it must raise instead of mis-masking."""
    q, k, v = _mk(1, 16, 16, 1, 2, 8)
    with pytest.raises(ValueError, match="causal"):
        fn(q, k, v, scale=0.3, causal=False, window=4)


@pytest.mark.parametrize("fn", [chunked_attention, chunked_attention_ref])
def test_noncausal_prefix_raises(fn):
    q, k, v = _mk(1, 16, 16, 1, 2, 8)
    with pytest.raises(ValueError, match="causal"):
        fn(q, k, v, scale=0.3, causal=False, prefix_len=5)


def test_noncausal_without_window_still_bidirectional():
    """Plain causal=False (no window/prefix) keeps working and attends to
    every key."""
    q, k, v = _mk(1, 12, 12, 1, 2, 8)
    got = chunked_attention(q, k, v, scale=0.3, causal=False,
                            q_chunk=4, k_chunk=4)
    want = chunked_attention_ref(q, k, v, scale=0.3, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
