"""Energy model must reproduce the paper's empirical relationships."""
import numpy as np

from repro.core import energy
from repro.core.blocking import solve_blocks
from repro.core.lifting import TPU_V5E


def test_energy_tracks_time_across_block_sizes():
    """Figs 6-8: the energy-optimal block size is (near-)time-optimal and
    vice versa (ties on time are broken by the lower-traffic block)."""
    res = dict(energy.energy_vs_blocksize(8192, [64, 128, 256, 512, 1024]))
    t_min = min(r.time_s for r in res.values())
    e_min = min(r.energy_J for r in res.values())
    best_e = min(res, key=lambda b: res[b].energy_J)
    best_t = min(res, key=lambda b: res[b].time_s)
    assert res[best_e].time_s <= 1.05 * t_min
    assert res[best_t].energy_J <= 1.10 * e_min
    # and both orderings agree on the bad blocks: smallest block is worst
    assert res[64].time_s == max(r.time_s for r in res.values())
    assert res[64].energy_J == max(r.energy_J for r in res.values())


def test_power_flat_while_time_varies():
    """§3.6.3: power max/min ~1.1x while time varies much more."""
    res = [r for _, r in energy.energy_vs_blocksize(8192, [64, 128, 256, 512, 1024])]
    p = [r.power_W for r in res]
    t = [r.time_s for r in res]
    power_ratio = max(p) / min(p)
    time_ratio = max(t) / min(t)
    assert power_ratio < 1.6
    assert time_ratio > 2.0
    assert time_ratio > 2 * power_ratio


def test_energy_linear_in_matrix_size_when_bandwidth_bound():
    """Abstract claim: energy quadratic in N (linear in elements) in the
    bandwidth-bound regime — E(2N)/E(N) ~ 4 with small blocks."""
    b = 128       # small block => memory bound
    blocks = lambda n: energy.energy_vs_blocksize(n, [b])[0][1]
    e1, e2 = blocks(4096), blocks(8192)
    assert e1.bound == "memory" and e2.bound == "memory"
    ratio = e2.energy_J / e1.energy_J
    assert 3.0 < ratio < 9.0      # between quadratic(4) and cubic(8) + static


def test_blocked_traffic_beats_unblocked():
    n = 4096
    bc = solve_blocks(n, n, n, "bfloat16", TPU_V5E)
    hbm_blocked, _ = energy.gemm_traffic(n, n, n, bc)
    hbm_naive = energy.gemm_unblocked_traffic(n, n, n)
    assert hbm_blocked < hbm_naive / 10


def test_solver_block_is_energy_optimal_among_squares():
    """The paper's central claim, on the TPU table: the solver-chosen block
    beats smaller/larger square blocks on modeled energy."""
    n = 16384
    candidates = [64, 128, 256, 512, 1024, 2048]
    res = dict(energy.energy_vs_blocksize(n, candidates))
    bc = solve_blocks(n, n, n, "bfloat16", TPU_V5E)
    solver_e = energy.gemm_energy(n, n, n, bc).energy_J
    assert solver_e <= min(r.energy_J for r in res.values()) * 1.05
