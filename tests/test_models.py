"""Per-architecture smoke + decode-cache consistency tests (reduced configs,
one forward/train step on CPU, asserting shapes and finiteness — full configs
are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import registry

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32, seed=1):
    kt = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab_size),
             "targets": jax.random.randint(kt, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, :s - cfg.num_patches]
        batch["targets"] = batch["targets"][:, :s - cfg.num_patches]
        batch["patches"] = jax.random.normal(
            kt, (b, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            kt, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss_finite(arch):
    cfg = get_config(arch, reduced=True)
    params, axes = registry.init(cfg, KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: registry.loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_grads_finite(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = registry.init(cfg, KEY)
    batch = make_batch(cfg)
    g = jax.jit(jax.grad(lambda p: registry.loss(p, cfg, batch)[0]))(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = registry.init(cfg, KEY)
    b, cache_len = 2, 32
    cache = registry.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    logits, new_cache = jax.jit(
        lambda p, t, pos, c: registry.decode_step(p, cfg, t, pos, c))(
        params, jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32), cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


# ---------------------------------------------------------------------------
# decode-cache consistency: token-by-token decode == full forward
# ---------------------------------------------------------------------------

CONSISTENCY_ARCHS = ["command-r-plus-104b", "minicpm3-4b", "gemma-2b",
                     "stablelm-1.6b", "mamba2-780m", "recurrentgemma-9b",
                     "whisper-base"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    """Greedy caches must reproduce teacher-forced logits — validates every
    cache type (KV, latent MLA, SSM state, RG-LRU state, ring buffers,
    enc-dec cross attention)."""
    cfg = get_config(arch, reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    toks = batch["tokens"]

    from repro.models import encdec, transformer
    from repro.models.layers import logits_from_hidden
    if cfg.family == "audio":
        enc = encdec.encode(params, cfg, batch["frames"])
        hidden, _ = encdec.decoder_forward(params, cfg, toks, enc)
        full_logits = logits_from_hidden(params, hidden, cfg)
        cache = encdec.init_encdec_cache(cfg, b, s, dtype=jnp.float32)
        cache = cache._replace(cross_kv=jax.vmap(
            lambda lp: encdec._cross_kv(lp, enc, cfg))(
            params["decoder"]["cross_attn"]))
        step = jax.jit(lambda t, pos, c: encdec.encdec_decode_step(
            params, cfg, t, pos, c))
    else:
        hidden, _, _ = transformer.forward(params, cfg, toks)
        full_logits = logits_from_hidden(params, hidden, cfg)
        cache = registry.init_cache(cfg, b, s, dtype=jnp.float32)
        step = jax.jit(lambda t, pos, c: registry.decode_step(
            params, cfg, t, pos, c))

    errs = []
    for t in range(s):
        logits, cache = step(toks[:, t], jnp.full((b,), t, jnp.int32), cache)
        errs.append(float(jnp.max(jnp.abs(
            logits - full_logits[:, t, :]))))
    assert max(errs) < 2e-2, (arch, errs)


def test_vlm_prefix_attention_is_bidirectional():
    cfg = get_config("paligemma-3b", reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    from repro.models import transformer
    b, s = 1, 24
    batch = make_batch(cfg, b, s)
    h1, _, _ = transformer.forward(params, cfg, batch["tokens"],
                                   patches=batch["patches"])
    # permuting patch 0/1 must change position-0 patch outputs (bidir prefix)
    patches2 = batch["patches"].at[:, [0, 1]].set(batch["patches"][:, [1, 0]])
    h2, _, _ = transformer.forward(params, cfg, batch["tokens"], patches=patches2)
    assert float(jnp.max(jnp.abs(h1[:, 0] - h2[:, 0]))) > 1e-6


def test_causality_dense():
    """Future-token perturbation cannot change past logits."""
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    from repro.models import transformer
    from repro.models.layers import logits_from_hidden
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 12), 0, cfg.vocab_size)
    h1, _, _ = transformer.forward(params, cfg, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab_size)
    h2, _, _ = transformer.forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(h1[:, :-1], np.float32),
                               np.asarray(h2[:, :-1], np.float32), atol=1e-5)


def test_ssm_chunked_matches_tiny_chunks():
    """SSD chunk size must not change semantics (chunking = lifting)."""
    cfg = get_config("mamba2-780m", reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    from repro.models import transformer
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab_size)
    h1, _, _ = transformer.forward(params, cfg.with_(ssm_chunk=4), toks)
    h2, _, _ = transformer.forward(params, cfg.with_(ssm_chunk=16), toks)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=2e-3)


def test_param_counts_match_analytic():
    """Analytic param_count (used for MODEL_FLOPS) vs real init, per arch."""
    import numpy as np
    for arch in ["gemma-2b", "stablelm-1.6b"]:
        cfg = get_config(arch)
        total, _ = cfg.param_count()
        # reduced check at full scale is too big to init; verify the analytic
        # formula on the reduced config against its own init instead
        r = get_config(arch, reduced=True)
        params, _ = registry.init(r, KEY)
        got = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        want, _ = r.param_count()
        assert abs(got - want) / got < 0.15, (arch, got, want)
