"""Per-architecture smoke + decode-cache consistency tests (reduced configs,
one forward/train step on CPU, asserting shapes and finiteness — full configs
are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import registry

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32, seed=1):
    kt = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab_size),
             "targets": jax.random.randint(kt, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, :s - cfg.num_patches]
        batch["targets"] = batch["targets"][:, :s - cfg.num_patches]
        batch["patches"] = jax.random.normal(
            kt, (b, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            kt, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss_finite(arch):
    cfg = get_config(arch, reduced=True)
    params, axes = registry.init(cfg, KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: registry.loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_grads_finite(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = registry.init(cfg, KEY)
    batch = make_batch(cfg)
    g = jax.jit(jax.grad(lambda p: registry.loss(p, cfg, batch)[0]))(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = registry.init(cfg, KEY)
    b, cache_len = 2, 32
    cache = registry.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    logits, new_cache = jax.jit(
        lambda p, t, pos, c: registry.decode_step(p, cfg, t, pos, c))(
        params, jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32), cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


# ---------------------------------------------------------------------------
# decode-cache consistency: token-by-token decode == full forward
# ---------------------------------------------------------------------------

CONSISTENCY_ARCHS = ["command-r-plus-104b", "minicpm3-4b", "gemma-2b",
                     "stablelm-1.6b", "mamba2-780m", "recurrentgemma-9b",
                     "whisper-base"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    """Greedy caches must reproduce teacher-forced logits — validates every
    cache type (KV, latent MLA, SSM state, RG-LRU state, ring buffers,
    enc-dec cross attention)."""
    cfg = get_config(arch, reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    toks = batch["tokens"]

    from repro.models import encdec, transformer
    from repro.models.layers import logits_from_hidden
    if cfg.family == "audio":
        enc = encdec.encode(params, cfg, batch["frames"])
        hidden, _ = encdec.decoder_forward(params, cfg, toks, enc)
        full_logits = logits_from_hidden(params, hidden, cfg)
        cache = encdec.init_encdec_cache(cfg, b, s, dtype=jnp.float32)
        cache = cache._replace(cross_kv=jax.vmap(
            lambda lp: encdec._cross_kv(lp, enc, cfg))(
            params["decoder"]["cross_attn"]))
        step = jax.jit(lambda t, pos, c: encdec.encdec_decode_step(
            params, cfg, t, pos, c))
    else:
        hidden, _, _ = transformer.forward(params, cfg, toks)
        full_logits = logits_from_hidden(params, hidden, cfg)
        cache = registry.init_cache(cfg, b, s, dtype=jnp.float32)
        step = jax.jit(lambda t, pos, c: registry.decode_step(
            params, cfg, t, pos, c))

    errs = []
    for t in range(s):
        logits, cache = step(toks[:, t], jnp.full((b,), t, jnp.int32), cache)
        errs.append(float(jnp.max(jnp.abs(
            logits - full_logits[:, t, :]))))
    assert max(errs) < 2e-2, (arch, errs)


def test_vlm_prefix_attention_is_bidirectional():
    cfg = get_config("paligemma-3b", reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    from repro.models import transformer
    b, s = 1, 24
    batch = make_batch(cfg, b, s)
    h1, _, _ = transformer.forward(params, cfg, batch["tokens"],
                                   patches=batch["patches"])
    # permuting patch 0/1 must change position-0 patch outputs (bidir prefix)
    patches2 = batch["patches"].at[:, [0, 1]].set(batch["patches"][:, [1, 0]])
    h2, _, _ = transformer.forward(params, cfg, batch["tokens"], patches=patches2)
    assert float(jnp.max(jnp.abs(h1[:, 0] - h2[:, 0]))) > 1e-6


def test_causality_dense():
    """Future-token perturbation cannot change past logits."""
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    from repro.models import transformer
    from repro.models.layers import logits_from_hidden
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 12), 0, cfg.vocab_size)
    h1, _, _ = transformer.forward(params, cfg, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab_size)
    h2, _, _ = transformer.forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(h1[:, :-1], np.float32),
                               np.asarray(h2[:, :-1], np.float32), atol=1e-5)


def test_ssm_chunked_matches_tiny_chunks():
    """SSD chunk size must not change semantics (chunking = lifting)."""
    cfg = get_config("mamba2-780m", reduced=True).with_(remat=False)
    params, _ = registry.init(cfg, KEY)
    from repro.models import transformer
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab_size)
    h1, _, _ = transformer.forward(params, cfg.with_(ssm_chunk=4), toks)
    h2, _, _ = transformer.forward(params, cfg.with_(ssm_chunk=16), toks)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=2e-3)


def test_param_counts_match_analytic():
    """Analytic param_count (used for MODEL_FLOPS) vs real init, per arch."""
    import numpy as np
    for arch in ["gemma-2b", "stablelm-1.6b"]:
        cfg = get_config(arch)
        total, _ = cfg.param_count()
        # reduced check at full scale is too big to init; verify the analytic
        # formula on the reduced config against its own init instead
        r = get_config(arch, reduced=True)
        params, _ = registry.init(r, KEY)
        got = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        want, _ = r.param_count()
        assert abs(got - want) / got < 0.15, (arch, got, want)


# ---------------------------------------------------------------------------
# attention-layer regressions (PR 4 bugfixes)
# ---------------------------------------------------------------------------

def _attn_setup(cfg, b=1, s=24, seed=3):
    from repro.models import attention as attn
    from repro.models.common import Collector
    col = Collector(jax.random.PRNGKey(seed), dtype=jnp.float32)
    attn.init_attention(col, "a", cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (b, s, cfg.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return col.params["a"], x, positions


def test_rope_applied_on_noncausal_attention(monkeypatch):
    """Regression: bidirectional (encoder) passes with rope_pct > 0 must
    rotate q and k — the old gate silently skipped RoPE when causal=False."""
    import repro.models.attention as attn_mod
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
    assert cfg.rope_pct > 0
    p, x, positions = _attn_setup(cfg)

    calls = []
    orig = attn_mod.apply_rope
    monkeypatch.setattr(attn_mod, "apply_rope",
                        lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1])
    out_rope, _ = attn_mod.attention_fwd(p, x, cfg, positions=positions,
                                         causal=False)
    assert len(calls) == 2                       # q and k both rotated
    out_norope, _ = attn_mod.attention_fwd(p, x, cfg.with_(rope_pct=0.0),
                                           positions=positions, causal=False)
    # with the old bug both paths were identical (RoPE dropped)
    assert float(jnp.max(jnp.abs(out_rope - out_norope))) > 1e-4


def test_kv_cache_is_mask_independent():
    """K/V leaving attention_fwd feed the decode cache, whose masking DOES
    apply RoPE — so the cache must not depend on the masking mode.  Under
    the old gate, causal=False returned un-rotated keys while causal=True
    returned rotated ones."""
    import repro.models.attention as attn_mod
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
    assert cfg.rope_pct > 0
    p, x, positions = _attn_setup(cfg)
    _, kv_causal = attn_mod.attention_fwd(p, x, cfg, positions=positions,
                                          causal=True)
    _, kv_bidir = attn_mod.attention_fwd(p, x, cfg, positions=positions,
                                         causal=False)
    np.testing.assert_array_equal(np.asarray(kv_causal.k, np.float32),
                                  np.asarray(kv_bidir.k, np.float32))
    np.testing.assert_array_equal(np.asarray(kv_causal.v, np.float32),
                                  np.asarray(kv_bidir.v, np.float32))


def test_pallas_impl_runs_kernel_on_any_causal_shape(monkeypatch):
    """Regression: attn_impl="pallas" used to silently fall back to the jnp
    path off 512-multiples.  Now every causal full-sequence shape routes
    through ops.attention (the pad/slice wrapper) and matches the XLA path."""
    import repro.models.attention as attn_mod
    from repro.models import transformer

    calls = []
    orig = attn_mod.ops.attention
    monkeypatch.setattr(
        attn_mod.ops, "attention",
        lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1])

    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False,
                                                          head_dim=32)
    params, _ = registry.init(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 300), 0,
                              cfg.vocab_size)   # NOT a 512 multiple
    h_x, _, _ = transformer.forward(params, cfg.with_(attn_impl="xla"), toks)
    assert not calls
    h_p, _, _ = transformer.forward(params, cfg.with_(attn_impl="pallas"),
                                    toks)
    assert calls                                 # kernel path engaged
    assert float(jnp.max(jnp.abs(h_x - h_p))) < 5e-3


def test_noncausal_window_raises_on_dense_branch():
    """The honor-or-raise contract covers the materialized (_attend) branch
    too: short non-causal sequences with window/prefix_len must raise, not
    silently attend to everything."""
    import repro.models.attention as attn_mod
    cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
    p, x, positions = _attn_setup(cfg, s=8)   # far below attn_chunk_min_seq
    with pytest.raises(ValueError, match="causal"):
        attn_mod.attention_fwd(p, x, cfg, positions=positions,
                               causal=False, window=4)
    with pytest.raises(ValueError, match="causal"):
        attn_mod.attention_fwd(p, x, cfg, positions=positions,
                               causal=False, prefix_len=3)
