"""Distributed layer: sharding rules (in-process) + multi-device collective
matmul equivalence (subprocess with 8 forced host devices, so the main test
process keeps seeing exactly 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as sr

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh(shape, names):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(shape))
    # fake multi-axis mesh over 1 device is not possible; use abstract sizes
    # by constructing a mesh only when sizes are all 1 — rule tests below use
    # a synthetic Mesh via jax.make_mesh on 1 device for (1,1) only.
    raise NotImplementedError


class FakeMesh:
    """Duck-typed mesh (axis_names + devices.shape) for rule testing without
    actual devices."""
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()), dtype=object)
        self.empty = False


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_rules_fsdp_plus_tp():
    spec = sr.param_spec(("d_model", "d_ff"), (12288, 33792), MESH)
    assert spec == P(("pod", "data"), "model")


def test_param_rules_divisibility_fallback():
    # 40 heads don't divide 16-way model axis -> replicate that dim
    spec = sr.param_spec(("d_model", "heads", None), (2560, 40, 96), MESH)
    assert spec == P(("pod", "data"),)


def test_param_rules_mesh_axis_used_once():
    spec = sr.param_spec(("experts", "d_model", "moe_ff"), (64, 2048, 1408), MESH)
    assert spec == P("model", ("pod", "data"))   # moe_ff loses to experts


def test_act_rules_batch_and_kv():
    spec = sr.act_spec(("batch", "kv_seq", "kv_heads", None),
                       (128, 32768, 8, 128), MESH)
    assert spec == P(("pod", "data"), "model")
    # batch=1 (long_500k): falls back to replication, seq takes model
    spec = sr.act_spec(("batch", "kv_seq", "kv_heads", None),
                       (1, 524288, 8, 128), MESH)
    assert spec == P(None, "model")


def test_act_rules_seq_parallel():
    spec = sr.act_spec(("batch", "seq_sp", None), (256, 4096, 12288), MESH)
    assert spec == P(("pod", "data"), "model")


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.sharding import shard_map
    from repro.distributed import collectives as cl

    mesh = jax.make_mesh((8,), ("x",))
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(k1, (64, 32), jnp.float32)     # rows sharded
    W = jax.random.normal(k2, (32, 16), jnp.float32)

    ag = shard_map(lambda x, w: cl.ag_matmul(x, w, "x"), mesh=mesh,
                   in_specs=(P("x", None), P(None, None)),
                   out_specs=P(None, None), check_vma=False)
    ref = shard_map(lambda x, w: cl.reference_ag_matmul(x, w, "x"), mesh=mesh,
                    in_specs=(P("x", None), P(None, None)),
                    out_specs=P(None, None), check_vma=False)
    got, want = ag(X, W), ref(X, W)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-4), "ag_matmul"
    assert np.allclose(np.asarray(want), np.asarray(X @ W), atol=1e-4)

    X2 = jax.random.normal(k1, (48, 64), jnp.float32)    # k sharded
    W2 = jax.random.normal(k2, (64, 24), jnp.float32)
    ps = shard_map(lambda x, w: cl.psum_matmul(x, w, "x"), mesh=mesh,
                   in_specs=(P(None, "x"), P("x", None)),
                   out_specs=P(None, None), check_vma=False)
    got2 = ps(X2, W2)
    assert np.allclose(np.asarray(got2), np.asarray(X2 @ W2), atol=1e-3), "psum_matmul"
    print("SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_collective_matmuls_multi_device():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_pjit_sharded_train_step_multi_device():
    """8-device pjit train step with lifting-derived shardings runs and the
    loss matches the 1-device result (sharding must not change semantics)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.data import PipelineConfig, SyntheticLM
        from repro.distributed import sharding as sr
        from repro.launch.mesh import make_host_mesh
        from repro.train import train_step as ts

        cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
        key = jax.random.PRNGKey(0)
        data = SyntheticLM(PipelineConfig(cfg.vocab_size, 16, 8), cfg)
        batch = jax.tree.map(jnp.asarray, data.global_batch(0))

        losses = {}
        for dp, tp in [(1, 1), (4, 2)]:
            mesh = make_host_mesh(dp=dp, tp=tp)
            with mesh:
                state, axes = ts.init_state(cfg, key)
                st_axes = ts.state_logical_axes(state, axes)
                sh = sr.param_shardings(state, st_axes, mesh)
                state = jax.tree.map(jax.device_put, state, sh)
                step = jax.jit(ts.make_train_step(cfg))
                _, m = step(state, batch)
                losses[(dp, tp)] = float(m["loss"])
        a, b = losses[(1, 1)], losses[(4, 2)]
        assert abs(a - b) < 5e-3, losses
        print("SUBPROCESS_OK", losses)
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + r.stderr
