"""Property tests for the MoA algebra core (shapes, psi, gamma, ONF)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import moa, onf

dims = st.integers(1, 6)
small_shapes = st.lists(st.integers(1, 5), min_size=1, max_size=4).map(tuple)


# ---------------------------------------------------------------------------
# gamma family
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(small_shapes, st.data())
def test_gamma_row_bijection(shape, data):
    n = moa.pi(shape)
    off = data.draw(st.integers(0, n - 1))
    idx = moa.gamma_row_inverse(off, shape)
    assert moa.gamma_row(idx, shape) == off


@settings(max_examples=50, deadline=None)
@given(small_shapes)
def test_gamma_row_enumerates_all_offsets(shape):
    offsets = {moa.gamma_row(tuple(i), shape) for i in
               moa.iota(shape).reshape(-1, len(shape))}
    assert offsets == set(range(moa.pi(shape)))


def test_gamma_row_is_paper_formula():
    # eq. (3): gamma(<i,j>; <m,p>) = i*p + j
    m, p = 7, 11
    for i in range(m):
        for j in range(p):
            assert moa.gamma_row((i, j), (m, p)) == i * p + j


def test_gamma_col_matches_fortran_order():
    a = np.arange(24).reshape(2, 3, 4)
    flat_f = a.flatten(order="F")
    for idx in moa.iota(a.shape).reshape(-1, 3):
        assert flat_f[moa.gamma_col(tuple(idx), a.shape)] == a[tuple(idx)]


@settings(max_examples=50, deadline=None)
@given(small_shapes, st.data())
def test_gamma_col_bijection(shape, data):
    n = moa.pi(shape)
    off = data.draw(st.integers(0, n - 1))
    idx = moa.gamma_col_inverse(off, shape)
    assert moa.gamma_col(idx, shape) == off
    # and forward-then-back recovers the index
    rt = moa.gamma_col_inverse(moa.gamma_col(idx, shape), shape)
    assert rt == idx


@settings(max_examples=50, deadline=None)
@given(small_shapes, st.data())
def test_gamma_col_is_gamma_row_reversed(shape, data):
    """The two layouts are duals: gamma_col(i; s) == gamma_row(rev i; rev s),
    and the inverses commute with reversal the same way — the property the
    transposed-operand schedules lean on."""
    n = moa.pi(shape)
    off = data.draw(st.integers(0, n - 1))
    idx = moa.gamma_col_inverse(off, shape)
    assert idx == tuple(reversed(moa.gamma_row_inverse(off, tuple(reversed(shape)))))
    assert moa.gamma_col(idx, shape) == \
        moa.gamma_row(tuple(reversed(idx)), tuple(reversed(shape)))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4))
def test_gamma_col_inverse_is_transpose_of_row_inverse(m, n):
    """Reading a row-major (n, k) array through its transpose IS the
    column-major layout of the (k, n) view: for every flat offset the row
    index recovered under one layout is the reversed pair under the other."""
    shape = (m, n)
    for off in range(m * n):
        i, j = moa.gamma_row_inverse(off, shape)
        assert moa.gamma_col_inverse(off, (n, m)) == (j, i)


def test_gamma_col_inverse_rejects_out_of_range():
    with pytest.raises(IndexError):
        moa.gamma_col_inverse(6, (2, 3))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 3), st.integers(1, 3))
def test_gamma_blocked_bijection(mo, no, bm, bn):
    shape = (mo * bm, no * bn)
    block = (bm, bn)
    offs = {moa.gamma_blocked(tuple(i), shape, block)
            for i in moa.iota(shape).reshape(-1, 2)}
    assert offs == set(range(moa.pi(shape)))


# ---------------------------------------------------------------------------
# psi
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(small_shapes, st.integers(0, 100))
def test_psi_identity(shape, seed):
    """(iota(rho x)) psi x == x — the fundamental MoA identity."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    idxs = moa.iota(shape).reshape(-1, len(shape))
    rebuilt = np.array([moa.psi(tuple(i), x) for i in idxs]).reshape(shape)
    np.testing.assert_array_equal(rebuilt, x)


@settings(max_examples=30, deadline=None)
@given(small_shapes, st.integers(0, 100))
def test_psi_distributes_over_scalar_ops(shape, seed):
    rng = np.random.default_rng(seed)
    a, b = rng.standard_normal(shape), rng.standard_normal(shape)
    for idx in moa.iota(shape).reshape(-1, len(shape))[:10]:
        i = tuple(idx)
        assert moa.psi(i, a * b) == moa.psi(i, a) * moa.psi(i, b)


@settings(max_examples=30, deadline=None)
@given(small_shapes, st.integers(0, 100))
def test_onf_equals_dnf_indexing(shape, seed):
    """rav(x)[gamma(i)] == x[i] — DNF/ONF agreement."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for idx in moa.iota(shape).reshape(-1, len(shape))[:10]:
        assert moa.psi_flat(tuple(idx), x) == moa.psi(tuple(idx), x)


# ---------------------------------------------------------------------------
# GEMM normal forms
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 6), st.integers(0, 99))
def test_onf_gemm_equals_linear_algebra(m, n, p, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n))
    b = rng.standard_normal((n, p))
    c = moa.onf_gemm(moa.rav(a), moa.rav(b), m, n, p)
    np.testing.assert_allclose(c.reshape(m, p), a @ b, rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5), st.integers(0, 99))
def test_classical_equals_moa(m, n, p, seed):
    rng = np.random.default_rng(seed)
    a, b = rng.standard_normal((m, n)), rng.standard_normal((n, p))
    np.testing.assert_allclose(
        moa.onf_gemm(moa.rav(a), moa.rav(b), m, n, p),
        moa.classical_gemm(moa.rav(a), moa.rav(b), m, n, p), rtol=1e-12)


def test_moa_inner_loop_is_contiguous_and_classical_is_not():
    m, n, p = 64, 64, 64
    assert moa.moa_access_trace(m, n, p).contiguous
    assert not moa.classical_access_trace(m, n, p).contiguous
    # and the modeled line traffic is strictly lower for MoA
    assert (moa.cacheline_traffic(moa.moa_access_trace(m, n, p), m, n, p)
            < moa.cacheline_traffic(moa.classical_access_trace(m, n, p), m, n, p))


@pytest.mark.parametrize("m,n,p", [(4, 4, 16), (8, 8, 8), (16, 32, 64),
                                   (64, 64, 64)])
def test_cacheline_traffic_ratio_pinned(m, n, p):
    """MoA's contiguous inner loop moves (1+1)/line lines per iteration;
    classical moves 1/line for A plus a full min(p, line)-elem burst for B's
    strided column walk.  Ratio classical/moa == (1 + min(p, line)) / 2."""
    line = 8
    moa_t = moa.cacheline_traffic(moa.moa_access_trace(m, n, p), m, n, p, line)
    cls_t = moa.cacheline_traffic(moa.classical_access_trace(m, n, p), m, n, p,
                                  line)
    inner = m * n * p
    assert moa_t == 2 * inner // line
    assert cls_t == inner // line + inner * min(p, line) // line
    assert cls_t / moa_t == pytest.approx((1 + min(p, line)) / 2)


def test_cacheline_traffic_zero_stride_is_free():
    t = moa.AccessTrace("held", 0, 0, 0)
    assert moa.cacheline_traffic(t, 8, 8, 8) == 0


def test_moa_unified_ops_oracles():
    rng = np.random.default_rng(0)
    a, b = rng.standard_normal((3, 4)), rng.standard_normal((2, 5))
    np.testing.assert_allclose(moa.kron(a, b), np.kron(a, b), rtol=1e-12)
    h = moa.hadamard(a, a)
    np.testing.assert_allclose(h, a * a)
    op = moa.outer_product(a, b)
    assert op.shape == (3, 4, 2, 5)
    np.testing.assert_allclose(op, np.einsum("mn,pq->mnpq", a, b))
    ip = moa.inner_product(a, rng.standard_normal((4, 6)))
    assert ip.shape == (3, 6)


# ---------------------------------------------------------------------------
# ONF loop nests + dimension lifting (paper figs 3-5)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from([(2, 4, 8), (4, 8, 16), (6, 6, 6), (2, 2, 2)]),
       st.integers(0, 99))
def test_lifted_onf_preserves_semantics(mnp, seed):
    m, n, p = mnp
    rng = np.random.default_rng(seed)
    a, b = rng.standard_normal((m, n)), rng.standard_normal((n, p))
    want = (a @ b).ravel()
    base = onf.gemm_onf(m, n, p)
    np.testing.assert_allclose(base.execute(np.zeros(m * p), a.ravel(), b.ravel()),
                               want, rtol=1e-12)
    rows = onf.gemm_lifted_rows(m, n, p, np_procs=2)
    np.testing.assert_allclose(rows.execute(np.zeros(m * p), a.ravel(), b.ravel()),
                               want, rtol=1e-12)
    cols = onf.gemm_lifted_cols(m, n, p, rsize=2)
    np.testing.assert_allclose(cols.execute(np.zeros(m * p), a.ravel(), b.ravel()),
                               want, rtol=1e-12)
    full = onf.gemm_fully_lifted(m, n, p, procs=2, bk=max(n // 2, 1),
                                 bn=max(p // 2, 1))
    np.testing.assert_allclose(full.execute(np.zeros(m * p), a.ravel(), b.ravel()),
                               want, rtol=1e-12)


def test_lifting_raises_on_non_divisor():
    with pytest.raises(ValueError):
        onf.lift_loop(onf.gemm_onf(3, 4, 5), "i", 2, "proc")


def test_innermost_strides_match_paper():
    o = onf.gemm_onf(4, 5, 6)
    s = o.innermost_strides()
    assert s == {"A": 0, "B": 1, "C": 1}          # scalar x contiguous rows
    c = onf.gemm_classical_onf(4, 5, 6)
    sc = c.innermost_strides()
    assert sc["B"] == 6 and sc["A"] == 1 and sc["C"] == 0   # strided B


def test_render_c_smoke():
    txt = onf.gemm_fully_lifted(8, 8, 8, procs=2, bk=4, bn=4).render_c()
    assert "lifted: proc" in txt and "lifted: block" in txt and "+=" in txt
