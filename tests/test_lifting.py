"""Dimension lifting: factorization invariants + emitters."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import lifting
from repro.core.lifting import TPU_V5E, TPU_V5E_2POD, lift, lift_shape


def test_lift_factors_multiply_to_size():
    ax = lift("i", 4096, [("pod", 2), ("data", 16)])
    assert ax.factors == (("pod", 2), ("data", 16), (None, 128))


def test_lift_rejects_non_divisor():
    with pytest.raises(ValueError):
        lift("i", 10, [("data", 3)])


def test_partition_spec_from_lifting():
    ls = lifting.batch_lifting(TPU_V5E_2POD, 256, ("seq", 4096), ("d", 512))
    spec = ls.partition_spec()
    assert spec[0] == ("pod", "data")
    assert ls.local_shape() == (8, 4096, 512)


def test_model_lifting_spec():
    ls = lifting.model_lifting(TPU_V5E, "d_ff", 33792, ("d_model", 12288))
    assert ls.partition_spec()[0] == "model"
    assert ls.local_shape() == (33792 // 16, 12288)


def test_grid_emission():
    ls = lift_shape(TPU_V5E, [
        ("m", 4096, [("grid", 8)]),
        ("n", 4096, [("grid", 16)]),
    ])
    assert ls.grid() == (8, 16)
    assert ls.block_shape() == (512, 256)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8).map(lambda k: 2 ** k))
def test_lift_roundtrip_any_pow2(size):
    ax = lift("x", size * 16, [(None, 16)])
    assert ax.size == size * 16
    total = 1
    for _, e in ax.factors:
        total *= e
    assert total == ax.size


def test_hardware_table_matches_task_constants():
    assert TPU_V5E.peak_flops == 197e12
    assert TPU_V5E.hbm.bandwidth_Bps == 819e9
    assert TPU_V5E.ici_Bps == 50e9
    assert TPU_V5E.n_chips == 256
    assert TPU_V5E_2POD.n_chips == 512
