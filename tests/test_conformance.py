"""The kernel-body conformance analyzer: clean effect summaries on every
shipped kind, seeded-mutation tests asserting exact rule classification
(mirroring ``test_analysis``'s schedule-mutation matrix one layer down),
the traced-acc-width/working-set agreement property, the paged index-map
bound, and the ``verify_bundle(kernel=True)`` / ``apply(verify="kernel")``
wiring."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import analysis
from repro.analysis import conformance
from repro.core import expr as E
from repro.core import hardware as hwr
from repro.core import schedule as sched
from repro.core.blocking import _dtype_size
from repro.kernels import emit, ops

HW = hwr.get_entry("cpu")

WINDOWED_DECODE = dict(
    form=E.windowed_decode_form(2, 4, 64, page=16, view_pages=4,
                                pool_pages=6, page_table=(0, 3, 1, 5),
                                window=32),
    blocks=(4, 16))


def _rules(findings):
    return sorted({f.rule for f in findings if f.level == "error"})


def _mutated(bundle, kind):
    """Repoint the bundle's recurrence kind at a registered mutant."""
    rs = bundle.schedule
    return dataclasses.replace(
        bundle, schedule=dataclasses.replace(
            rs, state=dataclasses.replace(rs.state, kind=kind)))


@pytest.fixture
def mutant_kind():
    """Register a mutated kind builder for one test, then unregister."""
    registered = []

    def register(name, builder, contract_of):
        emit.register_recurrence_kind(
            name, builder, contract=emit.kind_contract(contract_of))
        registered.append(name)
        return name

    yield register
    for name in registered:
        del emit.RECURRENCE_KINDS[name]
        emit.KIND_CONTRACTS.pop(name, None)


# ---------------------------------------------------------------------------
# every shipped kernel body conforms to its schedule contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label,form,kw", [
    ("matmul", E.matmul_expr(300, 200, 160), {}),
    ("matmul_tb", E.matmul_expr(300, 200, 160, transpose_b=True), {}),
    ("hadamard", E.hadamard_expr(200, 300), {}),
    ("max_plus", E.inner("max", "add", E.arr("A", (100, 60)),
                         E.arr("B", (60, 80))), {}),
    ("attention", E.attention_form(1, 2, 2, 300, 300, 64), {}),
    ("attention_stats", E.attention_stats_form(1, 1, 1, 300, 300, 64), {}),
    ("attention_windowed", E.attention_form(1, 1, 1, 256, 256, 64,
                                            window=128), {}),
    ("flash_dq", E.attention_dq_form(1, 1, 1, 300, 300, 64), {}),
    ("flash_dkv", E.attention_dkv_form(1, 1, 1, 300, 300, 64), {}),
    ("ssd", E.ssd_form(1, 4, 64, 2, 16, 16), {}),
    ("ssd_chk", E.ssd_chk_form(1, 4, 64, 2, 16, 16), {}),
    ("ssd_bwd", E.ssd_bwd_form(1, 4, 64, 2, 16, 16), {}),
    ("rglru", E.rglru_form(1, 4, 64, 32), {}),
    ("rglru_bwd", E.rglru_bwd_form(1, 4, 64, 32), {}),
    ("windowed_decode", WINDOWED_DECODE["form"],
     {"blocks": WINDOWED_DECODE["blocks"]}),
], ids=lambda v: v if isinstance(v, str) else "")
def test_shipped_kernels_conform(label, form, kw):
    bundle = sched.get_schedule(form, dtype="float32", hardware=HW, **kw)
    findings = conformance.kernel_findings(bundle, dtype="float32")
    assert not findings, [str(f) for f in findings]


def test_effect_summary_shape_windowed_decode():
    """The worked README example: the paged decode step's summary exposes
    the dynamic-pos guard on every fold store."""
    bundle = sched.get_schedule(WINDOWED_DECODE["form"], dtype="float32",
                                hardware=HW,
                                blocks=WINDOWED_DECODE["blocks"])
    summary = conformance.summarize_kernel(bundle, dtype="float32")
    assert summary.guard_contract == "dynamic-pos"
    roles = [r.role for r in summary.refs]
    assert roles.count("output") == 1
    assert "scratch" in roles
    for r in summary.refs:
        if r.role == "input":
            assert not r.stores, f"input {r.name} is stored"
        if r.role == "scratch":
            # every fold store on carried state is guard- or mask-dominated
            # by the POS-derived block-skip (the "dynamic" class)
            folds = [s for s in r.stores
                     if not conformance._is_init_store(
                         s, summary.stream_dim)]
            assert folds
            for s in folds:
                kinds = {g if isinstance(g, str) else g[0]
                         for g in s.guards | s.masked}
                assert "dynamic" in kinds, summary.describe()
    # the rendering the README quotes stays available
    assert "guard='dynamic-pos'" in summary.describe()


# ---------------------------------------------------------------------------
# seeded-mutation matrix: one emitter defect per rule class, exact
# classification
# ---------------------------------------------------------------------------

def _gated_mutant(defect):
    """Variants of the gated (rglru) kind body, each seeding one defect."""

    def builder(rs, *, scale, causal, logical_stream, out_dtype, acc_dtype):
        ni = len(rs.ins)
        a_cell = emit._cell_shape(rs.ins[0])
        h_cell = rs.state_blocks()[0]
        nk = rs.grid[rs.stream_grid_dim].extent

        def mut(*refs):
            y_ref, hf_ref = refs[ni], refs[ni + 1]
            h_ref = refs[ni + 2]
            ki = pl.program_id(rs.stream_grid_dim)
            if defect == "read_first":
                carry = h_ref[...]            # read BEFORE the init store

            @pl.when(ki == 0)
            def _init():
                h_ref[...] = refs[2][...].reshape(h_cell).astype(acc_dtype)

            if defect != "read_first":
                carry = h_ref[...]
            a = jnp.exp(refs[0][...].reshape(a_cell).astype(acc_dtype))
            b = refs[1][...].reshape(a_cell).astype(acc_dtype)

            def comb(x, y):
                return (x[0] * y[0], y[0] * x[1] + y[1])

            aa, hh = jax.lax.associative_scan(comb, (a, b), axis=0)
            hh = hh + aa * carry
            y_ref[...] = hh.astype(out_dtype).reshape(rs.out.block)
            h_ref[...] = hh[-1:]
            if defect == "no_flush":
                return                        # hf_ref never stored
            flush_step = 0 if defect == "flush_first" else nk - 1

            @pl.when(ki == flush_step)
            def _flush():
                hf_ref[...] = h_ref[...].reshape(rs.state_outs[0].block)

        return mut, [pltpu.VMEM(h_cell, acc_dtype)]

    return builder


@pytest.mark.parametrize("defect,want", [
    ("no_flush", ["effect"]),             # dropped _flush store
    ("read_first", ["state-discipline"]),  # state read before step-0 init
    ("flush_first", ["state-discipline"]),  # flush off the final step
])
def test_mutation_gated_kind(mutant_kind, defect, want):
    name = mutant_kind(f"gated#{defect}", _gated_mutant(defect), "gated")
    bundle = sched.get_schedule(E.rglru_form(1, 4, 64, 32),
                                dtype="float32", hardware=HW)
    findings = conformance.kernel_findings(_mutated(bundle, name),
                                           dtype="float32")
    assert _rules(findings) == want, [str(f) for f in findings]


def test_mutation_softmax_dropped_stream_guard(mutant_kind):
    """Deleting the ``kpos < sk`` pad guard (``logical_stream=None``) on a
    padded stream is exactly a guard-dominance violation."""

    def no_guard(rs, *, scale, causal, logical_stream, out_dtype, acc_dtype):
        return emit._softmax_kind(rs, scale=scale, causal=causal,
                                  logical_stream=None,
                                  out_dtype=out_dtype, acc_dtype=acc_dtype)

    name = mutant_kind("softmax#no_guard", no_guard, "online_softmax")
    bundle = sched.get_schedule(E.attention_form(1, 2, 2, 300, 300, 64),
                                dtype="float32", hardware=HW)
    findings = conformance.kernel_findings(_mutated(bundle, name),
                                           dtype="float32")
    assert _rules(findings) == ["guard-dominance"], \
        [str(f) for f in findings]


def test_mutation_swapped_acc_dtype():
    """A bundle solved at bf16 accumulation but emitted at f32 silently
    widens off the certified working set — flagged on every scratch ref
    and every dot that folds at the wrong width."""
    bundle = sched.get_schedule(E.attention_form(1, 2, 2, 300, 300, 64),
                                dtype="bfloat16",
                                hardware=hwr.get_entry("tpu_v5e"),
                                acc_dtype="bfloat16")
    findings = conformance.kernel_findings(bundle, dtype="bfloat16",
                                           acc_dtype="float32")
    assert _rules(findings) == ["acc-dtype"], [str(f) for f in findings]
    assert any("silently widens" in f.message for f in findings)
    assert any("scratch" in f.message for f in findings)


def test_recurrent_form_refuses_integer_accumulator():
    """The emitter-side defect the conformance pass would flag is refused
    one layer earlier: no integer-acc recurrent schedule derives."""
    with pytest.raises(ValueError, match="floating"):
        sched.get_schedule(E.attention_form(1, 1, 1, 64, 64, 32),
                           dtype="int8", hardware=HW, acc_dtype="int32")


# ---------------------------------------------------------------------------
# traced accumulation widths agree with the certified working set
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hw_name", ["cpu", "tpu_v5e"])
@pytest.mark.parametrize("dtype,acc", [
    ("float32", "float32"), ("bfloat16", "float32"),
    ("bfloat16", "bfloat16"), ("int8", "int32")])
@pytest.mark.parametrize("label,form", [
    ("matmul", E.matmul_expr(300, 200, 160)),
    ("attention", E.attention_form(1, 2, 2, 300, 300, 64)),
    ("ssd", E.ssd_form(1, 4, 64, 2, 16, 16)),
], ids=lambda v: v if isinstance(v, str) else "")
def test_traced_acc_width_matches_working_set(hw_name, dtype, acc, label,
                                              form):
    """Property (satellite): for every (dtype, acc_dtype) pair the hardware
    tables accept, the dtypes the conformance pass traces out of the kernel
    body are the widths ``working_set_bytes`` assumed when the schedule was
    certified against the chip's memory."""
    entry = hwr.get_entry(hw_name)
    try:
        bundle = sched.get_schedule(form, dtype=dtype, hardware=entry,
                                    acc_dtype=acc)
    except (ValueError, AssertionError):
        pytest.skip("pair refused at derivation — nothing to trace")
    summary = conformance.summarize_kernel(bundle, dtype=dtype)
    assumed = _dtype_size(bundle.acc_dtype)
    scratch = [r for r in summary.refs if r.role == "scratch"]
    for r in scratch:
        assert np.dtype(r.dtype).itemsize == assumed, (
            f"{r.name} traced at {r.dtype} but working_set_bytes assumed "
            f"{assumed} bytes for acc_dtype={bundle.acc_dtype}")
    for r in summary.refs:
        if r.role in ("input",):
            assert np.dtype(r.dtype).itemsize == _dtype_size(dtype)
        if r.role == "state_out":
            # recurrent state exports at the accumulator width the working
            # set budgeted (PR 9: emit threads acc_dtype into out_dtypes)
            assert np.dtype(r.dtype).itemsize == assumed
    # the certificate itself moves with the width the trace confirmed
    ws_at_acc = bundle.schedule.working_set_bytes(dtype, bundle.acc_dtype)
    ws_at_f64 = bundle.schedule.working_set_bytes(dtype, "float64")
    if scratch:
        assert ws_at_acc <= ws_at_f64
        if assumed < 8:
            assert ws_at_acc < ws_at_f64


# ---------------------------------------------------------------------------
# the paged index-map bound
# ---------------------------------------------------------------------------

def test_index_map_page_table_bound():
    at_bound = tuple(range(emit.MAX_PAGE_TABLE_ENTRIES))
    imap = emit._index_map((0, None), page_table=at_bound)
    assert imap(jnp.int32(0), jnp.int32(0)) is not None
    over = tuple(range(emit.MAX_PAGE_TABLE_ENTRIES + 1))
    with pytest.raises(ValueError) as err:
        emit._index_map((0, None), page_table=over)
    # the error names the offending pool size and the escape hatch
    assert str(len(over)) in str(err.value)
    assert "MAX_PAGE_TABLE_ENTRIES" in str(err.value)


# ---------------------------------------------------------------------------
# wiring: verify_bundle(kernel=True), apply(verify="kernel"), the sweep
# ---------------------------------------------------------------------------

def test_verify_bundle_kernel_flag_extends_findings_and_cache():
    analysis.reset_verification_cache()
    bundle = sched.get_schedule(E.attention_form(1, 2, 2, 300, 300, 64),
                                dtype="float32", hardware=HW)
    key = ("conformance-test-attn",)
    base = analysis.verify_bundle(bundle, hardware=HW, dtype="float32",
                                  key=key)
    assert not analysis.verify.errors(base)
    withk = analysis.verify_bundle(bundle, hardware=HW, dtype="float32",
                                   key=key, kernel=True)
    assert not analysis.verify.errors(withk)
    # kernel=True results live under their own cache key: a second call
    # hits, and the schedule-only entry was not clobbered
    before = analysis.verification_cache_stats()
    analysis.verify_bundle(bundle, hardware=HW, dtype="float32", key=key,
                           kernel=True)
    analysis.verify_bundle(bundle, hardware=HW, dtype="float32", key=key)
    after = analysis.verification_cache_stats()
    assert after["hits"] == before["hits"] + 2
    assert after["misses"] == before["misses"]


def test_verify_bundle_kernel_strict_raises_on_mutant(mutant_kind):
    name = mutant_kind("gated#strict", _gated_mutant("no_flush"), "gated")
    bundle = sched.get_schedule(E.rglru_form(1, 4, 64, 32),
                                dtype="float32", hardware=HW)
    with pytest.raises(analysis.VerificationError, match="never stored"):
        analysis.verify_bundle(_mutated(bundle, name), hardware=HW,
                               dtype="float32", kernel=True, strict=True)


def test_apply_verify_kernel_matches_plain():
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (30, 20), jnp.float32)
    w = jax.random.normal(k2, (20, 40), jnp.float32)
    expr = E.matmul_expr(30, 20, 40)
    got = ops.apply(expr, x, w, interpret=True, verify="kernel")
    want = ops.apply(expr, x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conformance_all_cpu_sweep_and_json(tmp_path):
    from repro.analysis import conformance_all
    out = tmp_path / "conformance.json"
    assert conformance_all.main(["--hardware", "cpu", "--json",
                                 str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["sweep"] == "conformance_all"
    assert report["hardware"] == ["cpu"]
    assert report["failed"] == 0 and report["findings"] == []
    # pin the cpu slice: every registered kind and generic form stays swept
    assert report["checked"] == 76
    assert report["refused"] == 16
