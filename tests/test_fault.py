"""Fault tolerance policies: straggler watchdog, elastic re-mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault import (Coordinator, ElasticManager, StepWatchdog,
                                     best_mesh_shape)


def test_watchdog_flags_stragglers():
    c = Coordinator()
    w = StepWatchdog(c, factor=3.0, slack_s=0.0)
    trace = [1.0] * 10 + [10.0] + [1.0] * 5      # one 10x step
    flags = [w.observe(i, t) for i, t in enumerate(trace)]
    assert sum(flags) == 1 and flags[10]
    assert w.stragglers == 1
    assert c.events and c.events[0]["kind"] == "straggler"
    assert c.events[0]["step"] == 10


def test_watchdog_adapts_to_drift():
    """Gradually slowing steps are NOT stragglers (EMA tracks them)."""
    w = StepWatchdog(Coordinator(), factor=3.0, slack_s=0.0)
    flags = [w.observe(i, 1.0 + 0.05 * i) for i in range(50)]
    assert not any(flags)


def test_best_mesh_shape_ladder():
    assert best_mesh_shape(512) == (32, 16)
    assert best_mesh_shape(256) == (16, 16)
    assert best_mesh_shape(24) == (3, 8)
    assert best_mesh_shape(7) == (7, 1)          # prime: pure DP


def test_elastic_reshard_roundtrip():
    em = ElasticManager()
    mesh = em.make_mesh(jax.devices())
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    axes = {"w": ("d_model", "d_ff")}
    out = em.reshard(tree, axes, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_failure_reporting():
    c = Coordinator()
    c.report_failure(7, "host 3 lost heartbeat")
    assert c.events[0] == {"kind": "failure", "step": 7,
                           "detail": "host 3 lost heartbeat"}
