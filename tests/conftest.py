"""Make the shared test helpers (``_hypothesis_compat``) importable from
every test directory, including ``tests/kernels``."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
