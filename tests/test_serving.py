"""Serving subsystem: page-pool bookkeeping, paged-vs-contiguous kernel
bit-identity, page-bounds verification, the single-sweep prefill
regression, and continuous batching with recompute preemption."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_lint, verify
from repro.configs import get_config
from repro.core import expr as E
from repro.core import schedule as sched_mod
from repro.core.hardware import get_entry
from repro.kernels import ops
from repro.models import registry, transformer
from repro.serving import OutOfPages, PagePool, ServeEngine, pages_needed
from repro.train.serve_step import greedy_generate

CPU = get_entry("cpu")


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma-2b", reduced=True)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def mamba():
    cfg = get_config("mamba2-780m", reduced=True)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# -- page pool ---------------------------------------------------------------

def test_pages_needed():
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2
    assert pages_needed(64, 16) == 4


def test_pool_alloc_free_roundtrip(gemma):
    cfg, _ = gemma
    pool = PagePool(cfg, pool_pages=4, page=8)
    assert pool.free_pages == 4
    a = pool.alloc(2)
    assert a == [0, 1]                    # front-to-back on a fresh pool
    b = pool.alloc(1)
    assert b == [2] and pool.used_pages == 3
    pool.free([1])
    assert pool.alloc(1) == [1]           # lowest free slab reissues first
    with pytest.raises(OutOfPages):
        pool.alloc(2)                     # only slab 3 is free
    with pytest.raises(ValueError, match="outside pool"):
        pool.free([9])
    with pytest.raises(ValueError, match="double free"):
        pool.free([3])                    # 3 is already on the free stack


# -- paged decode kernel -----------------------------------------------------

def test_paged_decode_bit_identical_to_contiguous():
    """The same derived kernel through an identity table on a contiguous
    pool vs a scrambled table on a scattered pool: identical blocked
    compute order, so the outputs are bitwise equal on integer inputs."""
    hkv, g, hd, page, view_pages = 2, 4, 16, 8, 2
    sk = view_pages * page
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-3, 4, (hkv, g, hd)), jnp.float32)
    k = jnp.asarray(rng.integers(-3, 4, (sk, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.integers(-3, 4, (sk, hkv, hd)), jnp.float32)
    pos = jnp.asarray([[12, 0]], jnp.int32)

    # scatter the same pages into a larger pool, slabs (3, 1)
    pool_pages, perm = 4, (3, 1)
    k2 = jnp.zeros((pool_pages * page, hkv, hd), jnp.float32)
    v2 = jnp.zeros_like(k2)
    for vpg, slab in enumerate(perm):
        k2 = k2.at[slab * page:(slab + 1) * page].set(
            k[vpg * page:(vpg + 1) * page])
        v2 = v2.at[slab * page:(slab + 1) * page].set(
            v[vpg * page:(vpg + 1) * page])

    kw = dict(page=page, scale=hd ** -0.5, interpret=True, hardware=CPU)
    contig = ops.paged_decode(q, k, v, pos, page_table=(0, 1), **kw)
    paged = ops.paged_decode(q, k2, v2, pos, page_table=perm, **kw)
    assert np.array_equal(np.asarray(contig), np.asarray(paged))
    oracle = ops._paged_oracle(q, k, v, pos, (0, 1), page, hd ** -0.5, 0)
    np.testing.assert_allclose(np.asarray(contig), np.asarray(oracle),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_windowed_matches_oracle():
    hkv, g, hd, page = 1, 2, 8, 4
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(hkv, g, hd)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(16, hkv, hd)), jnp.float32)
    pos = jnp.asarray([[13, 0]], jnp.int32)
    kw = dict(page_table=(0, 1, 2, 3), page=page, scale=1.0, window=6)
    got = ops.paged_decode(q, kv, kv, pos, interpret=True, hardware=CPU,
                           **kw)
    want = ops._paged_oracle(q, kv, kv, pos, kw["page_table"], page, 1.0, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# -- static verification -----------------------------------------------------

def _paged_form(table=(0, 3, 1, 5), pool_pages=6):
    return E.windowed_decode_form(2, 4, 32, page=16, view_pages=4,
                                  pool_pages=pool_pages, page_table=table,
                                  window=32)


def test_verify_paged_form_clean():
    findings = verify.verify_expr(_paged_form(), dtype="float32",
                                  hardware=CPU, blocks=(4, 16),
                                  strict=False)
    assert not verify.errors(findings)


def test_verify_paged_form_kernel_body_conforms():
    """Serve-smoke pin for the PR-9 conformance rules: the paged decode
    kernel the engine binds passes the body checks (``kernel=True`` runs
    ``effect``/``acc-dtype``/``guard-dominance``/``state-discipline``
    alongside the schedule-layer rules)."""
    findings = verify.verify_expr(_paged_form(), dtype="float32",
                                  hardware=CPU, blocks=(4, 16),
                                  strict=False, kernel=True)
    assert not verify.errors(findings)
    banned = {"effect", "acc-dtype", "guard-dominance", "state-discipline"}
    assert not [f for f in findings if f.rule in banned]


def test_paged_form_refuses_out_of_pool_table():
    with pytest.raises(ValueError, match="outside the pool"):
        _paged_form(table=(0, 3, 1, 6))


def test_verify_schedule_flags_bad_page_table():
    """Tampering a derived schedule's page table past the slab pool is
    caught by the static verifier as a page-bounds error."""
    bundle = sched_mod.get_schedule(_paged_form(), dtype="float32",
                                    hardware=CPU, blocks=(4, 16))
    sched = bundle.schedule
    ins = tuple(
        dataclasses.replace(spec, page_table=(0, 3, 1, 99))
        if spec.page_table is not None else spec
        for spec in sched.ins)
    assert ins != sched.ins
    bad = dataclasses.replace(sched, ins=ins)
    errs = verify.errors(verify.verify_schedule(bad))
    assert errs and all(f.rule == "page-bounds" for f in errs)

    short = tuple(
        dataclasses.replace(spec, page_table=(0, 3))
        if spec.page_table is not None else spec
        for spec in sched.ins)
    errs = verify.errors(verify.verify_schedule(
        dataclasses.replace(sched, ins=short)))
    assert any(f.rule == "page-bounds" for f in errs)


# -- prefill regression ------------------------------------------------------

def test_greedy_generate_prefill_single_sweep(gemma, monkeypatch):
    """Prompt ingestion routes through ``registry.prefill`` — ONE derived
    kernel sweep — and ``decode_step`` traces only for the generation
    scan, never a token-by-token prompt feed."""
    cfg, params = gemma
    calls = {"prefill": 0, "decode": 0}
    real_prefill, real_decode = registry.prefill, registry.decode_step

    def count_prefill(*a, **kw):
        calls["prefill"] += 1
        return real_prefill(*a, **kw)

    def count_decode(*a, **kw):
        calls["decode"] += 1
        return real_decode(*a, **kw)

    monkeypatch.setattr(registry, "prefill", count_prefill)
    monkeypatch.setattr(registry, "decode_step", count_decode)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0,
                                cfg.vocab_size)
    out = greedy_generate(params, cfg, prompt, n_new=4, cache_len=16)
    assert out.shape == (1, 10)
    assert calls["prefill"] == 1
    assert calls["decode"] == 1           # the gen scan's single trace

    # the fallback feed-scan path produces the same tokens
    calls.update(prefill=0, decode=0)
    monkeypatch.setattr(transformer, "has_prefill_decode_relayout",
                        lambda _cfg: False)
    ref = greedy_generate(params, cfg, prompt, n_new=4, cache_len=16)
    assert calls["prefill"] == 0 and calls["decode"] == 2
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# -- engine ------------------------------------------------------------------

def test_engine_decode_binds_derived_kernel(gemma):
    """The engine's paged decode step binds the derived windowed_decode
    kernel through the page-table psi view — pinned by jaxpr lint: a
    pallas_call inside the layer scan, no oracle recompute, no silent
    fallback."""
    cfg, params = gemma
    engine = ServeEngine(cfg, params, max_slots=1, max_len=16, page=4,
                         interpret=True)
    assert engine.paged
    fn = engine._paged_decode_fn((0, 1))
    findings = jaxpr_lint.lint(
        fn, jnp.zeros((1,), jnp.int32), jnp.asarray([5], jnp.int32),
        engine.pool.pools,
        rules=("no-oracle-recompute", "no-silent-fallback"),
        min_calls=1)
    assert not findings, findings


def test_engine_eviction_under_pressure_matches_isolated(gemma):
    """Three concurrent requests against a pool too small for them all:
    the engine preempts (recompute eviction), and every request still
    decodes exactly what it would have alone."""
    cfg, params = gemma
    key = jax.random.PRNGKey(7)
    prompts = [jax.random.randint(k, (n,), 0, cfg.vocab_size).tolist()
               for k, n in zip(jax.random.split(key, 3), (5, 6, 4))]
    max_new = 5
    engine = ServeEngine(cfg, params, max_slots=3, max_len=16, page=4,
                         pool_pages=5, interpret=True)
    rids = [engine.submit(p, max_new) for p in prompts]
    results = engine.run()
    assert sum(r["request"].evictions for r in results.values()) > 0
    for rid, prompt in zip(rids, prompts):
        ref = greedy_generate(params, cfg,
                              jnp.asarray([prompt], jnp.int32),
                              n_new=max_new, cache_len=16)
        assert results[rid]["tokens"] == np.asarray(
            ref[0, len(prompt):]).tolist()


# -- batched multi-slot decode -----------------------------------------------

def _stacked_form(tables=((0, 3, 1, 5), (2, 4, 6, 7)), slots=2,
                  pool_pages=8):
    return E.batched_decode_form(slots, 2, 4, 32, page=16, view_pages=4,
                                 pool_pages=pool_pages,
                                 page_tables=tables, window=32)


def test_batched_decode_bit_identical_to_sequential():
    """One batched launch over N slots vs N sequential per-slot launches
    of the same derived kernel against the same pools: each (s, h) grid
    cell folds exactly the per-slot float ops, so live rows are bitwise
    equal on integer inputs; a dead row (pos -1) flushes exact zeros."""
    slots, hkv, g, hd, page, view = 3, 2, 4, 16, 8, 2
    pool_pages = 8
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.integers(-3, 4, (slots, hkv, g, hd)), jnp.float32)
    kp = jnp.asarray(rng.integers(-3, 4, (pool_pages * page, hkv, hd)),
                     jnp.float32)
    vp = jnp.asarray(rng.integers(-3, 4, kp.shape), jnp.float32)
    tables = ((5, 2), (0, 7), (3, 3))     # slot 2 is dead: stale entries
    pos = jnp.asarray([[11, 0], [4, 0], [-1, 0]], jnp.int32)

    kw = dict(page=page, scale=hd ** -0.5, window=6, interpret=True,
              hardware=CPU)
    got = ops.paged_decode_batched(q, kp, vp, pos, page_tables=tables,
                                   **kw)
    for s in range(slots):
        if int(pos[s, 0]) < 0:
            assert not np.asarray(got[s]).any()
            continue
        one = ops.paged_decode(q[s], kp, vp, pos[s:s + 1],
                               page_table=tables[s], **kw)
        assert np.array_equal(np.asarray(got[s]), np.asarray(one)), s


def test_stacked_form_refusals():
    with pytest.raises(ValueError, match="rows for"):
        _stacked_form(tables=((0, 1, 2, 3),), slots=2)
    with pytest.raises(ValueError, match="view_pages"):
        _stacked_form(tables=((0, 1, 2), (3, 4, 5, 6)))
    with pytest.raises(ValueError, match="outside the pool"):
        _stacked_form(tables=((0, 1, 2, 9), (3, 4, 5, 6)))


def test_verify_stacked_form_clean_and_tamperable():
    """The batched form passes the full static + kernel-body check; a
    tampered stacked row (out-of-pool slab, slot-labeled) and a dropped
    row (slot-grid mismatch) are both page-bounds errors."""
    form = _stacked_form()
    findings = verify.verify_expr(form, dtype="float32", hardware=CPU,
                                  blocks=(4, 16), strict=False,
                                  kernel=True)
    assert not verify.errors(findings)

    bundle = sched_mod.get_schedule(form, dtype="float32", hardware=CPU,
                                    blocks=(4, 16))
    sched = bundle.schedule
    bad = tuple(
        dataclasses.replace(spec, page_table=((0, 3, 1, 99), (2, 4, 6, 7)))
        if spec.page_table is not None else spec
        for spec in sched.ins)
    errs = verify.errors(verify.verify_schedule(
        dataclasses.replace(sched, ins=bad)))
    assert errs and all(f.rule == "page-bounds" for f in errs)
    assert any("slot 0" in f.message for f in errs)

    dropped = tuple(
        dataclasses.replace(spec, page_table=((0, 3, 1, 5),))
        if spec.page_table is not None else spec
        for spec in sched.ins)
    errs = verify.errors(verify.verify_schedule(
        dataclasses.replace(sched, ins=dropped)))
    assert any(f.rule == "page-bounds" for f in errs)


def test_engine_batched_iteration_binds_one_pallas_call(gemma):
    """The tentpole pin: one batched engine iteration traces to exactly
    ONE pallas_call — the slot axis rides the grid of a single derived
    kernel (shared across the layer scan), not a per-slot launch loop."""
    cfg, params = gemma
    engine = ServeEngine(cfg, params, max_slots=3, max_len=16, page=4,
                         interpret=True)
    assert engine.batched
    tables = tuple((0,) * engine._view_pages
                   for _ in range(engine.max_slots))
    fn = engine._batched_decode_fn(tables)
    jaxpr = jax.make_jaxpr(fn)(
        jnp.zeros((3,), jnp.int32),
        jnp.asarray([5, 2, -1], jnp.int32), engine.pool.pools)
    assert jaxpr_lint.jaxpr_primitives(jaxpr)["pallas_call"] == 1


def test_engine_batched_eviction_under_pressure_matches_isolated(gemma):
    """Four concurrent requests through the BATCHED path against a pool
    too small for them all: recompute preemption still fires, every
    request decodes exactly its isolated greedy tokens, and the launch
    count stays below one per token (the dispatch-amortization claim)."""
    cfg, params = gemma
    key = jax.random.PRNGKey(11)
    prompts = [jax.random.randint(k, (n,), 0, cfg.vocab_size).tolist()
               for k, n in zip(jax.random.split(key, 4), (5, 6, 4, 7))]
    max_new = 5
    engine = ServeEngine(cfg, params, max_slots=4, max_len=16, page=4,
                         pool_pages=7, interpret=True)
    assert engine.batched
    rids = [engine.submit(p, max_new) for p in prompts]
    results = engine.run()
    assert sum(r["request"].evictions for r in results.values()) > 0
    n_tokens = sum(len(r["tokens"]) for r in results.values())
    assert engine.kernel_calls < n_tokens
    for rid, prompt in zip(rids, prompts):
        ref = greedy_generate(params, cfg,
                              jnp.asarray([prompt], jnp.int32),
                              n_new=max_new, cache_len=16)
        assert results[rid]["tokens"] == np.asarray(
            ref[0, len(prompt):]).tolist()


def test_engine_contiguous_fallback_ssm(mamba):
    """Families without a paged KV view serve through per-slot contiguous
    caches under the same scheduler."""
    cfg, params = mamba
    engine = ServeEngine(cfg, params, max_slots=2, max_len=16)
    assert not engine.paged and engine.pool is None
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 6), 0,
                                cfg.vocab_size)
    rid = engine.submit(prompt[0].tolist(), 4)
    results = engine.run()
    ref = greedy_generate(params, cfg, prompt, n_new=4, cache_len=16)
    assert results[rid]["tokens"] == np.asarray(ref[0, 6:]).tolist()
