"""End-to-end behaviour tests for the whole system."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_resume_from_checkpoint_is_bitwise_consistent(tmp_path):
    """Train 6 steps; train 3 + restart + 3 from checkpoint: same params.
    This is the node-failure recovery guarantee."""
    from repro.configs import get_config
    from repro.checkpoint import Checkpointer
    from repro.data import PipelineConfig, SyntheticLM
    from repro.train import train_step as ts

    cfg = get_config("gemma-2b", reduced=True).with_(remat=False)
    key = jax.random.PRNGKey(0)
    data = SyntheticLM(PipelineConfig(cfg.vocab_size, 16, 4), cfg)
    step = jax.jit(ts.make_train_step(cfg))

    def run(state, lo, hi):
        for s in range(lo, hi):
            state, _ = step(state, jax.tree.map(jnp.asarray, data.global_batch(s)))
        return state

    straight, _ = ts.init_state(cfg, key)
    straight = run(straight, 0, 6)

    st, _ = ts.init_state(cfg, key)
    st = run(st, 0, 3)
    ck = Checkpointer(str(tmp_path))
    ck.save(3, st, metadata={"data_step": 3})
    restored, man = ck.restore(st)
    resumed = run(restored, man["metadata"]["data_step"], 6)

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    results = main(["--arch", "gemma-2b", "--reduced", "--requests", "2",
                    "--prompt-len", "4", "--new-tokens", "4",
                    "--max-slots", "2", "--page", "4"])
    assert len(results) == 2
    assert all(len(r["tokens"]) == 4 for r in results.values())


def test_greedy_generation_is_deterministic():
    from repro.configs import get_config
    from repro.models import registry
    from repro.train.serve_step import greedy_generate
    cfg = get_config("stablelm-1.6b", reduced=True)
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 4), jnp.int32)
    a = greedy_generate(params, cfg, prompt, 6, 16)
    b = greedy_generate(params, cfg, prompt, 6, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The real dry-run path: 512 forced devices, production mesh, lower +
    compile + roofline for one cheap cell on both meshes."""
    prog = textwrap.dedent("""
        from repro.launch import dryrun
        rec = dryrun.run_cell("whisper-base", "train_4k", "single", None)
        assert rec["status"] == "OK", rec
        assert rec["roofline"]["global_flops"] > 0
        assert rec["n_chips"] == 256
        rec2 = dryrun.run_cell("whisper-base", "train_4k", "multi", None)
        assert rec2["status"] == "OK", rec2
        assert rec2["n_chips"] == 512
        print("SUBPROCESS_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_input_specs_cover_all_cells():
    """Every (arch x shape) cell has well-formed abstract inputs."""
    from repro.configs import SHAPES, all_cells, cell_applicable, get_config
    from repro.models import registry
    n_ok = n_skip = 0
    for arch, shape in all_cells():
        ok, why = cell_applicable(arch, shape)
        if not ok:
            n_skip += 1
            assert "full-attention" in why
            continue
        cfg = get_config(arch)
        specs = registry.input_specs(cfg, SHAPES[shape])
        leaves = jax.tree.leaves(specs)
        assert leaves and all(hasattr(l, "shape") for l in leaves)
        n_ok += 1
    assert n_ok + n_skip == 40
    assert n_skip == 7


@pytest.mark.slow
def test_elastic_restart_different_mesh():
    """Train on a (4,2) mesh, checkpoint, restore onto a (2,4) mesh and keep
    training: the elastic re-shard path must preserve semantics exactly
    (same data order via the pure-function pipeline)."""
    prog = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import Checkpointer
        from repro.configs import get_config
        from repro.data import PipelineConfig, SyntheticLM
        from repro.distributed import sharding as sr
        from repro.launch.mesh import make_host_mesh
        from repro.train import train_step as ts

        cfg = get_config("stablelm-1.6b", reduced=True).with_(remat=False)
        key = jax.random.PRNGKey(0)
        data = SyntheticLM(PipelineConfig(cfg.vocab_size, 16, 8), cfg)
        step_fn = ts.make_train_step(cfg)

        def put(state, mesh):
            axes = ts.state_logical_axes(state, p_axes)
            sh = sr.param_shardings(state, axes, mesh)
            return jax.tree.map(jax.device_put, state, sh), sh

        # straight-through on one mesh
        mesh_a = make_host_mesh(dp=4, tp=2)
        with mesh_a:
            state, p_axes = ts.init_state(cfg, key)
            state, _ = put(state, mesh_a)
            step = jax.jit(step_fn)
            for s in range(4):
                state, m = step(state, jax.tree.map(jnp.asarray, data.global_batch(s)))
            straight = jax.tree.map(np.asarray, state.params)

        # train 2 on mesh A, checkpoint, restore on mesh B, train 2 more
        with mesh_a:
            state, _ = ts.init_state(cfg, key)
            state, _ = put(state, mesh_a)
            step = jax.jit(step_fn)
            for s in range(2):
                state, _ = step(state, jax.tree.map(jnp.asarray, data.global_batch(s)))
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(2, state, metadata={"data_step": 2})

        mesh_b = make_host_mesh(dp=2, tp=4)            # DIFFERENT mesh
        with mesh_b:
            like, _ = ts.init_state(cfg, jax.random.PRNGKey(1))
            axes = ts.state_logical_axes(like, p_axes)
            sh = sr.param_shardings(like, axes, mesh_b)
            state_b, man = ck.restore(like, shardings=sh)
            step_b = jax.jit(step_fn)
            for s in range(man["metadata"]["data_step"], 4):
                state_b, _ = step_b(state_b, jax.tree.map(jnp.asarray, data.global_batch(s)))
            resumed = jax.tree.map(np.asarray, state_b.params)

        for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(resumed)):
            np.testing.assert_allclose(a.astype(np.float32), b.astype(np.float32),
                                       atol=2e-4)
        print("SUBPROCESS_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
